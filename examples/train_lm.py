"""End-to-end training driver: train a ~100M-parameter LM (xlstm-125m
reduced or full) with Scavenger-backed fault-tolerant checkpointing.

Fast demo (CPU, ~2 min):
  PYTHONPATH=src python examples/train_lm.py

Full 125M model for a few hundred steps (CPU, hours — the EXPERIMENTS.md
run uses this):
  PYTHONPATH=src python examples/train_lm.py --full --steps 200

Crash/restart demo:
  PYTHONPATH=src python examples/train_lm.py --crash
"""

import argparse
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).parent.parent


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full xlstm-125m (slow on CPU)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--crash", action="store_true",
                    help="inject a failure then auto-resume")
    ap.add_argument("--ckpt-dir", default="/tmp/repro-train-ckpt")
    args = ap.parse_args()

    steps = args.steps or (200 if args.full else 25)
    base = [sys.executable, "-m", "repro.launch.train",
            "--arch", "xlstm-125m",
            "--steps", str(steps), "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "10", "--quota-mb", "4096" if args.full
            else "64", "--log-every", "5"]
    if args.full:
        base += ["--batch", "8", "--seq", "256", "--accum", "2"]
    else:
        base += ["--smoke", "--batch", "4", "--seq", "64"]

    if args.crash:
        crash_at = max(5, steps // 2)
        print(f"=== run 1: will crash at step {crash_at} ===")
        r = subprocess.run(base + ["--fail-at-step", str(crash_at),
                                   "--fresh"])
        assert r.returncode == 42, "expected injected crash"
        print("=== run 2: resuming from the Scavenger checkpoint store ===")
        r = subprocess.run(base)
        sys.exit(r.returncode)
    else:
        sys.exit(subprocess.run(base + ["--fresh"]).returncode)


if __name__ == "__main__":
    main()
