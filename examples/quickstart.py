"""Quickstart: the paper's core result in one script.

Builds a Scavenger store and a TerarkDB store, runs a scaled Mixed-8K
update workload under a 1.5x space quota, and prints the space-time
trade-off (paper Fig. 12 / Fig. 2).

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import EngineConfig, Store
from repro.workloads import Runner, mixed_8k


def main():
    spec = mixed_8k(dataset_bytes=8 << 20)
    print(f"workload: {spec.name}, {spec.n_keys} keys, "
          f"{spec.n_updates} updates, 1.5x space quota\n")
    results = {}
    for engine in ("rocksdb", "terarkdb", "scavenger"):
        cfg = EngineConfig.scaled(
            engine, spec.dataset_bytes,
            space_quota_bytes=int(1.5 * spec.dataset_bytes))
        store = Store(cfg)
        r = Runner(store, spec)
        r.load()
        up = r.update()
        st = store.stats()
        results[engine] = st
        print(f"{engine:10s} update={up['ops']/up['sim_s']/1e3:7.1f} kops/s"
              f"  space_amp={st['space_amp']:.2f}"
              f"  S_index={st['s_index']:.2f}"
              f"  write_amp={st['write_amp']:.2f}"
              f"  GC_runs={st['n_gc_runs']}")
    sc, tdb = results["scavenger"], results["terarkdb"]
    print(f"\nScavenger vs TerarkDB: space amp {tdb['space_amp']:.2f} -> "
          f"{sc['space_amp']:.2f} "
          f"({100 * (1 - sc['space_amp'] / tdb['space_amp']):.0f}% lower)")


if __name__ == "__main__":
    main()
