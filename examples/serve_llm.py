"""Batched serving with the Scavenger-paged KV cache.

  PYTHONPATH=src python examples/serve_llm.py
"""

import subprocess
import sys

sys.exit(subprocess.run([
    sys.executable, "-m", "repro.launch.serve", "--arch", "smollm-360m",
    "--smoke", "--requests", "10", "--max-new", "12", "--slots", "4",
]).returncode)
