"""Checkpoint retention under a disk quota: Scavenger GC vs naive.

Writes real tensor checkpoints into both stores, keeps the last 2 steps,
and shows space amplification + GC I/O — the paper's trade-off on the
training substrate.

  PYTHONPATH=src python examples/checkpoint_gc.py
"""

import shutil
import tempfile

import numpy as np

from repro.checkpoint import CheckpointStore, drop_steps, save_pytree


def main():
    rng = np.random.default_rng(0)
    tree = {f"layer{i}": rng.standard_normal((64, 256)).astype(np.float32)
            for i in range(8)}
    for engine in ("scavenger", "naive"):
        root = tempfile.mkdtemp(prefix=f"ckptgc-{engine}-")
        st = CheckpointStore(root, engine=engine, log_target=256 << 10,
                             quota_bytes=8 << 20)
        peak = 0
        for step in range(10):
            # params change every step (hot); metadata cold
            for k in tree:
                tree[k] += 0.01
            save_pytree(st, "train", step, tree, hot=True)
            st.put(f"meta/{step}", b"{}", hot=False)
            drop_steps(st, "train", keep_last=2)
            st.run_gc()
            peak = max(peak, st.total_bytes())
        s = st.stats()
        print(f"{engine:10s} space_amp={s['space_amp']:.2f} "
              f"peak={peak / 1e6:.1f}MB gc_read={s['gc_read_bytes'] / 1e6:.1f}MB "
              f"gc_runs={s['gc_runs']} throttles={s['throttle_events']}")
        st.close()
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
