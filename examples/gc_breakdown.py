"""Reproduce the paper's Fig. 3: GC latency breakdown by step.

  PYTHONPATH=src python examples/gc_breakdown.py
"""

from repro.core import EngineConfig, Store
from repro.core.engine import io as sio
from repro.workloads import Runner, fixed, pareto_1k


def main():
    for mk, nm in ((lambda: fixed(16384, 16 << 20), "Fixed-16K"),
                   (lambda: pareto_1k(8 << 20), "Pareto-1K")):
        print(f"--- {nm} ---")
        for engine in ("titan", "terarkdb", "scavenger"):
            spec = mk()
            store = Store(EngineConfig.scaled(engine, spec.dataset_bytes))
            r = Runner(store, spec)
            r.load()
            r.update()
            gc = {c: store.io.time_us.get(c, 0.0) for c in sio.GC_CATS}
            tot = max(sum(gc.values()), 1e-9)
            print(f"  {engine:10s} " + "  ".join(
                f"{c.split('_', 1)[1]}={100 * v / tot:5.1f}%"
                for c, v in gc.items()))


if __name__ == "__main__":
    main()
