"""Deterministic, resumable, sharded token pipeline.

Design for 1000+-node operation (DESIGN.md §6):
  * statelessly seeded per (step, host): any host can produce its shard of
    any step in O(1) — skip-ahead for straggler recovery and elastic
    rescale (a host joining mid-run needs only (seed, step));
  * checkpoint state is just the integer step (stored in the Scavenger
    checkpoint store as a cold key);
  * synthetic corpus by default (offline container); binary token files
    (one uint32 array per shard) are memory-mapped when present.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np


@dataclasses.dataclass
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    corpus_dir: str | None = None


class TokenPipeline:
    def __init__(self, cfg: PipelineConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.step = 0
        self._corpus = None
        if cfg.corpus_dir:
            files = sorted(Path(cfg.corpus_dir).glob("*.bin"))
            if files:
                self._corpus = [np.memmap(f, np.uint32, "r")
                                for f in files]

    @property
    def host_batch(self) -> int:
        return self.cfg.global_batch // self.cfg.n_hosts

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 31 + self.cfg.host_id)

    def batch_at(self, step: int) -> dict:
        """O(1) random access — the skip-ahead/elasticity primitive."""
        c = self.cfg
        rng = self._rng(step)
        if self._corpus is not None:
            rows = []
            for _ in range(self.host_batch):
                shard = self._corpus[int(rng.integers(len(self._corpus)))]
                start = int(rng.integers(0,
                                         max(1, len(shard) - c.seq_len)))
                rows.append(np.asarray(shard[start:start + c.seq_len],
                                       np.int32) % c.vocab)
            tok = np.stack(rows)
        else:
            # synthetic zipf-ish token stream (deterministic)
            tok = (rng.zipf(1.2, (self.host_batch, c.seq_len)) - 1) \
                % c.vocab
            tok = tok.astype(np.int32)
        return {"tokens": tok}

    def __next__(self) -> dict:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    def __iter__(self):
        return self

    # ------------------------------------------------------ checkpointing
    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
