"""Workload generators matching the paper's evaluation setup (§IV-A).

Value-size distributions:
  * Fixed-<n>   — constant value size (paper sweeps 256B..16KB).
  * Mixed-8K    — 1:1 small (uniform 100..512B) : large (16KB); ByteDance
                  OLTP pattern (large = DB page updates, small = user writes).
  * Pareto-1K   — generalized Pareto, mean ~1KB (paper's variable-length wl).

Key distribution: Zipfian (YCSB scrambled-zipfian style) with constant 0.99
by default, or uniform.  Keys are dense integers (order-preserving, so range
scans are meaningful); 24B on-disk size is accounted by the engine config.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ValueDist:
    name: str

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        raise NotImplementedError

    @property
    def mean(self) -> float:
        raise NotImplementedError


@dataclasses.dataclass
class Fixed(ValueDist):
    size: int = 1024

    def __init__(self, size: int):
        super().__init__(name=f"fixed-{size}")
        self.size = size

    def sample(self, rng, n):
        return np.full(n, self.size, np.int64)

    @property
    def mean(self):
        return float(self.size)


@dataclasses.dataclass
class Mixed(ValueDist):
    """small:large mix; paper default 1:1 of U(100,512) and 16KB (~8K avg)."""
    small_lo: int = 100
    small_hi: int = 512
    large: int = 16384
    large_frac: float = 0.5

    def __init__(self, small_lo=100, small_hi=512, large=16384,
                 large_frac=0.5):
        super().__init__(name=f"mixed-{large_frac:.1f}x{large}")
        self.small_lo, self.small_hi = small_lo, small_hi
        self.large, self.large_frac = large, large_frac

    def sample(self, rng, n):
        is_large = rng.random(n) < self.large_frac
        small = rng.integers(self.small_lo, self.small_hi + 1, n)
        return np.where(is_large, self.large, small).astype(np.int64)

    @property
    def mean(self):
        return (self.large_frac * self.large
                + (1 - self.large_frac) * (self.small_lo + self.small_hi) / 2)


@dataclasses.dataclass
class Pareto(ValueDist):
    """Generalized Pareto (paper refs [32,33]); clipped to [64, 64KB]."""
    mean_size: float = 1024.0
    shape: float = 0.2

    def __init__(self, mean_size=1024.0, shape=0.2):
        super().__init__(name=f"pareto-{int(mean_size)}")
        self.mean_size, self.shape = mean_size, shape

    def sample(self, rng, n):
        # GPD with xi=shape, mu=64; scale chosen to hit the requested mean:
        # mean = mu + sigma / (1 - xi)
        sigma = (self.mean_size - 64) * (1 - self.shape)
        u = rng.random(n)
        x = 64 + sigma * ((1 - u) ** (-self.shape) - 1) / self.shape
        return np.clip(x, 64, 65536).astype(np.int64)

    @property
    def mean(self):
        return self.mean_size


class ZipfKeys:
    """Scrambled-zipfian over [0, n) (YCSB-style), vectorized via rejection-
    free inverse-CDF on a precomputed table for the head + uniform tail."""

    def __init__(self, n: int, theta: float = 0.99, seed: int = 0):
        self.n = n
        self.theta = theta
        head = min(n, 10_000)
        ranks = np.arange(1, head + 1, dtype=np.float64)
        w = ranks ** (-theta)
        # tail mass approximated by integral
        if n > head:
            tail_mass = ((n ** (1 - theta)) - (head ** (1 - theta))) / (1 - theta)
        else:
            tail_mass = 0.0
        self._head = head
        self._head_cdf = np.cumsum(w) / (w.sum() + tail_mass)
        self._perm_seed = np.uint64(seed * 2654435761 + 1)

    def sample(self, rng: np.random.Generator, m: int) -> np.ndarray:
        u = rng.random(m)
        head_p = self._head_cdf[-1]
        is_head = u < head_p
        out = np.empty(m, np.int64)
        out[is_head] = np.searchsorted(self._head_cdf, u[is_head])
        n_tail = int((~is_head).sum())
        if n_tail:
            out[~is_head] = rng.integers(self._head, self.n, n_tail)
        # scramble so hot keys are spread over the key space (YCSB)
        from repro.core.engine.keys import splitmix64
        scram = splitmix64(out.astype(np.uint64) ^ self._perm_seed)
        return (scram % np.uint64(self.n)).astype(np.int64)


class UniformKeys:
    def __init__(self, n: int):
        self.n = n

    def sample(self, rng, m):
        return rng.integers(0, self.n, m)


class HotspotKeys:
    """Shifting-hotspot distribution (adaptive-GC stressor, DESIGN.md §8).

    ``hot_frac`` of ops hit a contiguous hot set of ``hot_n`` keys; the rest
    are uniform over the whole keyspace.  Every ``shift_every`` sampled ops
    the hotspot relocates to a pseudorandom position (``splitmix64`` of the
    phase number — deterministic given the seed), so write hotness is
    *non-stationary*: trackers that never decay keep heating retired
    hotspots, and static policies keep rewriting values that stopped dying.
    Vectorized: phase assignment and hot-set offsets are pure array math.
    """

    def __init__(self, n: int, hot_n: int | None = None,
                 hot_frac: float = 0.9, shift_every: int = 10_000,
                 seed: int = 0):
        self.n = int(n)
        self.hot_n = int(hot_n) if hot_n is not None else max(1, self.n // 50)
        if self.hot_n < 1:
            raise ValueError(f"hot_n must be >= 1, got {self.hot_n}")
        self.hot_frac = float(hot_frac)
        self.shift_every = max(1, int(shift_every))
        self.seed = np.uint64(seed * 0x9E3779B9 + 7)
        self._i = 0         # ops sampled so far (drives the phase)

    def sample(self, rng: np.random.Generator, m: int) -> np.ndarray:
        from repro.core.engine.keys import splitmix64
        idx = self._i + np.arange(m, dtype=np.int64)
        self._i += m
        phase = (idx // self.shift_every).astype(np.uint64)
        start = splitmix64(phase ^ self.seed) % np.uint64(self.n)
        is_hot = rng.random(m) < self.hot_frac
        off = rng.integers(0, self.hot_n, m).astype(np.uint64)
        hot_keys = (start + off) % np.uint64(self.n)
        uni = rng.integers(0, self.n, m).astype(np.uint64)
        return np.where(is_hot, hot_keys, uni).astype(np.int64)


@dataclasses.dataclass
class WorkloadSpec:
    """A scaled version of the paper's load/update/read/scan procedure."""
    name: str
    value_dist: ValueDist
    dataset_bytes: int = 32 << 20
    update_factor: float = 3.0          # paper: 100GB load + 300GB updates
    zipf_theta: float = 0.99
    seed: int = 0

    @property
    def n_keys(self) -> int:
        return max(64, int(self.dataset_bytes / self.value_dist.mean))

    @property
    def n_updates(self) -> int:
        return int(self.n_keys * self.update_factor)


def mixed_8k(dataset_bytes=32 << 20, **kw) -> WorkloadSpec:
    return WorkloadSpec("Mixed-8K", Mixed(), dataset_bytes, **kw)


def pareto_1k(dataset_bytes=32 << 20, **kw) -> WorkloadSpec:
    return WorkloadSpec("Pareto-1K", Pareto(), dataset_bytes, **kw)


def fixed(size: int, dataset_bytes=32 << 20, **kw) -> WorkloadSpec:
    return WorkloadSpec(f"Fixed-{size}", Fixed(size), dataset_bytes, **kw)


class Runner:
    """Drives a Store through load / update / read / scan phases.

    Ops are issued through the batched columnar API (``WriteBatch`` /
    ``multi_get`` / ``multi_scan``) in chunks of ``batch`` keys; the oracle
    updates column-wise with the same last-write-wins semantics the store
    applies inside a batch.  ``batch=1`` degenerates to the scalar loop."""

    def __init__(self, store, spec: WorkloadSpec, batch: int = 256,
                 key_gen=None):
        self.store = store
        self.spec = spec
        self.batch = max(1, int(batch))
        self.rng = np.random.default_rng(spec.seed)
        # key_gen overrides the spec's default update/read key distribution
        # (e.g. HotspotKeys for the shifting-hotspot benchmark)
        self.keys = key_gen if key_gen is not None else (
            ZipfKeys(spec.n_keys, spec.zipf_theta, spec.seed)
            if spec.zipf_theta else UniformKeys(spec.n_keys))
        self.oracle: dict[int, int] = {}

    # ------------------------------------------------------------- batching
    def apply_puts(self, keys: np.ndarray, sizes: np.ndarray) -> None:
        """Write a key/vsize column in WriteBatch chunks, updating the
        oracle (later occurrences of a key win, as in the store)."""
        from repro.core.batch import WriteBatch
        keys = np.asarray(keys).astype(np.uint64)
        sizes = np.asarray(sizes).astype(np.int64)
        for i in range(0, len(keys), self.batch):
            kc, vc = keys[i:i + self.batch], sizes[i:i + self.batch]
            vids = self.store.write(WriteBatch().puts(kc, vc))
            self.oracle.update(zip(kc.tolist(), vids.tolist()))

    def check_reads(self, keys: np.ndarray) -> int:
        """multi_get a key column, compare against the oracle, return the
        mismatch count (0 expected; vids start at 1, so 0 = not-found)."""
        keys = np.asarray(keys).astype(np.uint64)
        errors = 0
        for i in range(0, len(keys), self.batch):
            kc = keys[i:i + self.batch]
            res = self.store.multi_get(kc)
            expect = np.array([self.oracle.get(k, 0) for k in kc.tolist()],
                              np.uint64)
            errors += int((res["vid"] != expect).sum())
        return errors

    # --------------------------------------------------------------- phases
    def load(self) -> dict:
        """Insert every key once (random order), as the paper's load phase."""
        t0 = self.store.io.clock_us
        order = self.rng.permutation(self.spec.n_keys)
        sizes = self.spec.value_dist.sample(self.rng, self.spec.n_keys)
        self.apply_puts(order, sizes)
        self.store.flush()
        return {"phase": "load", "ops": self.spec.n_keys,
                "sim_s": (self.store.io.clock_us - t0) / 1e6}

    def update(self, n: int | None = None) -> dict:
        n = self.spec.n_updates if n is None else n
        t0 = self.store.io.clock_us
        ks = self.keys.sample(self.rng, n)
        sizes = self.spec.value_dist.sample(self.rng, n)
        self.apply_puts(ks, sizes)
        self.store.settle()
        return {"phase": "update", "ops": n,
                "sim_s": (self.store.io.fg_clock_us - t0) / 1e6}

    def read(self, n: int) -> dict:
        t0 = self.store.io.fg_clock_us
        ks = self.keys.sample(self.rng, n)
        errors = self.check_reads(ks)
        assert errors == 0, f"{errors} read mismatches"
        return {"phase": "read", "ops": n,
                "sim_s": (self.store.io.fg_clock_us - t0) / 1e6}

    def scan(self, n: int, max_len: int = 100) -> dict:
        """Batched range queries with per-scan lengths — the same draws as
        the scalar loop, one columnar multi_scan call per chunk."""
        t0 = self.store.io.fg_clock_us
        starts = self.rng.integers(0, self.spec.n_keys, n)
        lens = self.rng.integers(1, max_len + 1, n)
        total = 0
        for i in range(0, n, self.batch):
            for out in self.store.multi_scan(starts[i:i + self.batch],
                                             lens[i:i + self.batch]):
                total += len(out)
        return {"phase": "scan", "ops": n, "entries": total,
                "sim_s": (self.store.io.fg_clock_us - t0) / 1e6}
