from .generator import (Fixed, HotspotKeys, Mixed, Pareto, Runner,
                        UniformKeys, WorkloadSpec, ZipfKeys, fixed, mixed_8k,
                        pareto_1k)
from .ycsb import run_ycsb, YCSB_MIX

__all__ = ["Fixed", "HotspotKeys", "Mixed", "Pareto", "Runner",
           "UniformKeys", "WorkloadSpec", "ZipfKeys", "fixed", "mixed_8k",
           "pareto_1k", "run_ycsb", "YCSB_MIX"]
