"""YCSB core workloads A-F (paper §IV-C), scaled.

  A: 50% read / 50% update        B: 95% read / 5% update
  C: 100% read                    D: 95% read-latest / 5% insert
  E: 95% scan / 5% insert         F: 50% read / 50% read-modify-write
"""

from __future__ import annotations

import numpy as np

from .generator import Runner, WorkloadSpec

YCSB_MIX = {
    "A": dict(read=0.5, update=0.5),
    "B": dict(read=0.95, update=0.05),
    "C": dict(read=1.0),
    "D": dict(read_latest=0.95, insert=0.05),
    "E": dict(scan=0.95, insert=0.05),
    "F": dict(read=0.5, rmw=0.5),
}


def run_ycsb(store, spec: WorkloadSpec, workload: str, n_ops: int,
             runner: Runner | None = None) -> dict:
    """Run one YCSB workload; assumes the store is already loaded+updated
    (paper: 100GB load + 300GB updates before each YCSB run)."""
    mix = YCSB_MIX[workload.upper()]
    r = runner or Runner(store, spec)
    rng = r.rng
    t0 = store.io.clock_us
    kinds = list(mix.keys())
    probs = np.array([mix[k] for k in kinds])
    choice = rng.choice(len(kinds), size=n_ops, p=probs / probs.sum())
    next_key = spec.n_keys
    recent: list[int] = []
    errors = 0
    for c in choice.tolist():
        kind = kinds[c]
        if kind in ("read", "rmw"):
            k = int(r.keys.sample(rng, 1)[0])
            got = store.get(k)
            if got != r.oracle.get(k):
                errors += 1
            if kind == "rmw":
                vs = int(spec.value_dist.sample(rng, 1)[0])
                r.oracle[k] = store.put(k, vs)
        elif kind == "update":
            k = int(r.keys.sample(rng, 1)[0])
            vs = int(spec.value_dist.sample(rng, 1)[0])
            r.oracle[k] = store.put(k, vs)
        elif kind == "insert":
            vs = int(spec.value_dist.sample(rng, 1)[0])
            r.oracle[next_key] = store.put(next_key, vs)
            recent.append(next_key)
            next_key += 1
        elif kind == "read_latest":
            pool = recent[-100:] if recent else [0]
            k = int(pool[int(rng.integers(0, len(pool)))])
            got = store.get(k)
            if got != r.oracle.get(k):
                errors += 1
        elif kind == "scan":
            s = int(rng.integers(0, spec.n_keys))
            ln = int(rng.integers(1, 101))
            store.scan(s, ln)
    assert errors == 0, f"{errors} YCSB read mismatches"
    sim_s = (store.io.clock_us - t0) / 1e6
    return {"workload": workload, "ops": n_ops, "sim_s": sim_s,
            "kops_per_s": n_ops / sim_s / 1e3 if sim_s else float("inf")}
