"""YCSB core workloads A-F (paper §IV-C), scaled, issued in batches.

  A: 50% read / 50% update        B: 95% read / 5% update
  C: 100% read                    D: 95% read-latest / 5% insert
  E: 95% scan / 5% insert         F: 50% read / 50% read-modify-write

The op stream is cut into segments of ``batch`` ops; within a segment all
reads execute first as one ``multi_get`` (against segment-start state, the
pipelined-client model), then scans as one ``multi_scan``, then all writes
apply atomically as one ``WriteBatch``.  The oracle advances per segment
with the same last-write-wins rule the store applies inside a batch.
"""

from __future__ import annotations

import numpy as np

from repro.core.batch import WriteBatch

from .generator import Runner, WorkloadSpec

YCSB_MIX = {
    "A": dict(read=0.5, update=0.5),
    "B": dict(read=0.95, update=0.05),
    "C": dict(read=1.0),
    "D": dict(read_latest=0.95, insert=0.05),
    "E": dict(scan=0.95, insert=0.05),
    "F": dict(read=0.5, rmw=0.5),
}


def run_ycsb(store, spec: WorkloadSpec, workload: str, n_ops: int,
             runner: Runner | None = None, batch: int = 64) -> dict:
    """Run one YCSB workload; assumes the store is already loaded+updated
    (paper: 100GB load + 300GB updates before each YCSB run)."""
    mix = YCSB_MIX[workload.upper()]
    r = runner or Runner(store, spec)
    rng = r.rng
    t0 = store.io.clock_us
    kinds = list(mix.keys())
    probs = np.array([mix[k] for k in kinds])
    choice = rng.choice(len(kinds), size=n_ops, p=probs / probs.sum())
    next_key = spec.n_keys
    recent: list[int] = []
    errors = 0
    for s0 in range(0, n_ops, batch):
        seg = choice[s0:s0 + batch]
        read_keys: list[int] = []
        write_keys: list[int] = []
        write_sizes: list[int] = []
        scan_starts: list[int] = []
        for c in seg.tolist():
            kind = kinds[c]
            if kind in ("read", "rmw"):
                k = int(r.keys.sample(rng, 1)[0])
                read_keys.append(k)
                if kind == "rmw":
                    write_keys.append(k)
                    write_sizes.append(int(spec.value_dist.sample(rng, 1)[0]))
            elif kind == "update":
                k = int(r.keys.sample(rng, 1)[0])
                write_keys.append(k)
                write_sizes.append(int(spec.value_dist.sample(rng, 1)[0]))
            elif kind == "insert":
                write_keys.append(next_key)
                write_sizes.append(int(spec.value_dist.sample(rng, 1)[0]))
                recent.append(next_key)
                next_key += 1
            elif kind == "read_latest":
                pool = recent[-100:] if recent else [0]
                read_keys.append(int(pool[int(rng.integers(0, len(pool)))]))
            elif kind == "scan":
                scan_starts.append(int(rng.integers(0, spec.n_keys)))
        if read_keys:
            res = store.multi_get(np.array(read_keys, np.uint64))
            expect = np.array([r.oracle.get(k, 0) for k in read_keys],
                              np.uint64)
            errors += int((res["vid"] != expect).sum())
        if scan_starts:
            store.multi_scan(np.array(scan_starts, np.int64),
                             rng.integers(1, 101, len(scan_starts)))
        if write_keys:
            vids = store.write(
                WriteBatch().puts(np.array(write_keys, np.uint64),
                                  np.array(write_sizes, np.int64)))
            r.oracle.update(zip(write_keys, vids.tolist()))
    assert errors == 0, f"{errors} YCSB read mismatches"
    sim_s = (store.io.clock_us - t0) / 1e6
    return {"workload": workload, "ops": n_ops, "sim_s": sim_s,
            "kops_per_s": n_ops / sim_s / 1e3 if sim_s else float("inf")}
