"""scavlint CLI: ``python -m repro.analysis [paths...]`` (DESIGN.md §10).

Human output is one ``path:line: [pass] message`` block per finding (with
a fix hint); ``--json`` emits a machine-readable report for CI tooling.
Exit status: 0 when the tree is clean (baselined findings do not fail),
1 when unbaselined findings remain, 2 on usage errors.

The baseline at ``<root>/scavlint_baseline.json`` is picked up
automatically; ``--write-baseline`` (re)writes it from the current
findings — the reviewable way to grandfather a violation instead of
weakening a pass.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .baseline import (BASELINE_NAME, default_baseline, load_baseline,
                       write_baseline)
from .framework import all_passes, find_root, run_analysis


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="scavlint: architectural invariant analyzer for the "
                    "layered store core")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/dirs to analyze, relative to the repo root "
                         "(default: src)")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: nearest ancestor with "
                         "pyproject.toml)")
    ap.add_argument("--select", default=None,
                    help="comma-separated pass names to run (default: all)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help=f"baseline file (default: <root>/{BASELINE_NAME} "
                         f"when present)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline file and "
                         "exit 0")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable JSON report on stdout")
    ap.add_argument("--list-passes", action="store_true",
                    help="list registered passes and exit")
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_passes:
        for name, p in sorted(all_passes().items()):
            print(f"{name:<20} {p.description}")
        return 0

    root = args.root or find_root(Path.cwd())
    try:
        baseline = (load_baseline(args.baseline) if args.baseline
                    else default_baseline(root))
        select = args.select.split(",") if args.select else None
        res = run_analysis(args.paths or ["src"], root=root, select=select,
                           baseline_keys=baseline)
    except (ValueError, OSError) as e:
        print(f"scavlint: error: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        path = args.baseline or (root / BASELINE_NAME)
        write_baseline(path, [f.key for f in res.findings])
        print(f"scavlint: wrote {len(res.findings)} baseline entr"
              f"{'y' if len(res.findings) == 1 else 'ies'} to {path}")
        return 0

    if args.as_json:
        print(json.dumps({
            "findings": [f.to_dict() for f in res.findings],
            "baselined": [f.to_dict() for f in res.baselined],
            "parse_errors": [f.to_dict() for f in res.parse_errors],
            "failed": res.failed,
        }, indent=2))
        return 1 if res.failed else 0

    for f in res.parse_errors + res.findings:
        print(f.render())
    n, nb = len(res.findings) + len(res.parse_errors), len(res.baselined)
    tail = f" ({nb} baselined)" if nb else ""
    if n:
        print(f"scavlint: {n} finding{'s' if n != 1 else ''}{tail}")
        return 1
    print(f"scavlint: clean{tail}")
    return 0
