"""Entry point: ``python -m repro.analysis`` (DESIGN.md §10)."""

import sys

from .cli import main

sys.exit(main())
