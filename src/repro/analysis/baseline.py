"""Baseline (grandfather) file for scavlint findings (DESIGN.md §10).

A baseline is a JSON list of ``Finding.key`` strings: findings whose keys
appear in it are reported separately and do not fail the run.  Keys are
line-independent (pass / path / scope / message), so a baseline survives
unrelated edits; a baselined finding that gets *fixed* simply stops
matching and the stale key can be pruned with ``--write-baseline``.

The repo's checked-in baseline lives at ``scavlint_baseline.json`` in the
repo root (the CLI picks it up automatically when present).  The merged
tree carries **zero** baselined findings — the file exists so a future PR
can land with an explicit, reviewable grandfather list instead of a
weakened pass.
"""

from __future__ import annotations

import json
from pathlib import Path

BASELINE_NAME = "scavlint_baseline.json"
FORMAT = 1


def load_baseline(path: Path | str) -> set[str]:
    obj = json.loads(Path(path).read_text())
    if obj.get("format") != FORMAT:
        raise ValueError(f"unsupported baseline format {obj.get('format')!r}"
                         f" in {path}")
    return set(obj.get("suppress", []))


def write_baseline(path: Path | str, keys) -> Path:
    path = Path(path)
    obj = {"format": FORMAT, "suppress": sorted(set(keys))}
    path.write_text(json.dumps(obj, indent=2, sort_keys=True) + "\n")
    return path


def default_baseline(root: Path) -> set[str]:
    p = root / BASELINE_NAME
    return load_baseline(p) if p.exists() else set()
