"""Finding model for scavlint (DESIGN.md §10).

A ``Finding`` is one architectural-invariant violation: which pass raised
it, where (repo-relative path + line + enclosing scope), what is wrong,
and how to fix it.  ``Finding.key`` is deliberately *line-independent*
(pass / path / scope / message) so baseline entries survive unrelated
edits that shift line numbers.
"""

from __future__ import annotations

import dataclasses

SEV_ERROR = "error"
SEV_WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class Finding:
    pass_name: str
    severity: str
    path: str                 # repo-relative, posix separators
    line: int
    message: str
    hint: str = ""
    context: str = ""         # enclosing function qualname or "<module>"

    @property
    def key(self) -> str:
        """Stable identity used by the baseline file (no line number)."""
        return "::".join((self.pass_name, self.path, self.context,
                          self.message))

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["key"] = self.key
        return d

    def render(self) -> str:
        where = f"{self.path}:{self.line}"
        out = f"{where}: [{self.pass_name}] {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out
