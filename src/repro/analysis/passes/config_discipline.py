"""config-discipline: numeric behaviour knobs live in EngineConfig
(DESIGN.md §10, invariant from §3).

A bare numeric literal in the store core is an unregistered knob: it
tunes behaviour but is invisible to ``EngineConfig.scaled()``, ablation
sweeps, and the config MANIFEST edit — the code-level analogue of the
paper's unaccounted space.  This pass flags int/float literals in
``core/`` outside the sanctioned constant homes:

  * ``engine/config.py``  (EngineConfig itself)
  * ``engine/io.py``      (the DeviceModel cost constants)

Exempt by construction (not knobs):

  * small structural literals: ints {-2,-1,0,1,2}, floats
    {-1.0, 0.0, 0.5, 1.0, 2.0} and the unit conversions 1e3/1e6,
  * module/class-level ``ALL_CAPS = ...`` constant definitions (named
    constants are the point),
  * function-signature default values (named, self-documenting),
  * shift widths (``1 << 20``-style size spellings),
  * subscript indices (``rec[3]``, ``shape[0]`` — positions in a fixed
    layout, not tunables).

Escape hatch: ``# scavlint: allow-const <why>`` for structural literals
that are genuinely not tunable (sentinels, format widths).

The kernels module (``src/repro/kernels/``) gets the inverse rule: its
code is full of structural literals (lane widths, shift amounts), but its
*tuning* constants — tile sizes, chunk extents, pad sentinels — must be
shared, or the per-package copies drift and the padding contracts between
ops silently diverge.  There the pass flags module-level ``ALL_CAPS``
numeric definitions anywhere outside ``kernels/common.py``: import the
constant from ``..common`` instead of redefining it.
"""

from __future__ import annotations

import ast

from ..framework import Pass, register

_OK_INTS = {-2, -1, 0, 1, 2}
_OK_FLOATS = {-1.0, 0.0, 0.5, 1.0, 2.0, 1e3, 1e6}

_EXCLUDED = ("src/repro/core/engine/config.py",
             "src/repro/core/engine/io.py")


def _exempt_ids(tree: ast.AST) -> set[int]:
    """ids of Constant nodes inside sanctioned contexts."""
    out: set[int] = set()

    def mark(node):
        for n in ast.walk(node):
            if isinstance(n, ast.Constant):
                out.add(id(n))

    for node in ast.walk(tree):
        # ALL_CAPS constant definitions (module or class level)
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            if all(isinstance(t, ast.Name) and t.id.isupper()
                   for t in targets) and node.value is not None:
                mark(node.value)
        # function-signature defaults
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for d in (*node.args.defaults, *node.args.kw_defaults):
                if d is not None:
                    mark(d)
        # shift-width spellings like 8 << 10
        elif isinstance(node, ast.BinOp) and \
                isinstance(node.op, (ast.LShift, ast.RShift)):
            mark(node)
        # subscript indices: rec[3], shape[0], v[1:4] — layout positions
        elif isinstance(node, ast.Subscript):
            mark(node.slice)
    return out


@register
class ConfigDisciplinePass(Pass):
    name = "config-discipline"
    description = ("numeric literals in core/ outside EngineConfig / "
                   "DeviceModel are unregistered knobs")
    allow_token = "allow-const"

    def scope(self, rel: str) -> bool:
        if rel.startswith("src/repro/kernels/"):
            return rel != "src/repro/kernels/common.py"
        return (rel.startswith("src/repro/core/")
                and rel not in _EXCLUDED)

    def check(self, sf):
        if sf.rel.startswith("src/repro/kernels/"):
            yield from self._check_kernels(sf)
            return
        exempt = _exempt_ids(sf.tree)
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Constant) or id(node) in exempt:
                continue
            v = node.value
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            if isinstance(v, int) and v in _OK_INTS:
                continue
            if isinstance(v, float) and v in _OK_FLOATS:
                continue
            yield self.finding(
                sf, node,
                f"unregistered numeric knob {v!r}",
                hint="promote to an EngineConfig field (so scaled()/"
                     "ablations/the config MANIFEST edit see it), hoist to "
                     "an ALL_CAPS constant, or annotate "
                     "'# scavlint: allow-const <why>'")

    def _check_kernels(self, sf):
        """Kernel packages must not redefine tile/chunk/sentinel constants:
        module-level ALL_CAPS numerics belong in ``kernels/common.py``."""
        for node in sf.tree.body:
            if not isinstance(node, (ast.Assign, ast.AnnAssign)):
                continue
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            if not targets or node.value is None:
                continue
            if not all(isinstance(t, ast.Name) and t.id.isupper()
                       for t in targets):
                continue
            if any(isinstance(n, ast.Constant)
                   and isinstance(n.value, (int, float))
                   and not isinstance(n.value, bool)
                   for n in ast.walk(node.value)):
                names = ", ".join(t.id for t in targets)
                yield self.finding(
                    sf, node,
                    f"kernel constant {names} defined outside common.py",
                    hint="tile sizes, chunk extents and pad sentinels are "
                         "shared contracts between kernel packages: define "
                         "in repro/kernels/common.py and import from "
                         "..common (or annotate "
                         "'# scavlint: allow-const <why>')")
