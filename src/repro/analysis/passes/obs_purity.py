"""obs-purity: the observability layer is a read-only tap (DESIGN.md §11).

``repro.obs`` is handed live ``Store`` objects so it can read clocks,
counters, and the version — but the whole point of the ``NullObserver``
byte-parity contract is that *watching the accounting must not change it*.
Three things would break that silently:

  * calling a clock-advancing / mutating method on a store (or anything
    reached through a function parameter): ``io.seq_write``, ``stall``,
    ``write``, ``pump`` … — the observer would charge simulated time;
  * assigning state rooted at a parameter (``store.x = …``,
    ``store.io.lanes[k] = …``) — the observer would mutate the observed;
  * importing ``repro.core`` at module scope — the tap must stay
    dependency-free of the substrate it watches (core imports obs for the
    ``NULL_OBSERVER`` default; a back-import is a cycle waiting to happen).

Observer-local state (``self.…``) and host-side file output
(``dump_json``) are of course fine — that is what the layer is for.

Escape hatch: ``# scavlint: allow-obs-impure <why>`` on the offending
line, the line above, or the enclosing ``def`` line.
"""

from __future__ import annotations

import ast

from ..framework import Pass, attr_root, called_attr, register

# Methods that advance the simulated device or mutate store/version state:
# calling any of these on an object reached through a parameter means the
# observer changed what it was measuring.  (Generic container names like
# ``get`` are deliberately absent — dict.get on a parameter is everywhere
# in export/summary code and a scalar Store.get routes through multi_get,
# which is listed.)
CLOCK_CALLS = ("seq_write", "seq_read", "rand_read", "cache_hit", "stall",
               "batched", "write", "put", "delete", "scan",
               "multi_get", "multi_scan", "_write_arrays", "flush", "drain",
               "pump", "settle", "run_job", "rotate_memtable", "checkpoint",
               "arm_crash", "add_l0", "set_level", "add_value_file",
               "retire_value_file", "expose_garbage", "build_value_files",
               "_log_edit", "log_edit")


def _param_names(fn) -> set[str]:
    a = fn.args
    names = [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]
    for p in (a.vararg, a.kwarg):
        if p is not None:
            names.append(p.arg)
    return set(names) - {"self", "cls"}


@register
class ObsPurityPass(Pass):
    name = "obs-purity"
    description = ("repro.obs reads stores; it may not advance clocks, "
                   "mutate store state, or import repro.core")
    allow_token = "allow-obs-impure"

    def scope(self, rel: str) -> bool:
        return rel.startswith("src/repro/obs/")

    def check(self, sf):
        yield from self._check_imports(sf)
        for fn in ast.walk(sf.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_fn(sf, fn)

    def _check_imports(self, sf):
        hint = ("repro.obs must stay import-free of repro.core; take live "
                "objects as arguments instead")
        for node in sf.tree.body:
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.split(".")[:2] == ["repro", "core"]:
                        yield self.finding(
                            sf, node,
                            f"module-scope import of {alias.name}", hint=hint)
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if node.level == 0 and \
                        mod.split(".")[:2] == ["repro", "core"] or \
                        node.level >= 2:
                    yield self.finding(
                        sf, node,
                        f"module-scope import reaching outside repro.obs "
                        f"({'.' * node.level}{mod})", hint=hint)

    def _check_fn(self, sf, fn):
        params = _param_names(fn)
        if not params:
            return
        hint = ("the observer is a read-only tap (DESIGN.md §11): read "
                "clocks/counters, keep state on self")
        for node in ast.walk(fn):
            targets = []
            if isinstance(node, (ast.Assign, ast.AnnAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
            elif isinstance(node, ast.AugAssign):
                targets = [node.target]
            elif isinstance(node, ast.Delete):
                targets = node.targets
            for t in targets:
                if isinstance(t, (ast.Attribute, ast.Subscript)) and \
                        attr_root(t) in params:
                    yield self.finding(
                        sf, node,
                        f"{fn.name}() assigns state rooted at parameter "
                        f"{attr_root(t)!r}", hint=hint)
            if isinstance(node, ast.Call):
                attr = called_attr(node)
                if attr in CLOCK_CALLS and attr_root(node.func) in params:
                    yield self.finding(
                        sf, node,
                        f"{fn.name}() calls clock-advancing/mutating "
                        f"method {attr}() on parameter "
                        f"{attr_root(node.func)!r}", hint=hint)
