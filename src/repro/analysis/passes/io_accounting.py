"""io-accounting: all byte movement in the store core routes through the
counted two-lane device (DESIGN.md §10, invariant from §3).

Raw host IO — builtin ``open``, ``os.read``-family calls, ``mmap``,
``Path.read_bytes``-style helpers — inside ``core/`` bypasses ``SimIO``'s
per-category byte/latency accounting, so its cost is invisible to every
space/time figure the repro validates.  The only sanctioned raw-IO sites
are ``engine/io.py`` (the device model itself) and ``core/durability/``
(host-side persistence of WAL/MANIFEST/snapshots, which by design costs
zero *simulated* time — DESIGN.md §9).

Escape hatch: ``# scavlint: allow-raw-io`` with a reason.
"""

from __future__ import annotations

import ast

from ..framework import Pass, attr_root, called_attr, register

_OS_IO = ("open", "read", "write", "pread", "pwrite", "sendfile",
          "readv", "writev")
_PATH_IO = ("read_bytes", "write_bytes", "read_text", "write_text")

_EXCLUDED = ("src/repro/core/engine/io.py", "src/repro/core/durability/")


@register
class IOAccountingPass(Pass):
    name = "io-accounting"
    description = ("no raw host IO in core/ outside engine/io.py and "
                   "durability/ — route bytes through the counted SimIO")
    allow_token = "allow-raw-io"

    def scope(self, rel: str) -> bool:
        return (rel.startswith("src/repro/core/")
                and not rel.startswith(_EXCLUDED))

    def check(self, sf):
        hint = ("charge the transfer on store.io (seq_read/seq_write/"
                "rand_read) or move host-side persistence into "
                "core/durability/; annotate '# scavlint: allow-raw-io' "
                "only with a reason")
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Name) and node.func.id == "open":
                yield self.finding(sf, node,
                                   "raw builtin open() in core/", hint=hint)
                continue
            attr = called_attr(node)
            root = attr_root(node.func)
            if root == "os" and attr in _OS_IO:
                yield self.finding(sf, node,
                                   f"raw os.{attr}() in core/", hint=hint)
            elif root == "mmap" and attr == "mmap":
                yield self.finding(sf, node,
                                   "raw mmap.mmap() in core/", hint=hint)
            elif attr in _PATH_IO:
                yield self.finding(
                    sf, node,
                    f"raw .{attr}() file IO in core/", hint=hint)
