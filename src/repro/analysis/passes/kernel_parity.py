"""kernel-parity: every Pallas kernel package ships kernel + oracle +
dispatch and is exercised by a test (DESIGN.md §10, invariant from §5).

Each ``src/repro/kernels/<name>/`` package must contain

  * ``kernel.py`` — the Pallas implementation,
  * ``ref.py``    — the pure-jnp oracle it is validated against,
  * ``ops.py``    — the jitted dispatch wrapper callers import,

and the kernel must be referenced from ``tests/`` (by package name or by
one of its ``ops.py`` public functions), so an orphaned kernel cannot
silently rot: the interpret-mode parity harness in ``tests/test_kernels.py``
is the only thing standing between "kernel" and "untested device code".

Escape hatch: baseline entry (there is no inline comment to hang an
allow on for a *missing* file).
"""

from __future__ import annotations

import ast

from ..findings import Finding
from ..framework import ProjectPass, register

REQUIRED = ("kernel.py", "ref.py", "ops.py")


def _public_ops(ops_path) -> list[str]:
    try:
        tree = ast.parse(ops_path.read_text())
    except (OSError, SyntaxError):
        return []
    return [n.name for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            and not n.name.startswith("_")]


@register
class KernelParityPass(ProjectPass):
    name = "kernel-parity"
    description = ("kernels/<name>/ ships kernel.py + ref.py + ops.py and "
                   "is referenced by a test")

    def check_project(self, files, root):
        kdir = root / "src" / "repro" / "kernels"
        if not kdir.is_dir():
            return
        # Only enforce when the kernels tree is part of this run's scope.
        if not any(sf.rel.startswith("src/repro/kernels/") for sf in files):
            return
        tests_text = "\n".join(
            p.read_text() for p in sorted((root / "tests").glob("test_*.py"))
        ) if (root / "tests").is_dir() else ""

        for pkg in sorted(p for p in kdir.iterdir()
                          if p.is_dir() and (p / "__init__.py").exists()):
            rel = pkg.relative_to(root).as_posix()
            missing = [f for f in REQUIRED if not (pkg / f).exists()]
            for f in missing:
                yield Finding(
                    self.name, self.severity, f"{rel}/__init__.py", 1,
                    f"kernel package {pkg.name!r} is missing {f}",
                    hint="every kernel ships the Pallas kernel, its jnp "
                         "oracle (ref.py), and the dispatch wrapper "
                         "(ops.py) — see src/repro/kernels/bloom/")
            if "ops.py" in missing:
                continue
            names = [pkg.name] + _public_ops(pkg / "ops.py")
            if not any(n in tests_text for n in names):
                yield Finding(
                    self.name, self.severity, f"{rel}/ops.py", 1,
                    f"kernel package {pkg.name!r} is not referenced by any "
                    f"test under tests/",
                    hint="add an interpret-mode parity test against ref.py "
                         "in tests/test_kernels.py")
