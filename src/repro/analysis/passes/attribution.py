"""attribution-coverage: background work must carry a cause record
(DESIGN.md §10, invariant from §13).

Two rules keep the amplification ledger's decomposition meaningful:

  * Every ``run_job(...)`` call — the single entry point that advances
    the bg/gc lane clocks — must pass an explicit ``trigger=`` (third
    positional argument also accepted).  A job run without a trigger
    silently inherits ``lane_budget`` even when it was really servicing a
    stall or a quota, which mis-attributes its bytes in the ledger.
  * Any function that logs a MANIFEST ``add_value_file`` /
    ``retire_value_file`` edit must, in the same function, report the
    space transition to the observer (``.on_space(...)``) or open a cause
    scope (``.cause(...)``): value-file births and deaths are exactly the
    space-amplification events the ledger decomposes, so an edit without
    attribution is an unaccounted byte.

Scoped exclusions: ``core/durability/`` (recovery replays edits; restored
state re-attributes nothing).  Escape hatch:
``# scavlint: allow-attribution`` on the call or the enclosing ``def``.
"""

from __future__ import annotations

import ast

from ..framework import Pass, called_attr, register

SPACE_EDITS = ("add_value_file", "retire_value_file")
ATTRIBUTORS = ("on_space", "cause")

_EXCLUDED = ("src/repro/core/durability/",)


def _edit_kind(call: ast.Call) -> str | None:
    """First-arg string literal of a ``_log_edit``/``log_edit`` call."""
    if call.args and isinstance(call.args[0], ast.Constant) \
            and isinstance(call.args[0].value, str):
        return call.args[0].value
    return None


@register
class AttributionCoveragePass(Pass):
    name = "attribution-coverage"
    description = ("run_job calls need an explicit trigger=; value-file "
                   "MANIFEST edits need on_space/cause attribution")
    allow_token = "allow-attribution"

    def scope(self, rel: str) -> bool:
        return (rel.startswith("src/repro/core/")
                and not rel.startswith(_EXCLUDED))

    def check(self, sf):
        for fn in ast.walk(sf.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            edits, attributed = [], False
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                attr = called_attr(node)
                if attr == "run_job" and fn.name != "run_job":
                    has_trigger = (len(node.args) >= 3 or any(
                        kw.arg == "trigger" for kw in node.keywords))
                    if not has_trigger:
                        yield self.finding(
                            sf, node,
                            f"{fn.name}() runs a background job without an "
                            f"explicit trigger cause",
                            hint="pass trigger=... to run_job so the "
                                 "ledger attributes the job's bytes to the "
                                 "scheduling decision, or annotate "
                                 "'# scavlint: allow-attribution'")
                elif attr in ("_log_edit", "log_edit") \
                        and _edit_kind(node) in SPACE_EDITS:
                    edits.append((node, _edit_kind(node)))
                elif attr in ATTRIBUTORS:
                    attributed = True
            if attributed:
                continue
            for node, kind in edits:
                yield self.finding(
                    sf, node,
                    f"{fn.name}() logs a {kind} MANIFEST edit without "
                    f"attributing the space transition",
                    hint="call store.obs.on_space(...) (or open a "
                         "store.obs.cause(...) scope) in the same function "
                         "so the ledger sees the value-file event, or "
                         "annotate '# scavlint: allow-attribution'")
