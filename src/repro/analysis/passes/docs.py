"""docs-citation: module docstrings cite real DESIGN.md sections
(DESIGN.md §10; single enforcement point for the former tests/test_docs.py
checks, which now wrap this pass).

Three invariants keep code and architecture doc linked:

  * DESIGN.md's ``## §N`` sections are contiguous ``1..max`` (a hole means
    a reshuffle left dangling numbers);
  * every public module under ``src/repro/core/`` opens with a docstring
    citing its section (``DESIGN.md §N``);
  * every ``DESIGN §N`` reference in any analyzed source file — plus
    README.md — resolves to an existing section.

Stale/missing citations have no meaningful inline escape (fixing the
citation *is* the fix), so the only suppression is the baseline file.
"""

from __future__ import annotations

import ast
import re

from ..findings import Finding
from ..framework import ProjectPass, register

CITE_RE = re.compile(r"DESIGN(?:\.md)?\s*§(\d+)")
HEADING_RE = re.compile(r"^## §(\d+)\b", re.M)


def design_sections(root) -> set[int]:
    p = root / "DESIGN.md"
    if not p.exists():
        return set()
    return {int(m) for m in HEADING_RE.findall(p.read_text())}


@register
class DocsCitationPass(ProjectPass):
    name = "docs-citation"
    description = ("core module docstrings cite their DESIGN.md section; "
                   "all DESIGN § references resolve")

    def check_project(self, files, root):
        secs = design_sections(root)
        if not secs:
            yield Finding(self.name, self.severity, "DESIGN.md", 1,
                          "DESIGN.md is missing or has no '## §N' sections")
            return
        if secs != set(range(1, max(secs) + 1)):
            yield Finding(
                self.name, self.severity, "DESIGN.md", 1,
                f"DESIGN.md sections are not contiguous: {sorted(secs)}",
                hint="renumber sections 1..N; stale numbers break every "
                     "code citation")

        for sf in files:
            # citation requirement: public core modules only
            base = sf.rel.rsplit("/", 1)[-1]
            if sf.rel.startswith("src/repro/core/") and (
                    not base.startswith("_") or base == "__init__.py"):
                doc = ast.get_docstring(sf.tree) or ""
                if not CITE_RE.search(doc):
                    yield Finding(
                        self.name, self.severity, sf.rel, 1,
                        "core module docstring does not cite its DESIGN.md "
                        "section",
                        hint="open the module docstring with a "
                             "'(DESIGN.md §N)' pointer to the architecture "
                             "doc section it implements")
            # resolution requirement: every analyzed file
            for i, line in enumerate(sf.text.splitlines(), start=1):
                for m in CITE_RE.findall(line):
                    if int(m) not in secs:
                        yield Finding(
                            self.name, self.severity, sf.rel, i,
                            f"stale reference to nonexistent DESIGN.md "
                            f"§{m}",
                            hint=f"DESIGN.md has §1..§{max(secs)}")

        readme = root / "README.md"
        if readme.exists():
            for i, line in enumerate(readme.read_text().splitlines(),
                                     start=1):
                for m in CITE_RE.findall(line):
                    if int(m) not in secs:
                        yield Finding(
                            self.name, self.severity, "README.md", i,
                            f"stale reference to nonexistent DESIGN.md "
                            f"§{m}")
