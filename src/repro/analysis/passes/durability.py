"""durability-coverage: version-state mutations must emit MANIFEST edits
(DESIGN.md §10, invariant from §9).

Every function in the store core that mutates the ``Version`` registry —
``add_l0`` / ``set_level`` / ``add_value_file`` / ``retire_value_file`` —
must, in the *same function*, append a MANIFEST ``VersionEdit``
(``_log_edit`` / ``log_edit`` / ``ManifestWriter.edit``).  A mutation
without a paired edit is unaccounted state: the durable audit log diverges
from the in-memory version, which is exactly the "hidden garbage" failure
mode the paper pins on unaccounted space.

Scoped exclusions: ``engine/version.py`` (defines the mutators) and
``core/durability/`` (recovery *replays* edits; restoring state must not
re-log it).  Escape hatch: ``# scavlint: allow-durability`` on the call
or the enclosing ``def``.
"""

from __future__ import annotations

import ast

from ..framework import Pass, called_attr, register

MUTATORS = ("add_l0", "set_level", "add_value_file", "retire_value_file")
LOGGERS = ("_log_edit", "log_edit", "edit")

_EXCLUDED = ("src/repro/core/engine/version.py",
             "src/repro/core/durability/")


@register
class DurabilityCoveragePass(Pass):
    name = "durability-coverage"
    description = ("Version-registry mutations must log a MANIFEST "
                   "VersionEdit in the same function")
    allow_token = "allow-durability"

    def scope(self, rel: str) -> bool:
        return (rel.startswith("src/repro/core/")
                and not rel.startswith(_EXCLUDED))

    def check(self, sf):
        for fn in ast.walk(sf.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            mutations, logs = [], False
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                attr = called_attr(node)
                if attr in MUTATORS:
                    mutations.append((node, attr))
                elif attr in LOGGERS or (
                        isinstance(node.func, ast.Name)
                        and node.func.id in LOGGERS):
                    logs = True
            if logs:
                continue
            for node, attr in mutations:
                yield self.finding(
                    sf, node,
                    f"{fn.name}() calls version-mutating {attr}() without "
                    f"a paired MANIFEST log_edit",
                    hint="emit store._log_edit(...) for the mutation (it is "
                         "a no-op when durability is off), or annotate the "
                         "call '# scavlint: allow-durability' with a reason")
