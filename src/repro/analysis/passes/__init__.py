"""scavlint's built-in passes (DESIGN.md §10).

Importing this package registers every pass with the framework registry;
each module is one architectural invariant:

  * ``durability``        — version mutations emit MANIFEST edits (§9)
  * ``purity``            — pure EngineStrategy hooks stay pure (§7)
  * ``io_accounting``     — bytes route through the counted SimIO (§3)
  * ``vectorization``     — hot paths stay columnar (§7)
  * ``kernel_parity``     — kernel packages ship kernel/ref/ops + test (§5)
  * ``config_discipline`` — numeric knobs live in EngineConfig (§3)
  * ``docs``              — docstrings cite real DESIGN sections
  * ``obs_purity``        — repro.obs is a read-only tap (§11)
  * ``attribution``       — background work carries a cause record (§13)
"""

from . import (attribution, config_discipline, docs, durability,  # noqa: F401
               io_accounting, kernel_parity, obs_purity, purity,
               vectorization)
