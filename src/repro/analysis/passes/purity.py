"""hook-purity: EngineStrategy scoring/observation hooks stay pure
(DESIGN.md §10, invariant from §7).

The seven engines are parity-comparable because the *pure* strategy hooks
— ``separation_mask``, ``level_weight``, ``file_weight``,
``gc_candidate_score``, ``rewrite_temperature``, ``observe_batch`` — only
read store state and return a value.  A hook that assigns a Store/Version
attribute or calls a mutation/IO-charging method smuggles engine-specific
side effects into shared code paths, breaking the golden byte-parity
contract (engine-local state on ``self`` is fine: that is where adaptive
trackers live).

Mutating hooks (``on_compaction_kept``, ``gc_finalize``,
``gc_read_candidate``, ``gc_value_read``, ``rank_compaction_inputs``) are
*by contract* effectful and are not checked here — their effects are
covered by durability-coverage and io-accounting.

Escape hatch: ``# scavlint: allow-impure-hook`` on the offending line or
the hook's ``def`` line.
"""

from __future__ import annotations

import ast

from ..framework import Pass, attr_root, called_attr, register

PURE_HOOKS = ("separation_mask", "level_weight", "file_weight",
              "gc_candidate_score", "rewrite_temperature", "observe_batch")

# Methods whose call inside a pure hook means store/version mutation or
# simulated-device time: the hook is no longer a pure policy function.
MUTATION_CALLS = ("add_l0", "set_level", "add_value_file",
                  "retire_value_file", "writeback_index",
                  "writeback_index_batch", "expose_garbage",
                  "build_value_files", "_log_edit", "log_edit",
                  "seq_write", "seq_read", "rand_read", "cache_hit",
                  "stall", "record", "erase_file", "put")

_SCOPES = ("src/repro/core/engines/", "src/repro/core/adaptive/engine.py")


@register
class HookPurityPass(Pass):
    name = "hook-purity"
    description = ("pure EngineStrategy hooks may not mutate store state "
                   "or charge device time")
    allow_token = "allow-impure-hook"

    def scope(self, rel: str) -> bool:
        return rel.startswith(_SCOPES)

    def check(self, sf):
        for fn in ast.walk(sf.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    or fn.name not in PURE_HOOKS:
                continue
            yield from self._check_hook(sf, fn)

    def _check_hook(self, sf, fn):
        hint = ("pure hooks return policy decisions; move side effects "
                "into an effectful hook (gc_finalize / on_compaction_kept) "
                "or keep state on self")
        for node in ast.walk(fn):
            targets = []
            if isinstance(node, (ast.Assign, ast.AnnAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
            elif isinstance(node, ast.AugAssign):
                targets = [node.target]
            elif isinstance(node, ast.Delete):
                targets = node.targets
            for t in targets:
                if isinstance(t, (ast.Attribute, ast.Subscript)):
                    root = attr_root(t)
                    if root not in (None, "self"):
                        yield self.finding(
                            sf, node,
                            f"pure hook {fn.name}() assigns state rooted at "
                            f"parameter {root!r}", hint=hint)
            if isinstance(node, ast.Call):
                attr = called_attr(node)
                if attr in MUTATION_CALLS and \
                        attr_root(node.func) not in (None, "self"):
                    yield self.finding(
                        sf, node,
                        f"pure hook {fn.name}() calls mutating/IO method "
                        f"{attr}()", hint=hint)
