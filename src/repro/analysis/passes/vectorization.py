"""vectorization: no per-element Python loops on the columnar hot paths
(DESIGN.md §10, invariant from §7).

The read/value/adaptive layers are batch-shaped end to end — PR 1/3
measured 23x on exactly this discipline, and the Pallas roadmap item
(kernels over the same columns) depends on it staying columnar.  This
pass flags ``for`` statements in ``core/read/`` / ``core/values/`` /
``core/adaptive/`` whose iterator is batch-shaped per *element*:

  * ``for ... in zip(a, b)``         — lockstep element walk
  * ``for ... in range(len(a))``     — index walk
  * ``for ... in a.tolist()``        — array spilled to Python objects

Loops over *deduplicated* domains (``np.unique(...)`` — per touched file
/ block, not per record) and ``reversed(...)`` structure walks are
exempt: their trip count is bounded by structure size, not batch size.

A flagged loop that is genuinely per-file/per-run (bounded small) takes
``# scavlint: allow-loop`` with a reason on the same line — the escape
hatch doubles as documentation of *why* the loop is not per-key.
"""

from __future__ import annotations

import ast

from ..framework import Pass, register

_HOT_PATHS = ("src/repro/core/read/", "src/repro/core/values/",
              "src/repro/core/adaptive/")


def _contains_exempt_call(node: ast.AST) -> bool:
    """Iterator subtree mentions np.unique(...) or reversed(...)."""
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            f = n.func
            if isinstance(f, ast.Name) and f.id in ("reversed", "unique"):
                return True
            if isinstance(f, ast.Attribute) and f.attr == "unique":
                return True
    return False


def _loop_kind(it: ast.AST) -> str | None:
    if not isinstance(it, ast.Call):
        return None
    f = it.func
    if isinstance(f, ast.Name):
        if f.id == "zip":
            return "zip(...) element walk"
        if f.id == "range" and len(it.args) == 1 and \
                isinstance(it.args[0], ast.Call) and \
                isinstance(it.args[0].func, ast.Name) and \
                it.args[0].func.id == "len":
            return "range(len(...)) index walk"
    if isinstance(f, ast.Attribute) and f.attr == "tolist":
        return ".tolist() array spill"
    return None


@register
class VectorizationPass(Pass):
    name = "vectorization"
    description = ("no per-element Python for-loops over batch-shaped "
                   "iterables in core/read, core/values, core/adaptive")
    allow_token = "allow-loop"

    def scope(self, rel: str) -> bool:
        return rel.startswith(_HOT_PATHS)

    def check(self, sf):
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.For):
                continue
            kind = _loop_kind(node.iter)
            if kind is None or _contains_exempt_call(node.iter):
                continue
            yield self.finding(
                sf, node,
                f"per-element loop on a hot path: {kind}",
                hint="vectorize with numpy column ops, or — if the loop is "
                     "per-file/per-run (bounded by structure, not batch) — "
                     "annotate '# scavlint: allow-loop <why>'")
