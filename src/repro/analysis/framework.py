"""scavlint pass framework: parsed sources, suppressions, pass registry
(DESIGN.md §10).

The analyzer is a small AST-visitor harness, not a general linter:

  * ``SourceFile`` parses one module and records per-line
    ``# scavlint: allow-<token>`` suppressions plus function extents, so a
    pass can ask "is this node's finding suppressed?" (on the node's line,
    the line above, or the enclosing ``def`` line).
  * ``Pass`` subclasses implement ``check(sf)`` over one file;
    ``ProjectPass`` subclasses implement ``check_project(files, root)``
    for repo-shaped invariants (kernel packaging, docs citations).
  * ``@register`` collects passes; ``run_analysis`` parses the selected
    trees once and feeds every pass, returning active + baselined
    findings.

Passes declare a ``scope(rel)`` predicate over repo-relative paths, so
running the CLI over ``benchmarks/`` or ``examples/`` only applies the
passes that are meaningful there (the rest are documented scoped
exclusions, not silent skips — see DESIGN.md §10).
"""

from __future__ import annotations

import ast
import re
from pathlib import Path

from .findings import SEV_ERROR, Finding

_ALLOW_RE = re.compile(r"#\s*scavlint:\s*(allow-[\w-]+)")


class SourceFile:
    """One parsed module: AST + suppression comments + function extents."""

    def __init__(self, text: str, rel: str, path: Path | None = None):
        self.text = text
        self.rel = rel.replace("\\", "/")
        self.path = path
        self.tree = ast.parse(text)          # SyntaxError surfaces to caller
        self.allows: dict[int, set[str]] = {}
        for i, line in enumerate(text.splitlines(), start=1):
            toks = _ALLOW_RE.findall(line)
            if toks:
                self.allows[i] = set(toks)
        # (start, end, def_line, qualname) per function, innermost last
        self.func_spans: list[tuple[int, int, int, str]] = []
        self._index_functions(self.tree, prefix="")

    @classmethod
    def load(cls, path: Path, root: Path) -> "SourceFile":
        rel = path.resolve().relative_to(root.resolve()).as_posix()
        return cls(path.read_text(), rel, path)

    def _index_functions(self, node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                qual = f"{prefix}{child.name}"
                if not isinstance(child, ast.ClassDef):
                    end = getattr(child, "end_lineno", child.lineno)
                    self.func_spans.append(
                        (child.lineno, end, child.lineno, qual))
                self._index_functions(child, prefix=f"{qual}.")

    def qualname_at(self, line: int) -> str:
        """Innermost enclosing function qualname, or ``<module>``."""
        best = "<module>"
        for start, end, _, qual in self.func_spans:
            if start <= line <= end:
                best = qual        # spans are indexed outer-to-inner
        return best

    def suppressed(self, line: int, token: str) -> bool:
        """True if ``allow-<token>`` appears on the line, the line above,
        or the enclosing ``def`` line."""
        tok = token if token.startswith("allow-") else f"allow-{token}"
        if tok in self.allows.get(line, ()) or \
           tok in self.allows.get(line - 1, ()):
            return True
        for start, end, def_line, _ in self.func_spans:
            if start <= line <= end and tok in self.allows.get(def_line, ()):
                return True
        return False


def attr_root(node: ast.AST) -> str | None:
    """Root ``Name`` id of an attribute/subscript chain (``a.b[c].d`` -> a)."""
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Call)):
        node = node.func if isinstance(node, ast.Call) else node.value
    return node.id if isinstance(node, ast.Name) else None


def called_attr(call: ast.Call) -> str | None:
    """Attribute name of a method call (``x.y.z(...)`` -> ``z``)."""
    return call.func.attr if isinstance(call.func, ast.Attribute) else None


# ============================================================= pass model
class Pass:
    """One architectural invariant, checked per file."""

    name: str = ""
    description: str = ""
    severity: str = SEV_ERROR
    allow_token: str = ""          # inline escape hatch ("" = baseline only)
    project: bool = False

    def scope(self, rel: str) -> bool:
        """Repo-relative paths this pass applies to (default: store core)."""
        return rel.startswith("src/repro/core/")

    def check(self, sf: SourceFile):
        raise NotImplementedError

    # helper: build a finding unless suppressed by the inline escape hatch
    def finding(self, sf: SourceFile, node: ast.AST, message: str,
                hint: str = "") -> Finding | None:
        line = getattr(node, "lineno", 1)
        if self.allow_token and sf.suppressed(line, self.allow_token):
            return None
        return Finding(self.name, self.severity, sf.rel, line, message,
                       hint=hint, context=sf.qualname_at(line))


class ProjectPass(Pass):
    """Invariant over the whole selected tree (runs once per analysis)."""

    project = True

    def check_project(self, files: list[SourceFile], root: Path):
        raise NotImplementedError


_REGISTRY: dict[str, Pass] = {}


def register(cls):
    inst = cls()
    if not inst.name:
        raise ValueError(f"pass {cls.__name__} has no name")
    if inst.name in _REGISTRY:
        raise ValueError(f"duplicate pass name {inst.name!r}")
    _REGISTRY[inst.name] = inst
    return cls


def all_passes() -> dict[str, Pass]:
    from . import passes  # noqa: F401  (importing registers the passes)
    return dict(_REGISTRY)


# ================================================================ running
def find_root(start: Path) -> Path:
    """Nearest ancestor containing pyproject.toml (else ``start``)."""
    p = start.resolve()
    if p.is_file():
        p = p.parent
    for cand in (p, *p.parents):
        if (cand / "pyproject.toml").exists():
            return cand
    return start.resolve()


def collect_files(root: Path, paths: list[str]) -> list[Path]:
    out: list[Path] = []
    for p in paths:
        fp = (root / p) if not Path(p).is_absolute() else Path(p)
        if fp.is_file() and fp.suffix == ".py":
            out.append(fp)
        elif fp.is_dir():
            out.extend(sorted(fp.rglob("*.py")))
    # de-dup, keep order, skip caches
    seen, files = set(), []
    for f in out:
        r = f.resolve()
        if r in seen or "__pycache__" in r.parts:
            continue
        seen.add(r)
        files.append(r)
    return files


class Result:
    def __init__(self):
        self.findings: list[Finding] = []    # active (unbaselined)
        self.baselined: list[Finding] = []
        self.parse_errors: list[Finding] = []

    @property
    def failed(self) -> bool:
        return bool(self.parse_errors) or any(
            f.severity == SEV_ERROR for f in self.findings)


def run_analysis(paths: list[str], root: Path | None = None,
                 select: list[str] | None = None,
                 baseline_keys: set[str] | None = None) -> Result:
    """Parse ``paths`` (files/dirs, relative to ``root``) and run passes."""
    if root is None:
        root = find_root(Path(paths[0]) if paths else Path.cwd())
    passes = all_passes()
    if select:
        unknown = set(select) - set(passes)
        if unknown:
            raise ValueError(f"unknown pass(es): {sorted(unknown)} "
                             f"(have: {sorted(passes)})")
        passes = {k: v for k, v in passes.items() if k in select}

    res = Result()
    files: list[SourceFile] = []
    for path in collect_files(root, paths):
        try:
            files.append(SourceFile.load(path, root))
        except SyntaxError as e:
            rel = path.relative_to(root).as_posix()
            res.parse_errors.append(Finding(
                "parse", SEV_ERROR, rel, e.lineno or 1,
                f"syntax error: {e.msg}"))

    raw: list[Finding] = []
    for p in passes.values():
        if p.project:
            raw.extend(p.check_project(files, root))
        else:
            for sf in files:
                if p.scope(sf.rel):
                    raw.extend(f for f in p.check(sf) if f is not None)

    raw.sort(key=lambda f: (f.path, f.line, f.pass_name, f.message))
    baseline_keys = baseline_keys or set()
    for f in raw:
        (res.baselined if f.key in baseline_keys else res.findings).append(f)
    return res
