"""scavlint: AST-based architectural invariant analyzer (DESIGN.md §10).

The store core's correctness rests on cross-cutting invariants — every
version mutation emits a MANIFEST edit (§9), pure EngineStrategy hooks
stay pure so the engines remain parity-comparable (§7), all byte movement
routes through the counted two-lane device (§3), hot paths stay columnar
for the Pallas roadmap — which the dynamic test suite only catches after
the fact.  scavlint rejects such code at lint time: a small pass
framework (``framework``), a finding model with line-independent baseline
keys (``findings`` / ``baseline``), seven built-in passes (``passes``),
and a CLI (``python -m repro.analysis``; wired into ``make lint`` / CI).

Library use::

    from repro.analysis import run_analysis
    res = run_analysis(["src"], root=repo_root)
    assert not res.failed, [f.render() for f in res.findings]
"""

from .baseline import load_baseline, write_baseline
from .findings import Finding
from .framework import SourceFile, all_passes, run_analysis

__all__ = ["Finding", "SourceFile", "all_passes", "run_analysis",
           "load_baseline", "write_baseline"]
