"""Pytree (de)serialization over the CheckpointStore.

Each leaf is one KV: key = "<name>/<step>/<leaf-path>", value = npy bytes.
Shards are mesh-shape-agnostic (full logical tensors + dtype/shape headers
in npy), so restore can reshard onto a different device count — the
elasticity requirement in DESIGN.md §6.
"""

from __future__ import annotations

import io
import json

import jax
import numpy as np

from .store import CheckpointStore


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    return keys, [l for _, l in flat], treedef


def save_pytree(store: CheckpointStore, name: str, step: int, tree,
                hot: bool = True) -> None:
    keys, leaves, _ = _leaf_paths(tree)
    for k, leaf in zip(keys, leaves):
        buf = io.BytesIO()
        np.save(buf, np.asarray(leaf))
        store.put(f"{name}/{step}/{k}", buf.getvalue(), hot=hot)
    store.put(f"{name}/{step}/__done__",
              json.dumps({"n_leaves": len(keys)}).encode(), hot=hot)
    store.flush()


def steps_available(store: CheckpointStore, name: str) -> list[int]:
    steps = set()
    for k in store.keys(prefix=f"{name}/"):
        if k.endswith("/__done__"):
            steps.add(int(k.split("/")[1]))
    return sorted(steps)


def load_pytree(store: CheckpointStore, name: str, step: int, like):
    """Restore into the structure of ``like`` (dtypes cast to match)."""
    keys, leaves, treedef = _leaf_paths(like)
    out = []
    for k, leaf in zip(keys, leaves):
        raw = store.get(f"{name}/{step}/{k}")
        arr = np.load(io.BytesIO(raw))
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        out.append(np.asarray(arr).astype(want_dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def drop_steps(store: CheckpointStore, name: str, keep_last: int) -> None:
    """Delete old checkpoints -> garbage for the Scavenger GC."""
    steps = steps_available(store, name)
    for s in steps[:-keep_last] if keep_last else steps:
        for k in store.keys(prefix=f"{name}/{s}/"):
            store.delete(k)
