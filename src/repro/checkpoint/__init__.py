from .store import CheckpointStore, ValueLog
from .pytree import (save_pytree, load_pytree, steps_available, drop_steps)

__all__ = ["CheckpointStore", "ValueLog", "save_pytree", "load_pytree",
           "steps_available", "drop_steps"]
