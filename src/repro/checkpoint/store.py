"""Scavenger-backed checkpoint store: the paper's technique as a deployable
training-framework feature (DESIGN.md §4).

Incremental checkpointing IS KV separation: tensor shards are large values
in append-only value logs (vSST analog); the manifest (index LSM analog)
holds only <key, locator> entries.  Old checkpoint steps become garbage;
under a disk quota the GC/throttle trade-off is exactly the paper's.

Scavenger mechanics carried over 1:1 — on real files:
  * RTable-style dense footer index per value log -> GC validates a whole
    log by reading ONLY the footer ("lazy read", §III-B.1), then copies
    only live records.
  * Garbage exposure happens at manifest compaction (§II-D): dropping a
    superseded manifest entry increments its log's garbage counter.
  * Hotness-aware placement (§III-B.3): high-churn classes (optimizer
    state, params — rewritten every save) and cold classes (config, data
    iterator state, RNG) go to separate logs so whole files die together.
  * Space-aware throttling (§III-D): saves block on aggressive GC when the
    quota is hit.

Crash safety: records are CRC-checked in the repo-wide durability framing
(``repro.core.durability.records`` — the same ``(crc32, key_len, val_len)``
record log the core's WAL/MANIFEST/snapshots use, DESIGN.md §9); the
manifest is an append-only log replayed on open; value logs are fsync'd
before their manifest entries.
"""

from __future__ import annotations

import json
import os
import struct
from pathlib import Path

from repro.core.durability.records import (REC_HDR as _REC_HDR,
                                           append_record, read_record,
                                           scan_records)


class ValueLog:
    """Append-only record file with an RTable-style dense footer index."""

    def __init__(self, path: Path, hot: bool):
        self.path = path
        self.hot = hot
        self.index: dict[str, tuple[int, int]] = {}   # key -> (off, len)
        self.bytes = 0
        self.garbage_bytes = 0
        self._fh = open(path, "ab")

    def append(self, key: str, data: bytes) -> None:
        off = self._fh.tell()
        rec_len = append_record(self._fh, key, data)
        self.index[key] = (off, rec_len)
        self.bytes += rec_len

    def read(self, key: str) -> bytes:
        if not self._fh.closed and self._fh.name != os.devnull:
            self._fh.flush()          # appends are buffered
        off, rec_len = self.index[key]
        with open(self.path, "rb") as f:
            f.seek(off)
            rec = read_record(f)      # CRC-verified shared framing
        if rec is None:
            raise IOError(f"checksum mismatch for {key} in {self.path}")
        return rec[1]

    def seal(self) -> None:
        """Write the dense footer index and close for appends."""
        if getattr(self, "sealed", False) or self._fh.closed:
            return
        self.sealed = True
        footer = json.dumps({k: v for k, v in self.index.items()}).encode()
        self._fh.write(footer)
        self._fh.write(struct.pack("<Q", len(footer)))
        self._fh.flush()
        os.fsync(self._fh.fileno())
        self._fh.close()

    @classmethod
    def recover_unsealed(cls, path: Path, hot: bool) -> "ValueLog | None":
        """Crash recovery: sequentially parse CRC'd records, truncate at the
        first torn record, seal."""
        index: dict[str, tuple[int, int]] = {}
        good_end = 0
        for off, kb, data in scan_records(path):
            rec_len = _REC_HDR.size + len(kb) + len(data)
            index[kb.decode()] = (off, rec_len)
            good_end = off + rec_len
        if not index:
            return None
        os.truncate(path, good_end)
        self = cls.__new__(cls)
        self.path = path
        self.hot = hot
        self.index = index
        self.bytes = good_end
        self.garbage_bytes = 0
        self._fh = open(path, "ab")
        self.seal()
        return self

    @classmethod
    def open_sealed(cls, path: Path, hot: bool) -> "ValueLog":
        """Recover a sealed log by reading only its footer (lazy read)."""
        self = cls.__new__(cls)
        self.path = path
        self.hot = hot
        self.sealed = True
        self.garbage_bytes = 0
        with open(path, "rb") as f:
            f.seek(-8, 2)
            (flen,) = struct.unpack("<Q", f.read(8))
            f.seek(-8 - flen, 2)
            self.index = {k: tuple(v)
                          for k, v in json.loads(f.read(flen)).items()}
            self.bytes = f.tell() + 8
        self._fh = open(os.devnull, "ab")   # sealed: no appends
        return self

    def garbage_ratio(self) -> float:
        return self.garbage_bytes / max(self.bytes, 1)


class CheckpointStore:
    """KV-separated checkpoint store with Scavenger GC.

    engine="scavenger": lazy-read GC + hot/cold placement + throttling.
    engine="naive":     no GC — old logs deleted only when every key in
                        them is dead AND a full-file scan confirms it
                        (BlobDB-style exhaustion), for benchmarks.
    """

    LOG_TARGET = 64 << 20

    def __init__(self, root: str | Path, engine: str = "scavenger",
                 quota_bytes: int | None = None,
                 gc_threshold: float = 0.2, log_target: int | None = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.engine = engine
        self.quota = quota_bytes
        self.gc_threshold = gc_threshold
        self.log_target = log_target or self.LOG_TARGET
        self.manifest_path = self.root / "MANIFEST"
        self.manifest: dict[str, tuple[int, int]] = {}  # key -> (log, gen)
        self.logs: dict[int, ValueLog] = {}
        self.next_log = 0
        self.open_logs: dict[bool, ValueLog | None] = {True: None,
                                                       False: None}
        self.gc_runs = 0
        self.gc_read_bytes = 0
        self.gc_copied_bytes = 0
        self.throttle_events = 0
        self._gen = 0
        self._manifest_fh = None
        self._recover()

    # ------------------------------------------------------------ recovery
    def _recover(self) -> None:
        for p in sorted(self.root.glob("vlog-*.log")):
            stem = p.stem.split("-")[1]
            lid, hot = int(stem[:-1]), stem.endswith("h")
            try:
                log = ValueLog.open_sealed(p, hot)
            except Exception:
                # crashed before seal: sequential-scan recovery via record
                # CRCs, then seal in place
                log = ValueLog.recover_unsealed(p, hot)
                if log is None:
                    p.unlink()
                    continue
            log.lid = lid
            self.logs[lid] = log
            self.next_log = max(self.next_log, lid + 1)
        if self.manifest_path.exists():
            with open(self.manifest_path) as f:
                for line in f:
                    try:
                        op = json.loads(line)
                    except json.JSONDecodeError:
                        break           # torn tail write
                    if op["o"] == "put":
                        if op["l"] in self.logs and \
                                op["k"] in self.logs[op["l"]].index:
                            prev = self.manifest.get(op["k"])
                            if prev is not None:
                                self._expose_key(prev, op["k"])
                            self.manifest[op["k"]] = (op["l"], op["g"])
                    elif op["o"] == "del":
                        prev = self.manifest.pop(op["k"], None)
                        if prev is not None:
                            self._expose_key(prev, op["k"])
        self._manifest_fh = open(self.manifest_path, "a")

    # --------------------------------------------------------------- write
    def _log_for(self, hot: bool) -> ValueLog:
        log = self.open_logs[hot]
        if log is None or log.bytes >= self.log_target:
            if log is not None:
                log.seal()
            lid = self.next_log
            self.next_log += 1
            suffix = "h" if hot else "c"
            log = ValueLog(self.root / f"vlog-{lid:06d}{suffix}.log", hot)
            self.logs[lid] = log
            self.open_logs[hot] = log
            log.lid = lid
        return log

    def put(self, key: str, data: bytes, hot: bool = True) -> None:
        self._throttle(len(data))
        log = self._log_for(hot)
        log.append(key, data)
        prev = self.manifest.get(key)
        if prev is not None:
            self._expose_key(prev, key)
        self._gen += 1
        self.manifest[key] = (log.lid, self._gen)
        self._manifest_fh.write(json.dumps(
            {"o": "put", "k": key, "l": log.lid, "g": self._gen}) + "\n")

    def _expose_key(self, loc, key) -> None:
        log = self.logs.get(loc[0])
        if log is not None and key in log.index:
            log.garbage_bytes += log.index[key][1]

    def delete(self, key: str) -> None:
        prev = self.manifest.pop(key, None)
        if prev is not None:
            self._expose_key(prev, key)
            self._manifest_fh.write(json.dumps({"o": "del", "k": key})
                                    + "\n")

    def flush(self) -> None:
        for log in self.open_logs.values():
            if log is not None and not log._fh.closed:
                log._fh.flush()
                os.fsync(log._fh.fileno())
        self._manifest_fh.flush()
        os.fsync(self._manifest_fh.fileno())

    # ---------------------------------------------------------------- read
    def get(self, key: str) -> bytes:
        loc = self.manifest[key]
        return self.logs[loc[0]].read(key)

    def keys(self, prefix: str = ""):
        return [k for k in self.manifest if k.startswith(prefix)]

    # ------------------------------------------------------------------ GC
    def total_bytes(self) -> int:
        return sum(l.bytes for l in self.logs.values()) \
            + (self.manifest_path.stat().st_size
               if self.manifest_path.exists() else 0)

    def live_bytes(self) -> int:
        return sum(l.bytes - l.garbage_bytes for l in self.logs.values())

    def space_amp(self) -> float:
        return self.total_bytes() / max(self.live_bytes(), 1)

    def run_gc(self, threshold: float | None = None) -> int:
        """Scavenger lazy-read GC: validate via footer indexes only, copy
        only live records.  Returns reclaimed bytes."""
        if self.engine != "scavenger":
            return self._naive_gc()
        thr = self.gc_threshold if threshold is None else threshold
        reclaimed = 0
        for lid, log in sorted(self.logs.items(),
                               key=lambda kv: -kv[1].garbage_ratio()):
            if log is self.open_logs[True] or log is self.open_logs[False]:
                continue
            if log.garbage_ratio() < thr:
                continue
            # lazy read: the footer index IS the key list (no data read)
            self.gc_read_bytes += len(json.dumps(
                {k: v for k, v in log.index.items()}))
            live = [k for k in log.index
                    if self.manifest.get(k, (None,))[0] == lid]
            for k in live:
                data = log.read(k)            # only live records touched
                self.gc_read_bytes += len(data)
                self.gc_copied_bytes += len(data)
                self.put(k, data, hot=log.hot)
            reclaimed += log.bytes
            log.seal()
            log.path.unlink()
            del self.logs[lid]
            self.gc_runs += 1
        return reclaimed

    def _naive_gc(self) -> int:
        """BlobDB-style: a log dies only when fully dead (full scan)."""
        reclaimed = 0
        for lid, log in list(self.logs.items()):
            if log is self.open_logs[True] or log is self.open_logs[False]:
                continue
            live = [k for k in log.index
                    if self.manifest.get(k, (None,))[0] == lid]
            self.gc_read_bytes += log.bytes   # full scan to verify
            if not live:
                reclaimed += log.bytes
                log.seal()
                log.path.unlink()
                del self.logs[lid]
                self.gc_runs += 1
        return reclaimed

    def _throttle(self, incoming: int) -> None:
        if self.quota is None:
            return
        if self.total_bytes() + incoming > self.quota:
            self.throttle_events += 1
            self.run_gc(threshold=0.05)       # aggressive under pressure
            if self.total_bytes() + incoming > self.quota:
                self.compact_manifest()       # expose hidden garbage
                self.run_gc(threshold=0.05)

    def compact_manifest(self) -> None:
        """Rewrite the manifest log dropping dead entries (the index-LSM
        compaction analog; exposure already happened incrementally)."""
        tmp = self.manifest_path.with_suffix(".tmp")
        with open(tmp, "w") as f:
            for k, (lid, gen) in self.manifest.items():
                f.write(json.dumps({"o": "put", "k": k, "l": lid,
                                    "g": gen}) + "\n")
            f.flush()
            os.fsync(f.fileno())
        self._manifest_fh.close()
        os.replace(tmp, self.manifest_path)
        self._manifest_fh = open(self.manifest_path, "a")

    def stats(self) -> dict:
        return {
            "engine": self.engine,
            "total_bytes": self.total_bytes(),
            "live_bytes": self.live_bytes(),
            "space_amp": self.space_amp(),
            "n_logs": len(self.logs),
            "gc_runs": self.gc_runs,
            "gc_read_bytes": self.gc_read_bytes,
            "gc_copied_bytes": self.gc_copied_bytes,
            "throttle_events": self.throttle_events,
        }

    def close(self) -> None:
        for log in self.open_logs.values():
            if log is not None:
                log.seal()
        self._manifest_fh.flush()
        self._manifest_fh.close()
