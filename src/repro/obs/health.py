"""Health sampler: periodic snapshots of derived store series
(DESIGN.md §11).

Every ``sample_every`` observer ticks (one tick per user batch op) the
sampler derives one sample per store: space amplification and its
breakdown (index-tree ``s_index``, exposed garbage over valid), the
per-temperature vSST byte mix, the per-vSST garbage-ratio distribution,
lane utilization, stall totals, and — for durable stores — WAL/MANIFEST
host-side sizes.  Samples accumulate into a per-shard time series that
benchmarks and the ``python -m repro.obs`` dashboard dump as
``health.json``.

Read-only by contract (the ``obs-purity`` scavlint pass): sampling calls
only pure accessors — it never advances a clock or mutates store state.
"""

from __future__ import annotations

import json

TEMP_NAMES = {0: "cold", 1: "warm", 2: "hot"}


def _garbage_quantile(ratios: list, q: float) -> float:
    if not ratios:
        return 0.0
    s = sorted(ratios)
    return s[min(len(s) - 1, int(q * len(s)))]


def sample_store(store) -> dict:
    """One derived health sample from a ``Store`` (pure reads only)."""
    io = store.io
    lanes = dict(io.lanes)
    clock = max(lanes.values())
    temp_bytes: dict[str, int] = {}
    ratios = []
    for t in store.version.value_files.values():
        name = TEMP_NAMES.get(getattr(t, "temperature", None), "none")
        temp_bytes[name] = temp_bytes.get(name, 0) + int(t.file_bytes)
        tot = int(t.total_value_bytes)
        if tot > 0:
            ratios.append(int(t.garbage_bytes) / tot)
    wal_b = man_b = 0
    dur = getattr(store, "durability", None)
    if dur is not None:
        man_b = getattr(dur.manifest, "bytes_written", 0)
        wal_b = getattr(dur, "wal_bytes_written", 0)
    return {
        "clock_us": clock,
        "lanes": lanes,
        "lane_util": {k: (v / clock if clock else 0.0)
                      for k, v in lanes.items()},
        "space_bytes": store.space_bytes(),
        "valid_bytes": store.valid_bytes,
        "space_amp": store.space_amplification(),
        "s_index": store.s_index(),
        "exposed_over_valid": store.exposed_over_valid(),
        "n_value_files": len(store.version.value_files),
        "temp_bytes": temp_bytes,
        "garbage_ratio": {
            "mean": (sum(ratios) / len(ratios)) if ratios else 0.0,
            "p50": _garbage_quantile(ratios, 0.50),
            "p90": _garbage_quantile(ratios, 0.90),
            "max": max(ratios) if ratios else 0.0,
        },
        "stall_us": store.stall_us,
        "n_compactions": store.n_compactions,
        "n_gc_runs": store.n_gc_runs,
        "wal_bytes": wal_b,
        "manifest_bytes": man_b,
    }


class HealthSampler:
    def __init__(self, sample_every: int = 64):
        self.sample_every = int(sample_every)
        self.series: dict[str, list] = {}
        self._ticks: dict[str, int] = {}

    def tick(self, store, label: str) -> None:
        n = self._ticks.get(label, 0) + 1
        self._ticks[label] = n
        if n % self.sample_every == 0:
            self.sample(store, label)

    def sample(self, store, label: str) -> dict:
        s = sample_store(store)
        s["tick"] = self._ticks.get(label, 0)
        self.series.setdefault(label, []).append(s)
        return s

    def state_dict(self) -> dict:
        return {"sample_every": self.sample_every, "series": self.series}

    def dump_json(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.state_dict(), f, indent=1, sort_keys=True)
