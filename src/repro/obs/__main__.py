"""Entry point for ``python -m repro.obs`` (see ``cli.py``)."""

from .cli import main

raise SystemExit(main())
