"""``python -m repro.obs`` — summarize/convert/verify observability dumps
(DESIGN.md §11, §13).

Works on the dump directories ``Observer.dump`` (and
``benchmarks/run.py --trace=DIR``) produce::

    summarize DIR   percentile table (p50/p95/p99) for every histogram
    convert DIR     events.json -> trace.json (Chrome trace-event JSON)
    check DIR       verify per-(shard, lane) span durations tile the
                    recorded SimIO lane clocks AND the ledger conservation
                    law (per-cause bytes sum byte-identically to the SimIO
                    per-category counters); exit 1 on mismatch
    dashboard DIR   text dashboard: lane utilization, amplification
                    breakdown, per-cause blame bars, tail exemplars,
                    top span classes
    blame DIR       per-cause write/space amplification table from
                    ledger.json; also writes blame.json next to it

DIR may be a single dump directory (contains metrics.json) or a parent
holding one dump directory per benchmark module.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from .ledger import blame_rows, check_conservation
from .metrics import LogHist
from .trace import SpanTracer, dump_chrome_trace


def _load(path):
    with open(path) as f:
        return json.load(f)


def find_dumps(root: str) -> list[str]:
    """Dump dirs under ``root`` (root itself, or its direct children)."""
    if os.path.isfile(os.path.join(root, "metrics.json")):
        return [root]
    out = []
    for name in sorted(os.listdir(root)):
        d = os.path.join(root, name)
        if os.path.isfile(os.path.join(d, "metrics.json")):
            out.append(d)
    if not out:
        raise SystemExit(f"no observability dumps under {root} "
                         "(expected metrics.json)")
    return out


def _fmt(v: float) -> str:
    if v >= 1e6:
        return f"{v / 1e6:.2f}M"
    if v >= 1e4:
        return f"{v / 1e3:.1f}k"
    return f"{v:.2f}" if isinstance(v, float) else str(v)


def _label_str(labels: dict) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(labels.items()))


def summarize(dirs: list[str], out=None) -> None:
    out = out or sys.stdout
    for d in dirs:
        metrics = _load(os.path.join(d, "metrics.json"))
        print(f"== {d} ==", file=out)
        hdr = (f"{'metric':<28} {'labels':<40} {'count':>8} {'mean':>10} "
               f"{'p50':>10} {'p95':>10} {'p99':>10}")
        print(hdr, file=out)
        for name in sorted(metrics):
            for s in metrics[name]:
                if s.get("type") != "hist":
                    continue
                mean = s["total"] / s["count"] if s["count"] else 0.0
                print(f"{name:<28} {_label_str(s['labels']):<40} "
                      f"{s['count']:>8} {_fmt(mean):>10} "
                      f"{_fmt(s['p50']):>10} {_fmt(s['p95']):>10} "
                      f"{_fmt(s['p99']):>10}", file=out)
        counters = [(n, s) for n in sorted(metrics) for s in metrics[n]
                    if s.get("type") == "counter"]
        if counters:
            print(f"{'counter':<28} {'labels':<40} {'value':>8}", file=out)
            for name, s in counters:
                print(f"{name:<28} {_label_str(s['labels']):<40} "
                      f"{s['value']:>8}", file=out)


def convert(dirs: list[str]) -> None:
    for d in dirs:
        tracer = SpanTracer.from_state(_load(os.path.join(d, "events.json")))
        out = os.path.join(d, "trace.json")
        dump_chrome_trace(tracer, out)
        print(f"{out}: {len(tracer.events)} events, "
              f"{tracer.dropped} dropped")


def check(dirs: list[str], rtol: float = 1e-6) -> int:
    """Verify span tiling: per-(shard, lane) span durations must sum to
    the recorded final lane clocks within float tolerance.  When the dump
    carries a ledger.json, also verify the §13 conservation law: per-cause
    ledger bytes must sum *byte-identically* (exact integers) to the SimIO
    per-category counters."""
    failures = 0
    for d in dirs:
        tracer = SpanTracer.from_state(_load(os.path.join(d, "events.json")))
        sums = tracer.track_sums()
        if tracer.dropped:
            print(f"{d}: SKIP ({tracer.dropped} events dropped; "
                  "tiling unverifiable)")
            continue
        dir_fail = 0
        for shard, lanes in sorted(tracer.shard_lanes.items()):
            for lane, want in lanes.items():
                got = sums.get((shard, lane), 0.0)
                ok = abs(got - want) <= rtol * max(abs(want), 1.0)
                if not ok:
                    dir_fail += 1
                    print(f"{d}: FAIL shard {shard} lane {lane}: "
                          f"spans sum to {got:.3f}us, clock {want:.3f}us")
        ledger_path = os.path.join(d, "ledger.json")
        ncauses = 0
        if os.path.isfile(ledger_path):
            state = _load(ledger_path)
            ncauses = sum(len(sh.get("cells", {}))
                          for sh in state.get("shards", {}).values())
            for msg in check_conservation(state):
                dir_fail += 1
                print(f"{d}: FAIL ledger conservation: {msg}")
        if dir_fail == 0:
            print(f"{d}: OK ({len(tracer.events)} events, "
                  f"{len(tracer.shard_lanes)} shards, {ncauses} causes)")
        failures += dir_fail
    return failures


def _bar(frac: float, width: int = 30) -> str:
    n = int(round(max(0.0, min(1.0, frac)) * width))
    return "#" * n + "-" * (width - n)


def _cause_str(row: dict) -> str:
    """Compact cause label: op<-origin [trigger/pick/policy/temp]."""
    bits = [f"{row.get('op', '?')}<-{row.get('origin', '?')}"]
    extra = [row[k] for k in ("trigger", "pick", "policy", "temp")
             if row.get(k)]
    if extra:
        bits.append("[" + "/".join(extra) + "]")
    return " ".join(bits)


def blame(dirs: list[str], out=None) -> int:
    """Per-cause amplification table from ledger.json (§13); writes the
    machine-readable rollup to blame.json next to it."""
    out = out or sys.stdout
    missing = 0
    for d in dirs:
        path = os.path.join(d, "ledger.json")
        if not os.path.isfile(path):
            print(f"{d}: no ledger.json (run with the ledger-bearing "
                  "Observer)", file=out)
            missing += 1
            continue
        state = _load(path)
        rows = blame_rows(state)
        conservation = check_conservation(state)
        bpath = os.path.join(d, "blame.json")
        with open(bpath, "w") as f:
            json.dump({"rows": rows, "conservation_failures": conservation},
                      f, indent=1, sort_keys=True)
        print(f"== {d} ==", file=out)
        total_wb = sum(r["write_bytes"] for r in rows) or 1
        print(f"{'cause':<44} {'write':>8} {'read':>8} {'wa':>6}  share",
              file=out)
        for r in rows:
            if not (r["write_bytes"] or r["read_bytes"] or r["space"]):
                continue
            share = r["write_bytes"] / total_wb
            print(f"{_cause_str(r):<44} {_fmt(float(r['write_bytes'])):>8} "
                  f"{_fmt(float(r['read_bytes'])):>8} {r['wa']:>6.3f}  "
                  f"{_bar(share, 20)} {share:5.1%}", file=out)
        space_rows = [r for r in rows if r["space"] or r["edits"]]
        if space_rows:
            print("space/edit events by cause:", file=out)
            for r in space_rows:
                evs = {**r["space"], **{f"edit:{k}": v
                                        for k, v in r["edits"].items()}}
                print(f"  {_cause_str(r):<42} " + "  ".join(
                    f"{k}={_fmt(float(v))}" for k, v in sorted(evs.items())),
                    file=out)
        status = "FAIL" if conservation else "OK"
        print(f"conservation: {status}  -> {bpath}", file=out)
        for msg in conservation:
            print(f"  {msg}", file=out)
        missing += len(conservation)
    return missing


def dashboard(dirs: list[str], out=None) -> None:
    out = out or sys.stdout
    for d in dirs:
        print(f"== {d} ==", file=out)
        health = _load(os.path.join(d, "health.json"))["series"]
        events = _load(os.path.join(d, "events.json"))
        for shard in sorted(health):
            series = health[shard]
            if not series:
                continue
            last = series[-1]
            eng = events.get("shard_meta", {}).get(shard, {}).get(
                "engine", "?")
            print(f"shard {shard} [{eng}]  clock "
                  f"{last['clock_us'] / 1e6:.3f}s  "
                  f"({len(series)} samples)", file=out)
            for lane in ("fg", "bg", "gc"):
                frac = last["lane_util"].get(lane, 0.0)
                print(f"  {lane} lane util {_bar(frac)} {frac:6.1%}",
                      file=out)
            print(f"  space_amp {last['space_amp']:.3f}  "
                  f"s_index {last['s_index']:.3f}  "
                  f"exposed/valid {last['exposed_over_valid']:.3f}  "
                  f"stall {last['stall_us'] / 1e6:.3f}s", file=out)
            mix = last.get("temp_bytes", {})
            tot = sum(mix.values()) or 1
            if mix:
                print("  vSST mix " + "  ".join(
                    f"{k}={v / tot:.0%}" for k, v in sorted(mix.items())),
                    file=out)
            gr = last.get("garbage_ratio", {})
            print(f"  garbage ratio p50 {gr.get('p50', 0):.3f}  "
                  f"p90 {gr.get('p90', 0):.3f}  "
                  f"max {gr.get('max', 0):.3f}", file=out)
        # per-cause amplification bars (§13 ledger)
        ledger_path = os.path.join(d, "ledger.json")
        if os.path.isfile(ledger_path):
            rows = [r for r in blame_rows(_load(ledger_path))
                    if r["write_bytes"]]
            total_wb = sum(r["write_bytes"] for r in rows) or 1
            if rows:
                print("write bytes by cause:", file=out)
                for r in rows[:8]:
                    share = r["write_bytes"] / total_wb
                    print(f"  {_cause_str(r):<42} {_bar(share, 20)} "
                          f"{share:5.1%} ({_fmt(float(r['write_bytes']))})",
                          file=out)
        # tail exemplars: p99 bucket -> trace id, per op-class histogram
        metrics = _load(os.path.join(d, "metrics.json"))
        tails = []
        for name in sorted(metrics):
            for s in metrics[name]:
                if s.get("type") != "hist" or not s.get("exemplars"):
                    continue
                h = LogHist.from_state(s)
                ex = h.exemplar_at(0.99)
                if ex is not None:
                    tails.append((name, _label_str(s["labels"]),
                                  s["p99"], ex))
        if tails:
            print("tail exemplars (p99 -> trace id):", file=out)
            for name, labels, p99, ex in tails[:10]:
                print(f"  {name:<24} {labels:<36} p99 {_fmt(p99):>9}  "
                      f"trace {ex}", file=out)
        # top span classes by total lane time
        totals: dict[str, float] = {}
        for ev in events.get("events", ()):
            if ev["ph"] == "X":
                totals[ev["name"]] = totals.get(ev["name"], 0.0) + ev["dur"]
        if totals:
            print("top span classes (total lane-us):", file=out)
            for name, t in sorted(totals.items(), key=lambda kv: -kv[1])[:8]:
                print(f"  {name:<16} {t:14.1f}", file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    for cmd in ("summarize", "convert", "check", "dashboard", "blame"):
        p = sub.add_parser(cmd)
        p.add_argument("dir", help="dump directory (or parent of dumps)")
    args = ap.parse_args(argv)
    dirs = find_dumps(args.dir)
    if args.cmd == "summarize":
        summarize(dirs)
    elif args.cmd == "convert":
        convert(dirs)
    elif args.cmd == "dashboard":
        dashboard(dirs)
    elif args.cmd == "blame":
        return 1 if blame(dirs) else 0
    else:
        return 1 if check(dirs) else 0
    return 0


if __name__ == "__main__":          # pragma: no cover - exercised via main()
    raise SystemExit(main())
