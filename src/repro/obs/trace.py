"""Span tracer on the simulated two-lane clock (DESIGN.md §11).

Spans are recorded against the *simulated* per-lane clocks
(``SimIO.lanes``), not wall time: a span's ``ts``/``dur`` are the lane
clock at begin and the lane time it consumed.  Core instrumentation
guarantees the tiling invariant — on every (shard, lane) track the
recorded span durations sum to that shard's final ``io.lanes[lane]``
(lane jumps from scheduler synchronization are themselves recorded as
``lane_sync`` spans) — which is what lets ``make trace`` cross-check the
exported trace against the device counters.

Events live in a bounded ring buffer (oldest dropped first, drops
counted) and export as Chrome trace-event JSON: one process per shard,
one thread per lane, viewable in Perfetto / chrome://tracing.
"""

from __future__ import annotations

import json
from collections import deque

LANE_TIDS = {"fg": 0, "bg": 1, "gc": 2}

# Default event cap: large enough that the bench workloads never drop
# (dropping would break the track-sum cross-check), small enough to bound
# memory at ~a few hundred MB worst case.
DEFAULT_CAP = 1 << 20


class SpanTracer:
    """Bounded ring buffer of span ("X") and instant ("i") events."""

    def __init__(self, cap: int = DEFAULT_CAP):
        self.cap = int(cap)
        self.events: deque = deque(maxlen=self.cap)
        self.dropped = 0
        # final per-shard lane clocks, filled by Observer.finish_store()
        self.shard_lanes: dict[str, dict] = {}
        self.shard_meta: dict[str, dict] = {}

    def add(self, ev: dict) -> None:
        if len(self.events) == self.cap:
            self.dropped += 1
        self.events.append(ev)

    def span(self, name, lane, shard, ts, dur, args=None,
             span_id=None, parent_id=None, trace_id=None) -> None:
        ev = {"name": name, "ph": "X", "lane": lane, "shard": str(shard),
              "ts": ts, "dur": dur}
        if span_id:
            ev["id"] = span_id
        if parent_id:
            ev["parent"] = parent_id
        if trace_id:
            ev["trace"] = trace_id
        if args:
            ev["args"] = args
        self.add(ev)

    def instant(self, name, lane, shard, ts, args=None,
                trace_id=None) -> None:
        ev = {"name": name, "ph": "i", "lane": lane, "shard": str(shard),
              "ts": ts}
        if trace_id:
            ev["trace"] = trace_id
        if args:
            ev["args"] = args
        self.add(ev)

    # ------------------------------------------------------------ summaries
    def track_sums(self) -> dict:
        """Sum of span durations per (shard, lane) — the tiling check."""
        out: dict[tuple, float] = {}
        for ev in self.events:
            if ev["ph"] != "X":
                continue
            key = (ev["shard"], ev["lane"])
            out[key] = out.get(key, 0.0) + ev["dur"]
        return out

    def state_dict(self) -> dict:
        return {
            "cap": self.cap,
            "dropped": self.dropped,
            "shard_lanes": self.shard_lanes,
            "shard_meta": self.shard_meta,
            "events": list(self.events),
        }

    def dump_json(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.state_dict(), f)

    @classmethod
    def from_state(cls, state: dict) -> "SpanTracer":
        t = cls(cap=state.get("cap", DEFAULT_CAP))
        t.dropped = state.get("dropped", 0)
        t.shard_lanes = state.get("shard_lanes", {})
        t.shard_meta = state.get("shard_meta", {})
        for ev in state.get("events", ()):
            t.add(ev)
        return t


def chrome_trace(tracer: SpanTracer) -> dict:
    """Convert a tracer to Chrome trace-event JSON (Perfetto-viewable).

    One process per shard, one thread per lane; ts/dur are the simulated
    lane clocks in microseconds, which Chrome's unit happens to match.
    """
    shards = sorted({ev["shard"] for ev in tracer.events}
                    | set(tracer.shard_lanes))
    pid_of = {s: i for i, s in enumerate(shards)}
    out = []
    for s in shards:
        meta = tracer.shard_meta.get(s, {})
        pname = f"shard {s}"
        if meta.get("engine"):
            pname += f" [{meta['engine']}]"
        out.append({"name": "process_name", "ph": "M", "pid": pid_of[s],
                    "tid": 0, "args": {"name": pname}})
        out.append({"name": "process_sort_index", "ph": "M",
                    "pid": pid_of[s], "tid": 0,
                    "args": {"sort_index": pid_of[s]}})
        for lane, tid in LANE_TIDS.items():
            out.append({"name": "thread_name", "ph": "M", "pid": pid_of[s],
                        "tid": tid, "args": {"name": f"{lane} lane"}})
            out.append({"name": "thread_sort_index", "ph": "M",
                        "pid": pid_of[s], "tid": tid,
                        "args": {"sort_index": tid}})
    for ev in tracer.events:
        ce = {"name": ev["name"], "ph": ev["ph"],
              "pid": pid_of[ev["shard"]], "tid": LANE_TIDS[ev["lane"]],
              "ts": ev["ts"], "cat": ev["lane"]}
        if ev["ph"] == "X":
            ce["dur"] = ev["dur"]
        else:
            ce["s"] = "t"          # instant scope: thread
        if "args" in ev:
            ce["args"] = dict(ev["args"])
        # span identity rides in args so Perfetto's query/search can find
        # a LogHist exemplar's trace id (round-trip tested)
        for src, dst in (("id", "span_id"), ("parent", "parent_id"),
                         ("trace", "trace_id")):
            if src in ev:
                ce.setdefault("args", {})[dst] = ev[src]
        out.append(ce)
    return {
        "traceEvents": out,
        "displayTimeUnit": "ms",
        "otherData": {
            "clock": "simulated lane clocks (SimIO.lanes), us",
            "dropped": tracer.dropped,
            "shard_lanes": tracer.shard_lanes,
        },
    }


def dump_chrome_trace(tracer: SpanTracer, path) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer), f)
