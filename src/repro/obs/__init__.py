"""Observability subsystem: tracing, metrics, and health on the simulated
two-lane clock (DESIGN.md §11).

Three composable pieces behind one ``Observer`` hook object:

  * ``trace``   — span tracer on the per-lane simulated clocks, ring-
                  buffered, exportable as Chrome trace-event JSON
  * ``metrics`` — counters / gauges / mergeable log-bucket histograms
                  (p50/p95/p99 per op class, per-engine/per-shard labels)
  * ``health``  — periodic derived snapshots (space amp, s_index, vSST
                  temperature mix, garbage distribution, lane utilization)

Two more pieces make causality first-class (DESIGN.md §13):

  * ``causality`` — deterministic span ids with parent/child links and
                    trace ids (request-scoped tracing)
  * ``ledger``    — the amplification attribution ledger: every SimIO
                    byte charged to a cause record, conserved
                    byte-identically against the per-category counters

Attach via ``EngineConfig(observer=Observer())``; the default
``NullObserver`` keeps observability-off runs byte-identical to
un-instrumented ones.  This package must stay import-free of
``repro.core`` (the core imports it) — I/O category names are plain
strings here for that reason.
"""

from .causality import Causality, Frame
from .health import HealthSampler, sample_store
from .ledger import (AmplificationLedger, blame_rows, cause_key,
                     check_conservation, live_breakdown, parse_cause)
from .metrics import Counter, Gauge, LogHist, MetricsRegistry
from .observer import NULL_OBSERVER, NullObserver, Observer
from .trace import SpanTracer, chrome_trace, dump_chrome_trace

__all__ = ["AmplificationLedger", "Causality", "Counter", "Frame", "Gauge",
           "HealthSampler", "LogHist", "MetricsRegistry", "NULL_OBSERVER",
           "NullObserver", "Observer", "SpanTracer", "blame_rows",
           "cause_key", "check_conservation", "chrome_trace",
           "dump_chrome_trace", "live_breakdown", "parse_cause",
           "sample_store"]
