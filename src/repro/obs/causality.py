"""Request-scoped causality: span ids, parent/child links, and the cause
stack behind the amplification ledger (DESIGN.md §13).

Two small pieces of shared state, both owned by the ``Observer``:

  * **Span identity** — every span gets a monotonically increasing
    ``id``; nesting follows the (synchronous) Python call stack, so the
    parent of a span is simply the span that was open when it began.  A
    span opened with an empty stack starts a new *trace*; children
    inherit the trace id.  Because the simulator is single-threaded,
    this gives exact request-scoped traces: a GC job force-run inside a
    stalled ``write`` is a *child* of that write's span, which is how a
    stalled op shows the background job that blocked it.
  * **Origin** — the op class of the innermost (or, when the stack is
    empty, the most recent) user operation.  Background work scheduled
    synchronously after an op (``pump()``) is attributed to that op: the
    deterministic two-lane scheduler only runs background jobs in
    response to foreground progress, so "most recent user op" *is* the
    causal trigger.  A cause scope may pin an explicit origin (e.g. the
    serving tier's admission writes), which user-op spans then do not
    override.

Ids are allocated deterministically (a counter, no wall clock), so traces
are reproducible run-to-run.
"""

from __future__ import annotations

# Foreground op classes that (re)set the causal origin.
USER_OPS = ("write", "multi_get", "multi_scan")


class Frame:
    """One open span: identity plus the ledger token to restore on exit."""

    __slots__ = ("span_id", "parent_id", "trace_id", "token", "label")

    def __init__(self, span_id: int, parent_id: int, trace_id: int):
        self.span_id = span_id
        self.parent_id = parent_id
        self.trace_id = trace_id
        self.token = None
        self.label = None


class Causality:
    """Deterministic span-id allocator + global synchronous span stack."""

    def __init__(self):
        self._next_id = 1
        self.stack: list[Frame] = []
        self.origin = "init"

    def push(self) -> Frame:
        sid = self._next_id
        self._next_id += 1
        if self.stack:
            top = self.stack[-1]
            frame = Frame(sid, top.span_id, top.trace_id)
        else:
            frame = Frame(sid, 0, sid)
        self.stack.append(frame)
        return frame

    def pop(self, frame: Frame) -> None:
        if self.stack and self.stack[-1] is frame:
            self.stack.pop()
        elif frame in self.stack:       # defensive: out-of-order exit
            self.stack.remove(frame)

    def current_trace(self) -> int:
        """Trace id of the innermost open span (0 when idle)."""
        return self.stack[-1].trace_id if self.stack else 0

    def note_user_op(self, name: str) -> None:
        if name in USER_OPS:
            self.origin = name
