"""Observer hook surface wired through the core (DESIGN.md §11, §13).

``Store``/``ShardedStore``/``FleetScheduler``/``ServeEngine`` call one
hook object — ``EngineConfig.observer`` — at every instrumentation point.
The default ``NullObserver`` makes each hook a constant-time no-op that
never reads or writes the simulated device, so observability-off runs are
byte-identical to un-instrumented ones (golden-locked in
``tests/test_obs.py``).

``Observer`` is the real implementation: spans/instants go to a
``SpanTracer`` on the simulated lane clocks, scalar observations to a
``MetricsRegistry`` (per-engine/per-shard labels), periodic derived
snapshots to a ``HealthSampler``, and — §13 — every span doubles as a
*cause frame*: spans carry parent/child links and a trace id
(``causality.py``), and byte deltas between frame boundaries are charged
to the innermost cause in the ``AmplificationLedger`` (``ledger.py``),
which conserves byte-identically against the SimIO counters.

No-op contract (enforced by the ``obs-purity`` scavlint pass): hook code
may *read* store and SimIO state freely but must never advance a lane
clock, charge simulated I/O, or mutate store-rooted state — observability
is a tap, not a participant.
"""

from __future__ import annotations

import contextlib
import os

from .causality import USER_OPS, Causality
from .health import HealthSampler
from .ledger import AmplificationLedger
from .metrics import MetricsRegistry
from .trace import DEFAULT_CAP, SpanTracer, dump_chrome_trace

_NULL_CTX = contextlib.nullcontext()

# Byte/op counter fields snapshotted around a span to attach per-category
# payload deltas (names mirror SimIO's counters).
_IO_FIELDS = ("read_bytes", "write_bytes", "read_ops", "write_ops")


class NullObserver:
    """No-op observer: the default.  Every hook returns immediately; the
    span and cause hooks hand back one shared, reusable null context."""

    enabled = False

    def register_store(self, store) -> str:
        return "0"

    def span(self, store, name, lane="fg", **args):
        return _NULL_CTX

    def instant(self, store, name, lane="fg", **args) -> None:
        pass

    def lane_sync(self, store, lane, t0) -> None:
        pass

    def cause(self, store, **fields):
        return _NULL_CTX

    def on_op(self, store, name, value) -> None:
        pass

    def on_count(self, store, name, n=1) -> None:
        pass

    def on_stall(self, store, us, kind) -> None:
        pass

    def on_space(self, store, event, nbytes) -> None:
        pass

    def on_edit(self, store, kind, nbytes) -> None:
        pass

    def tick(self, store) -> None:
        pass


NULL_OBSERVER = NullObserver()


class _Span:
    """Context manager recording one span against a lane clock.

    ``dur`` is the *lane-time* delta, so nested work on other lanes (a
    ``pump()`` inside a foreground op) never pollutes this track — the
    per-(shard, lane) tiling invariant (see ``trace.py``) depends on it.
    On enter the span also becomes a causality frame (id/parent/trace) and
    a ledger cause scope (§13)."""

    __slots__ = ("obs", "store", "name", "lane", "args", "t0", "io0",
                 "frame")

    def __init__(self, obs, store, name, lane, args):
        self.obs = obs
        self.store = store
        self.name = name
        self.lane = lane
        self.args = args

    def __enter__(self):
        io = self.store.io
        self.t0 = io.lanes[self.lane]
        self.io0 = {f: dict(getattr(io, f)) for f in _IO_FIELDS}
        self.frame = self.obs._begin_span(self.store, self.name, self.lane,
                                          self.args)
        return self

    def __exit__(self, *exc):
        io = self.store.io
        t1 = io.lanes[self.lane]
        args = dict(self.args) if self.args else {}
        for f in _IO_FIELDS:
            before = self.io0[f]
            d = {k: v - before.get(k, 0)
                 for k, v in getattr(io, f).items() if v != before.get(k, 0)}
            if d:
                args[f] = d
        self.obs._end_span(self.store, self.name, self.lane, self.t0,
                           t1 - self.t0, args or None, self.frame)
        return False


class _Cause:
    """Ledger-only cause scope (no span event): fine-grained attribution
    inside a job frame — e.g. per-temperature vSST builds (§13)."""

    __slots__ = ("obs", "store", "fields", "token")

    def __init__(self, obs, store, fields):
        self.obs = obs
        self.store = store
        self.fields = fields

    def __enter__(self):
        self.token = self.obs.ledger.push(
            self.obs._label(self.store), self.store.io, self.fields,
            pin="origin" in self.fields)
        return self

    def __exit__(self, *exc):
        self.obs.ledger.pop(self.obs._label(self.store), self.store.io,
                            self.token)
        return False


class Observer(NullObserver):
    """Tracing + metrics + health + causal ledger, on the simulated
    clocks."""

    enabled = True

    def __init__(self, cap: int = DEFAULT_CAP, sample_every: int = 64,
                 health: HealthSampler | None = None):
        self.tracer = SpanTracer(cap=cap)
        self.metrics = MetricsRegistry()
        self.health = health or HealthSampler(sample_every=sample_every)
        self.ledger = AmplificationLedger()
        self.causality = Causality()
        self._stores: dict[str, object] = {}

    # ------------------------------------------------------------- registry
    def register_store(self, store) -> str:
        label = str(len(self._stores))
        self._stores[label] = store
        self.tracer.shard_meta[label] = {"engine": store.cfg.engine}
        self.ledger.register(label, store.io)
        return label

    def _label(self, store) -> str:
        return getattr(store, "obs_label", "0")

    def _labels(self, store) -> dict:
        return {"engine": store.cfg.engine, "shard": self._label(store)}

    # ---------------------------------------------------------------- spans
    def span(self, store, name, lane="fg", **args):
        return _Span(self, store, name, lane, args)

    def _begin_span(self, store, name, lane, args):
        frame = self.causality.push()
        self.causality.note_user_op(name)
        overrides = {"op": name}
        cause = args.get("cause") if args else None
        if cause:
            overrides.update(cause)
        if name in USER_OPS:
            overrides.setdefault("trigger", "user")
        frame.label = self._label(store)
        frame.token = self.ledger.push(frame.label, store.io, overrides,
                                       global_origin=self.causality.origin)
        return frame

    def _end_span(self, store, name, lane, ts, dur, args, frame) -> None:
        self.ledger.pop(frame.label, store.io, frame.token)
        self.causality.pop(frame)
        self.tracer.span(name, lane, self._label(store), ts, dur, args,
                         span_id=frame.span_id, parent_id=frame.parent_id,
                         trace_id=frame.trace_id)
        self.metrics.hist(f"{name}_us", **self._labels(store)).record(
            dur, exemplar=frame.trace_id)

    def instant(self, store, name, lane="fg", **args) -> None:
        self.tracer.instant(name, lane, self._label(store),
                            store.io.lanes[lane], args or None,
                            trace_id=self.causality.current_trace())

    def lane_sync(self, store, lane, t0) -> None:
        """A scheduler jumped ``lane``'s clock from ``t0`` to its current
        value (stall service / drain barrier); record the jump as a span so
        the track still tiles the lane clock."""
        t1 = store.io.lanes[lane]
        if t1 > t0:
            self.tracer.span("lane_sync", lane, self._label(store), t0,
                             t1 - t0,
                             trace_id=self.causality.current_trace())

    # ---------------------------------------------------------- cause scopes
    def cause(self, store, **fields):
        return _Cause(self, store, fields)

    # -------------------------------------------------------------- metrics
    def on_op(self, store, name, value) -> None:
        self.metrics.hist(name, **self._labels(store)).record(
            value, exemplar=self.causality.current_trace() or None)

    def on_count(self, store, name, n=1) -> None:
        self.metrics.counter(name, **self._labels(store)).inc(n)

    def on_stall(self, store, us, kind) -> None:
        if us > 0:
            labels = self._labels(store)
            self.metrics.hist("stall_us", **labels).record(
                us, exemplar=self.causality.current_trace() or None)
            self.metrics.counter("stalls", kind=kind, **labels).inc()

    # --------------------------------------------------------------- ledger
    def on_space(self, store, event, nbytes) -> None:
        self.ledger.charge_space(self._label(store), event, nbytes)

    def on_edit(self, store, kind, nbytes) -> None:
        self.ledger.charge_edit(self._label(store), kind, nbytes)

    # --------------------------------------------------------------- health
    def tick(self, store) -> None:
        self.health.tick(store, self._label(store))

    # ------------------------------------------------------------ reporting
    def finish(self) -> None:
        """Record final per-shard lane clocks (the tiling reference), the
        final SimIO counter snapshots (the ledger conservation reference),
        and a last health sample for every registered store."""
        for label, store in self._stores.items():
            self.tracer.shard_lanes[label] = dict(store.io.lanes)
            self.ledger.finish(label, store.io, meta={
                "engine": store.cfg.engine,
                "user_write_bytes": store.user_write_bytes,
                "valid_bytes": store.valid_bytes,
                "space_bytes": store.space_bytes(),
            })
            self.health.sample(store, label)

    def dump(self, outdir, chrome: bool = True) -> dict:
        """Write events.json / metrics.json / health.json / ledger.json
        (and trace.json, the Chrome trace-event conversion) under
        ``outdir``."""
        self.finish()
        os.makedirs(outdir, exist_ok=True)
        paths = {}
        paths["events"] = os.path.join(outdir, "events.json")
        self.tracer.dump_json(paths["events"])
        paths["metrics"] = os.path.join(outdir, "metrics.json")
        self.metrics.dump_json(paths["metrics"])
        paths["health"] = os.path.join(outdir, "health.json")
        self.health.dump_json(paths["health"])
        paths["ledger"] = os.path.join(outdir, "ledger.json")
        self.ledger.dump_json(paths["ledger"])
        if chrome:
            paths["trace"] = os.path.join(outdir, "trace.json")
            dump_chrome_trace(self.tracer, paths["trace"])
        return paths
