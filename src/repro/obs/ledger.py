"""Amplification attribution ledger: every simulated byte gets a cause
(DESIGN.md §13).

The ledger decomposes write/read amplification *by cause*: each cell is
keyed by a **cause record** — ``origin`` (the user op class that caused
the work), ``op`` (the job or path doing the I/O: write, flush, compact,
gc, vsst_build, blob_reloc, …), ``trigger`` (the scheduling decision:
user, lane_budget, memtable_stall, l0_stop, quota_stall, drain, …),
plus optional ``pick`` (the policy that chose the job: compensated_size /
physical_size / garbage_ratio / adaptive_dead_byte), ``policy`` (fleet
scheduler) and ``temp`` (temperature class of the written file).

Attribution is *exclusive* (self-cost style): per registered store there
is one current cause; pushing/popping a cause settles the byte counters
accumulated since the last boundary into the cause that was active.
Because settlement reads the same integer ``SimIO`` per-category
counters the device maintains, the decomposition obeys a machine-checked
**conservation law**: for every (shard, category) the per-cause ledger
bytes sum *byte-identically* to ``final − base`` of the SimIO counter —
the same tiling-style invariant §11 enforces for span durations on the
lane clocks.  ``python -m repro.obs check`` verifies it on every dump.

Space events (garbage exposed, GC rewrite/reclaim, vSST adds, value-file
retirements) and host-side MANIFEST edit bytes ride on the same cause
keys, so space amplification decomposes by cause next to write amp
(``python -m repro.obs blame``).

The ledger is observer-local state: it *reads* SimIO counters and never
touches the store (the §11 obs-purity contract), so runs with the ledger
enabled stay byte-identical to unobserved runs.
"""

from __future__ import annotations

import json

# Conservation-checked SimIO counter fields (all integer-valued).
COUNTER_FIELDS = ("read_bytes", "write_bytes", "read_ops", "write_ops")

ROOT_CAUSE = {"origin": "init", "op": "init", "trigger": "init"}


def cause_key(cause: dict) -> str:
    """Canonical string form of a cause record (stable across runs)."""
    return "|".join(f"{k}={cause[k]}" for k in sorted(cause))


def parse_cause(key: str) -> dict:
    return dict(part.split("=", 1) for part in key.split("|") if part)


class Cell:
    """Per-(shard, cause) accumulator: I/O counters + space/edit events."""

    __slots__ = COUNTER_FIELDS + ("space", "edits")

    def __init__(self):
        for f in COUNTER_FIELDS:
            setattr(self, f, {})
        self.space: dict[str, int] = {}
        self.edits: dict[str, int] = {}

    def state_dict(self) -> dict:
        out = {f: dict(getattr(self, f)) for f in COUNTER_FIELDS
               if getattr(self, f)}
        if self.space:
            out["space"] = dict(self.space)
        if self.edits:
            out["edits"] = dict(self.edits)
        return out


class AmplificationLedger:
    """Byte-exact cause attribution over the SimIO per-category counters."""

    def __init__(self):
        # label -> cause_key -> Cell
        self.cells: dict[str, dict[str, Cell]] = {}
        self.base: dict[str, dict] = {}         # counters at registration
        self.final: dict[str, dict] = {}        # counters at finish()
        self.meta: dict[str, dict] = {}         # per-store derived stats
        self._cur: dict[str, tuple[dict, bool]] = {}   # label -> (cause, pin)
        self._ckpt: dict[str, dict] = {}        # label -> last-settled view

    # ------------------------------------------------------------ lifecycle
    @staticmethod
    def _counters(io) -> dict:
        return {f: dict(getattr(io, f)) for f in COUNTER_FIELDS}

    def register(self, label: str, io) -> None:
        snap = self._counters(io)
        self.base[label] = {f: dict(v) for f, v in snap.items()}
        self._ckpt[label] = snap
        self._cur[label] = (dict(ROOT_CAUSE), False)
        self.cells.setdefault(label, {})

    def _cell(self, label: str, cause: dict) -> Cell:
        key = cause_key(cause)
        cell = self.cells[label].get(key)
        if cell is None:
            cell = self.cells[label][key] = Cell()
        return cell

    def settle(self, label: str, io) -> None:
        """Charge counter deltas since the last boundary to the current
        cause.  Integer adds only — conservation is exact by construction."""
        ckpt = self._ckpt.get(label)
        if ckpt is None:
            return
        cause, _ = self._cur[label]
        cell = None
        for f in COUNTER_FIELDS:
            now = getattr(io, f)
            before = ckpt[f]
            for cat, v in now.items():
                d = v - before.get(cat, 0)
                if d:
                    if cell is None:
                        cell = self._cell(label, cause)
                    bucket = getattr(cell, f)
                    bucket[cat] = bucket.get(cat, 0) + d
                before[cat] = v

    # --------------------------------------------------------- cause frames
    def push(self, label: str, io, overrides: dict,
             global_origin: str | None = None, pin: bool = False):
        """Enter a cause scope; returns a token for ``pop``.

        ``overrides`` merge over the store's current cause; when
        ``global_origin`` is given and the current origin is not pinned,
        the merged cause's origin is refreshed from it (span-push rule —
        background jobs are attributed to the live user op)."""
        prev = self._cur.get(label)
        if prev is None:                # unregistered store: no-op token
            return None
        self.settle(label, io)
        cur, pinned = prev
        merged = dict(cur)
        if global_origin is not None and not pinned:
            merged["origin"] = global_origin
        merged.update(overrides)
        self._cur[label] = (merged, pinned or pin or "origin" in overrides)
        return prev

    def pop(self, label: str, io, token) -> None:
        if token is None:
            return
        self.settle(label, io)
        self._cur[label] = token

    # --------------------------------------------------------- side ledgers
    def charge_space(self, label: str, event: str, nbytes: int) -> None:
        cur = self._cur.get(label)
        if cur is None or nbytes == 0:
            return
        cell = self._cell(label, cur[0])
        cell.space[event] = cell.space.get(event, 0) + int(nbytes)

    def charge_edit(self, label: str, kind: str, nbytes: int) -> None:
        cur = self._cur.get(label)
        if cur is None:
            return
        cell = self._cell(label, cur[0])
        cell.edits[kind] = cell.edits.get(kind, 0) + int(nbytes)

    # ------------------------------------------------------------ reporting
    def finish(self, label: str, io, meta: dict | None = None) -> None:
        self.settle(label, io)
        self.final[label] = self._counters(io)
        if meta is not None:
            self.meta[label] = meta

    def state_dict(self) -> dict:
        shards = {}
        for label in sorted(self.cells):
            shards[label] = {
                "base": self.base.get(label, {}),
                "final": self.final.get(label, {}),
                "meta": self.meta.get(label, {}),
                "cells": {k: c.state_dict()
                          for k, c in sorted(self.cells[label].items())},
            }
        return {"shards": shards}

    def dump_json(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.state_dict(), f, indent=1, sort_keys=True)


# ===================================================== conservation check
def check_conservation(state: dict) -> list[str]:
    """Verify the ledger conservation law on a ``ledger.json`` state:
    per (shard, category) the cause cells must sum *exactly* (integer
    equality, no tolerance) to ``final − base`` of the SimIO counter.
    Returns a list of human-readable failures (empty = pass)."""
    failures = []
    for label, sh in sorted(state.get("shards", {}).items()):
        final, base = sh.get("final", {}), sh.get("base", {})
        if not final:
            failures.append(f"shard {label}: no final counter snapshot "
                            "(finish() never ran)")
            continue
        for f in COUNTER_FIELDS:
            want = {cat: v - base.get(f, {}).get(cat, 0)
                    for cat, v in final.get(f, {}).items()}
            got: dict[str, int] = {}
            for cell in sh.get("cells", {}).values():
                for cat, v in cell.get(f, {}).items():
                    got[cat] = got.get(cat, 0) + v
            for cat in sorted(set(want) | set(got)):
                w, g = want.get(cat, 0), got.get(cat, 0)
                if w != g:
                    failures.append(
                        f"shard {label}: {f}[{cat}] ledger sums to {g}, "
                        f"SimIO counted {w}")
    return failures


# ============================================================ blame rollup
def blame_rows(state: dict) -> list[dict]:
    """Aggregate ledger cells across shards into per-cause rows with
    write-amp / space-event decompositions (the ``obs blame`` table).

    ``wa`` is the cause's share of write amplification: cause write bytes
    over total user write bytes (WAL excluded, matching ``stats()``)."""
    user_wb = sum(m.get("user_write_bytes", 0)
                  for m in (sh.get("meta", {})
                            for sh in state.get("shards", {}).values()))
    agg: dict[str, dict] = {}
    for sh in state.get("shards", {}).values():
        for key, cell in sh.get("cells", {}).items():
            row = agg.setdefault(key, {"write_bytes": 0, "read_bytes": 0,
                                       "space": {}, "edits": {}})
            row["write_bytes"] += sum(cell.get("write_bytes", {}).values())
            row["read_bytes"] += sum(cell.get("read_bytes", {}).values())
            for name, field in (("space", "space"), ("edits", "edits")):
                for k, v in cell.get(field, {}).items():
                    row[name][k] = row[name].get(k, 0) + v
    rows = []
    for key in sorted(agg):
        row = agg[key]
        cause = parse_cause(key)
        wal = cause.get("op") in ("write",)     # user writes carry the WAL
        rows.append({
            "cause": key,
            **cause,
            "write_bytes": row["write_bytes"],
            "read_bytes": row["read_bytes"],
            "wa": (row["write_bytes"] / user_wb) if user_wb and not wal
            else 0.0,
            "space": row["space"],
            "edits": row["edits"],
        })
    rows.sort(key=lambda r: -(r["write_bytes"] + r["read_bytes"]))
    return rows


# ===================================================== live benchmark view
def live_breakdown(observer, store) -> dict:
    """Settle and roll up the ledger for one (possibly sharded) live store:
    write bytes per ``op`` cause class + space-event totals.  Read-only on
    the store (obs-purity §11); used by ``benchmarks/fig05`` for the
    live-ledger column next to the paper's analytical decomposition."""
    ledger = observer.ledger
    shards = getattr(store, "shards", None) or [store]
    labels = []
    for s in shards:
        label = getattr(s, "obs_label", None)
        if label in ledger.cells:
            ledger.settle(label, s.io)
            labels.append(label)
    by_op: dict[str, int] = {}
    by_pick: dict[str, int] = {}
    space: dict[str, int] = {}
    for label in labels:
        for key, cell in ledger.cells[label].items():
            cause = parse_cause(key)
            wb = sum(cell.write_bytes.values())
            op = cause.get("op", "?")
            by_op[op] = by_op.get(op, 0) + wb
            pick = cause.get("pick")
            if pick:
                by_pick[pick] = by_pick.get(pick, 0) + wb
            for k, v in cell.space.items():
                space[k] = space.get(k, 0) + v
    return {"write_bytes_by_op": by_op, "write_bytes_by_pick": by_pick,
            "space_events": space}
