"""Counters, gauges, and mergeable log-bucket histograms (DESIGN.md §11).

The registry gives every op class a tail-latency story: histograms bucket
values into quarter-octave (``2**(1/NSUB)``-spaced) bins whose bounds are
exact binary floats, so recording, merging, and quantile extraction are
deterministic across shards and across merge orders.  Quantiles are upper
bounds: ``quantile(q)`` returns the upper edge of the bucket holding the
empirical q-quantile (clamped to the observed max), so the estimate ``e``
of a true positive quantile ``t`` satisfies ``t <= e <= t * (1 + 1/NSUB)``.

Merging adds integer bucket counts, which is exactly associative — the
property tests in ``tests/test_obs.py`` lean on this to let per-shard
registries collapse into a fleet view in any order.
"""

from __future__ import annotations

import json
import math

# Quarter-octave buckets: each bucket spans a 2**(1/4)-ish ratio; the
# relative quantile overestimate is bounded by 1/NSUB = 25%.
NSUB = 4


def bucket_index(value: float) -> int:
    """Map a positive float to its log-bucket index (exact, via frexp)."""
    m, e = math.frexp(value)          # value = m * 2**e, m in [0.5, 1)
    sub = int((m - 0.5) * 2 * NSUB)   # 0..NSUB-1, exact for binary floats
    if sub >= NSUB:                   # guard m == 1.0-ulp rounding
        sub = NSUB - 1
    return e * NSUB + sub


def bucket_upper(idx: int) -> float:
    """Exact upper bound of bucket ``idx``: ``(NSUB+sub+1) * 2**(e-3)``."""
    e, sub = divmod(idx, NSUB)
    return (NSUB + sub + 1) * math.ldexp(1.0, e) / (2 * NSUB)


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n=1):
        self.value += n

    def state_dict(self):
        return {"type": "counter", "value": self.value}


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v):
        self.value = v

    def state_dict(self):
        return {"type": "gauge", "value": self.value}


class LogHist:
    """Mergeable log-bucket histogram over non-negative floats.

    Values ``<= 0`` land in a dedicated zero bucket (stall times and byte
    deltas are frequently exactly zero); positive values go to quarter-
    octave buckets with exact binary bounds (see module docstring).

    Each positive bucket can keep one **exemplar** — an opaque id (the
    observer stores the trace id of the span whose value landed there,
    DESIGN.md §13) with last-observation-wins semantics, so a tail
    quantile links back to a concrete span in the Chrome trace export.
    """

    __slots__ = ("buckets", "zeros", "count", "total", "vmin", "vmax",
                 "exemplars")

    def __init__(self):
        self.buckets: dict[int, int] = {}
        self.zeros = 0
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.exemplars: dict[int, object] = {}

    def record(self, value: float, n: int = 1, exemplar=None):
        value = float(value)
        self.count += n
        self.total += value * n
        if value < self.vmin:
            self.vmin = value
        if value > self.vmax:
            self.vmax = value
        if value <= 0.0:
            self.zeros += n
        else:
            idx = bucket_index(value)
            self.buckets[idx] = self.buckets.get(idx, 0) + n
            if exemplar is not None:
                self.exemplars[idx] = exemplar

    def merge(self, other: "LogHist") -> "LogHist":
        self.count += other.count
        self.total += other.total
        self.zeros += other.zeros
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        for idx, n in other.buckets.items():
            self.buckets[idx] = self.buckets.get(idx, 0) + n
        for idx, ex in other.exemplars.items():
            self.exemplars.setdefault(idx, ex)
        return self

    def quantile(self, q: float) -> float:
        """Upper-bound estimate of the empirical q-quantile.

        Walks buckets in value order until the cumulative count reaches
        ``ceil(q * count)``; returns that bucket's upper edge clamped to
        the observed [min, max] envelope.  Returns 0.0 on empty.
        """
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(q * self.count))
        seen = self.zeros
        if seen >= rank:
            return min(max(0.0, self.vmin), self.vmax)
        for idx in sorted(self.buckets):
            seen += self.buckets[idx]
            if seen >= rank:
                return max(self.vmin, min(bucket_upper(idx), self.vmax))
        return self.vmax

    def exemplar_at(self, q: float):
        """Exemplar id nearest the empirical q-quantile's bucket.

        Prefers the quantile bucket itself, then walks down (faster ops),
        then up; returns None when no record carried an exemplar or the
        quantile lands in the zero bucket.
        """
        if self.count == 0 or not self.exemplars:
            return None
        rank = max(1, math.ceil(q * self.count))
        seen = self.zeros
        if seen >= rank:
            return None
        idxs = sorted(self.buckets)
        hit = idxs[-1]
        for idx in idxs:
            seen += self.buckets[idx]
            if seen >= rank:
                hit = idx
                break
        below = [i for i in idxs if i <= hit]
        above = [i for i in idxs if i > hit]
        for idx in list(reversed(below)) + above:
            ex = self.exemplars.get(idx)
            if ex is not None:
                return ex
        return None

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def state_dict(self):
        out = {
            "type": "hist",
            "count": self.count,
            "total": self.total,
            "zeros": self.zeros,
            "min": self.vmin if self.count else None,
            "max": self.vmax if self.count else None,
            "buckets": {str(k): v for k, v in sorted(self.buckets.items())},
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }
        if self.exemplars:
            out["exemplars"] = {str(k): v for k, v
                                in sorted(self.exemplars.items())}
        return out

    @classmethod
    def from_state(cls, state: dict) -> "LogHist":
        h = cls()
        h.count = state["count"]
        h.total = state["total"]
        h.zeros = state["zeros"]
        h.vmin = math.inf if state["min"] is None else state["min"]
        h.vmax = -math.inf if state["max"] is None else state["max"]
        h.buckets = {int(k): v for k, v in state["buckets"].items()}
        h.exemplars = {int(k): v for k, v
                       in state.get("exemplars", {}).items()}
        return h


def _key(name: str, labels: dict) -> tuple:
    return (name,) + tuple(sorted(labels.items()))


class MetricsRegistry:
    """Name + label keyed collection of counters/gauges/histograms.

    Labels are free-form (``engine=..., shard=...``); each distinct label
    set is an independent series.  ``merged(name)`` collapses a histogram
    across all label sets for fleet-level percentiles.
    """

    def __init__(self):
        self._series: dict[tuple, object] = {}

    def _get(self, cls, name, labels):
        key = _key(name, labels)
        m = self._series.get(key)
        if m is None:
            m = self._series[key] = cls()
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def hist(self, name: str, **labels) -> LogHist:
        return self._get(LogHist, name, labels)

    def merged(self, name: str) -> LogHist:
        """Histogram for ``name`` merged across every label set."""
        out = LogHist()
        for (nm, *_), m in self._series.items():
            if nm == name and isinstance(m, LogHist):
                out.merge(m)
        return out

    def names(self) -> list[str]:
        return sorted({k[0] for k in self._series})

    def state_dict(self) -> dict:
        out = {}
        for key, m in sorted(self._series.items(), key=lambda kv: kv[0]):
            name, *labels = key
            out.setdefault(name, []).append(
                {"labels": dict(labels), **m.state_dict()})
        return out

    def dump_json(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.state_dict(), f, indent=1, sort_keys=True)
