"""qwen2-0.5b: dense LM, aggressive GQA (kv=2), QKV bias.
[arXiv:2407.10671; hf]  24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense", tie_embeddings=True,
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab=151936, head_dim=64, qkv_bias=True, norm="rms", act="swiglu",
    rope=True, source="arXiv:2407.10671",
)
SMOKE = CONFIG.smoke()
