"""llava-next-mistral-7b: VLM — anyres tiling frontend is a STUB
(input_specs() provides precomputed patch embeddings); the backbone is
Mistral-7B with sliding-window attention (window 4096 -> sub-quadratic
long-context decode with a rolling KV ring).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]  32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm", modality="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, head_dim=128, norm="rms", act="swiglu", rope=True,
    window=4096, n_patches=2880,        # anyres: 5 tiles x 576 patches
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
SMOKE = CONFIG.smoke()
