"""phi3.5-moe-42b-a6.6b: MoE LM, 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]  32L d_model=4096 32H (GQA kv=8)
d_ff=6400 vocab=32064."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab=32064, head_dim=128, ffn_pattern=("moe",), n_experts=16,
    top_k=2, norm="ln", act="swiglu", rope=True,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
SMOKE = CONFIG.smoke()
