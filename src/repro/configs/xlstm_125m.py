"""xlstm-125m: pure recurrent LM — alternating mLSTM / sLSTM blocks (1:1),
no separate FFN (d_ff=0; the cells carry their own projections).
[arXiv:2405.04517; unverified]  12L d_model=768 4H vocab=50304."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, head_dim=192,
    block_pattern=("mlstm", "slstm"),
    ffn_pattern=("none", "none"),
    norm="ln", act="gelu", rope=False,
    source="arXiv:2405.04517",
)
SMOKE = CONFIG.smoke()
