"""smollm-360m: llama-arch small dense LM.
[hf:HuggingFaceTB/SmolLM-360M; hf]  32L d_model=960 15H (GQA kv=5)
d_ff=2560 vocab=49152."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense", tie_embeddings=True,
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab=49152, head_dim=64, norm="rms", act="swiglu", rope=True,
    source="hf:HuggingFaceTB/SmolLM-360M",
)
SMOKE = CONFIG.smoke()
