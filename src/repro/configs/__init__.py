"""Assigned architecture configs (``--arch <id>``).

Each module exports ``CONFIG`` (the exact public config) and ``SMOKE``
(reduced same-family config for CPU smoke tests).  ``get_config(name)``
resolves either.
"""

from __future__ import annotations

import importlib

ARCHS = [
    "smollm_360m",
    "qwen15_05b",
    "qwen2_05b",
    "stablelm_16b",
    "phi35_moe",
    "arctic_480b",
    "whisper_base",
    "llava_next_mistral_7b",
    "jamba_15_large",
    "xlstm_125m",
]

ALIASES = {
    "smollm-360m": "smollm_360m",
    "qwen1.5-0.5b": "qwen15_05b",
    "qwen2-0.5b": "qwen2_05b",
    "stablelm-1.6b": "stablelm_16b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "arctic-480b": "arctic_480b",
    "whisper-base": "whisper_base",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "jamba-1.5-large-398b": "jamba_15_large",
    "xlstm-125m": "xlstm_125m",
}


def get_config(name: str, smoke: bool = False):
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.SMOKE if smoke else mod.CONFIG
    return cfg


def all_configs(smoke: bool = False):
    return {a: get_config(a, smoke) for a in ARCHS}
