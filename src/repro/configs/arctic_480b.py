"""arctic-480b: Dense-MoE hybrid — 128 experts top-2 IN PARALLEL with a
dense residual FFN per layer (Snowflake Arctic architecture).
[hf:Snowflake/snowflake-arctic-base; hf]  35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab=32000, head_dim=128, ffn_pattern=("moe+dense",), n_experts=128,
    top_k=2, norm="rms", act="swiglu", rope=True,
    source="hf:Snowflake/snowflake-arctic-base",
)
SMOKE = CONFIG.smoke()
