"""qwen1.5-0.5b: dense LM with QKV bias, MHA (kv=16).
[hf:Qwen/Qwen1.5-0.5B; hf]  24L d_model=1024 16H d_ff=2816 vocab=151936."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=2816,
    vocab=151936, head_dim=64, qkv_bias=True, norm="rms", act="swiglu",
    rope=True, source="hf:Qwen/Qwen1.5-0.5B",
)
SMOKE = CONFIG.smoke()
