"""jamba-1.5-large-398b: hybrid Mamba+attention 1:7 with MoE every other
layer (16 experts top-2).  Period of 8: layers 0-6 mamba, layer 7
attention; MoE on odd layers within the period (4 of 8), dense on even —
matches arXiv:2403.19887's interleave and the ~398B total / ~94B active
budget within rounding.
[arXiv:2403.19887; hf]  72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab=65536, head_dim=128,
    block_pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "mamba",
                   "mamba", "attn"),
    ffn_pattern=("dense", "moe", "dense", "moe", "dense", "moe", "dense",
                 "moe"),
    n_experts=16, top_k=2, d_state=16, d_conv=4, expand=2,
    norm="rms", act="swiglu", rope=True,
    source="arXiv:2403.19887",
)
SMOKE = CONFIG.smoke()
