"""whisper-base: encoder-decoder ASR backbone; conv frontend is a STUB —
input_specs() provides precomputed frame embeddings (B, S, 512).
[arXiv:2212.04356; unverified]  6L enc + 6L dec, d_model=512 8H d_ff=2048
vocab=51865 (padded to 51968 for 16-way TP)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio", modality="audio", tie_embeddings=True,
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab=51865, head_dim=64, norm="ln", act="gelu", rope=False,
    enc_dec=True, n_enc_layers=6,
    source="arXiv:2212.04356",
)
SMOKE = CONFIG.smoke()
