"""stablelm-1.6b: dense LM, MHA.
[hf:stabilityai/stablelm-2-1_6b; unverified]  24L d_model=2048 32H
d_ff=5632 vocab=100352."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab=100352, head_dim=64, norm="ln", act="swiglu", rope=True,
    source="hf:stabilityai/stablelm-2-1_6b",
)
SMOKE = CONFIG.smoke()
