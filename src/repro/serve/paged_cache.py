"""Scavenger-style paged KV-cache manager (serving substrate).

Mapping of the paper onto HBM cache management (DESIGN.md §3/§4):
  pages               <-> value records
  extents (page runs) <-> vSSTs (allocation/GC granularity)
  page table          <-> index LSM-tree
  finished sequences  <-> overwritten keys (garbage)
  HBM budget          <-> the 1.5x space quota

Scavenger mechanics:
  * lazy validity — extent liveness is decided from the page table alone
    (never touching page bytes), the §III-B.1 idea;
  * hotness-aware placement (§III-B.3) — sequences hinted long-lived
    (shared prefixes / system prompts) allocate from cold extents, decode
    bursts from hot extents, so extents die together;
  * GC (§III-B) — when free pages run low, the manager first reclaims
    fully-dead extents (free), then *relocates* live pages out of the
    garbage-heaviest extents (copy cost = live fraction), exactly the
    paper's ratio-triggered GC;
  * throttling (§III-D) — admission blocks when a request's worst-case
    page need exceeds what GC can free.

The manager is policy + bookkeeping over a page pool array; the gather from
pool to contiguous per-sequence KV is `repro.kernels.paged_gather`.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Extent:
    eid: int
    start: int                 # first page index in the pool
    n_pages: int
    hot: bool
    live: int = 0
    dead: int = 0

    def garbage_ratio(self) -> float:
        used = self.live + self.dead
        return self.dead / used if used else 0.0


class PagedKVCacheManager:
    def __init__(self, n_pages: int, page_size: int,
                 extent_pages: int = 64, gc_threshold: float = 0.2):
        assert n_pages % extent_pages == 0
        self.n_pages = n_pages
        self.page_size = page_size
        self.extent_pages = extent_pages
        self.gc_threshold = gc_threshold
        self.extents: list[Extent] = [
            Extent(i, i * extent_pages, extent_pages, hot=False)
            for i in range(n_pages // extent_pages)]
        self.free_extents = list(range(len(self.extents)))
        self.active: dict[int, Extent] = {}        # hot-> open extent
        self.page_owner = np.full(n_pages, -1, np.int64)   # seq id or -1
        self.page_tables: dict[int, list[int]] = {}        # seq -> pages
        self.seq_hot: dict[int, bool] = {}
        self.next_free_in_extent: dict[int, int] = {}
        # stats
        self.pages_relocated = 0
        self.gc_runs = 0
        self.admission_blocks = 0

    # ----------------------------------------------------------- allocation
    def _open_extent(self, hot: bool) -> Extent | None:
        ext = self.active.get(hot)
        if ext is not None and self.next_free_in_extent[ext.eid] \
                < ext.n_pages:
            return ext
        if not self.free_extents:
            return None
        ext = self.extents[self.free_extents.pop(0)]
        ext.hot, ext.live, ext.dead = hot, 0, 0
        self.active[hot] = ext
        self.next_free_in_extent[ext.eid] = 0
        return ext

    def _alloc_page(self, seq: int, hot: bool) -> int | None:
        ext = self._open_extent(hot)
        if ext is None:
            self.run_gc()
            ext = self._open_extent(hot)
            if ext is None:
                return None
        slot = self.next_free_in_extent[ext.eid]
        self.next_free_in_extent[ext.eid] += 1
        page = ext.start + slot
        ext.live += 1
        self.page_owner[page] = seq
        return page

    def admit(self, seq: int, n_pages: int, hot: bool = True) -> bool:
        """Reserve pages for a sequence; False if HBM can't hold it."""
        if self.free_pages() < n_pages:
            self.run_gc()
        if self.free_pages() < n_pages:
            self.admission_blocks += 1
            return False
        self.page_tables[seq] = []
        self.seq_hot[seq] = hot
        for _ in range(n_pages):
            p = self._alloc_page(seq, hot)
            if p is None:
                self.finish(seq)
                self.admission_blocks += 1
                return False
            self.page_tables[seq].append(p)
        return True

    def extend(self, seq: int, n_pages: int = 1) -> bool:
        """Grow a sequence during decode."""
        for _ in range(n_pages):
            p = self._alloc_page(seq, self.seq_hot.get(seq, True))
            if p is None:
                return False
            self.page_tables[seq].append(p)
        return True

    def finish(self, seq: int) -> None:
        """Sequence done: its pages become garbage (lazy — page table only,
        no page bytes touched)."""
        for p in self.page_tables.pop(seq, []):
            ext = self.extents[p // self.extent_pages]
            ext.live -= 1
            ext.dead += 1
            self.page_owner[p] = -1
        self.seq_hot.pop(seq, None)

    # ------------------------------------------------------------------ GC
    def free_pages(self) -> int:
        n = len(self.free_extents) * self.extent_pages
        for hot, ext in self.active.items():
            if ext is not None:
                n += ext.n_pages - self.next_free_in_extent[ext.eid]
        return n

    def run_gc(self) -> int:
        """Reclaim dead extents; relocate live pages out of garbage-heavy
        extents (copy cost tracked).  Returns pages reclaimed."""
        self.gc_runs += 1
        reclaimed = 0
        for ext in self.extents:
            if ext in self.active.values():
                # an open extent that is fully dead resets in place
                if ext.live == 0 and ext.dead > 0:
                    reclaimed += self.next_free_in_extent[ext.eid]
                    ext.dead = 0
                    self.next_free_in_extent[ext.eid] = 0
                continue
            used = ext.live + ext.dead
            if used == 0 or ext.eid in self.free_extents:
                continue
            if ext.live == 0:
                ext.dead = 0
                self.free_extents.append(ext.eid)
                reclaimed += ext.n_pages
            elif ext.garbage_ratio() >= self.gc_threshold:
                moved = self._relocate(ext)
                if moved is not None:
                    reclaimed += ext.n_pages
        return reclaimed

    def _relocate(self, ext: Extent) -> int | None:
        live_pages = [p for p in range(ext.start, ext.start + ext.n_pages)
                      if self.page_owner[p] >= 0]
        # need room elsewhere first
        if self.free_pages() - (ext.n_pages - len(live_pages)) \
                < len(live_pages):
            return None
        for p in live_pages:
            seq = int(self.page_owner[p])
            np_ = self._alloc_page(seq, self.seq_hot.get(seq, True))
            if np_ is None:
                return None
            pt = self.page_tables[seq]
            pt[pt.index(p)] = np_
            self.page_owner[p] = -1
            self.pages_relocated += 1
        ext.live = ext.dead = 0
        self.free_extents.append(ext.eid)
        return len(live_pages)

    # ----------------------------------------------------------- interface
    def page_table_array(self, seqs: list[int], max_pages: int,
                         zero_page: int = 0) -> np.ndarray:
        """(B, max_pages) int32 table for kernels.paged_gather."""
        out = np.full((len(seqs), max_pages), zero_page, np.int32)
        for i, s in enumerate(seqs):
            pt = self.page_tables.get(s, [])[:max_pages]
            out[i, :len(pt)] = pt
        return out

    def stats(self) -> dict:
        live = sum(e.live for e in self.extents)
        dead = sum(e.dead for e in self.extents)
        return {"free_pages": self.free_pages(), "live_pages": live,
                "dead_pages": dead, "gc_runs": self.gc_runs,
                "pages_relocated": self.pages_relocated,
                "admission_blocks": self.admission_blocks,
                "frag_amp": (live + dead) / max(live, 1)}
