"""Batched serving engine: continuous batching over fixed decode slots.

Each slot holds a dense per-slot KV cache (model.serve_step); HBM paging
policy (admission, eviction, GC) is delegated to the Scavenger
PagedKVCacheManager, which accounts pages for every slot's cache growth.
Greedy sampling; CPU-runnable with smoke configs (examples/serve_llm.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, ShardedStore, Store, WriteBatch

from .paged_cache import PagedKVCacheManager

# Bytes one page costs in a rid's metadata record (the vsize written at
# admission and decoded by restore_page_tables: vsize // _PAGE_META_BYTES
# = reserved page count).
_PAGE_META_BYTES = 16


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    hot: bool = True        # False for long-lived shared-prefix requests
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, batch_slots: int = 4,
                 cache_len: int = 256, page_size: int = 16,
                 hbm_pages: int | None = None,
                 meta_store: Store | None = None,
                 meta_shards: int = 1, meta_shard_policy: str = "hash",
                 meta_engine: str = "scavenger"):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.slots = batch_slots
        self.cache_len = cache_len
        self.page_size = page_size
        n_pages = hbm_pages or (batch_slots * cache_len // page_size * 2)
        per_layer_pages = max(1, cache_len // page_size)
        self.pager = PagedKVCacheManager(
            n_pages, page_size, extent_pages=max(4, per_layer_pages // 2))
        # per-request paged-cache metadata (page-table records) lives in a
        # small KV store; admission/retirement waves go through the batched
        # write path (one WriteBatch per wave), mirroring how the Titan
        # writeback GC batches its index rewrites.  meta_shards > 1 shards
        # the metadata store (hash over rids — the rid domain is unbounded,
        # so range partitioning has nothing to split on).
        # ``meta_engine`` selects any registered engine strategy for the
        # metadata store (the serving tier rides the same registry as the
        # paper benchmarks).
        if meta_store is not None:
            self.meta = meta_store
        elif meta_shards > 1:
            self.meta = ShardedStore(
                EngineConfig.scaled(meta_engine, (4 << 20) // meta_shards),
                n_shards=meta_shards, shard_policy=meta_shard_policy,
                key_space=1 << 20)      # rid domain bound for range policy
        else:
            self.meta = Store(EngineConfig.scaled(meta_engine, 4 << 20))
        self.cache = model.init_cache(batch_slots, cache_len)
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int64)
        self.queue: list[Request] = []
        self.steps = 0
        self._step_fn = jax.jit(model.serve_step)

    # ----------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def restore_page_tables(self, scan_chunk: int = 1 << 12) -> list[int]:
        """Rebuild the pager's page reservations from the metadata store.

        After recovering the metadata store (``Store.open`` on its
        durability directory, passed in as ``meta_store``), every live rid
        record re-reserves the page count recorded at admission
        (``_PAGE_META_BYTES`` per page-table entry), so HBM accounting and
        the duplicate-rid admission guard pick up exactly where the
        crashed engine left off.  Scans continue past ``scan_chunk`` rids
        until the keyspace is exhausted — no silent truncation.  KV-cache
        *contents* are model state and are recomputed on the next prefill
        — only the page table is durable (DESIGN.md §9).  Returns the
        restored rids."""
        restored = []
        start = 0
        while True:
            pairs = self.meta.multi_scan(np.array([start], np.int64),
                                         count=scan_chunk)[0]
            if not pairs:
                break
            rids = np.array([k for k, _ in pairs], np.uint64)
            res = self.meta.multi_get(rids)
            for rid, found, vsize in zip(rids.tolist(),
                                         res["found"].tolist(),
                                         res["vsize"].tolist()):
                if not found or rid in self.pager.page_tables:
                    continue
                n_pages = max(1, int(vsize) // _PAGE_META_BYTES)
                if self.pager.admit(rid, n_pages,
                                    hot=self._rid_hot(rid, True)):
                    restored.append(rid)
            if len(pairs) < scan_chunk:
                break
            start = int(rids[-1]) + 1
        return restored

    def _admit(self) -> None:
        admitted: list[tuple[int, int]] = []     # (rid, n_pages)
        try:
            for i in range(self.slots):
                if self.slot_req[i] is not None or not self.queue:
                    continue
                req = self.queue[0]
                need = (len(req.prompt) + req.max_new
                        + self.page_size - 1) // self.page_size
                # a live metadata record means this rid already owns pages —
                # admitting it again would corrupt its page table; drop the
                # duplicate before raising so the queue can still drain
                if any(req.rid == a[0] for a in admitted) or bool(
                        self.meta.multi_get(
                            np.array([req.rid], np.uint64))["found"][0]):
                    self.queue.pop(0)
                    req.done = True
                    raise ValueError(
                        f"request id {req.rid} already admitted")
                if not self.pager.admit(req.rid, need,
                                        hot=self._admit_hot(req)):
                    break                  # HBM full: wait for GC headroom
                self.queue.pop(0)
                admitted.append((req.rid, need))
                self.slot_req[i] = req
                self.slot_pos[i] = 0
                # prefill token-by-token (keeps a single compiled step)
                for t in req.prompt[:-1]:
                    self._single(i, t)
                self._pending_first = (i, req.prompt[-1])
                self._single(i, req.prompt[-1], sample=True)
        finally:
            # record the wave even if a later queue entry was rejected —
            # an admitted request without a metadata record would dodge the
            # duplicate-rid guard
            if admitted:
                rids = np.array([a[0] for a in admitted], np.uint64)
                sizes = np.array([a[1] * _PAGE_META_BYTES
                                  for a in admitted], np.int64)
                t0 = self.meta.io.fg_clock_us
                # pinned origin (§13): everything this metadata write
                # triggers downstream — flushes, compactions, GC — blames
                # the serving tier's admission path, not a generic "write"
                with self.meta.obs.cause(self.meta, origin="admission"):
                    self.meta.write(WriteBatch().puts(rids, sizes))
                # admission-path observability (DESIGN.md §11): simulated
                # foreground latency of the metadata write on the serving
                # critical path, plus the admitted page mix
                obs = self.meta.obs
                obs.on_op(self.meta, "admission_us",
                          self.meta.io.fg_clock_us - t0)
                obs.on_op(self.meta, "admission_pages",
                          sum(a[1] for a in admitted))

    def _admit_hot(self, req: Request) -> bool:
        """Hot/cold extent placement for a request's pages.

        With ``meta_engine="scavenger_adaptive"`` the metadata store's
        workload tracker has seen every admission/retirement write for this
        rid: a rid whose metadata churns (re-submitted short bursty
        requests) classifies hot, long-lived rids cool off to cold extents
        — the serving tier consumes the same temperature signal that drives
        vSST segregation.  Falls back to the caller's ``req.hot`` hint when
        the meta store has no tracker (default engines, sharded meta)."""
        return self._rid_hot(req.rid, req.hot)

    def _rid_hot(self, rid: int, default: bool) -> bool:
        tempmap = getattr(getattr(self.meta, "strategy", None),
                          "tempmap", None)
        if tempmap is None:
            return default
        rid = np.array([rid], np.uint64)
        if tempmap.tracker.write_rate(rid)[0] < 1.0:
            # no evidence for this rid: its metadata write happens after
            # admission, so a first-time rid has no observations — the
            # caller's hint stands.  The < 1.0 bar (one undecayed
            # observation) also filters decayed sketch-collision noise;
            # a fresh full-count collision can still masquerade as
            # evidence — an accepted sketch trade-off for a placement
            # hint that only steers extent locality, never correctness.
            return default
        from repro.core.adaptive import TEMP_WARM
        return bool(tempmap.classify(rid)[0] >= TEMP_WARM)

    def _single(self, slot: int, token: int, sample: bool = False) -> None:
        b = np.zeros((self.slots, 1), np.int32)
        b[slot, 0] = token
        logits, self.cache = self._step_fn(
            self.params, self.cache,
            {"token": jnp.asarray(b), "pos": jnp.int32(self.slot_pos[slot])})
        self.slot_pos[slot] += 1
        if sample:
            req = self.slot_req[slot]
            nxt = int(jnp.argmax(logits[slot, 0, :self.cfg.vocab]))
            req.out.append(nxt)

    def step(self) -> None:
        """One decode step across all occupied slots."""
        self._admit()
        occupied = [i for i in range(self.slots)
                    if self.slot_req[i] is not None]
        if not occupied:
            return
        tok = np.zeros((self.slots, 1), np.int32)
        # NOTE: slots decode at their own positions; for simplicity (and
        # because smoke models are tiny) we step slots with equal pos
        # together and others individually.
        finished: list[int] = []
        for i in occupied:
            req = self.slot_req[i]
            last = req.out[-1] if req.out else req.prompt[-1]
            self._single(i, last, sample=True)
            if len(req.out) >= req.max_new:
                req.done = True
                self.pager.finish(req.rid)
                self.slot_req[i] = None
                finished.append(req.rid)
        if finished:
            self.meta.write(
                WriteBatch().deletes(np.array(finished, np.uint64)))
        self.steps += 1

    def run(self, max_steps: int = 1000) -> None:
        while (self.queue or any(self.slot_req)) and max_steps > 0:
            self.step()
            max_steps -= 1

    def stats(self) -> dict:
        s = self.pager.stats()
        s["steps"] = self.steps
        s["meta_space_amp"] = self.meta.space_amplification()
        return s
