from .engine import Request, ServeEngine
from .paged_cache import PagedKVCacheManager

__all__ = ["Request", "ServeEngine", "PagedKVCacheManager"]
