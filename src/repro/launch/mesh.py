"""Production mesh + sharding resolution.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: (16, 16) = ('data', 'model'); multi-pod:
(2, 16, 16) = ('pod', 'data', 'model') — 512 chips.

Param sharding roles (models/layers.py) resolve here:
  'fsdp' -> ('pod','data') [multi-pod] or ('data',)   # FSDP product axes
  'tp'   -> 'model'                                   # tensor parallel
  'exp'  -> 'model'                                   # expert parallel
Activations are batch-sharded over the FSDP axes.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import ShardCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def role_to_axes(mesh: Mesh):
    fsdp = batch_axes(mesh)
    return {"fsdp": fsdp if len(fsdp) > 1 else fsdp[0],
            "tp": "model", "exp": "model", "batch": fsdp}


def resolve_spec(role_spec: tuple, mesh: Mesh) -> P:
    """('fsdp','tp') -> PartitionSpec(('pod','data'), 'model') etc."""
    roles = role_to_axes(mesh)
    return P(*[roles.get(r) if r is not None else None for r in role_spec])


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def param_shardings(model, mesh: Mesh):
    """NamedSharding tree matching model.abstract_params().

    Dims that don't divide evenly by their mapped axis (smoke configs,
    small recurrent head counts) fall back to replication."""
    specs = model.param_specs()
    abstract = model.abstract_params()

    def resolve(rs, sds):
        roles = role_to_axes(mesh)
        rs = tuple(rs) + (None,) * (len(sds.shape) - len(rs))
        dims = []
        for dim_size, r in zip(sds.shape, rs):
            ax = roles.get(r) if r is not None else None
            if ax is not None and dim_size % _axes_size(mesh, ax) != 0:
                ax = None
            dims.append(ax)
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(resolve, specs, abstract,
                        is_leaf=lambda x: isinstance(x, tuple))


def serve_param_shardings(model, mesh: Mesh):
    """§Perf serving policy: undo FSDP (replicate over pod/data axes),
    keep TP — kills the per-decode-step parameter all-gather for models
    whose TP shards fit HBM."""
    base = param_shardings(model, mesh)
    drop = set(batch_axes(mesh))

    def strip(ns: NamedSharding):
        dims = []
        for d in ns.spec:
            if d is None or d in drop:
                dims.append(None)
            elif isinstance(d, tuple):
                kept = tuple(a for a in d if a not in drop)
                dims.append(kept if kept else None)
            else:
                dims.append(d)
        return NamedSharding(mesh, P(*dims))
    return jax.tree.map(strip, base)


def shard_ctx(mesh: Mesh) -> ShardCtx:
    return ShardCtx(mesh=mesh, batch_axes=batch_axes(mesh),
                    tp_axis="model")


def batch_sharding(mesh: Mesh, ndim: int, batch_dim: int = 0):
    dims = [None] * ndim
    dims[batch_dim] = batch_axes(mesh)
    return NamedSharding(mesh, P(*dims))
