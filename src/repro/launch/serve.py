"""Batched serving driver: continuous batching + Scavenger-paged KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --requests 8 --max-new 12
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--shards", type=int, default=1,
                    help="shard the paged-cache metadata store N ways")
    ap.add_argument("--shard-policy", choices=("hash", "range"),
                    default="hash")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(args.seed))
    engine = ServeEngine(model, params, batch_slots=args.slots,
                         cache_len=args.cache_len,
                         meta_shards=args.shards,
                         meta_shard_policy=args.shard_policy)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for rid in range(args.requests):
        plen = int(rng.integers(4, 16))
        prompt = rng.integers(0, cfg.vocab, plen).tolist()
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new=args.max_new, hot=rid % 4 != 0))
    engine.run()
    dt = time.time() - t0
    toks = args.requests * args.max_new
    print(f"[serve] {args.requests} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks / dt:.1f} tok/s)")
    print("[serve] pager:", json.dumps(engine.stats()))


if __name__ == "__main__":
    main()
