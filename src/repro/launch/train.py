"""End-to-end training driver with Scavenger-backed fault tolerance.

CPU-runnable with the smoke/small configs; the same driver lowers onto the
production mesh on TPU.  Demonstrates:
  * incremental checkpointing into the KV-separated store under a disk
    quota (old steps = garbage; Scavenger GC reclaims),
  * crash / restart (--fail-at-step N aborts mid-run; rerunning with the
    same --ckpt-dir resumes from the last durable step),
  * deterministic resumable data (pipeline state is a cold checkpoint key).

Example (examples/train_lm.py wraps this):
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \
      --steps 30 --batch 4 --seq 64 --ckpt-dir /tmp/ckpt --ckpt-every 10
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.pytree import (drop_steps, load_pytree, save_pytree,
                                     steps_available)
from repro.checkpoint.store import CheckpointStore
from repro.configs import get_config
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models.model import build_model
from repro.train.trainer import (TrainConfig, init_opt_state,
                                 make_train_step)


def make_batch_for(cfg, tokens):
    if cfg.enc_dec:
        b, s = tokens.shape
        rng = np.random.default_rng(int(tokens[0, 0]))
        return {"frames": jnp.asarray(
                    rng.standard_normal((b, s, cfg.d_model)), jnp.float32),
                "tokens": jnp.asarray(tokens)}
    if cfg.modality == "vlm":
        b, s = tokens.shape
        p = min(cfg.n_patches, max(1, s // 4))
        rng = np.random.default_rng(int(tokens[0, 0]))
        return {"patches": jnp.asarray(
                    rng.standard_normal((b, p, cfg.d_model)), jnp.float32),
                "tokens": jnp.asarray(tokens[:, :s - 0])}
    return {"tokens": jnp.asarray(tokens)}


def run(args) -> dict:
    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    tcfg = TrainConfig(lr=args.lr, accum_steps=args.accum)
    step_fn = jax.jit(make_train_step(model, tcfg))

    pipe = TokenPipeline(PipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed))

    store = None
    start_step = 0
    params = opt_state = None
    if args.ckpt_dir:
        store = CheckpointStore(
            args.ckpt_dir, engine=args.ckpt_engine,
            quota_bytes=args.quota_mb * (1 << 20) if args.quota_mb else None,
            log_target=args.log_target_kb << 10)
        have = steps_available(store, "train")
        for cand in reversed(have if not args.fresh else []):
            try:        # newest complete checkpoint wins; torn ones skipped
                params = load_pytree(store, "train", cand,
                                     model.abstract_params())
                params = jax.tree.map(jnp.asarray, params)
                opt_abs = jax.eval_shape(
                    lambda p: init_opt_state(p, tcfg), params)
                opt_state = load_pytree(store, "train", cand, opt_abs)
                opt_state = jax.tree.map(jnp.asarray, opt_state)
                meta = json.loads(store.get(f"meta/{cand}/state"))
                pipe.restore(meta["pipeline"])
                start_step = cand
                print(f"[train] resuming from checkpoint step {cand}")
                break
            except KeyError:
                params = opt_state = None
                continue
    if params is None:
        params = model.init_params(jax.random.key(args.seed))
        opt_state = init_opt_state(params, tcfg)
        pipe.step = 0

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        tokens = next(pipe)["tokens"]
        batch = make_batch_for(cfg, tokens)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % max(1, args.log_every) == 0:
            dt = time.time() - t0
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({dt:.1f}s)", flush=True)
        if args.fail_at_step is not None and step + 1 == args.fail_at_step:
            print(f"[train] injected failure at step {step + 1}",
                  flush=True)
            os._exit(42)
        if store and (step + 1) % args.ckpt_every == 0:
            save_pytree(store, "train", step + 1, params, hot=True)
            save_pytree(store, "train", step + 1, opt_state, hot=True)
            store.put(f"meta/{step + 1}/state", json.dumps(
                {"pipeline": pipe.state(), "loss": loss}).encode(),
                hot=False)
            store.flush()          # durable before old steps become garbage
            drop_steps(store, "train", keep_last=args.keep_last)
            drop_steps(store, "meta", keep_last=args.keep_last)
            store.run_gc()
            store.flush()
    result = {"final_loss": losses[-1] if losses else None,
              "losses": losses, "steps_run": len(losses),
              "resumed_from": start_step}
    if store:
        result["store"] = store.stats()
        store.close()
    print(f"[train] done: {json.dumps(result['store'] if store else {})}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-engine", default="scavenger",
                    choices=["scavenger", "naive"])
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--keep-last", type=int, default=2)
    ap.add_argument("--quota-mb", type=int, default=None)
    ap.add_argument("--log-target-kb", type=int, default=1024)
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()
    run(args)


if __name__ == "__main__":
    main()
