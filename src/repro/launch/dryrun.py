import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: the 512
placeholder CPU devices let jax.make_mesh build the production meshes; the
compiled artifact yields memory_analysis (fits-per-device), cost_analysis
(FLOPs/bytes for §Roofline) and the post-SPMD HLO whose collective ops we
byte-count for the collective roofline term.

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k \
      [--mesh single|multi] [--smoke] [--out benchmarks/artifacts/dryrun]
  python -m repro.launch.dryrun --all [--mesh both]
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.launch import mesh as meshlib
from repro.launch.shapes import (SHAPES, TRAIN_OVERRIDES, cache_len_for,
                                 input_specs, runnable)
from repro.models.model import build_model
from repro.train.trainer import (TrainConfig, abstract_opt_state,
                                 make_train_step, opt_state_shardings)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def collective_bytes(hlo_text: str) -> dict:
    """Byte-count collective ops in post-SPMD (per-device) HLO text."""
    out = {c: 0 for c in COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(.+?)\s+(" + "|".join(COLLECTIVES)
                      + r")(-start|-done)?\(", line)
        if not m or (m.group(3) or "") == "-done":
            continue
        shapes_part, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in re.findall(r"([a-z]+[0-9]+|pred)\[([0-9,]*)\]",
                                   shapes_part):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES.get(dt, 4)
        out[op] += nbytes
        out["count"] += 1
    out["total"] = sum(out[c] for c in COLLECTIVES)
    return out


def _batch_shardings(mesh, specs):
    baxes = meshlib.batch_axes(mesh)
    nb = 1
    for a in baxes:
        nb *= mesh.shape[a]

    def shard(sds):
        if sds.shape and sds.shape[0] % nb == 0 and sds.shape[0] >= nb:
            return NamedSharding(mesh, P(baxes, *([None] *
                                                  (len(sds.shape) - 1))))
        return NamedSharding(mesh, P())
    return jax.tree.map(shard, specs)


def _cache_shardings(mesh, cache_specs):
    """Batch dim if divisible; else the first large seq/feature dim over
    'data' (sequence-parallel decode for batch=1 long-context)."""
    baxes = meshlib.batch_axes(mesh)
    nb = 1
    for a in baxes:
        nb *= mesh.shape[a]
    nd = mesh.shape["data"]

    def shard(sds):
        shape = sds.shape            # (n_periods, B, ...)
        dims = [None] * len(shape)
        if len(shape) >= 2 and shape[1] % nb == 0 and shape[1] >= nb:
            dims[1] = baxes
        else:
            for i in range(2, len(shape)):
                if shape[i] % nd == 0 and shape[i] >= nd:
                    dims[i] = "data"
                    break
        return NamedSharding(mesh, P(*dims))
    return jax.tree.map(shard, cache_specs)


OPT_REPLICATE_SERVE_PARAMS_GB = 8.0     # per-device bf16 budget for TP-only


def _apply_opt(cfg):
    import dataclasses
    return dataclasses.replace(cfg, attn_impl="chunked", gqa_grouped=True)


def _cost_dict(compiled) -> dict:
    """cost_analysis() returns a dict on new jax, a per-computation list of
    dicts on older releases — normalize to one dict."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _cost_fields(compiled) -> dict:
    cost = _cost_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "coll": coll["total"], "coll_by_op": coll}


def reconstruct_costs(cfg, shape_name, mesh, ctx, kind, specs, opt):
    """Differential cost reconstruction (see EXPERIMENTS.md §Roofline):
    XLA's cost_analysis counts While bodies once, so per-device totals are
    rebuilt from 1-period and 2-period lowerings:
      C(n) = C(1) + (n-1) * (C(2) - C(1))    per varied loop."""
    import dataclasses as dc
    base_kwargs = {"n_layers": cfg.period}
    loops = [("n_layers", cfg.period, cfg.n_periods)]
    if cfg.enc_dec:
        base_kwargs["n_enc_layers"] = 1
        loops.append(("n_enc_layers", 1, cfg.n_enc_layers))

    def lower_variant(**over):
        kw = dict(base_kwargs)
        kw.update(over)
        vcfg = dc.replace(cfg, **kw)
        if opt:
            vcfg = _apply_opt(vcfg)
        vmodel = build_model(vcfg)
        vkind, vspecs = input_specs(vcfg, shape_name, model=vmodel)
        return _lower(vcfg, vmodel, mesh, ctx, vkind, vspecs,
                      accum_override=1,
                      grad_shard=opt).compile()

    c_base = _cost_fields(lower_variant())
    out = dict(c_base)
    out["coll_by_op"] = dict(c_base["coll_by_op"])
    for field_name, step, actual in loops:
        c_double = _cost_fields(lower_variant(**{field_name: 2 * step}))
        mult = (actual - step) / step
        for f in ("flops", "bytes", "coll"):
            out[f] += mult * (c_double[f] - c_base[f])
        for op in COLLECTIVES:
            out["coll_by_op"][op] = out["coll_by_op"].get(op, 0) + mult * (
                c_double["coll_by_op"][op] - c_base["coll_by_op"][op])
    return out


def _lower(cfg, model, mesh, ctx, kind, specs, accum_override=None,
           grad_shard=False):
    p_abs = model.abstract_params()
    p_shard = meshlib.param_shardings(model, mesh)
    b_shard = _batch_shardings(mesh, specs["batch"])
    with mesh:
        if kind == "train":
            tov = dict(TRAIN_OVERRIDES.get(cfg.name, {}))
            # NOTE §Perf iteration 2 (refuted): reducing accum_steps 4x to
            # amortize FSDP gathers quadrupled per-microbatch activation
            # temps (64.9 -> 204 GB/device on arctic) — kept at baseline.
            if accum_override is not None:
                tov["accum_steps"] = accum_override
            tcfg = TrainConfig(**tov)
            step = make_train_step(
                model, tcfg, ctx,
                grad_shardings=p_shard if grad_shard else None)
            o_abs = abstract_opt_state(p_abs, tcfg)
            o_shard = opt_state_shardings(p_shard, mesh)
            fn = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
            return fn.lower(p_abs, o_abs, specs["batch"])
        if kind == "prefill":
            def prefill(params, batch):
                return model.prefill(params, batch, ctx=ctx)
            fn = jax.jit(prefill, in_shardings=(p_shard, b_shard))
            return fn.lower(p_abs, specs["batch"])
        c_shard = _cache_shardings(mesh, specs["cache"])
        c_out = c_shard
        if grad_shard:          # opt mode: serve params TP-only if they fit
            per_dev_gb = cfg.param_count() * 2 / mesh.shape["model"] / 1e9
            if per_dev_gb <= OPT_REPLICATE_SERVE_PARAMS_GB:
                p_shard = meshlib.serve_param_shardings(model, mesh)
            # §Perf: let XLA choose a self-consistent cache layout across
            # steps (explicit replicated-over-model caches forced a
            # re-replication gather of the whole cache per step)
            c_shard = None
            c_out = None

        def serve(params, cache, batch):
            return model.serve_step(params, cache, batch, ctx=ctx)
        fn = jax.jit(serve, in_shardings=(p_shard, c_shard, b_shard),
                     out_shardings=(None, c_out), donate_argnums=(1,))
        return fn.lower(p_abs, specs["cache"], specs["batch"])


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               smoke: bool = False, opt: bool = False,
               reconstruct: bool = False):
    """Lower + compile one (arch x shape x mesh) cell.

    Returns (compiled, lowered, info dict)."""
    cfg = get_config(arch, smoke=smoke)
    if opt:
        cfg = _apply_opt(cfg)
    model = build_model(cfg)
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    ctx = meshlib.shard_ctx(mesh)
    kind, specs = input_specs(cfg, shape_name, model=model)
    if smoke:   # shrink shapes, keep the mesh
        sh = SHAPES[shape_name]
        b = max(32, 512 if multi_pod else 256)
        seq = 64
        from repro.launch.shapes import (train_batch_specs,
                                         decode_batch_specs)
        if kind in ("train", "prefill"):
            specs = {"batch": train_batch_specs(cfg, seq, b)}
        else:
            cache = model.cache_shapes(b, seq,
                                       enc_len=seq if cfg.enc_dec else 0)
            specs = {"batch": decode_batch_specs(cfg, b), "cache": cache}

    lowered = _lower(cfg, model, mesh, ctx, kind, specs,
                     accum_override=1 if smoke else None,
                     grad_shard=opt)

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    cost = _cost_dict(compiled)
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes")
            if hasattr(mem, k)}
    except Exception:
        mem_info = {}
    coll = collective_bytes(compiled.as_text())

    n_chips = 512 if multi_pod else 256
    info = {
        "arch": cfg.name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips, "kind": kind, "smoke": smoke, "opt": opt,
        "compile_s": round(compile_s, 2),
        "flops_per_device": cost.get("flops", -1.0),
        "bytes_accessed_per_device": cost.get("bytes accessed", -1.0),
        "memory": mem_info,
        "collectives": coll,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    if reconstruct and not smoke:
        info["reconstructed"] = reconstruct_costs(
            get_config(arch), shape_name, mesh, ctx, kind, specs, opt)
    return compiled, lowered, info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="beyond-paper perf variant (chunked attention, "
                         "grouped GQA, sharded grad accum, TP-only serving)")
    ap.add_argument("--reconstruct", action="store_true",
                    help="differential HLO cost reconstruction (kept as a "
                         "documented negative result; see §Perf)")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = ARCHS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            ok, why = runnable(cfg, shape_name)
            if not ok:
                print(f"SKIP {arch} x {shape_name}: {why}")
                continue
            for multi in meshes:
                tag = (f"{cfg.name}_{shape_name}_"
                       f"{'multi' if multi else 'single'}"
                       f"{'_smoke' if args.smoke else ''}"
                       f"{'_opt' if args.opt else ''}")
                t0 = time.time()
                try:
                    _, _, info = lower_cell(
                        arch, shape_name, multi, smoke=args.smoke,
                        opt=args.opt, reconstruct=args.reconstruct)
                    info["total_s"] = round(time.time() - t0, 2)
                    (out_dir / f"{tag}.json").write_text(
                        json.dumps(info, indent=1))
                    print(f"OK   {tag}: compile={info['compile_s']}s "
                          f"flops/dev={info['flops_per_device']:.3e} "
                          f"coll={info['collectives']['total']/1e6:.1f}MB")
                except Exception as e:
                    failures += 1
                    print(f"FAIL {tag}: {e}")
                    traceback.print_exc()
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
