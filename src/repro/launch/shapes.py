"""Assigned input-shape catalog + abstract input construction.

LM transformer shapes are seq_len x global_batch; ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a KV cache of seq_len), NOT
``train_step``; ``prefill_32k`` lowers ``prefill``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# per-arch training memory knobs for the big configs (see EXPERIMENTS.md)
TRAIN_OVERRIDES = {
    "arctic-480b": dict(accum_steps=8, moment_dtype="bfloat16"),
    "jamba-1.5-large-398b": dict(accum_steps=8, moment_dtype="bfloat16"),
    "phi3.5-moe-42b-a6.6b": dict(accum_steps=4, moment_dtype="bfloat16"),
    "llava-next-mistral-7b": dict(accum_steps=4, moment_dtype="float32"),
}


def runnable(cfg, shape_name: str) -> tuple[bool, str]:
    """Whether (arch x shape) is a valid cell (DESIGN.md §5 skips)."""
    s = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k decode skipped"
    if s["kind"] == "decode" and cfg.family == "vlm" \
            and shape_name == "long_500k" and cfg.window is None:
        return False, "vlm without windowed attention"
    return True, ""


def train_batch_specs(cfg, seq: int, batch: int):
    """ShapeDtypeStruct stand-ins for one global training batch."""
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if cfg.enc_dec:
        return {"frames": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                               jnp.bfloat16),
                "tokens": tok}
    if cfg.modality == "vlm":
        p = min(cfg.n_patches, seq // 2)
        return {"patches": jax.ShapeDtypeStruct((batch, p, cfg.d_model),
                                                jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((batch, seq - p), jnp.int32)}
    return {"tokens": tok}


def decode_batch_specs(cfg, batch: int):
    return {"token": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def cache_len_for(cfg, shape_name: str) -> int:
    seq = SHAPES[shape_name]["seq"]
    if shape_name == "long_500k" and cfg.window is not None:
        return cfg.window                 # rolling ring (mistral SWA)
    return seq


def input_specs(cfg, shape_name: str, model=None):
    """-> (kind, specs dict) for lowering; decode includes 'cache'."""
    s = SHAPES[shape_name]
    if s["kind"] == "train":
        return "train", {"batch": train_batch_specs(cfg, s["seq"],
                                                    s["batch"])}
    if s["kind"] == "prefill":
        return "prefill", {"batch": train_batch_specs(cfg, s["seq"],
                                                      s["batch"])}
    cache_len = cache_len_for(cfg, shape_name)
    enc_len = s["seq"] if cfg.enc_dec else 0
    cache = model.cache_shapes(s["batch"], cache_len, enc_len=enc_len)
    return "decode", {"batch": decode_batch_specs(cfg, s["batch"]),
                      "cache": cache}
