"""Model configuration for the assigned architectures.

A single ModelConfig drives decoder-only LMs (dense / MoE / hybrid / SSM),
the whisper encoder-decoder, and the llava VLM backbone.  Layer heterogeneity
(jamba's mamba:attn 1:7 interleave, xlstm's mLSTM/sLSTM alternation, MoE
every other layer) is expressed as a repeating *period*: ``block_pattern``
and ``ffn_pattern`` describe one period; the model is scan-compiled over
``n_layers / period`` stacked periods (homogeneous across periods, so one
XLA While body per architecture).
"""

from __future__ import annotations

import dataclasses
import math


def round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # block structure (one period; cycled over layers)
    block_pattern: tuple = ("attn",)          # attn | mamba | mlstm | slstm
    ffn_pattern: tuple = ("dense",)           # dense | moe | moe+dense | none

    # attention details
    head_dim: int | None = None
    qkv_bias: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    window: int | None = None                 # sliding-window attention

    # MoE
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25

    # SSM (mamba)
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    # norms / activations
    norm: str = "rms"                         # rms | ln
    act: str = "swiglu"                       # swiglu | gelu

    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0

    # modality frontend stubs
    modality: str = "text"                    # text | audio | vlm
    n_patches: int = 0                        # vlm: image patch stub length

    # numerics / padding
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256
    tie_embeddings: bool = False

    # performance variants (§Perf; baseline = naive/False)
    attn_impl: str = "naive"        # naive | chunked (flash-style)
    gqa_grouped: bool = False       # grouped einsum, no KV-head repeat

    # bookkeeping
    family: str = "dense"                     # dense|moe|hybrid|ssm|audio|vlm
    source: str = ""

    def __post_init__(self):
        assert self.n_layers % len(self.block_pattern) == 0, \
            f"{self.name}: n_layers must be a multiple of the period"
        assert len(self.ffn_pattern) == len(self.block_pattern)

    # ------------------------------------------------------------- derived
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab, self.vocab_pad_multiple)

    @property
    def period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, self.d_model // 16)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the 500k-token decode shape?  Per the brief:
        SSM / hybrid (attention is a small minority of layers) / windowed
        attention qualify; pure full-attention archs are skipped."""
        attn_layers = sum(b == "attn" for b in self.block_pattern)
        if self.enc_dec:
            return False
        if attn_layers == 0:
            return True
        if self.window is not None:
            return True
        return (self.family in ("ssm", "hybrid")
                and attn_layers * 4 <= len(self.block_pattern))

    @property
    def has_attention(self) -> bool:
        return any(b == "attn" for b in self.block_pattern) or self.enc_dec

    # --------------------------------------------------------- param count
    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, f, hd = self.d_model, self.d_ff, self.hd
        n_q, n_kv = self.n_heads, self.n_kv_heads
        total = self.vocab_padded * d * (1 if self.tie_embeddings else 2)
        if self.modality == "vlm":
            total += d * d                          # patch projector stub
        def attn_params():
            p = d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
            if self.qkv_bias:
                p += n_q * hd + 2 * n_kv * hd
            return p
        def dense_ffn():
            if self.act == "swiglu":
                return 3 * d * f
            return 2 * d * f
        def moe_ffn():
            per = 3 * d * f if self.act == "swiglu" else 2 * d * f
            return self.n_experts * per + d * self.n_experts
        def mamba_params():
            di = self.d_inner
            return (d * 2 * di + di * self.d_conv
                    + di * (self.dt_rank + 2 * self.d_state)
                    + self.dt_rank * di + di * self.d_state + di + di * d)
        def lstm_params(kind):
            di = d
            if kind == "mlstm":
                return d * 3 * n_q * hd + 2 * d * n_q + d * n_q * hd \
                    + n_q * hd * d
            return 4 * (d * d + d) + d * d
        for b, fk in zip(self.block_pattern, self.ffn_pattern):
            per_layer = 0
            if b == "attn":
                per_layer += attn_params()
            elif b == "mamba":
                per_layer += mamba_params()
            elif b == "mlstm":
                per_layer += lstm_params("mlstm")
            elif b == "slstm":
                per_layer += lstm_params("slstm")
            if fk == "dense":
                per_layer += dense_ffn()
            elif fk == "moe":
                per_layer += moe_ffn()
            elif fk == "moe+dense":
                per_layer += moe_ffn() + dense_ffn()
            total += per_layer * self.n_periods
        if self.enc_dec:
            # encoder self-attn + ffn, decoder cross-attn already in blocks
            total += self.n_enc_layers * (attn_params() + dense_ffn())
            total += self.n_layers * attn_params()      # cross attention
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        per = 3 * d * f if self.act == "swiglu" else 2 * d * f
        inactive = 0
        for fk in self.ffn_pattern:
            if fk in ("moe", "moe+dense"):
                inactive += (self.n_experts - self.top_k) * per
        return self.param_count() - inactive * self.n_periods

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(self.period, 2 * self.period if self.period == 1
                         else self.period),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads
            < self.n_heads else 4,
            d_ff=128 if self.d_ff else 0,
            head_dim=16,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            n_enc_layers=min(self.n_enc_layers, 2),
            n_patches=min(self.n_patches, 8),
            vocab_pad_multiple=64,
            dtype="float32",
        )
