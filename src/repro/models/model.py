"""Model assembly: decoder-only LMs (dense/MoE/hybrid/SSM), whisper
encoder-decoder, llava VLM backbone — all from one ModelConfig.

Layers are stacked per *period* (config.block_pattern) and compiled with a
single ``lax.scan`` over periods (one XLA While body per arch, essential for
512-device compile times); the period body is rematerialized.

Public surface:
  build_model(cfg) -> Model with
    init_params / abstract_params / param_specs
    loss(params, batch)                      # training forward + CE
    forward(params, batch)                   # logits
    prefill(params, batch)  -> (logits, cache)
    serve_step(params, cache, batch)-> (logits, cache)
    cache_shapes(batch_size, cache_len)
    input_specs(shape_name ...)  — see launch/dryrun.py
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from . import ssm
from .config import ModelConfig
from .layers import (NO_CTX, ShardCtx, apply_norm, attention, cross_entropy,
                     ffn, init_attention, init_ffn, init_linear, init_moe,
                     init_norm, linear, moe_ffn, sinusoidal_pos,
                     spec_attention, spec_ffn, spec_linear, spec_moe,
                     spec_norm)

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


# ========================================================== period building
def _init_slot(key, cfg, kind, ffn_kind, dtype, cross=False):
    ks = jax.random.split(key, 6)
    p = {"ln1": init_norm(cfg.d_model, cfg.norm, dtype)}
    if kind == "attn":
        p["attn"] = init_attention(ks[0], cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = ssm.init_mamba(ks[0], cfg, dtype)
    elif kind == "mlstm":
        p["mlstm"] = ssm.init_mlstm(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["slstm"] = ssm.init_slstm(ks[0], cfg, dtype)
    if cross:
        p["ln_x"] = init_norm(cfg.d_model, cfg.norm, dtype)
        p["xattn"] = init_attention(ks[1], cfg, dtype)
    if ffn_kind != "none":
        p["ln2"] = init_norm(cfg.d_model, cfg.norm, dtype)
        if ffn_kind in ("dense", "moe+dense"):
            p["ffn"] = init_ffn(ks[2], cfg, dtype)
        if ffn_kind in ("moe", "moe+dense"):
            p["moe"] = init_moe(ks[3], cfg, dtype)
    return p


def _spec_slot(cfg, kind, ffn_kind, cross=False):
    s = {"ln1": spec_norm(cfg.norm)}
    if kind == "attn":
        s["attn"] = spec_attention(cfg)
    elif kind == "mamba":
        s["mamba"] = ssm.spec_mamba(cfg)
    elif kind == "mlstm":
        s["mlstm"] = ssm.spec_mlstm(cfg)
    elif kind == "slstm":
        s["slstm"] = ssm.spec_slstm(cfg)
    if cross:
        s["ln_x"] = spec_norm(cfg.norm)
        s["xattn"] = spec_attention(cfg)
    if ffn_kind != "none":
        s["ln2"] = spec_norm(cfg.norm)
        if ffn_kind in ("dense", "moe+dense"):
            s["ffn"] = spec_ffn(cfg)
        if ffn_kind in ("moe", "moe+dense"):
            s["moe"] = spec_moe(cfg)
    return s


def _slot_forward(p, x, cfg, ctx, kind, ffn_kind, *, causal=True,
                  positions=None, cache=None, cache_pos=None, enc=None):
    h = apply_norm(p["ln1"], x, cfg.norm)
    if kind == "attn":
        y, new_cache = attention(p["attn"], h, cfg, ctx, causal=causal,
                                 positions=positions, cache=cache,
                                 cache_pos=cache_pos)
    elif kind == "mamba":
        y, new_cache = ssm.mamba_forward(p["mamba"], h, cfg, ctx,
                                         cache=cache)
    elif kind == "mlstm":
        y, new_cache = ssm.mlstm_forward(p["mlstm"], h, cfg, ctx,
                                         cache=cache)
    else:
        y, new_cache = ssm.slstm_forward(p["slstm"], h, cfg, ctx,
                                         cache=cache)
    x = x + y
    if "xattn" in p:
        h = apply_norm(p["ln_x"], x, cfg.norm)
        xc = cache.get("xcache") if isinstance(cache, dict) else None
        y, new_xc = attention(p["xattn"], h, cfg, ctx, cross=True,
                              kv_src=enc, cache=xc)
        x = x + y
        if new_cache is None:
            new_cache = {}
        if xc is not None or enc is not None:
            new_cache = dict(new_cache or {})
            new_cache["xcache"] = new_xc if new_xc is not None else xc
    if ffn_kind != "none":
        h = apply_norm(p["ln2"], x, cfg.norm)
        y = 0.0
        if "moe" in p:
            y = y + moe_ffn(p["moe"], h, cfg, ctx)
        if "ffn" in p:
            y = y + ffn(p["ffn"], h, cfg, ctx)
        x = x + y
    return x, new_cache


def _slot_cache_shape(cfg, kind, batch, cache_len, dtype, cross=False,
                      enc_len=0):
    if kind == "attn":
        c = {"k": jax.ShapeDtypeStruct(
                (batch, cache_len, cfg.n_kv_heads, cfg.hd), dtype),
             "v": jax.ShapeDtypeStruct(
                (batch, cache_len, cfg.n_kv_heads, cfg.hd), dtype)}
    elif kind == "mamba":
        c = ssm.mamba_cache_shape(cfg, batch, dtype)
    elif kind == "mlstm":
        c = ssm.mlstm_cache_shape(cfg, batch, dtype)
    else:
        c = ssm.slstm_cache_shape(cfg, batch, dtype)
    if cross:
        c["xcache"] = {
            "k": jax.ShapeDtypeStruct(
                (batch, enc_len, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jax.ShapeDtypeStruct(
                (batch, enc_len, cfg.n_kv_heads, cfg.hd), dtype)}
    return c


# ================================================================== model
@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------- params
    def _init_raw(self, rng):
        cfg = self.cfg
        dtype = DTYPES[cfg.dtype]
        keys = jax.random.split(rng, 8)
        cross = cfg.enc_dec
        p = {
            "embed": (jax.random.normal(
                keys[0], (cfg.vocab_padded, cfg.d_model)) * 0.02
            ).astype(dtype),
            "final_norm": init_norm(cfg.d_model, cfg.norm, dtype),
        }
        if not cfg.tie_embeddings:
            p["head"] = init_linear(keys[1], cfg.d_model, cfg.vocab_padded,
                                    False, dtype)
        if cfg.modality == "vlm":
            p["patch_proj"] = init_linear(keys[2], cfg.d_model, cfg.d_model,
                                          True, dtype)

        def init_period(key):
            ks = jax.random.split(key, cfg.period)
            return {f"slot{i}": _init_slot(ks[i], cfg, cfg.block_pattern[i],
                                           cfg.ffn_pattern[i], dtype,
                                           cross=cross)
                    for i in range(cfg.period)}
        p["layers"] = jax.vmap(init_period)(
            jax.random.split(keys[3], cfg.n_periods))

        if cfg.enc_dec:
            def init_enc_layer(key):
                ks = jax.random.split(key, 2)
                return {"ln1": init_norm(cfg.d_model, cfg.norm, dtype),
                        "attn": init_attention(ks[0], cfg, dtype),
                        "ln2": init_norm(cfg.d_model, cfg.norm, dtype),
                        "ffn": init_ffn(ks[1], cfg, dtype)}
            p["enc_layers"] = jax.vmap(init_enc_layer)(
                jax.random.split(keys[4], cfg.n_enc_layers))
            p["enc_norm"] = init_norm(cfg.d_model, cfg.norm, dtype)
        return p

    def init_params(self, rng):
        return self._init_raw(rng)

    def abstract_params(self):
        return jax.eval_shape(self._init_raw, jax.random.key(0))

    def param_specs(self):
        """Role tree matching the param structure (see layers.py docs)."""
        cfg = self.cfg
        cross = cfg.enc_dec
        s = {
            "embed": ("tp", "fsdp"),
            "final_norm": spec_norm(cfg.norm),
        }
        if not cfg.tie_embeddings:
            s["head"] = spec_linear(False, "fsdp", "tp")
        if cfg.modality == "vlm":
            s["patch_proj"] = spec_linear(True, "fsdp", "tp")
        period = {f"slot{i}": _spec_slot(cfg, cfg.block_pattern[i],
                                         cfg.ffn_pattern[i], cross=cross)
                  for i in range(cfg.period)}
        s["layers"] = jax.tree.map(lambda spec: (None,) + tuple(spec),
                                   period,
                                   is_leaf=lambda x: isinstance(x, tuple))
        if cfg.enc_dec:
            enc = {"ln1": spec_norm(cfg.norm), "attn": spec_attention(cfg),
                   "ln2": spec_norm(cfg.norm), "ffn": spec_ffn(cfg)}
            s["enc_layers"] = jax.tree.map(
                lambda spec: (None,) + tuple(spec), enc,
                is_leaf=lambda x: isinstance(x, tuple))
            s["enc_norm"] = spec_norm(cfg.norm)
        return s

    # ------------------------------------------------------------ encoder
    def _encode(self, p, frames, ctx):
        cfg = self.cfg
        x = frames + sinusoidal_pos(frames.shape[1], cfg.d_model,
                                    frames.dtype)[None]

        def body(x, lp):
            h = apply_norm(lp["ln1"], x, cfg.norm)
            y, _ = attention(lp["attn"], h, cfg, ctx, causal=False)
            x = x + y
            h = apply_norm(lp["ln2"], x, cfg.norm)
            return x + ffn(lp["ffn"], h, cfg, ctx), None
        body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, p["enc_layers"])
        return apply_norm(p["enc_norm"], x, cfg.norm)

    # ----------------------------------------------------------- embed in
    def _embed_inputs(self, p, batch, ctx):
        """-> (x (B,S,d), labels (B,S-?) handled by loss, enc_out or None)"""
        cfg = self.cfg
        dtype = p["embed"].dtype
        enc = None
        if cfg.enc_dec:
            enc = self._encode(p, batch["frames"].astype(dtype), ctx)
            x = jnp.take(p["embed"], batch["tokens"], axis=0)
            x = x + sinusoidal_pos(x.shape[1], cfg.d_model, dtype)[None]
        elif cfg.modality == "vlm":
            pe = linear(p["patch_proj"], batch["patches"].astype(dtype))
            te = jnp.take(p["embed"], batch["tokens"], axis=0)
            x = jnp.concatenate([pe, te], axis=1)
        else:
            x = jnp.take(p["embed"], batch["tokens"], axis=0)
        return ctx.constrain(x, "batch", None, None), enc

    def _labels(self, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        if cfg.modality == "vlm":
            b = tokens.shape[0]
            pad = jnp.full((b, cfg_patches(cfg, batch)), -1, tokens.dtype)
            seq = jnp.concatenate([pad, tokens], axis=1)
        else:
            seq = tokens
        return seq[:, 1:]

    def _head_logits(self, params, x, ctx):
        if self.cfg.tie_embeddings:
            logits = x @ params["embed"].T.astype(x.dtype)
        else:
            logits = linear(params["head"], x)
        return ctx.constrain(logits, "batch", None, "tp")

    # ------------------------------------------------------------ forward
    def forward(self, params, batch, ctx=NO_CTX):
        cfg = self.cfg
        x, enc = self._embed_inputs(params, batch, ctx)
        positions = jnp.arange(x.shape[1])

        def body(x, lp):
            for i in range(cfg.period):
                x, _ = _slot_forward(
                    lp[f"slot{i}"], x, cfg, ctx, cfg.block_pattern[i],
                    cfg.ffn_pattern[i], causal=True, positions=positions,
                    enc=enc)
            return x, None
        body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["layers"])
        x = apply_norm(params["final_norm"], x, cfg.norm)
        return self._head_logits(params, x, ctx)

    def loss(self, params, batch, ctx=NO_CTX):
        logits = self.forward(params, batch, ctx)
        labels = self._labels(batch)
        return cross_entropy(logits[:, :-1], labels, self.cfg.vocab)

    # ------------------------------------------------------------ serving
    def cache_shapes(self, batch, cache_len, enc_len=0):
        """cache_len: callers pass min(seq, window) for rolling-ring decode
        (long-context) or the full length for prefill+windowed-mask decode."""
        cfg = self.cfg
        dtype = DTYPES[cfg.dtype]
        period = {
            f"slot{i}": _slot_cache_shape(cfg, cfg.block_pattern[i], batch,
                                          cache_len, dtype,
                                          cross=cfg.enc_dec, enc_len=enc_len)
            for i in range(cfg.period)}

        def stack(sds):
            return jax.ShapeDtypeStruct((cfg.n_periods,) + sds.shape,
                                        sds.dtype)
        return jax.tree.map(stack, period)

    def init_cache(self, batch, cache_len, enc_len=0):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_shapes(batch, cache_len, enc_len))

    def serve_step(self, params, cache, batch, ctx=NO_CTX):
        """One decode step.  batch: {"token": (B,1) i32, "pos": () i32,
        + whisper: nothing extra (cross cache precomputed)}."""
        cfg = self.cfg
        dtype = params["embed"].dtype
        x = jnp.take(params["embed"], batch["token"], axis=0)
        if cfg.enc_dec:
            x = x + sinusoidal_pos(1, cfg.d_model, dtype)[None]
        pos = batch["pos"]
        positions = jnp.full((1,), pos)

        def body(x, scan_in):
            lp, lc = scan_in
            new_c = {}
            for i in range(cfg.period):
                x, nc = _slot_forward(
                    lp[f"slot{i}"], x, cfg, ctx, cfg.block_pattern[i],
                    cfg.ffn_pattern[i], positions=positions,
                    cache=lc[f"slot{i}"], cache_pos=pos, enc=None)
                new_c[f"slot{i}"] = nc
            return x, new_c
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        x = apply_norm(params["final_norm"], x, cfg.norm)
        return self._head_logits(params, x, ctx), new_cache

    def prefill(self, params, batch, cache_len=None, ctx=NO_CTX):
        """Process a full prompt, returning (last-token logits, cache)."""
        cfg = self.cfg
        x, enc = self._embed_inputs(params, batch, ctx)
        b, s, _ = x.shape
        cache_len = cache_len or s
        assert cfg.window is None or cache_len >= s, \
            "rolling-cache prefill not supported; decode token by token"
        cache = self.init_cache(b, cache_len,
                                enc_len=enc.shape[1] if enc is not None
                                else 0)
        positions = jnp.arange(s)

        def body(x, scan_in):
            lp, lc = scan_in
            new_c = {}
            for i in range(cfg.period):
                x, nc = _slot_forward(
                    lp[f"slot{i}"], x, cfg, ctx, cfg.block_pattern[i],
                    cfg.ffn_pattern[i], positions=positions,
                    cache=lc[f"slot{i}"], cache_pos=0, enc=enc)
                new_c[f"slot{i}"] = nc
            return x, new_c
        body = jax.checkpoint(body)
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        x = apply_norm(params["final_norm"], x[:, -1:], cfg.norm)
        return self._head_logits(params, x, ctx), new_cache


def cfg_patches(cfg, batch):
    return batch["patches"].shape[1] if "patches" in batch else 0


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
