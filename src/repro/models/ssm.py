"""State-space and recurrent blocks: Mamba (jamba), mLSTM/sLSTM (xlstm).

Training uses a chunked selective scan: an outer ``lax.scan`` over time
chunks carries the (B, d_inner, d_state) state while an associative scan
runs inside each chunk — the (B, chunk, d_inner, d_state) discretized tensor
is never materialized for the full sequence (the same reason real Mamba
fuses this into a kernel).  Decode is the O(1) recurrent update.

The xLSTM cells follow arXiv:2405.04517 (exponential gating with the m
stabilizer); projection plumbing is simplified (qkv straight from the
residual stream) — noted in DESIGN.md.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import init_linear, linear, spec_linear

MAMBA_CHUNK = 64


# ====================================================================== mamba
def init_mamba(key, cfg, dtype):
    d, di = cfg.d_model, cfg.d_inner
    ds, dc, dr = cfg.d_state, cfg.d_conv, cfg.dt_rank
    ks = jax.random.split(key, 6)
    return {
        "in_proj": init_linear(ks[0], d, 2 * di, False, dtype),
        "conv_w": (jax.random.normal(ks[1], (dc, di)) / math.sqrt(dc)
                   ).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": init_linear(ks[2], di, dr + 2 * ds, False, dtype),
        "dt_proj": init_linear(ks[3], dr, di, True, dtype),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
        ).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": init_linear(ks[4], di, d, False, dtype),
    }


def spec_mamba(cfg):
    return {
        "in_proj": spec_linear(False, "fsdp", "tp"),
        "conv_w": (None, "tp"),
        "conv_b": ("tp",),
        "x_proj": spec_linear(False, "tp", None),
        "dt_proj": spec_linear(True, None, "tp"),
        "A_log": ("tp", None),
        "D": ("tp",),
        "out_proj": spec_linear(False, "tp", "fsdp"),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv1d.  x (B,S,di), w (dc,di).
    state (B, dc-1, di) holds the previous tokens for decode."""
    dc = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)              # (B, S+dc-1, di)
    y = sum(xp[:, j:j + x.shape[1], :] * w[j][None, None, :]
            for j in range(dc))
    new_state = xp[:, -(dc - 1):, :]
    return y + b[None, None, :], new_state


def _ssm_chunk(h0, dA, dBx, C):
    """Associative scan within one chunk.
    h0 (B,di,ds); dA,dBx (B,L,di,ds); C (B,L,ds) -> (y (B,L,di), hL)."""
    def combine(a, b):
        a1, a2 = a
        b1, b2 = b
        return b1 * a1, b2 + b1 * a2
    P, L = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    hs = P * h0[:, None] + L                            # (B,L,di,ds)
    y = jnp.einsum("blds,bls->bld", hs, C)
    return y, hs[:, -1]


def mamba_forward(p, x, cfg, ctx, *, cache=None):
    """x (B,S,d).  cache = {"h": (B,di,ds), "conv": (B,dc-1,di)} for decode
    (S==1).  Returns (y (B,S,d), new_cache)."""
    b, s, _ = x.shape
    di, ds = cfg.d_inner, cfg.d_state
    xz = linear(p["in_proj"], x)
    x1, z = jnp.split(xz, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    x1, new_conv = _causal_conv(x1, p["conv_w"].astype(x.dtype),
                                p["conv_b"].astype(x.dtype), conv_state)
    x1 = jax.nn.silu(x1)
    proj = linear(p["x_proj"], x1)
    dt_r, Bm, Cm = jnp.split(proj, [cfg.dt_rank, cfg.dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(linear(p["dt_proj"], dt_r))    # (B,S,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))        # (di,ds)

    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf[..., None] * A[None, None])        # (B,S,di,ds)
    dBx = (dtf[..., None] * Bm.astype(jnp.float32)[:, :, None, :]
           * x1.astype(jnp.float32)[..., None])

    if cache is not None:                               # decode: S == 1
        h = cache["h"]
        h = dA[:, 0] * h + dBx[:, 0]
        y = jnp.einsum("bds,bs->bd", h, Cm.astype(jnp.float32)[:, 0])[:,
                                                                      None]
        new_cache = {"h": h, "conv": new_conv}
    else:
        h0 = jnp.zeros((b, di, ds), jnp.float32)
        nchunks = max(1, s // MAMBA_CHUNK)
        if s % MAMBA_CHUNK == 0 and nchunks > 1:
            dA_c = dA.reshape(b, nchunks, MAMBA_CHUNK, di, ds)
            dBx_c = dBx.reshape(b, nchunks, MAMBA_CHUNK, di, ds)
            C_c = Cm.astype(jnp.float32).reshape(b, nchunks, MAMBA_CHUNK,
                                                 ds)

            def body(h, inp):
                da, dbx, c = inp
                y, hl = _ssm_chunk(h, da, dbx, c)
                return hl, y
            hL, ys = jax.lax.scan(
                body, h0, (dA_c.swapaxes(0, 1), dBx_c.swapaxes(0, 1),
                           C_c.swapaxes(0, 1)))
            y = ys.swapaxes(0, 1).reshape(b, s, di)
        else:
            y, hL = _ssm_chunk(h0, dA, dBx, Cm.astype(jnp.float32))
        new_cache = {"h": hL, "conv": new_conv}

    y = y.astype(x.dtype) + p["D"].astype(x.dtype)[None, None, :] * x1
    y = y * jax.nn.silu(z)
    return linear(p["out_proj"], y), new_cache


def mamba_cache_shape(cfg, batch, dtype):
    return {
        "h": jax.ShapeDtypeStruct((batch, cfg.d_inner, cfg.d_state),
                                  jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.d_conv - 1, cfg.d_inner),
                                     dtype),
    }


# ====================================================================== mlstm
def init_mlstm(key, cfg, dtype):
    d, nh, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 6)
    return {
        "wq": init_linear(ks[0], d, nh * hd, False, dtype),
        "wk": init_linear(ks[1], d, nh * hd, False, dtype),
        "wv": init_linear(ks[2], d, nh * hd, False, dtype),
        "wi": init_linear(ks[3], d, nh, True, dtype),
        "wf": init_linear(ks[4], d, nh, True, dtype),
        "wo": init_linear(ks[5], d, nh * hd, True, dtype),
        "out": init_linear(jax.random.fold_in(key, 7), nh * hd, d, False,
                           dtype),
    }


def spec_mlstm(cfg):
    return {
        "wq": spec_linear(False, "fsdp", "tp"),
        "wk": spec_linear(False, "fsdp", "tp"),
        "wv": spec_linear(False, "fsdp", "tp"),
        "wi": spec_linear(True, "fsdp", None),
        "wf": spec_linear(True, "fsdp", None),
        "wo": spec_linear(True, "fsdp", "tp"),
        "out": spec_linear(False, "tp", "fsdp"),
    }


def _mlstm_step(state, qkvif):
    """state: (C (B,H,hd,hd), n (B,H,hd), m (B,H)); one time step."""
    C, n, m = state
    q, k, v, ig, fg = qkvif
    m_new = jnp.maximum(fg + m, ig)
    i_p = jnp.exp(ig - m_new)[..., None]                 # (B,H,1)
    f_p = jnp.exp(fg + m - m_new)[..., None]
    C = f_p[..., None] * C + i_p[..., None] * (v[..., :, None]
                                               * k[..., None, :])
    n = f_p * n + i_p * k
    hn = jnp.einsum("bhij,bhj->bhi", C, q)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), 1.0)
    y = hn / denom[..., None]
    return (C, n, m_new), y


def mlstm_forward(p, x, cfg, ctx, *, cache=None):
    b, s, d = x.shape
    nh, hd = cfg.n_heads, cfg.hd
    q = linear(p["wq"], x).reshape(b, s, nh, hd).astype(jnp.float32)
    k = (linear(p["wk"], x).reshape(b, s, nh, hd)
         / math.sqrt(hd)).astype(jnp.float32)
    v = linear(p["wv"], x).reshape(b, s, nh, hd).astype(jnp.float32)
    ig = linear(p["wi"], x).astype(jnp.float32)          # (B,S,H)
    fg = jax.nn.log_sigmoid(linear(p["wf"], x).astype(jnp.float32))
    if cache is None:
        C0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, nh, hd), jnp.float32)
        m0 = jnp.zeros((b, nh), jnp.float32)
    else:
        C0, n0, m0 = cache["C"], cache["n"], cache["m"]
    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          ig.swapaxes(0, 1), fg.swapaxes(0, 1))
    (Cn, nn, mn), ys = jax.lax.scan(_mlstm_step, (C0, n0, m0), xs)
    y = ys.swapaxes(0, 1).reshape(b, s, nh * hd).astype(x.dtype)
    o = jax.nn.sigmoid(linear(p["wo"], x))
    out = linear(p["out"], y * o)
    return out, {"C": Cn, "n": nn, "m": mn}


def mlstm_cache_shape(cfg, batch, dtype):
    nh, hd = cfg.n_heads, cfg.hd
    return {"C": jax.ShapeDtypeStruct((batch, nh, hd, hd), jnp.float32),
            "n": jax.ShapeDtypeStruct((batch, nh, hd), jnp.float32),
            "m": jax.ShapeDtypeStruct((batch, nh), jnp.float32)}


# ====================================================================== slstm
def init_slstm(key, cfg, dtype):
    d, nh, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 9)
    p = {}
    for i, g in enumerate(("z", "i", "f", "o")):
        p[f"w{g}"] = init_linear(ks[i], d, nh * hd, True, dtype)
        p[f"r{g}"] = (jax.random.normal(ks[4 + i], (nh, hd, hd))
                      / math.sqrt(hd)).astype(dtype)
    p["out"] = init_linear(ks[8], nh * hd, d, False, dtype)
    return p


def spec_slstm(cfg):
    p = {}
    for g in ("z", "i", "f", "o"):
        p[f"w{g}"] = spec_linear(True, "fsdp", "tp")
        p[f"r{g}"] = ("tp", None, None)
    p["out"] = spec_linear(False, "tp", "fsdp")
    return p


def _slstm_step(p, state, wx):
    """state: (c, n, m, h) each (B,H,hd); wx: dict of gate pre-activations."""
    c, n, m, h = state

    def rec(g):
        return jnp.einsum("bhd,hde->bhe", h, p[f"r{g}"].astype(jnp.float32))
    z = jnp.tanh(wx["z"] + rec("z"))
    i_t = wx["i"] + rec("i")
    f_t = wx["f"] + rec("f")
    o = jax.nn.sigmoid(wx["o"] + rec("o"))
    m_new = jnp.maximum(f_t + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(f_t + m - m_new)
    c = f_p * c + i_p * z
    n = f_p * n + i_p
    h_new = o * c / jnp.maximum(n, 1.0)
    return (c, n, m_new, h_new), h_new


def slstm_forward(p, x, cfg, ctx, *, cache=None):
    b, s, d = x.shape
    nh, hd = cfg.n_heads, cfg.hd
    wx = {g: linear(p[f"w{g}"], x).reshape(b, s, nh, hd).astype(jnp.float32)
          for g in ("z", "i", "f", "o")}
    if cache is None:
        zeros = jnp.zeros((b, nh, hd), jnp.float32)
        state = (zeros, zeros, zeros, zeros)
    else:
        state = (cache["c"], cache["n"], cache["m"], cache["h"])

    def step(st, inp):
        return _slstm_step(p, st, {g: inp[gi]
                                   for gi, g in enumerate("zifo")})
    xs = tuple(wx[g].swapaxes(0, 1) for g in "zifo")
    (c, n, m, h), ys = jax.lax.scan(step, state, xs)
    y = ys.swapaxes(0, 1).reshape(b, s, nh * hd).astype(x.dtype)
    return linear(p["out"], y), {"c": c, "n": n, "m": m, "h": h}


def slstm_cache_shape(cfg, batch, dtype):
    nh, hd = cfg.n_heads, cfg.hd
    sd = jax.ShapeDtypeStruct((batch, nh, hd), jnp.float32)
    return {"c": sd, "n": sd, "m": sd, "h": sd}
