"""Core layers: norms, RoPE, GQA attention (cached / windowed), dense and
MoE FFNs — pure-JAX functional style.

Params are nested dicts of arrays.  Every init_* has a matching spec_*
returning the same structure with per-dim sharding *roles*:
  'fsdp' (shard over the data/pod axes), 'tp' (tensor-parallel axis),
  'exp' (expert-parallel, mapped to the tp axis), or None.
The launch layer resolves roles to mesh axes (launch/mesh.py).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ----------------------------------------------------------------- context
@dataclasses.dataclass
class ShardCtx:
    """Activation-sharding context; no-op when mesh is None."""
    mesh: object = None
    batch_axes: tuple = ("data",)
    tp_axis: str | None = "model"

    def _axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def constrain(self, x, *roles):
        if self.mesh is None:
            return x
        dims = []
        for dim_size, r in zip(x.shape, roles):
            ax = (self.batch_axes if r == "batch"
                  else self.tp_axis if r == "tp" else None)
            # only shard dims that divide evenly (smoke meshes, odd heads)
            if ax is not None and dim_size % self._axis_size(ax) != 0:
                ax = None
            dims.append(ax)
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, P(*dims)))


NO_CTX = ShardCtx(mesh=None)


# ------------------------------------------------------------------- inits
def _dense_init(key, shape, in_axis=0, dtype=jnp.float32):
    fan_in = shape[in_axis] if in_axis is not None else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def init_linear(key, d_in, d_out, bias=False, dtype=jnp.float32):
    p = {"w": _dense_init(key, (d_in, d_out), 0, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def spec_linear(bias=False, in_role="fsdp", out_role="tp"):
    s = {"w": (in_role, out_role)}
    if bias:
        s["b"] = (out_role,)
    return s


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ------------------------------------------------------------------- norms
def init_norm(d, kind="rms", dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "ln":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def spec_norm(kind="rms"):
    s = {"scale": (None,)}
    if kind == "ln":
        s["bias"] = (None,)
    return s


def apply_norm(p, x, kind="rms", eps=1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rms":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if kind == "ln":
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# -------------------------------------------------------------------- rope
def rope_angles(positions, hd, theta=10000.0):
    """positions (...,) -> (cos, sin) of shape (..., hd//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (B,S,H,hd); cos/sin (B,S,hd//2) or (S,hd//2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1).astype(x.dtype)


def sinusoidal_pos(seq, d, dtype):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = 1.0 / (10000 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)],
                           axis=-1).astype(dtype)


# --------------------------------------------------------------- attention
def init_attention(key, cfg, dtype, cross=False):
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, cfg.n_heads * hd, cfg.qkv_bias, dtype),
        "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd, cfg.qkv_bias,
                          dtype),
        "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd, cfg.qkv_bias,
                          dtype),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d, False, dtype),
    }


def spec_attention(cfg):
    return {
        "wq": spec_linear(cfg.qkv_bias, "fsdp", "tp"),
        "wk": spec_linear(cfg.qkv_bias, "fsdp", "tp"),
        "wv": spec_linear(cfg.qkv_bias, "fsdp", "tp"),
        "wo": spec_linear(False, "tp", "fsdp"),
    }


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    b, s, kh, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :],
                            (b, s, kh, n_rep, hd)).reshape(b, s, kh * n_rep,
                                                           hd)


def _sdpa(q, k, v, mask):
    """q (B,Sq,H,hd), k/v (B,Sk,H,hd), mask broadcastable (B,1,Sq,Sk)."""
    hd = q.shape[-1]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)
    return out


def _sdpa_grouped(q, k, v, mask, n_rep):
    """GQA without materializing repeated KV heads: q regrouped to
    (B,Sq,K,G,hd) and contracted against k/v (B,Sk,K,hd) directly.
    Cuts the decode memory term by G (§Perf iteration 'gqa_grouped').
    Inputs stay in cache dtype (bf16 on TPU) with f32 accumulation —
    upcasting inputs makes XLA hoist a whole-cache convert (§Perf log)."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    qg = (q.astype(jnp.float32) / math.sqrt(hd)).astype(k.dtype)
    qg = qg.reshape(b, sq, kh, n_rep, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    logits = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask,
                       logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, hd).astype(v.dtype)


CHUNK_KV = 1024


def _sdpa_chunked(q, k, v, q_pos, k_pos, window, n_rep, chunk=CHUNK_KV):
    """Flash-style attention: lax.scan over KV chunks with running
    (max, sum, acc) — never materializes the (Sq, Sk) score matrix
    (§Perf iteration 'attn_impl=chunked').  Grouped GQA built in.
    q (B,Sq,H,hd); k/v (B,Sk,K,hd); positions give causal/window masks."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kh = k.shape[2]
    if sk % chunk != 0:
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=jnp.iinfo(
            jnp.int32).max)
        sk += pad
    nc = sk // chunk
    qg = (q.astype(jnp.float32) / math.sqrt(hd)).astype(k.dtype)
    qg = qg.reshape(b, sq, kh, n_rep, hd)
    kc = k.reshape(b, nc, chunk, kh, hd).swapaxes(0, 1)
    vc = v.reshape(b, nc, chunk, kh, hd).swapaxes(0, 1)
    pc = k_pos.reshape(nc, chunk)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp
        # cache-dtype inputs, f32 accumulation (MXU-native; input upcasts
        # get hoisted into whole-cache converts by XLA)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kb,
                       preferred_element_type=jnp.float32)
        valid = pb[None, :] <= q_pos[:, None]
        if window is not None:
            valid &= (q_pos[:, None] - pb[None, :]) < window
        s = jnp.where(valid[None, None, None], s, -1e30)
        m2 = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m2[..., None])
        corr = jnp.exp(m - m2)
        l2 = l * corr + p.sum(axis=-1)
        acc2 = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m2, l2, acc2), None

    m0 = jnp.full((b, kh, n_rep, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kh, n_rep, sq), jnp.float32)
    a0 = jnp.zeros((b, kh, n_rep, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(v.dtype)


def attention(p, x, cfg, ctx, *, causal=True, positions=None,
              cache=None, cache_pos=None, kv_src=None, cross=False):
    """GQA attention.

    Self-attention decode: ``cache`` dict(k, v) (B, S_cache, K, hd) — the
    new token is written at ``cache_pos`` (rolling slot for sliding-window
    configs, keys are rope'd at write time with absolute positions), then
    attends over the valid prefix.
    Cross-attention (``cross=True``): keys/values come from ``kv_src``
    (encoder output) or, at decode, from a precomputed ``cache``.
    Returns (out, new_cache)."""
    b, s, d = x.shape
    hd, nh, nkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = linear(p["wq"], x).reshape(b, s, nh, hd)
    if cross and kv_src is None:
        k, v = cache["k"], cache["v"]                  # precomputed cross kv
    else:
        src = kv_src if cross else x
        k = linear(p["wk"], src).reshape(b, -1, nkv, hd)
        v = linear(p["wv"], src).reshape(b, -1, nkv, hd)

    if positions is None:
        positions = jnp.arange(s)
    if cfg.rope and not cross:
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    new_cache = cache
    rolling = False
    kv_positions = None
    if cross:
        if kv_src is not None and cache is not None:
            new_cache = {"k": k, "v": v}
        mask = jnp.ones((1, 1, 1, 1), bool)            # full cross attention
    elif cache is not None:
        s_cache = cache["k"].shape[1]
        rolling = cfg.window is not None and s_cache == cfg.window
        if rolling:
            assert s == 1, "rolling-window cache supports single-token decode"
            slot = cache_pos % s_cache
        else:
            slot = cache_pos
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        idx = jnp.arange(s_cache)
        kv_positions = idx
        if rolling:
            # all slots valid once the ring has wrapped
            valid = ((idx <= cache_pos) | (cache_pos >= s_cache - 1))[
                None, :]
        else:
            # positions are the absolute query positions (s of them,
            # starting at cache_pos) — supports multi-token prefill
            valid = idx[None, :] <= positions[:, None]
            if cfg.window is not None:
                valid &= (positions[:, None] - idx[None, :]) < cfg.window
        mask = valid[None, None, :, :] if valid.ndim == 2 else \
            valid[None, None, None, :]
    else:
        sk = k.shape[1]
        kv_positions = jnp.arange(sk)
        qi = positions[:, None]
        ki = kv_positions[None, :]
        if causal:
            m = ki <= qi
            if cfg.window is not None:
                m = m & (qi - ki < cfg.window)
        else:
            m = jnp.ones((s, sk), bool)
        mask = m[None, None, :, :]

    n_rep = nh // nkv
    # chunked attention pays off for multi-token queries (train/prefill);
    # single-token decode's score matrix is small — the grouped path
    # (selected via cfg.gqa_grouped in opt mode) handles it instead
    use_chunked = (cfg.attn_impl == "chunked" and not cross and not rolling
                   and s > 1 and k.shape[1] >= 2 * CHUNK_KV)
    if use_chunked:
        if causal or cache is not None:
            q_pos = positions
        else:
            q_pos = jnp.full((s,), jnp.iinfo(jnp.int32).max - 1)
        out = _sdpa_chunked(q, k, v, q_pos, kv_positions, cfg.window,
                            n_rep)
    elif cfg.gqa_grouped and n_rep > 1:
        out = _sdpa_grouped(q, k, v, mask, n_rep)
    else:
        k = _repeat_kv(k, n_rep)
        v = _repeat_kv(v, n_rep)
        out = _sdpa(q, k, v, mask)
    out = ctx.constrain(out.reshape(b, s, nh * hd), "batch", None, "tp")
    return linear(p["wo"], out), new_cache


# --------------------------------------------------------------- dense FFN
def init_ffn(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {"w1": init_linear(ks[0], d, f, False, dtype),
                "w3": init_linear(ks[1], d, f, False, dtype),
                "w2": init_linear(ks[2], f, d, False, dtype)}
    return {"w1": init_linear(ks[0], d, f, True, dtype),
            "w2": init_linear(ks[2], f, d, True, dtype)}


def spec_ffn(cfg):
    if cfg.act == "swiglu":
        return {"w1": spec_linear(False, "fsdp", "tp"),
                "w3": spec_linear(False, "fsdp", "tp"),
                "w2": spec_linear(False, "tp", "fsdp")}
    return {"w1": spec_linear(True, "fsdp", "tp"),
            "w2": spec_linear(True, "tp", "fsdp")}


def ffn(p, x, cfg, ctx):
    if cfg.act == "swiglu":
        h = jax.nn.silu(linear(p["w1"], x)) * linear(p["w3"], x)
    else:
        h = jax.nn.gelu(linear(p["w1"], x))
    h = ctx.constrain(h, "batch", None, "tp")
    return linear(p["w2"], h)


# ---------------------------------------------------------------- MoE FFN
def init_moe(key, cfg, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    p = {
        "wg": init_linear(ks[0], d, e, False, dtype),
        "w1": (jax.random.normal(ks[1], (e, d, f)) * std).astype(dtype),
        "w2": (jax.random.normal(ks[2], (e, f, d))
               / math.sqrt(f)).astype(dtype),
    }
    if cfg.act == "swiglu":
        p["w3"] = (jax.random.normal(ks[3], (e, d, f)) * std).astype(dtype)
    return p


def spec_moe(cfg):
    s = {"wg": spec_linear(False, "fsdp", None),
         "w1": ("exp", "fsdp", None),
         "w2": ("exp", None, "fsdp")}
    if cfg.act == "swiglu":
        s["w3"] = ("exp", "fsdp", None)
    return s


def moe_ffn(p, x, cfg, ctx):
    """Top-k expert routing with static capacity (GShard-style, sort-based
    dispatch so FLOPs stay ~6*N_active*D — see DESIGN.md §5)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)
    gates = jax.nn.softmax(
        linear(p["wg"], xf).astype(jnp.float32), axis=-1)   # (T, E)
    topv, topi = jax.lax.top_k(gates, k)                     # (T, k)
    topv = topv / jnp.clip(topv.sum(-1, keepdims=True), 1e-9)

    cap = int(math.ceil(k * t / e * cfg.capacity_factor))
    cap = max(cap, 1)

    # flatten assignments, sort by expert, compute slot in expert buffer
    eid = topi.reshape(-1)                                   # (T*k,)
    tok = jnp.repeat(jnp.arange(t), k)
    wgt = topv.reshape(-1)
    order = jnp.argsort(eid, stable=True)
    eid_s, tok_s, wgt_s = eid[order], tok[order], wgt[order]
    # position within expert segment
    seg_start = jnp.searchsorted(eid_s, jnp.arange(e))       # (E,)
    pos_in_e = jnp.arange(t * k) - seg_start[eid_s]
    keep = pos_in_e < cap
    slot = jnp.where(keep, eid_s * cap + pos_in_e, e * cap)  # overflow slot

    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(
        xf[tok_s])
    # expert-parallel: shard the expert dim over the tp axis (all-to-all)
    buf = ctx.constrain(buf[: e * cap].reshape(e, cap, d), "tp", None, None)

    h1 = jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(x.dtype))
    if cfg.act == "swiglu":
        h1 = jax.nn.silu(h1) * jnp.einsum("ecd,edf->ecf", buf,
                                          p["w3"].astype(x.dtype))
    else:
        h1 = jax.nn.gelu(h1)
    out_e = jnp.einsum("ecf,efd->ecd", h1, p["w2"].astype(x.dtype))

    flat = jnp.concatenate([out_e.reshape(e * cap, d),
                            jnp.zeros((1, d), x.dtype)], axis=0)
    contrib = flat[slot] * wgt_s[:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[tok_s].add(
        jnp.where(keep[:, None], contrib, 0))
    return y.reshape(b, s, d)


# ------------------------------------------------------------------- loss
def cross_entropy(logits, labels, vocab_real):
    """logits (B,S,Vp); labels (B,S) with -1 = ignore (modality frontends,
    padding).  Padded vocab columns are masked out of the softmax."""
    vp = logits.shape[-1]
    if vp > vocab_real:
        col = jnp.arange(vp)
        logits = jnp.where(col[None, None, :] < vocab_real, logits, -1e30)
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(mask, lse - gold, 0.0)
    return nll.sum() / jnp.maximum(mask.sum(), 1)
