"""Leveled compaction with dynamic level sizing and the paper's
compensated-size strategy (paper §III-C; DESIGN.md §7).

Vanilla mode scores levels by *physical* bytes — which, after KV separation,
are tiny (the paper measures 211KB kSSTs vs 64MB), delaying compaction and
inflating the index LSM-tree's space amplification (hidden garbage).

Compensated mode scores levels, picks files, and cuts output files by
``file_bytes + referenced value bytes`` — "converting a separated LSM-tree
into a non-separated one": the index tree re-acquires the vanilla multi-level
shape (S_index -> ~1.11 at ratio 10) and pushes high-density files down so
hidden garbage is exposed promptly.
"""

from __future__ import annotations

import numpy as np

from .engine import io as sio
from .engine.tables import ETYPE_REF, ETYPE_TOMB, SSTable, KIND_KEY


def compute_targets(store):
    """RocksDB dynamic-level-bytes: data targets the bottom level; level
    targets derived from the actual last-level size; returns
    (targets, base_level).  Level weights come from the engine strategy
    (compensated bytes under the paper's §III-C scoring)."""
    cfg = store.cfg
    v = store.version
    last = cfg.max_levels - 1
    s_last = store.strategy.level_weight(v, last)
    targets = [0] * cfg.max_levels
    t = float(max(s_last, cfg.base_level_bytes))
    targets[last] = t
    base_level = last
    for i in range(last - 1, 0, -1):
        t = t / cfg.level_ratio
        if t < cfg.base_level_bytes / cfg.level_ratio:
            break
        targets[i] = max(t, 1.0)
        base_level = i
    return targets, base_level


def level_scores(store):
    """-> list of (score, level). L0 scores by file count; others by
    (compensated) bytes / target."""
    cfg = store.cfg
    v = store.version
    targets, base_level = compute_targets(store)
    scores = [(len(v.levels[0]) / cfg.l0_trigger, 0)]
    last = cfg.max_levels - 1
    for i in range(base_level, last):
        if not v.levels[i]:
            continue
        size = store.strategy.level_weight(v, i)
        if targets[i] > 0:
            scores.append((size / targets[i], i))
    return scores, base_level


def pick_compaction(store):
    scores, base_level = level_scores(store)
    score, level = max(scores, key=lambda s: s[0])
    if score < 1.0:
        return None
    return level, base_level


def _merge_inputs(store, inputs: list[SSTable], drop_tombstones: bool):
    """Merge sorted runs newest-wins; returns (kept arrays, dropped arrays)."""
    keys = np.concatenate([t.keys for t in inputs])
    seqs = np.concatenate([t.seqs for t in inputs])
    ety = np.concatenate([t.etype for t in inputs])
    vids = np.concatenate([t.vids for t in inputs])
    vsz = np.concatenate([t.vsizes for t in inputs])
    vf = np.concatenate([t.vfiles for t in inputs])
    # sort by (key asc, seq desc): lexsort uses last key as primary
    order = np.lexsort((np.iinfo(np.uint64).max - seqs, keys))
    keys, seqs, ety, vids, vsz, vf = (a[order] for a in
                                      (keys, seqs, ety, vids, vsz, vf))
    first = np.ones(len(keys), bool)
    first[1:] = keys[1:] != keys[:-1]
    kept = first.copy()
    dropped = ~first
    if drop_tombstones:
        kept &= ety != ETYPE_TOMB
    return ((keys[kept], seqs[kept], ety[kept], vids[kept], vsz[kept],
             vf[kept]),
            (keys[dropped], ety[dropped], vids[dropped], vsz[dropped],
             vf[dropped]))


def _cut_outputs(store, arrays):
    """Cut merged entries into kSSTs at the *physical* target size.

    Compensation affects level scores and input-file selection (paper
    §III-C / RocksDB compensated_file_size semantics), never the physical
    output file size."""
    cfg = store.cfg
    keys, seqs, ety, vids, vsz, vf = arrays
    n = len(keys)
    if n == 0:
        return []
    rec = np.where(ety == ETYPE_REF, cfg.ref_rec_bytes(),
                   np.where(ety == ETYPE_TOMB, cfg.tomb_rec_bytes(),
                            cfg.inline_rec_bytes(vsz)))
    weight = rec.astype(np.int64)
    cum = np.cumsum(weight)
    file_no = ((cum - weight) // cfg.ksst_bytes).astype(np.int64)
    outs = []
    for f in np.unique(file_no):
        m = file_no == f
        t = SSTable(cfg, KIND_KEY, cfg.ksst_layout, keys[m], seqs[m],
                    ety[m], vids[m], vsz[m], vf[m])
        t.compensated_extra = int(vsz[m][ety[m] == ETYPE_REF].sum())
        outs.append(t)
    return outs


def run_compaction(store, level: int, base_level: int) -> None:
    cfg = store.cfg
    v = store.version
    last = cfg.max_levels - 1

    if level == 0:
        ups = list(v.levels[0])
        if not ups:
            return
        out_level = base_level
        lo = min(t.min_key for t in ups)
        hi = max(t.max_key for t in ups)
    else:
        files = v.levels[level]
        if not files:
            return
        # One job models a round of parallel subcompactions: move enough
        # files to bring the level back under target (cap 8 per job).
        targets, _ = compute_targets(store)
        sz = store.strategy.file_weight
        overshoot = sum(sz(t) for t in files) - targets[level]
        ranked = store.strategy.rank_compaction_inputs(store, files, level)
        ups, moved = [], 0
        for t in ranked:
            ups.append(t)
            moved += sz(t)
            if moved >= overshoot or len(ups) >= cfg.compaction_pick_cap:
                break
        out_level = level + 1
        lo = min(t.min_key for t in ups)
        hi = max(t.max_key for t in ups)

    downs = v.overlapping(out_level, lo, hi)
    inputs = ups + downs
    drop_tomb = out_level == last
    kept, dropped = _merge_inputs(store, inputs, drop_tomb)

    # ---- I/O ----
    in_bytes = sum(t.file_bytes for t in inputs)
    if cfg.readahead_compaction:
        store.io.seq_read(in_bytes, sio.CAT_COMPACT_READ)
    else:
        for t in inputs:
            for b in range(t.n_data_blocks):
                store.io.rand_read(cfg.block_size, sio.CAT_COMPACT_READ)

    # ---- engine hook: compaction-triggered relocation (BlobDB) ----
    kept = store.strategy.on_compaction_kept(store, kept)

    outs = _cut_outputs(store, kept)
    for t in outs:
        store.io.seq_write(t.file_bytes, sio.CAT_COMPACT_WRITE)
    store._crashpoint("mid_compaction")   # outputs written, version not yet
    #                                       updated (DESIGN.md §9)

    # ---- version update ----
    if level == 0:
        v.levels[0] = []
    else:
        v.levels[level] = [t for t in v.levels[level] if t not in ups]
        v._bounds_cache.pop(level, None)
    remain = [t for t in v.levels[out_level] if t not in downs]
    v.set_level(out_level, remain + outs)
    for t in inputs:
        store.cache.erase_file(t.fid)
    if store.durability is not None:
        for t in inputs:
            store._log_edit("drop_file", fid=t.fid)
        for t in outs:
            store._log_edit("add_file", fid=t.fid, level=out_level,
                            nbytes=t.file_bytes)

    # ---- garbage exposure + DropCache (paper §II-D, §III-B.3) ----
    dk, de, dvid, dvsz, dvf = dropped
    store.expose_garbage(dk, de, dvid, dvsz, dvf)
    if cfg.hotcold_write and len(dk):
        store.dropcache.record(dk)
    store.n_compactions += 1


def maybe_compact(store, max_rounds: int = 10_000) -> None:
    for _ in range(max_rounds):
        pick = pick_compaction(store)
        if pick is None:
            return
        run_compaction(store, *pick)
