"""Block cache (two-priority LRU, RocksDB-style) and DropCache
(DESIGN.md §2).

BlockCache models RocksDB's LRUCache with a high-priority pool: blocks
inserted at high priority (index/filter blocks, and — Scavenger §III-B.2 —
DTable's KF index-key blocks) are kept in a protected pool; low-priority data
blocks evict first.  Capacity is in bytes; hits/misses are counted so
benchmarks can reproduce the paper's cache-hit-ratio analysis (§II-C).

DropCache (Scavenger §III-B.3) is an LRU *key* cache recording keys dropped
during compaction (over-written / deleted versions = hot-write data).  Flush
and GC consult it to route records to hot vs cold vSSTs.  32B/key accounting.
"""

from __future__ import annotations

from collections import OrderedDict

import numpy as np


class BlockCache:
    PRI_LOW = 0
    PRI_HIGH = 1

    def __init__(self, capacity_bytes: int, high_pri_frac: float = 0.5):
        self.capacity = int(capacity_bytes)
        self.high_capacity = int(capacity_bytes * high_pri_frac)
        self._low: OrderedDict = OrderedDict()   # key -> nbytes
        self._high: OrderedDict = OrderedDict()
        self.low_bytes = 0
        self.high_bytes = 0
        self.hits = 0
        self.misses = 0

    # ------------------------------------------------------------------ api
    def get(self, key) -> bool:
        if key in self._high:
            self._high.move_to_end(key)
            self.hits += 1
            return True
        if key in self._low:
            self._low.move_to_end(key)
            self.hits += 1
            return True
        self.misses += 1
        return False

    def put(self, key, nbytes: int, priority: int = PRI_LOW) -> None:
        nbytes = int(nbytes)
        if nbytes > self.capacity:
            return
        self.erase(key)
        if priority == self.PRI_HIGH:
            self._high[key] = nbytes
            self.high_bytes += nbytes
        else:
            self._low[key] = nbytes
            self.low_bytes += nbytes
        self._evict()

    def probe_records(self, fid: int, stream: str, positions, nbytes,
                      priority: int = PRI_LOW) -> np.ndarray:
        """Batched lookup-or-insert for per-record cache keys.

        For each position (in order): a resident record counts a hit and
        is touched; a missing one is inserted at ``priority`` — exactly the
        get-then-put-on-miss sequence of the scalar path, so LRU state and
        hit/miss counters stay byte-identical.  Returns the hit mask."""
        hits = np.empty(len(positions), bool)
        for i, (p, nb) in enumerate(zip(np.asarray(positions).tolist(),
                                        np.asarray(nbytes).tolist())):
            ck = (fid, stream, p)
            if self.get(ck):
                hits[i] = True
            else:
                hits[i] = False
                self.put(ck, int(nb), priority)
        return hits

    def erase(self, key) -> None:
        if key in self._high:
            self.high_bytes -= self._high.pop(key)
        elif key in self._low:
            self.low_bytes -= self._low.pop(key)

    def erase_file(self, fid: int) -> None:
        """Drop all blocks of a deleted file (active replacement, §III-B.2)."""
        for q, attr in ((self._high, "high_bytes"), (self._low, "low_bytes")):
            dead = [k for k in q if k[0] == fid]
            for k in dead:
                setattr(self, attr, getattr(self, attr) - q.pop(k))

    @property
    def used(self) -> int:
        return self.low_bytes + self.high_bytes

    def hit_ratio(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def reset_stats(self) -> None:
        self.hits = self.misses = 0

    # ------------------------------------------------------------- internal
    def _evict(self) -> None:
        while self.used > self.capacity:
            # Evict from the low-pri pool first; only shrink the high-pri
            # pool when it exceeds its reserved share (RocksDB behaviour).
            if self._low and (self.high_bytes <= self.high_capacity
                              or not self._high):
                _, nb = self._low.popitem(last=False)
                self.low_bytes -= nb
            elif self._high:
                _, nb = self._high.popitem(last=False)
                self.high_bytes -= nb
            else:
                break


class DropCache:
    """LRU of keys dropped during compaction (hot-write detection)."""

    BYTES_PER_KEY = 32

    def __init__(self, capacity_keys: int):
        self.capacity = int(capacity_keys)
        self._lru: OrderedDict = OrderedDict()
        self.record_count = 0

    def record(self, keys: np.ndarray) -> None:
        """Record keys dropped during a compaction merge."""
        if self.capacity <= 0:
            return
        for k in np.asarray(keys, dtype=np.uint64).tolist():
            if k in self._lru:
                self._lru.move_to_end(k)
            else:
                self._lru[k] = None
            self.record_count += 1
        while len(self._lru) > self.capacity:
            self._lru.popitem(last=False)

    def is_hot(self, keys: np.ndarray) -> np.ndarray:
        """Vectorized hotness test (does NOT touch LRU order: a probe is not
        a write-hotness signal)."""
        ks = np.asarray(keys, dtype=np.uint64)
        member = self._lru
        return np.fromiter((k in member for k in ks.tolist()),
                           dtype=bool, count=len(ks))

    @property
    def nbytes(self) -> int:
        return len(self._lru) * self.BYTES_PER_KEY
