"""Engine substrate: config, simulated device, tables, memtable, caches,
version (DESIGN.md §2-§3)."""

from .config import EngineConfig, ENGINES
from .io import SimIO, DeviceModel
from .cache import BlockCache, DropCache
from .memtable import Memtable
from .tables import (SSTable, build_ksst, build_vsst, ETYPE_INLINE,
                     ETYPE_REF, ETYPE_TOMB, KIND_KEY, KIND_VALUE)
from .version import Version
from .keys import BloomFilter, splitmix64, hash_family

__all__ = [
    "EngineConfig", "ENGINES", "SimIO", "DeviceModel", "BlockCache",
    "DropCache", "Memtable", "SSTable", "build_ksst", "build_vsst",
    "ETYPE_INLINE", "ETYPE_REF", "ETYPE_TOMB", "KIND_KEY", "KIND_VALUE",
    "Version", "BloomFilter", "splitmix64", "hash_family",
]
