"""Engine configuration (DESIGN.md §3).

Defaults follow the paper's setup (§IV-A, RocksDB tuning guide): 24B keys,
512B separation threshold, 64MB memtable/kSST, 256MB vSST, 10 bits/key bloom
filters, block cache = 1% of dataset, garbage-ratio threshold 0.2, inter-level
ratio 10, dynamic level sizing.  ``scaled()`` shrinks all absolute sizes while
holding every structural ratio, so laptop-scale runs reproduce the paper's
amplification behaviour.

Feature flags map to the paper's ablation variants (Fig. 16/17):
  - TDB      : engine="terarkdb"
  - TDB-C    : engine="terarkdb", compensated_compaction=True
  - Scavenger: engine="scavenger" (compensated + R lazy-read + L dtable +
               W hot/cold; each independently toggleable)
"""

from __future__ import annotations

import dataclasses

# Canonical built-in engine list (the five paper engines + hybrid +
# scavenger_adaptive).  The source of truth is the strategy registry
# (``repro.core.engines``) — this tuple exists so callers can enumerate
# engines without importing it; ``tests/test_engines_registry.py`` asserts
# the two stay in sync.
ENGINES = ("rocksdb", "blobdb", "titan", "terarkdb", "scavenger", "hybrid",
           "scavenger_adaptive")


@dataclasses.dataclass
class EngineConfig:
    engine: str = "scavenger"

    # ---- record format (bytes) ----
    key_bytes: int = 24
    seq_bytes: int = 8
    rec_header_bytes: int = 8
    ref_bytes: int = 16            # <file_number, size/offset> locator
    block_size: int = 4096
    block_overhead: int = 32
    index_entry_extra: int = 8     # offset field in index entries
    footer_bytes: int = 48
    filter_bits_per_key: int = 10
    wal_rec_overhead: int = 12     # per-record WAL framing (seq + header)

    # ---- structure sizes ----
    memtable_bytes: int = 64 << 20
    ksst_bytes: int = 64 << 20
    vsst_bytes: int = 256 << 20
    base_level_bytes: int = 256 << 20   # max_bytes_for_level_base
    level_ratio: int = 10
    max_levels: int = 7
    l0_trigger: int = 4
    l0_slowdown: int = 12
    l0_stop: int = 20

    # ---- cache ----
    cache_bytes: int = 1 << 30
    cache_high_frac: float = 0.5
    dropcache_keys: int = 4096

    # ---- write pressure ----
    max_immutables: int = 2         # immutable memtables before write stall
    delayed_write_rate: float = 16.0   # MB/s, RocksDB default under slowdown

    # ---- KV separation & GC ----
    sep_threshold: int = 512
    hybrid_large_threshold: int = 8 << 10   # hybrid engine: always-separate
    gc_scheme: str | None = None    # None -> engine default (validated)
    gc_garbage_ratio: float = 0.2
    gc_aggressive_ratio: float = 0.05
    gc_batch_files: int = 4         # max candidate vSSTs merged per GC run
    gc_batch_cap: int = 32          # hard cap on files per GC batch
    blobdb_age_cutoff: float = 0.25

    # ---- compaction job sizing ----
    compaction_pick_cap: int = 64   # max input files picked per compaction

    # ---- space management ----
    space_quota_bytes: int | None = None
    soft_quota_frac: float = 0.9
    slowdown_us_per_write: float = 20.0
    quota_stall_rounds: int = 256   # forced-GC rounds per stalled write call

    # ---- scan retry ----
    scan_retry_rounds: int = 32     # max refill rounds per scan call
    scan_retry_growth: int = 4      # per-source limit multiplier per round

    # ---- I/O behaviour ----
    readahead_gc: bool = False      # paper disables GC readahead by default
    readahead_compaction: bool = True

    # ---- Scavenger feature flags (paper ablations) ----
    compensated_compaction: bool | None = None   # None -> per-engine default
    lazy_read: bool | None = None                # R: RTable dense-index read
    index_decoupled: bool | None = None          # L: DTable KF/KV split
    hotcold_write: bool | None = None            # W: DropCache routing

    # ---- adaptive workload tracking (core/adaptive/, DESIGN.md §8) ----
    adaptive_enabled: bool | None = None    # None -> per-engine default
    adaptive_groups: int = 1024             # lifetime/temperature key-groups
    adaptive_sketch_width: int = 4096       # decayed-frequency sketch width
    adaptive_sketch_depth: int = 2          # count-min rows
    adaptive_half_life_ops: float = 50_000.0   # decay half-life, user ops
    adaptive_gc_horizon_ops: float = 25_000.0  # dead-byte prediction window
    adaptive_defer_weight: float = 0.7      # GC deferral strength, [0, 1]
    adaptive_score_refresh_ops: int = 2048  # candidate-score cache window
    temp_hot_mult: float = 4.0              # hot: rate >= mult * mean rate
    temp_cold_mult: float = 0.5             # cold: rate <= mult * mean rate
    adaptive_residual_floor: float = 0.1    # min residual lifetime, frac of mean

    # ---- kernel acceleration (repro.kernels via core/accel.py, §12) ----
    # Byte-identical routing of the batched hot paths through jitted
    # kernels; ``coalesce_window`` also bounds host-planned fetch runs
    # (None -> adjacency only), so it is a semantic knob, not a kernel one.
    use_kernels: bool = True
    kernel_interpret: bool | None = None    # None -> auto (resolve_mode)
    kernel_min_batch: int = 128             # below this, stay on the host
    coalesce_window: int | None = None      # max records per coalesced run

    # ---- elastic fleet: live split/merge + replication (§14) ----
    # All off by default: a fleet with elasticity off is byte-identical to
    # the static ShardedStore (golden-locked in tests/test_sharding.py).
    elastic_split_frac: float | None = None  # split when a shard's space or
    #                                          traffic share exceeds this
    elastic_merge_frac: float = 0.0          # merge a shard whose share
    #                                          fell below this (0 = never)
    elastic_max_shards: int = 8              # split ceiling
    elastic_cooldown_ops: int = 1024         # fleet user ops between
    #                                          trigger evaluations
    migration_chunk_records: int = 512       # records copied per pump step
    replica_count: int = 0                   # N-way replication per shard
    replica_lag_ops: int = 32                # applied-op lag per replica
    #                                          rank (replica 0 is synchronous)

    # ---- observability (repro.obs, DESIGN.md §11) ----
    # Hook object receiving spans/metrics/health ticks from the core; None
    # resolves to the no-op NullObserver (observability-off runs are
    # byte-identical to un-instrumented ones).  Excluded from persistence:
    # ``state_dict()`` strips it, so MANIFEST config edits and snapshots
    # stay JSON and a recovered store starts unobserved (re-attach via
    # ``Store.open(..., observer=...)``).
    observer: object | None = None

    def __post_init__(self):
        # lazy import: the strategy modules import table/IO substrate, which
        # imports this module — resolving at construction breaks the cycle
        from ..engines import get_strategy_class
        strat = get_strategy_class(self.engine)   # raises on unknown engine
        self.kv_separated = strat.kv_separated
        if self.gc_scheme is None:
            self.gc_scheme = strat.gc_schemes[0]
        elif self.gc_scheme not in strat.gc_schemes:
            raise ValueError(
                f"engine {self.engine!r} does not support gc_scheme "
                f"{self.gc_scheme!r} (supported: "
                f"{', '.join(strat.gc_schemes)})")
        for flag in ("compensated_compaction", "lazy_read",
                     "index_decoupled", "hotcold_write", "adaptive_enabled"):
            if getattr(self, flag) is None:
                setattr(self, flag, getattr(strat, flag))
        if self.adaptive_enabled and not strat.adaptive_enabled:
            # only strategies that construct a tracker honor the flag; a
            # silent no-op would masquerade as workload-adaptive GC
            raise ValueError(
                f"engine {self.engine!r} does not support "
                f"adaptive_enabled=True (use engine='scavenger_adaptive')")
        self._validate_adaptive()
        self._validate_kernels()
        self._validate_elastic()

    def _validate_adaptive(self):
        """Bounds for the adaptive-tracker knobs (always checked: the
        fields exist on every engine even when tracking is off)."""
        for field in ("adaptive_groups", "adaptive_sketch_width",
                      "adaptive_sketch_depth"):
            if getattr(self, field) < 1:
                raise ValueError(f"{field} must be >= 1, got "
                                 f"{getattr(self, field)}")
        for field in ("adaptive_half_life_ops", "adaptive_gc_horizon_ops",
                      "adaptive_score_refresh_ops"):
            if getattr(self, field) <= 0:
                raise ValueError(f"{field} must be > 0, got "
                                 f"{getattr(self, field)}")
        if not 0.0 < self.adaptive_residual_floor <= 1.0:
            raise ValueError("adaptive_residual_floor must be in (0, 1], got "
                             f"{self.adaptive_residual_floor}")
        if not 0.0 <= self.adaptive_defer_weight <= 1.0:
            raise ValueError("adaptive_defer_weight must be in [0, 1], got "
                             f"{self.adaptive_defer_weight}")
        if not 0.0 <= self.temp_cold_mult < self.temp_hot_mult:
            raise ValueError(
                "need 0 <= temp_cold_mult < temp_hot_mult, got "
                f"{self.temp_cold_mult} / {self.temp_hot_mult}")

    def _validate_kernels(self):
        """Bounds for the kernel-routing knobs (core/accel.py, §12)."""
        if self.kernel_min_batch < 1:
            raise ValueError("kernel_min_batch must be >= 1, got "
                             f"{self.kernel_min_batch}")
        if self.coalesce_window is not None and self.coalesce_window < 1:
            raise ValueError("coalesce_window must be None or >= 1, got "
                             f"{self.coalesce_window}")

    def _validate_elastic(self):
        """Bounds for the elastic-fleet knobs (sharding/migrate.py, §14)."""
        if self.elastic_split_frac is not None \
                and not 0.0 < self.elastic_split_frac <= 1.0:
            raise ValueError("elastic_split_frac must be None or in (0, 1], "
                             f"got {self.elastic_split_frac}")
        if not 0.0 <= self.elastic_merge_frac < 1.0:
            raise ValueError("elastic_merge_frac must be in [0, 1), got "
                             f"{self.elastic_merge_frac}")
        if self.elastic_split_frac is not None \
                and self.elastic_merge_frac >= self.elastic_split_frac:
            raise ValueError(
                "elastic_merge_frac must be < elastic_split_frac (a shard "
                "eligible for both would split/merge forever), got "
                f"{self.elastic_merge_frac} / {self.elastic_split_frac}")
        if self.elastic_max_shards < 1:
            raise ValueError("elastic_max_shards must be >= 1, got "
                             f"{self.elastic_max_shards}")
        for field in ("elastic_cooldown_ops", "migration_chunk_records"):
            if getattr(self, field) < 1:
                raise ValueError(f"{field} must be >= 1, got "
                                 f"{getattr(self, field)}")
        if self.replica_count < 0:
            raise ValueError("replica_count must be >= 0, got "
                             f"{self.replica_count}")
        if self.replica_lag_ops < 0:
            raise ValueError("replica_lag_ops must be >= 0, got "
                             f"{self.replica_lag_ops}")

    # -------------------------------------------------------- serialization
    def state_dict(self) -> dict:
        """JSON-serializable field dict for MANIFEST/snapshot persistence.

        The live ``observer`` hook object is process state, not
        configuration — it is stripped here (and defaults to None when the
        dict is fed back through ``EngineConfig(**d)`` on recovery)."""
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self) if f.name != "observer"}

    # ------------------------------------------------------------ properties
    @property
    def vsst_layout(self) -> str:
        return "rtable" if self.lazy_read else "btable"

    @property
    def ksst_layout(self) -> str:
        return "dtable" if self.index_decoupled else "btable"

    # record serialized sizes --------------------------------------------
    def inline_rec_bytes(self, vsize):
        return self.key_bytes + self.seq_bytes + self.rec_header_bytes + vsize

    def ref_rec_bytes(self):
        return (self.key_bytes + self.seq_bytes + self.rec_header_bytes
                + self.ref_bytes)

    def tomb_rec_bytes(self):
        return self.key_bytes + self.seq_bytes + self.rec_header_bytes

    def value_rec_bytes(self, vsize):
        return self.key_bytes + self.seq_bytes + self.rec_header_bytes + vsize

    # ---------------------------------------------------------------- scaled
    @classmethod
    def scaled(cls, engine: str, dataset_bytes: int,
               scale_ref_gb: float = 100.0, est_keys: int | None = None,
               **overrides) -> "EngineConfig":
        """Shrink the paper's 100GB configuration to ``dataset_bytes``.

        Ratios held: memtable=kSST=dataset/1600, vSST=4x kSST,
        base level = dataset/400, cache = 1% of dataset.  Block size and
        record formats stay at their real values.  Pass ``est_keys`` (the
        workload's key count) when known; it defaults to a 1KB-value
        estimate.
        """
        scale = dataset_bytes / (scale_ref_gb * (1 << 30))
        mt = max(32 << 10, int((64 << 20) * scale))
        # DropCache: 2% of a 4KB-page keyspace, floored at 512 — but
        # clamped to a quarter of the keyspace (tiny CI datasets hold fewer
        # keys than the floor; a DropCache covering every key would mark
        # all writes hot and disable the hot/cold split)
        if est_keys is None:
            est_keys = dataset_bytes // 1024
        est_keys = max(64, est_keys)
        cfg = dict(
            engine=engine,
            memtable_bytes=mt,
            ksst_bytes=mt,
            vsst_bytes=4 * mt,
            base_level_bytes=max(2 * mt, int((256 << 20) * scale)),
            cache_bytes=max(64 << 10, int(dataset_bytes * 0.01)),
            dropcache_keys=min(max(512, int(dataset_bytes / 4096 * 0.02)),
                               max(16, est_keys // 4)),
            # adaptive-tracker windows scale with the keyspace: decay over
            # ~2 full passes of updates, predict one pass ahead
            adaptive_half_life_ops=float(max(4096, 2 * est_keys)),
            adaptive_gc_horizon_ops=float(max(2048, est_keys)),
        )
        cfg.update(overrides)
        return cls(**cfg)
