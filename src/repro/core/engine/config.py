"""Engine configuration.

Defaults follow the paper's setup (§IV-A, RocksDB tuning guide): 24B keys,
512B separation threshold, 64MB memtable/kSST, 256MB vSST, 10 bits/key bloom
filters, block cache = 1% of dataset, garbage-ratio threshold 0.2, inter-level
ratio 10, dynamic level sizing.  ``scaled()`` shrinks all absolute sizes while
holding every structural ratio, so laptop-scale runs reproduce the paper's
amplification behaviour.

Feature flags map to the paper's ablation variants (Fig. 16/17):
  - TDB      : engine="terarkdb"
  - TDB-C    : engine="terarkdb", compensated_compaction=True
  - Scavenger: engine="scavenger" (compensated + R lazy-read + L dtable +
               W hot/cold; each independently toggleable)
"""

from __future__ import annotations

import dataclasses


ENGINES = ("rocksdb", "blobdb", "titan", "terarkdb", "scavenger")


@dataclasses.dataclass
class EngineConfig:
    engine: str = "scavenger"

    # ---- record format (bytes) ----
    key_bytes: int = 24
    seq_bytes: int = 8
    rec_header_bytes: int = 8
    ref_bytes: int = 16            # <file_number, size/offset> locator
    block_size: int = 4096
    block_overhead: int = 32
    index_entry_extra: int = 8     # offset field in index entries
    footer_bytes: int = 48
    filter_bits_per_key: int = 10

    # ---- structure sizes ----
    memtable_bytes: int = 64 << 20
    ksst_bytes: int = 64 << 20
    vsst_bytes: int = 256 << 20
    base_level_bytes: int = 256 << 20   # max_bytes_for_level_base
    level_ratio: int = 10
    max_levels: int = 7
    l0_trigger: int = 4
    l0_slowdown: int = 12
    l0_stop: int = 20

    # ---- cache ----
    cache_bytes: int = 1 << 30
    cache_high_frac: float = 0.5
    dropcache_keys: int = 4096

    # ---- KV separation & GC ----
    sep_threshold: int = 512
    gc_garbage_ratio: float = 0.2
    gc_aggressive_ratio: float = 0.05
    gc_batch_files: int = 4         # max candidate vSSTs merged per GC run
    gc_batch_cap: int = 32          # hard cap on files per GC batch
    blobdb_age_cutoff: float = 0.25

    # ---- compaction job sizing ----
    compaction_pick_cap: int = 64   # max input files picked per compaction

    # ---- space management ----
    space_quota_bytes: int | None = None
    soft_quota_frac: float = 0.9
    slowdown_us_per_write: float = 20.0

    # ---- I/O behaviour ----
    readahead_gc: bool = False      # paper disables GC readahead by default
    readahead_compaction: bool = True

    # ---- Scavenger feature flags (paper ablations) ----
    compensated_compaction: bool | None = None   # None -> per-engine default
    lazy_read: bool | None = None                # R: RTable dense-index read
    index_decoupled: bool | None = None          # L: DTable KF/KV split
    hotcold_write: bool | None = None            # W: DropCache routing

    def __post_init__(self):
        if self.engine not in ENGINES:
            raise ValueError(f"unknown engine {self.engine!r}")
        scav = self.engine == "scavenger"
        if self.compensated_compaction is None:
            self.compensated_compaction = scav
        if self.lazy_read is None:
            self.lazy_read = scav
        if self.index_decoupled is None:
            self.index_decoupled = scav
        if self.hotcold_write is None:
            self.hotcold_write = scav

    # ------------------------------------------------------------ properties
    @property
    def kv_separated(self) -> bool:
        return self.engine != "rocksdb"

    @property
    def gc_scheme(self) -> str:
        return {
            "rocksdb": "none",
            "blobdb": "compaction",     # compaction-triggered relocation
            "titan": "writeback",       # GC rewrites index (Write-Index)
            "terarkdb": "inherit",      # file-number inheritance, no writeback
            "scavenger": "inherit",
        }[self.engine]

    @property
    def vsst_layout(self) -> str:
        return "rtable" if self.lazy_read else "btable"

    @property
    def ksst_layout(self) -> str:
        return "dtable" if self.index_decoupled else "btable"

    # record serialized sizes --------------------------------------------
    def inline_rec_bytes(self, vsize):
        return self.key_bytes + self.seq_bytes + self.rec_header_bytes + vsize

    def ref_rec_bytes(self):
        return (self.key_bytes + self.seq_bytes + self.rec_header_bytes
                + self.ref_bytes)

    def tomb_rec_bytes(self):
        return self.key_bytes + self.seq_bytes + self.rec_header_bytes

    def value_rec_bytes(self, vsize):
        return self.key_bytes + self.seq_bytes + self.rec_header_bytes + vsize

    # ---------------------------------------------------------------- scaled
    @classmethod
    def scaled(cls, engine: str, dataset_bytes: int,
               scale_ref_gb: float = 100.0, est_keys: int | None = None,
               **overrides) -> "EngineConfig":
        """Shrink the paper's 100GB configuration to ``dataset_bytes``.

        Ratios held: memtable=kSST=dataset/1600, vSST=4x kSST,
        base level = dataset/400, cache = 1% of dataset.  Block size and
        record formats stay at their real values.  Pass ``est_keys`` (the
        workload's key count) when known; it defaults to a 1KB-value
        estimate.
        """
        scale = dataset_bytes / (scale_ref_gb * (1 << 30))
        mt = max(32 << 10, int((64 << 20) * scale))
        # DropCache: 2% of a 4KB-page keyspace, floored at 512 — but
        # clamped to a quarter of the keyspace (tiny CI datasets hold fewer
        # keys than the floor; a DropCache covering every key would mark
        # all writes hot and disable the hot/cold split)
        if est_keys is None:
            est_keys = dataset_bytes // 1024
        est_keys = max(64, est_keys)
        cfg = dict(
            engine=engine,
            memtable_bytes=mt,
            ksst_bytes=mt,
            vsst_bytes=4 * mt,
            base_level_bytes=max(2 * mt, int((256 << 20) * scale)),
            cache_bytes=max(64 << 10, int(dataset_bytes * 0.01)),
            dropcache_keys=min(max(512, int(dataset_bytes / 4096 * 0.02)),
                               max(16, est_keys // 4)),
        )
        cfg.update(overrides)
        return cls(**cfg)
