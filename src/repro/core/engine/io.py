"""Deterministic simulated SSD with byte + latency accounting
(DESIGN.md §3).

The paper's evaluation is I/O-bound on a single NVMe SSD; foreground and
background (flush / compaction / GC) work share one device.  We therefore
model a single serialized I/O timeline: every block/file transfer advances a
simulated clock by a per-op fixed cost plus a per-byte cost.  Throughput
numbers in benchmarks are ``ops / simulated seconds``.  Absolute values are a
device model; the paper's *ratios* (x-improvements, amplification factors,
latency-percentage breakdowns) are what we validate.

Counters are kept per *category* so benchmarks can reproduce the paper's
figures (GC latency breakdown Fig.3, I/O reduction Fig.12(c)).
"""

from __future__ import annotations

import contextlib
import dataclasses
from collections import defaultdict

# I/O categories (used for the paper's breakdowns).
CAT_WAL = "wal"
CAT_FLUSH = "flush"
CAT_COMPACT_READ = "compact_read"
CAT_COMPACT_WRITE = "compact_write"
CAT_GC_READ = "gc_read"
CAT_GC_LOOKUP = "gc_lookup"
CAT_GC_WRITE = "gc_write"
CAT_GC_WRITE_INDEX = "gc_write_index"
CAT_FG_READ = "fg_read"
CAT_SCAN = "scan"

GC_CATS = (CAT_GC_READ, CAT_GC_LOOKUP, CAT_GC_WRITE, CAT_GC_WRITE_INDEX)


@dataclasses.dataclass
class DeviceModel:
    """NVMe-ish cost model (KIOXIA 500G class, ext4, direct I/O).

    Per-op overheads are amortized by the parallelism of the issuing lane:
    the compaction/flush pool (16 threads in the paper's setup) keeps a deep
    NVMe queue, while GC runs on a small dedicated pool (Titan/TerarkDB
    default 1-2 GC threads) and foreground point reads are latency-bound at
    queue depth ~1.  Sequential bandwidth is never multiplied — the device
    has one set of flash channels."""

    rand_read_op_us: float = 80.0      # 4K random-read latency floor
    seq_op_us: float = 10.0            # submission overhead for seq I/O
    read_gbps: float = 2.5             # sequential read bandwidth
    write_gbps: float = 1.2            # sequential write bandwidth
    cache_hit_us: float = 0.2          # CPU cost of a block-cache hit
    fg_qd_max: float = 16.0            # NVMe queue depth a batched user op
    #                                    can sustain (matches the bg pool's
    #                                    16 threads saturating one SSD)
    lane_parallelism: dict = dataclasses.field(
        default_factory=lambda: {"fg": 1.0, "bg": 8.0, "gc": 2.0})

    def rand_read_us(self, nbytes: int, lane: str = "fg") -> float:
        par = self.lane_parallelism.get(lane, 1.0)
        return (self.rand_read_op_us / par
                + nbytes / (self.read_gbps * 1e3))

    def seq_read_us(self, nbytes: int, lane: str = "fg") -> float:
        par = self.lane_parallelism.get(lane, 1.0)
        return self.seq_op_us / par + nbytes / (self.read_gbps * 1e3)

    def seq_write_us(self, nbytes: int, lane: str = "fg") -> float:
        par = self.lane_parallelism.get(lane, 1.0)
        return self.seq_op_us / par + nbytes / (self.write_gbps * 1e3)


class SimIO:
    """Two-lane device simulator with per-category accounting.

    The foreground lane carries user-op latencies (WAL appends, reads); the
    background lane carries flush/compaction/GC — 16 background threads
    saturating the device are modelled as one sequential lane at full device
    bandwidth.  The store's scheduler interleaves the lanes and converts
    background debt into foreground write stalls (L0/immutable triggers),
    which is the mechanism behind the paper's delayed-compaction analysis."""

    def __init__(self, device: DeviceModel | None = None):
        self.device = device or DeviceModel()
        self.lane = "fg"
        self.lanes = {"fg": 0.0, "bg": 0.0, "gc": 0.0}
        self.read_bytes = defaultdict(int)
        self.write_bytes = defaultdict(int)
        self.read_ops = defaultdict(int)
        self.write_ops = defaultdict(int)
        self.time_us = defaultdict(float)

    @property
    def clock_us(self) -> float:
        return max(self.lanes.values())

    @property
    def fg_clock_us(self) -> float:
        return self.lanes["fg"]

    @property
    def bg_clock_us(self) -> float:
        return self.lanes["bg"]

    @property
    def gc_clock_us(self) -> float:
        return self.lanes["gc"]

    def _advance(self, t: float, cat: str) -> float:
        self.time_us[cat] += t
        self.lanes[self.lane] += t
        return t

    @contextlib.contextmanager
    def batched(self, depth: int):
        """Issue foreground I/O at queue depth ``depth`` (capped).

        A multi-key user call (multi_get / multi_scan) submits its reads
        together, so the per-op latency floor amortizes across the batch —
        the same parallelism model the bg/gc lanes already use.  Sequential
        bandwidth is NOT multiplied (one set of flash channels); only the
        per-op overhead divides.  Nested contexts keep the deepest queue.
        """
        par = self.device.lane_parallelism
        prev = par.get("fg", 1.0)
        par["fg"] = max(prev, min(float(depth), self.device.fg_qd_max))
        try:
            yield
        finally:
            par["fg"] = prev

    # ------------------------------------------------------------------ I/O
    def rand_read(self, nbytes: int, cat: str) -> float:
        self.read_bytes[cat] += nbytes
        self.read_ops[cat] += 1
        return self._advance(self.device.rand_read_us(nbytes, self.lane),
                             cat)

    def seq_read(self, nbytes: int, cat: str) -> float:
        self.read_bytes[cat] += nbytes
        self.read_ops[cat] += 1
        return self._advance(self.device.seq_read_us(nbytes, self.lane),
                             cat)

    def seq_write(self, nbytes: int, cat: str) -> float:
        self.write_bytes[cat] += nbytes
        self.write_ops[cat] += 1
        return self._advance(self.device.seq_write_us(nbytes, self.lane),
                             cat)

    def cache_hit(self, cat: str, n: int = 1) -> float:
        t = 0.0
        # n separate advances (not one multiply): keeps the float clock
        # bit-identical whether hits are charged one by one or batched
        for _ in range(n):
            t += self._advance(self.device.cache_hit_us, cat)
        return t

    def stall(self, us: float, cat: str = "throttle") -> None:
        self._advance(us, cat)

    # ------------------------------------------------------------ summaries
    def total_read_bytes(self) -> int:
        return sum(self.read_bytes.values())

    def total_write_bytes(self) -> int:
        return sum(self.write_bytes.values())

    def gc_time_us(self) -> float:
        return sum(self.time_us[c] for c in GC_CATS)

    def snapshot(self) -> dict:
        return {
            "clock_us": self.clock_us,
            "lanes": dict(self.lanes),
            "read_bytes": dict(self.read_bytes),
            "write_bytes": dict(self.write_bytes),
            "read_ops": dict(self.read_ops),
            "write_ops": dict(self.write_ops),
            "time_us": dict(self.time_us),
        }

    @staticmethod
    def delta(after: dict, before: dict) -> dict:
        out = {}
        for field in ("read_bytes", "write_bytes", "read_ops", "write_ops",
                      "time_us", "lanes"):
            # .get({}) keeps old lane-less snapshots (pre-§11) subtractable
            af = after.get(field, {})
            bf = before.get(field, {})
            out[field] = {
                k: af.get(k, 0) - bf.get(k, 0)
                for k in set(af) | set(bf)
            }
        out["clock_us"] = after["clock_us"] - before["clock_us"]
        return out
