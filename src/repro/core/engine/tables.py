"""SSTable layouts: BTable (BlockBasedTable), RTable, DTable.

Entries are parallel numpy arrays (vectorized engine; see DESIGN.md §3 for
why fixed-width u64 keys).  A table never stores value *bytes* — it stores
``vids`` (value identities, the store writes the same vid into both the index
entry and the value record, standing in for Titan's <file,offset> locator)
and ``vsizes`` so all space/I-O is byte-accounted exactly.

Layouts (paper §III-B):
  * BTable  — data blocks + sparse index (one entry per block) + bloom.
  * RTable  — value table with a *dense* per-record <key, offset> index,
              partitioned into index blocks; GC reads only index blocks
              ("lazy read"), foreground reads skip in-block search.
  * DTable  — key table splitting KF entries (<key, file_number>, etype REF)
              and inline KV records into separate block streams with separate
              sparse indexes; GC-Lookup touches only (dense-packed) KF blocks.
"""

from __future__ import annotations

import numpy as np

from .config import EngineConfig
from .keys import BloomFilter

ETYPE_INLINE = 0
ETYPE_REF = 1
ETYPE_TOMB = 2
ETYPE_NONE = 255        # result-column sentinel: key not found

# vSST temperature classes (adaptive segregation, DESIGN.md §8; the
# adaptive layer's TemperatureMap re-exports these)
TEMP_COLD = 0
TEMP_WARM = 1
TEMP_HOT = 2

KIND_KEY = "k"
KIND_VALUE = "v"


def _block_layout(rec_bytes: np.ndarray, block_size: int):
    """Assign records to blocks by cumulative serialized size.

    Returns (block_of[i], n_blocks, block_nbytes[b]).
    """
    if len(rec_bytes) == 0:
        return np.zeros(0, np.int32), 0, np.zeros(0, np.int64)
    offs = np.cumsum(rec_bytes, dtype=np.int64) - rec_bytes
    block_of = (offs // block_size).astype(np.int32)
    n_blocks = int(block_of[-1]) + 1
    block_nbytes = np.bincount(block_of, weights=rec_bytes,
                               minlength=n_blocks).astype(np.int64)
    return block_of, n_blocks, block_nbytes


class SSTable:
    _next_fid = 1

    @classmethod
    def alloc_fid(cls) -> int:
        fid = cls._next_fid
        cls._next_fid += 1
        return fid

    def __init__(self, cfg: EngineConfig, kind: str, layout: str,
                 keys: np.ndarray, seqs: np.ndarray, etype: np.ndarray,
                 vids: np.ndarray, vsizes: np.ndarray, vfiles: np.ndarray,
                 is_hot: bool = False, temperature: int | None = None):
        assert kind in (KIND_KEY, KIND_VALUE)
        n = len(keys)
        self.fid = self.alloc_fid()
        self.cfg = cfg
        self.kind = kind
        self.layout = layout
        self.is_hot = is_hot
        # temperature class (adaptive engines: TEMP_COLD/WARM/HOT); the
        # binary is_hot flag maps to the extremes when not given explicitly
        self.temperature = (TEMP_HOT if is_hot else TEMP_COLD) \
            if temperature is None else int(temperature)
        self.keys = np.asarray(keys, np.uint64)
        self.seqs = np.asarray(seqs, np.uint64)
        self.etype = np.asarray(etype, np.uint8)
        self.vids = np.asarray(vids, np.uint64)
        self.vsizes = np.asarray(vsizes, np.int64)
        self.vfiles = np.asarray(vfiles, np.int64)
        assert np.all(self.keys[1:] > self.keys[:-1]), "keys must be unique+sorted"

        # ---- serialized record sizes ----
        if kind == KIND_VALUE:
            rec = cfg.value_rec_bytes(self.vsizes)
        else:
            rec = np.where(
                self.etype == ETYPE_REF, cfg.ref_rec_bytes(),
                np.where(self.etype == ETYPE_TOMB, cfg.tomb_rec_bytes(),
                         cfg.inline_rec_bytes(self.vsizes)))
        self.rec_bytes = rec.astype(np.int64)

        idx_entry = cfg.key_bytes + cfg.index_entry_extra

        if layout == "dtable":
            # two streams: KF (etype==REF) and KV (everything else)
            self.kf_mask = self.etype == ETYPE_REF
            kv_mask = ~self.kf_mask
            self.stream_of = np.where(self.kf_mask, 0, 1).astype(np.int8)
            self.block_of = np.full(n, -1, np.int32)
            kf_bo, self.n_kf_blocks, kf_bb = _block_layout(
                rec[self.kf_mask], cfg.block_size)
            kv_bo, self.n_kv_blocks, kv_bb = _block_layout(
                rec[kv_mask], cfg.block_size)
            self.block_of[self.kf_mask] = kf_bo
            self.block_of[kv_mask] = kv_bo
            self.block_nbytes = {0: kf_bb, 1: kv_bb}
            self.n_data_blocks = self.n_kf_blocks + self.n_kv_blocks
            index_bytes = (self.n_kf_blocks + self.n_kv_blocks) * idx_entry
            # per-stream first-key arrays for block lookup
            self._kf_keys = self.keys[self.kf_mask]
            self._kv_keys = self.keys[kv_mask]
        else:
            self.stream_of = np.zeros(n, np.int8)
            self.block_of, self.n_data_blocks, bb = _block_layout(
                rec, cfg.block_size)
            self.block_nbytes = {0: bb}
            if layout == "rtable":
                # dense <key, offset> index partitioned into blocks
                index_bytes = n * idx_entry
            else:
                index_bytes = self.n_data_blocks * idx_entry

        # partitioned index blocks (RTable dense index, read by lazy GC)
        if layout == "rtable":
            per_blk = max(1, cfg.block_size // idx_entry)
            self.index_block_of = (np.arange(n) // per_blk).astype(np.int32)
            self.n_index_blocks = int(np.ceil(n / per_blk)) if n else 0
        else:
            self.index_block_of = None
            self.n_index_blocks = 1 if n else 0

        self.bloom = BloomFilter(self.keys, cfg.filter_bits_per_key)
        self.data_bytes = int(self.rec_bytes.sum())
        self.index_bytes = int(index_bytes)
        self.filter_bytes = self.bloom.nbytes
        self.file_bytes = (self.data_bytes + self.index_bytes
                           + self.filter_bytes + cfg.footer_bytes
                           + self.n_data_blocks * cfg.block_overhead)

        # ---- value-store bookkeeping (vSST / blob file) ----
        if kind == KIND_VALUE:
            self.total_value_bytes = int(self.rec_bytes.sum())
            self.garbage_bytes = 0
            self.live_refs = n          # blobdb-style refcount
        self.merged_into: int | None = None

        # compensated size: filled by the store for kSSTs (paper §III-C)
        self.compensated_extra = 0

    # ------------------------------------------------------------------ api
    @property
    def n(self) -> int:
        return len(self.keys)

    @property
    def min_key(self) -> int:
        return int(self.keys[0]) if self.n else 0

    @property
    def max_key(self) -> int:
        return int(self.keys[-1]) if self.n else 0

    @property
    def compensated_bytes(self) -> int:
        return self.file_bytes + self.compensated_extra

    def garbage_ratio(self) -> float:
        assert self.kind == KIND_VALUE
        if self.total_value_bytes == 0:
            return 1.0
        return self.garbage_bytes / self.total_value_bytes

    def find(self, keys: np.ndarray) -> np.ndarray:
        """Positions of keys in this table; -1 where absent. Vectorized."""
        ks = np.atleast_1d(np.asarray(keys, np.uint64))
        pos = np.searchsorted(self.keys, ks)
        ok = (pos < self.n)
        safe = np.where(ok, pos, 0)
        ok &= self.keys[safe] == ks
        return np.where(ok, pos, -1).astype(np.int64)

    def data_block_bytes(self, stream: int, block_id: int) -> int:
        bb = self.block_nbytes[stream]
        return int(bb[block_id]) + self.cfg.block_overhead

    def index_block_bytes(self) -> int:
        if self.layout == "rtable" and self.n_index_blocks:
            return min(self.cfg.block_size,
                       max(1, self.index_bytes // max(1, self.n_index_blocks)))
        return max(1, self.index_bytes)

    # Range helpers -------------------------------------------------------
    def positions_in_range(self, lo: int, hi: int) -> np.ndarray:
        a = int(np.searchsorted(self.keys, np.uint64(lo), side="left"))
        b = int(np.searchsorted(self.keys, np.uint64(hi), side="right"))
        return np.arange(a, b, dtype=np.int64)


def build_ksst(cfg: EngineConfig, keys, seqs, etype, vids, vsizes, vfiles):
    return SSTable(cfg, KIND_KEY, cfg.ksst_layout, keys, seqs, etype, vids,
                   vsizes, vfiles)


def build_vsst(cfg: EngineConfig, keys, seqs, vids, vsizes,
               is_hot: bool = False, temperature: int | None = None):
    n = len(keys)
    return SSTable(cfg, KIND_VALUE, cfg.vsst_layout, keys, seqs,
                   np.zeros(n, np.uint8), vids, vsizes,
                   np.zeros(n, np.int64), is_hot=is_hot,
                   temperature=temperature)
