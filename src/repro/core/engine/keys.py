"""Fixed-width key codec and vectorized hashing (DESIGN.md §3).

The paper uses 24-byte string keys.  TPU vector units (and our vectorized
numpy engine) have no variable-length string compare, so the TPU-native
layout is fixed-width u64 key lanes; the engine still *accounts* 24 bytes per
key for space/I-O (``EngineConfig.key_bytes``).  This module provides the
splitmix64 hash family used by bloom filters and the DropCache, shared with
the Pallas kernels (``repro.kernels.bloom``).
"""

from __future__ import annotations

import numpy as np

_SPLITMIX_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)

_LN2 = 0.69                     # probes ~= ln2 * bits/key (RocksDB's round)
_WORD_BITS = 64                 # bloom bit array is u64 words
_WORD_BYTES = 8
_WORD_MASK = np.uint64(63)      # bit index within a word


def splitmix64(x: np.ndarray | int) -> np.ndarray:
    """Vectorized splitmix64 finalizer (u64 -> u64, wrapping)."""
    with np.errstate(over="ignore"):
        z = np.asarray(x, dtype=np.uint64) + _SPLITMIX_GAMMA
        z = (z ^ (z >> np.uint64(30))) * _MIX1
        z = (z ^ (z >> np.uint64(27))) * _MIX2
        return z ^ (z >> np.uint64(31))


def hash_family(keys: np.ndarray, k: int, seed: int = 0) -> np.ndarray:
    """k independent 64-bit hashes per key via double hashing.

    Returns array of shape (k, n) u64.
    """
    keys = np.asarray(keys, dtype=np.uint64)
    with np.errstate(over="ignore"):
        h1 = splitmix64(keys ^ splitmix64(np.uint64(seed)))
        h2 = splitmix64(h1) | np.uint64(1)  # odd so strides cover the table
        ks = np.arange(k, dtype=np.uint64)[:, None]
        return h1[None, :] + ks * h2[None, :]


def bloom_params(n_keys: int, bits_per_key: int) -> tuple[int, int]:
    """Canonical bloom sizing shared by the engine filter and the Pallas
    probe kernels (``repro.kernels.bloom``): ``(k, nbits)`` with
    ``k = round(ln2 * bits/key)`` probes and ``nbits`` rounded up to whole
    u64 words.  One derivation — the engine and the kernels can't drift."""
    k = max(1, int(round(bits_per_key * _LN2)))
    nbits = int(max(_WORD_BITS, max(1, n_keys) * bits_per_key))
    nwords = (nbits + _WORD_BITS - 1) // _WORD_BITS
    return k, nwords * _WORD_BITS


class BloomFilter:
    """Standard k-hash bloom filter over u64 keys (10 bits/key default).

    Real bit array; false positives occur naturally (and cost wasted block
    reads in the read path, as in RocksDB).
    """

    __slots__ = ("nbits", "k", "bits", "nbytes")

    @staticmethod
    def k_for(bits_per_key: int) -> int:
        """Number of hash probes for a given bits/key (ln2 * bits/key)."""
        return bloom_params(1, bits_per_key)[0]

    def __init__(self, keys: np.ndarray, bits_per_key: int = 10):
        self.k, self.nbits = bloom_params(len(keys), bits_per_key)
        nwords = self.nbits // _WORD_BITS
        self.bits = np.zeros(nwords, dtype=np.uint64)
        self.nbytes = nwords * _WORD_BYTES
        if len(keys):
            hs = hash_family(keys, self.k) % np.uint64(self.nbits)
            word = (hs >> np.uint64(6)).ravel()
            bit = (hs & _WORD_MASK).ravel()
            np.bitwise_or.at(self.bits, word, np.uint64(1) << bit)

    def may_contain(self, keys: np.ndarray,
                    raw: np.ndarray | None = None) -> np.ndarray:
        """Vectorized membership test -> bool array.

        ``raw`` may carry precomputed ``hash_family(keys, k)`` output (pre-
        modulo): the raw hashes depend only on the keys, so a batched lookup
        walking many tables hashes its key column once and reuses it against
        every filter of the same ``k``."""
        if raw is None or len(raw) != self.k:
            keys = np.atleast_1d(np.asarray(keys, dtype=np.uint64))
            raw = hash_family(keys, self.k)
        hs = raw % np.uint64(self.nbits)
        word = hs >> np.uint64(6)
        bit = hs & _WORD_MASK
        hit = (self.bits[word] >> bit) & np.uint64(1)
        return hit.all(axis=0)
