"""Version: level structure, value-file registry, inheritance
(DESIGN.md §2; the durable MANIFEST lives in ``core/durability``, §9).

TerarkDB-style no-writeback GC (paper §II-B) keeps the index LSM-tree's
``<key, file_number>`` entries stable across GC by recording *inheritance*:
a GC output file inherits from every candidate it merged.  ``resolve``
follows ``merged_into`` pointers (with path compression) to the live head.
"""

from __future__ import annotations

import numpy as np

from .tables import SSTable, KIND_VALUE


class Version:
    def __init__(self, max_levels: int):
        self.levels: list[list[SSTable]] = [[] for _ in range(max_levels)]
        self.value_files: dict[int, SSTable] = {}
        self._chain: dict[int, int] = {}     # old fid -> successor fid
        self._bounds_cache: dict[int, tuple] = {}

    # ---------------------------------------------------------------- kSSTs
    def add_l0(self, t: SSTable) -> None:
        self.levels[0].append(t)            # newest last

    def set_level(self, i: int, files: list[SSTable]) -> None:
        files.sort(key=lambda t: t.min_key)
        self.levels[i] = files
        self._bounds_cache.pop(i, None)

    def level_bytes(self, i: int) -> int:
        return sum(t.file_bytes for t in self.levels[i])

    def level_compensated_bytes(self, i: int) -> int:
        return sum(t.compensated_bytes for t in self.levels[i])

    def last_nonempty_level(self) -> int:
        for i in range(len(self.levels) - 1, 0, -1):
            if self.levels[i]:
                return i
        return 0

    def ksst_total_bytes(self) -> int:
        return sum(self.level_bytes(i) for i in range(len(self.levels)))

    def all_kssts(self):
        for lvl in self.levels:
            yield from lvl

    def level_bounds(self, i: int):
        """(min_keys, max_keys) arrays for vectorized file assignment."""
        if i not in self._bounds_cache:
            files = self.levels[i]
            mins = np.array([t.min_key for t in files], np.uint64)
            maxs = np.array([t.max_key for t in files], np.uint64)
            self._bounds_cache[i] = (mins, maxs)
        return self._bounds_cache[i]

    def assign_files(self, i: int, keys: np.ndarray) -> np.ndarray:
        """Vectorized: index of the file in level i whose range covers each
        key; -1 if none.  Level i>=1 files are disjoint and sorted."""
        files = self.levels[i]
        if not files:
            return np.full(len(keys), -1, np.int64)
        mins, maxs = self.level_bounds(i)
        pos = np.searchsorted(mins, keys, side="right") - 1
        ok = pos >= 0
        safe = np.where(ok, pos, 0)
        ok &= keys <= maxs[safe]
        return np.where(ok, pos, -1).astype(np.int64)

    def overlapping(self, i: int, lo: int, hi: int) -> list[SSTable]:
        return [t for t in self.levels[i]
                if not (t.max_key < lo or t.min_key > hi)]

    # ---------------------------------------------------------- value files
    def add_value_file(self, t: SSTable) -> None:
        assert t.kind == KIND_VALUE
        self.value_files[t.fid] = t

    def retire_value_file(self, fid: int, successor: int | None) -> None:
        t = self.value_files.pop(fid, None)
        if t is not None and successor is not None:
            t.merged_into = successor
            self._chain[fid] = successor

    def resolve(self, fid: int) -> int:
        """Chain-head resolution with path compression."""
        seen = []
        f = fid
        while f in self._chain:
            seen.append(f)
            f = self._chain[f]
        for s in seen:
            self._chain[s] = f
        return f

    def resolve_many(self, fids: np.ndarray) -> np.ndarray:
        return np.fromiter((self.resolve(int(f)) for f in fids),
                           dtype=np.int64, count=len(fids))

    def value_total_bytes(self) -> int:
        return sum(t.file_bytes for t in self.value_files.values())

    def value_garbage_bytes(self) -> int:
        return sum(t.garbage_bytes for t in self.value_files.values())

    def total_bytes(self) -> int:
        return self.ksst_total_bytes() + self.value_total_bytes()
