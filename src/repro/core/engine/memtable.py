"""Memtable: in-memory sorted write buffer (dict + sort-at-flush,
DESIGN.md §2).

Entries are (seq, etype, vid, vsize, vfile).  Normal user puts are INLINE
(the memtable holds the full value until flush decides separation); Titan's
GC Write-Index puts REF entries pointing at an existing blob file.

Reads go through a cached *columnar snapshot* (key-sorted parallel arrays,
rebuilt lazily after a write): ``get_batch`` probes a whole key column with
one ``searchsorted``, and scans slice key ranges out of the same arrays —
no per-key Python in the batched read path.  Immutable memtables never
rebuild; the active memtable rebuilds at most once per write batch.
"""

from __future__ import annotations

import numpy as np

from .config import EngineConfig
from .tables import ETYPE_INLINE, ETYPE_REF, ETYPE_TOMB


class Memtable:
    def __init__(self, cfg: EngineConfig):
        self.cfg = cfg
        # key -> (seq, etype, vid, vsize, vfile)
        self.entries: dict[int, tuple] = {}
        self.bytes = 0
        self._snap: tuple | None = None     # cached columnar snapshot

    def _entry_bytes(self, etype: int, vsize: int) -> int:
        if etype == ETYPE_TOMB:
            return self.cfg.tomb_rec_bytes()
        if etype == ETYPE_REF:
            return self.cfg.ref_rec_bytes()
        return self.cfg.inline_rec_bytes(vsize)

    def _set(self, key: int, entry: tuple) -> None:
        prev = self.entries.get(key)
        if prev is not None:
            self.bytes -= self._entry_bytes(prev[1], prev[3])
        self.entries[key] = entry
        self.bytes += self._entry_bytes(entry[1], entry[3])
        self._snap = None

    def put(self, key: int, seq: int, vid: int, vsize: int) -> None:
        self._set(key, (seq, ETYPE_INLINE, vid, vsize, -1))

    def put_ref(self, key: int, seq: int, vid: int, vsize: int,
                vfile: int) -> None:
        self._set(key, (seq, ETYPE_REF, vid, vsize, vfile))

    def delete(self, key: int, seq: int) -> None:
        self._set(key, (seq, ETYPE_TOMB, 0, 0, -1))

    def get(self, key: int):
        return self.entries.get(key)

    def snapshot(self) -> tuple:
        """Key-sorted columnar view: (keys, seqs, etype, vids, vsizes,
        vfiles) parallel arrays, cached until the next write."""
        if self._snap is None:
            n = len(self.entries)
            keys = np.fromiter(self.entries.keys(), np.uint64, count=n)
            order = np.argsort(keys, kind="stable")
            vals = list(self.entries.values())
            self._snap = (
                keys[order],
                np.fromiter((v[0] for v in vals), np.uint64, count=n)[order],
                np.fromiter((v[1] for v in vals), np.uint8, count=n)[order],
                np.fromiter((v[2] for v in vals), np.uint64, count=n)[order],
                np.fromiter((v[3] for v in vals), np.int64, count=n)[order],
                np.fromiter((v[4] for v in vals), np.int64, count=n)[order],
            )
        return self._snap

    def get_batch(self, keys: np.ndarray) -> tuple:
        """Vectorized point probe for a key column.

        Returns (found, seqs, etype, vids, vsizes, vfiles) parallel arrays
        aligned with ``keys``; rows where ``found`` is False hold the
        safe-gather placeholder and must be masked by the caller."""
        mk, seqs, ety, vids, vsz, vf = self.snapshot()
        nq = len(keys)
        if len(mk) == 0:
            return (np.zeros(nq, bool), np.zeros(nq, np.uint64),
                    np.zeros(nq, np.uint8), np.zeros(nq, np.uint64),
                    np.zeros(nq, np.int64), np.zeros(nq, np.int64))
        pos = np.searchsorted(mk, keys)
        ok = pos < len(mk)
        safe = np.where(ok, pos, 0)
        ok &= mk[safe] == keys
        return (ok, seqs[safe], ety[safe], vids[safe], vsz[safe], vf[safe])

    def entry_bytes_batch(self, ety: np.ndarray, vsizes: np.ndarray
                          ) -> np.ndarray:
        """Vectorized serialized-size computation for a record column."""
        return np.where(
            ety == ETYPE_TOMB, self.cfg.tomb_rec_bytes(),
            np.where(ety == ETYPE_REF, self.cfg.ref_rec_bytes(),
                     self.cfg.inline_rec_bytes(vsizes))).astype(np.int64)

    def put_batch(self, keys: np.ndarray, seqs: np.ndarray, ety: np.ndarray,
                  vids: np.ndarray, vsizes: np.ndarray, vfiles: np.ndarray,
                  entry_bytes: np.ndarray | None = None) -> int:
        """Insert a record column until the memtable fills.

        Returns how many records were consumed (always >= 1 on non-empty
        input); the caller rotates the memtable and re-submits the rest.
        Stops exactly where the scalar path would have rotated, so batch
        and scalar runs produce identical flush boundaries.
        """
        n = len(keys)
        if n == 0:
            return 0
        if entry_bytes is None:
            entry_bytes = self.entry_bytes_batch(ety, vsizes)
        cap = self.cfg.memtable_bytes
        entries = self.entries
        consumed = 0
        for k, rec, nbytes in zip(
                keys.tolist(),
                zip(seqs.tolist(), ety.tolist(), vids.tolist(),
                    vsizes.tolist(), vfiles.tolist()),
                entry_bytes.tolist()):
            prev = entries.get(k)
            if prev is not None:
                self.bytes -= self._entry_bytes(prev[1], prev[3])
            entries[k] = rec
            self.bytes += nbytes
            consumed += 1
            if self.bytes >= cap:
                break
        self._snap = None
        return consumed

    @property
    def full(self) -> bool:
        return self.bytes >= self.cfg.memtable_bytes

    def __len__(self) -> int:
        return len(self.entries)

    def sorted_arrays(self):
        """-> (keys, seqs, etype, vids, vsizes, vfiles) sorted by key."""
        return self.snapshot()
