"""Scavenger core: KV-separated LSM-tree engines (paper's contribution).

Seven selectable engines over one deterministic substrate:
rocksdb | blobdb | titan | terarkdb | scavenger | hybrid |
scavenger_adaptive — each a pluggable strategy object resolved from the
``repro.core.engines`` registry (see DESIGN.md §7 for the layered
architecture and the extension recipe, §8 for the adaptive subsystem).
"""

from .batch import WriteBatch
from .durability import CrashPoint, Durability
from .engine.config import EngineConfig, ENGINES
from .engines import (EngineStrategy, available_engines, make_strategy,
                      register_engine)
from .oracle import LatestOracle
from .sharding import FleetScheduler, ShardedStore
from .store import Store

__all__ = ["CrashPoint", "Durability", "EngineConfig", "ENGINES",
           "EngineStrategy", "FleetScheduler", "LatestOracle",
           "ShardedStore", "Store", "WriteBatch", "available_engines",
           "make_strategy", "register_engine"]
