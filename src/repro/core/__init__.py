"""Scavenger core: KV-separated LSM-tree engines (paper's contribution).

Five selectable engines over one deterministic substrate:
rocksdb | blobdb | titan | terarkdb | scavenger.
"""

from .batch import WriteBatch
from .engine.config import EngineConfig, ENGINES
from .sharding import FleetScheduler, ShardedStore
from .store import Store

__all__ = ["EngineConfig", "ENGINES", "FleetScheduler", "ShardedStore",
           "Store", "WriteBatch"]
