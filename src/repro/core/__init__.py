"""Scavenger core: KV-separated LSM-tree engines (paper's contribution).

Five selectable engines over one deterministic substrate:
rocksdb | blobdb | titan | terarkdb | scavenger.
"""

from .batch import WriteBatch
from .engine.config import EngineConfig, ENGINES
from .store import Store

__all__ = ["EngineConfig", "ENGINES", "Store", "WriteBatch"]
