"""CRC-framed record log: the one on-disk framing durability shares
(DESIGN.md §9).

Every durable artifact in the repo — WAL segments and the MANIFEST
(``core/durability``), store snapshots (``durability/snapshot.py``), and
the checkpoint store's value logs (``repro.checkpoint.store``) — is a
sequence of ``(key, payload)`` records framed as::

    <crc32 u32> <key_len u32> <val_len u64> <key bytes> <payload bytes>

with the CRC taken over ``key + payload``.  Readers stop at the first
torn or corrupt record (a crashed writer leaves at most one partial
record at the tail), so recovery never needs a separate "clean shutdown"
marker.  Arrays travel as self-describing ``pack_array`` payloads
(dtype + shape header, raw little-endian bytes).
"""

from __future__ import annotations

import json
import struct
import zlib
from pathlib import Path
from typing import Iterator

import numpy as np

REC_HDR = struct.Struct("<IIQ")          # crc32, key_len, val_len

# Sanity bounds applied while scanning: a torn tail can masquerade as a
# huge length field; anything past these is treated as corruption.
MAX_KEY_LEN = 1 << 20
MAX_VAL_LEN = 1 << 40


def append_record(fh, key: bytes | str, payload: bytes) -> int:
    """Append one framed record at the file's current position.

    Returns the serialized record length (header + key + payload)."""
    kb = key.encode() if isinstance(key, str) else key
    fh.write(REC_HDR.pack(zlib.crc32(kb + payload), len(kb), len(payload)))
    fh.write(kb)
    fh.write(payload)
    return REC_HDR.size + len(kb) + len(payload)


def read_record(fh) -> tuple[bytes, bytes] | None:
    """Read one record at the current position; None at EOF or on a torn /
    corrupt record (caller should stop scanning)."""
    hdr = fh.read(REC_HDR.size)
    if len(hdr) < REC_HDR.size:
        return None
    crc, klen, vlen = REC_HDR.unpack(hdr)
    if klen > MAX_KEY_LEN or vlen > MAX_VAL_LEN:
        return None
    kb = fh.read(klen)
    payload = fh.read(vlen)
    if len(kb) < klen or len(payload) < vlen \
            or zlib.crc32(kb + payload) != crc:
        return None
    return kb, payload


def scan_records(path: Path | str) -> Iterator[tuple[int, bytes, bytes]]:
    """Yield ``(offset, key, payload)`` for every intact record, stopping
    silently at the first torn tail (crash-recovery semantics)."""
    p = Path(path)
    if not p.exists():
        return
    with open(p, "rb") as fh:
        while True:
            off = fh.tell()
            rec = read_record(fh)
            if rec is None:
                return
            yield off, rec[0], rec[1]


# ---------------------------------------------------------------- arrays
_ARR_HDR = struct.Struct("<I")           # json header length


def pack_array(a: np.ndarray) -> bytes:
    """Self-describing array payload: JSON dtype/shape header + raw bytes."""
    a = np.ascontiguousarray(a)
    hdr = json.dumps({"dtype": a.dtype.str, "shape": list(a.shape)}).encode()
    return _ARR_HDR.pack(len(hdr)) + hdr + a.tobytes()


def unpack_array_at(b: bytes, off: int = 0) -> tuple[np.ndarray, int]:
    """Decode one packed array at ``off``; returns (array, next offset)."""
    (hlen,) = _ARR_HDR.unpack_from(b, off)
    off += _ARR_HDR.size
    meta = json.loads(b[off:off + hlen])
    off += hlen
    dt = np.dtype(meta["dtype"])
    count = int(np.prod(meta["shape"])) if meta["shape"] else 1
    nbytes = count * dt.itemsize
    arr = np.frombuffer(b[off:off + nbytes], dtype=dt) \
        .reshape(meta["shape"]).copy()
    return arr, off + nbytes


def unpack_array(b: bytes) -> np.ndarray:
    return unpack_array_at(b, 0)[0]
