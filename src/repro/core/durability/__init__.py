"""Durability subsystem: MANIFEST + WAL + snapshots + crash recovery
(DESIGN.md §9).

A durable store lives in one directory::

    MANIFEST            append-only VersionEdit log (manifest.py)
    wal-000000.log      WAL segments, rolled at each checkpoint (wal.py)
    snap-000001.ckpt    full-state snapshots (snapshot.py)

``Durability`` is the per-store manager ``Store`` / ``ShardedStore`` own
when opened with ``durability_dir``: it appends one WAL record per write
batch, one VersionEdit per metadata transition, and writes
checkpoint snapshots.  All of it is host-side persistence — the simulated
device already charges the WAL append on the write path, so durability
costs zero *simulated* time and a durable run's ``stats()`` are
byte-identical to a non-durable one.

Recovery (``recover_store``) replays MANIFEST then WAL: the manifest
yields the config, the latest intact checkpoint, and the WAL segment
registry; the snapshot restores the full state at the watermark; the WAL
tail re-applies through the normal columnar write path, deterministically
re-deriving flushes, compactions, and GC so the recovered store is
byte-identical to an uninterrupted run at the crash watermark
(``tests/test_durability.py`` crash matrix).

``CrashPoint`` + ``Store.arm_crash`` provide the crash-injection hooks the
matrix uses (kill between WAL append and memtable insert, mid-flush,
mid-compaction, mid-GC before/after the chain update).
"""

from __future__ import annotations

import json
from pathlib import Path

from .manifest import EDIT_KINDS, ManifestWriter, VersionEdit, read_manifest
from .records import (append_record, pack_array, read_record, scan_records,
                      unpack_array)
from .wal import WalWriter, read_wal, replay_into
from . import snapshot

__all__ = ["CrashPoint", "Durability", "EDIT_KINDS", "ManifestWriter",
           "VersionEdit", "WalWriter", "append_record", "pack_array",
           "read_record", "read_manifest", "read_wal", "recover_store",
           "replay_into", "scan_records", "snapshot", "unpack_array"]

# Crash-injection points instrumented in the core (Store._crashpoint);
# the last four fire in the elastic-fleet migration/failover machinery
# (ShardedStore._crashpoint, DESIGN.md §14).
CRASH_POINTS = ("after_wal", "mid_flush", "mid_compaction",
                "gc_pre_chain", "gc_post_chain",
                "mid_migration_copy", "pre_reroute", "mid_delta_replay",
                "pre_promote")


class CrashPoint(RuntimeError):
    """Raised by an armed crash-injection hook: the simulated process died
    here.  The store object must be abandoned; recovery goes through
    ``Store.open`` on its durability directory."""


class Durability:
    """Per-store durability manager: MANIFEST + WAL segments + snapshots."""

    MANIFEST = "MANIFEST"

    def __init__(self, root: Path, man: ManifestWriter, wal: bool,
                 epoch: int, next_snap: int):
        self.root = root
        self.manifest = man
        self.wal_enabled = wal
        self.epoch = epoch
        self._next_snap = next_snap
        self._wal: WalWriter | None = None
        self._wal_bytes_closed = 0      # rolled-segment total (health sampler)
        if wal:
            self._open_segment(epoch)

    @property
    def wal_bytes_written(self) -> int:
        """Host-side WAL bytes across all segments this manager wrote."""
        live = self._wal.bytes_written if self._wal is not None else 0
        return self._wal_bytes_closed + live

    # ----------------------------------------------------------- lifecycle
    @classmethod
    def create(cls, root: Path | str, cfg, wal: bool = True,
               meta: dict | None = None) -> "Durability":
        """Create a fresh durable directory (refuses to reuse one — recover
        existing directories through ``Store.open`` instead)."""
        root = Path(root)
        root.mkdir(parents=True, exist_ok=True)
        mpath = root / cls.MANIFEST
        if mpath.exists():
            raise FileExistsError(
                f"{mpath} exists; use Store.open()/ShardedStore.open() to "
                "recover an existing durable store")
        man = ManifestWriter(mpath)
        # state_dict, not asdict: the live observer hook (repro.obs) is
        # process state and must not leak into the JSON config edit
        man.edit("config", cfg=cfg.state_dict(), **(meta or {}))
        return cls(root, man, wal, epoch=0, next_snap=1)

    @classmethod
    def attach(cls, root: Path | str, wal: bool = True) -> "Durability":
        """Re-attach to a recovered directory: append to the existing
        MANIFEST, continue in a fresh WAL segment."""
        root = Path(root)
        epoch = max((int(p.stem.split("-")[1])
                     for p in root.glob("wal-*.log")), default=-1) + 1
        next_snap = max((int(p.stem.split("-")[1])
                         for p in root.glob("snap-*.ckpt")), default=0) + 1
        man = ManifestWriter(root / cls.MANIFEST)
        return cls(root, man, wal, epoch=epoch, next_snap=next_snap)

    def close(self) -> None:
        if self._wal is not None:
            self._wal.close()
        self.manifest.close()

    # ------------------------------------------------------------- logging
    def _open_segment(self, epoch: int) -> None:
        if self._wal is not None:
            self._wal_bytes_closed += self._wal.bytes_written
            self._wal.close()
        self.epoch = epoch
        fname = f"wal-{epoch:06d}.log"
        self._wal = WalWriter(self.root / fname)
        self.manifest.edit("wal_segment", epoch=epoch, file=fname)

    def roll_segment(self) -> None:
        """Close the live WAL segment and open the next epoch (recorded as
        a ``wal_segment`` edit).  Checkpoints roll so the journal tail a
        recovery replays starts at the checkpoint."""
        if self.wal_enabled:
            self._open_segment(self.epoch + 1)

    def log_batch(self, idx: int, seq_base: int, kinds, keys,
                  vsizes) -> None:
        if self._wal is not None:
            self._wal.append_batch(idx, seq_base, kinds, keys, vsizes)

    def log_ingest(self, idx: int, kinds, keys, vids, vsizes) -> None:
        if self._wal is not None:
            self._wal.append_ingest(idx, kinds, keys, vids, vsizes)

    def log_reads(self, idx: int, keys) -> None:
        if self._wal is not None:
            self._wal.append_reads(idx, keys)

    def log_scans(self, idx: int, starts, counts) -> None:
        if self._wal is not None:
            self._wal.append_scans(idx, starts, counts)

    def log_flush(self, idx: int) -> None:
        if self._wal is not None:
            self._wal.append_flush(idx)

    def log_edit(self, kind: str, **data) -> None:
        self.manifest.edit(kind, **data)

    # ---------------------------------------------------------- checkpoint
    def checkpoint(self, store) -> Path:
        """Snapshot the store, roll the WAL, and record the checkpoint."""
        fname = f"snap-{self._next_snap:06d}.ckpt"
        path = snapshot.write_snapshot(store, self.root / fname)
        self._next_snap += 1
        self.log_edit("watermark", seq=int(store.seq),
                      next_vid=int(store.next_vid))
        self.roll_segment()
        self.log_edit("checkpoint", file=fname, seq=int(store.seq),
                      wal_epoch=self.epoch)
        return path


# ================================================================ recovery
def recover_store(path: Path | str, io=None, cls=None, observer=None):
    """MANIFEST-then-WAL recovery of a single durable ``Store``.

    ``path`` may be a bare snapshot file (restore only) or a durable
    directory (restore latest intact checkpoint, then replay the WAL tail
    through the columnar write path).  The recovered store is re-attached
    to the directory, continuing in a fresh WAL segment.

    ``observer`` (repro.obs, DESIGN.md §11) attaches an Observer to the
    recovered store *before* replay, so the recovery run emits a replay
    timeline: ``recovery_begin`` / ``checkpoint_restored`` /
    ``replay_segment`` instants plus the replayed ops' own spans, followed
    by ``recovery_end``."""
    from ..store import Store
    cls = cls or Store
    root = Path(path)
    if root.is_file():
        store = snapshot.restore(root, io=io, cls=cls)
        _attach_observer(store, observer)
        return store
    edits = read_manifest(root / Durability.MANIFEST)
    if not edits:
        raise FileNotFoundError(f"no durable store at {root}")
    store, wal_from, ckpt_file = None, 0, None
    for e in reversed(edits):
        if e.kind == "checkpoint":
            try:
                store = snapshot.restore(root / e.data["file"], io=io,
                                         cls=cls)
            except IOError:
                continue               # torn snapshot: fall back further
            wal_from = int(e.data["wal_epoch"])
            ckpt_file = e.data["file"]
            break
    if store is None:
        cfg_edit = next(e for e in edits if e.kind == "config")
        from ..engine.config import EngineConfig
        store = cls(EngineConfig(**cfg_edit.data["cfg"]), io=io)
    obs = _attach_observer(store, observer)
    obs.instant(store, "recovery_begin", src=str(root))
    if ckpt_file is not None:
        obs.instant(store, "checkpoint_restored", file=ckpt_file,
                    wal_epoch=wal_from)
    for e in edits:
        if e.kind == "wal_segment" and int(e.data["epoch"]) >= wal_from:
            records = read_wal(root / e.data["file"])
            obs.instant(store, "replay_segment", file=e.data["file"],
                        n_records=len(records))
            applied = replay_into(store, records)
            obs.on_op(store, "replay_records", applied)
    obs.instant(store, "recovery_end", wal_index=int(store.wal_index))
    store.durability = Durability.attach(root)
    return store


def _attach_observer(store, observer):
    """Point a recovered store at ``observer`` (its persisted config never
    carries one); returns the store's live observer hook."""
    if observer is not None:
        store.obs = observer
        store.obs_label = observer.register_store(store)
    return store.obs


def manifest_summary(path: Path | str) -> dict:
    """Edit-kind histogram + watermarks of a MANIFEST (debug/audit aid)."""
    edits = read_manifest(Path(path))
    kinds: dict[str, int] = {}
    last_seq = None
    for e in edits:
        kinds[e.kind] = kinds.get(e.kind, 0) + 1
        if "seq" in e.data:
            last_seq = e.data["seq"]
    return {"n_edits": len(edits), "kinds": kinds, "last_seq": last_seq}


def _json_default(o):  # pragma: no cover - debug helper
    return str(o)


def describe(path: Path | str) -> str:  # pragma: no cover - debug helper
    return json.dumps(manifest_summary(path), indent=2, default=_json_default)
