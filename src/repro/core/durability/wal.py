"""WAL segments: a durable journal of the user-op stream + deterministic
replay (DESIGN.md §9).

A segment is a CRC-framed record log (``records.py``).  Write batches are
journaled as their columnar ``(kinds u8, keys u64, vsizes i64)`` triple
stamped with the batch's first preassigned sequence number — the simulated
device already charges this append on the write path (``CAT_WAL``), so the
host-side persistence here costs zero *simulated* time.

Unlike a production WAL, the journal also records **reads** (``multi_get``
/ ``multi_scan``) and explicit ``flush`` calls: under the two-lane clock a
read advances the foreground lane and therefore moves background
scheduling, so reads are part of the deterministic schedule that
byte-identical recovery must reproduce.  (A real engine recovers logical
state only; this simulator promises the full ``stats()`` byte counters —
see the recovery contract in DESIGN.md §9.)

Every record carries a monotone op index (``Store.wal_index``); replay
pushes records back through the normal columnar entry points
(``_write_arrays`` / ``multi_get`` / ``multi_scan`` / ``flush``) skipping
indexes at or below the store's restored watermark, so replaying a prefix
twice equals replaying it once (hypothesis-tested prefix idempotence).
"""

from __future__ import annotations

import struct
from pathlib import Path

import numpy as np

from .records import (append_record, pack_array, scan_records,
                      unpack_array_at)

_IDX_HDR = struct.Struct("<Q")          # op index
_SEQ_HDR = struct.Struct("<Q")          # seq_base (write batches only)
_BATCH_ARRAYS = 3                       # "b" payload: kinds, keys, vsizes
_INGEST_ARRAYS = 4                      # "i" payload: kinds, keys, vids,
#                                         vsizes


def _encode_arrays(*arrays) -> bytes:
    return b"".join(pack_array(a) for a in arrays)


def _decode_arrays(payload: bytes, off: int, n: int):
    out = []
    for _ in range(n):
        arr, off = unpack_array_at(payload, off)
        out.append(arr)
    return out


class WalWriter:
    def __init__(self, path: Path | str):
        self.path = Path(path)
        self._fh = open(self.path, "ab")
        # host-side segment size (bytes), surfaced by the health sampler
        # (repro.obs, DESIGN.md §11)
        self.bytes_written = self._fh.tell()

    def _append(self, key: str, idx: int, body: bytes) -> None:
        append_record(self._fh, key, _IDX_HDR.pack(int(idx)) + body)
        self._fh.flush()
        self.bytes_written = self._fh.tell()

    def append_batch(self, idx: int, seq_base: int, kinds, keys,
                     vsizes) -> None:
        self._append("b", idx, _SEQ_HDR.pack(int(seq_base)) + _encode_arrays(
            np.asarray(kinds, np.uint8), np.asarray(keys, np.uint64),
            np.asarray(vsizes, np.int64)))

    def append_ingest(self, idx: int, kinds, keys, vids, vsizes) -> None:
        """Journal a vid-preserving ingest (migration copy-in / replica
        promotion replay, DESIGN.md §14): records that already own their
        value identity, so replay must not re-mint vids."""
        self._append("i", idx, _encode_arrays(
            np.asarray(kinds, np.uint8), np.asarray(keys, np.uint64),
            np.asarray(vids, np.uint64), np.asarray(vsizes, np.int64)))

    def append_reads(self, idx: int, keys) -> None:
        self._append("r", idx, _encode_arrays(np.asarray(keys, np.uint64)))

    def append_scans(self, idx: int, starts, counts) -> None:
        self._append("s", idx, _encode_arrays(
            np.asarray(starts, np.int64), np.asarray(counts, np.int64)))

    def append_flush(self, idx: int) -> None:
        self._append("f", idx, b"")

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.flush()
            self._fh.close()


def read_wal(path: Path | str) -> list[tuple]:
    """All intact journal records, in order.

    Each entry is ``(kind, idx, *payload)``: ``("b", idx, seq_base, kinds,
    keys, vsizes)``, ``("i", idx, kinds, keys, vids, vsizes)``,
    ``("r", idx, keys)``, ``("s", idx, starts, counts)``, or
    ``("f", idx)``."""
    out = []
    for _, key, payload in scan_records(path):
        kind = key.decode()
        (idx,) = _IDX_HDR.unpack_from(payload)
        off = _IDX_HDR.size
        if kind == "b":
            (seq_base,) = _SEQ_HDR.unpack_from(payload, off)
            arrays = _decode_arrays(payload, off + _SEQ_HDR.size,
                                    _BATCH_ARRAYS)
            out.append(("b", idx, seq_base, *arrays))
        elif kind == "i":
            out.append(("i", idx, *_decode_arrays(payload, off,
                                                  _INGEST_ARRAYS)))
        elif kind == "r":
            out.append(("r", idx, *_decode_arrays(payload, off, 1)))
        elif kind == "s":
            out.append(("s", idx, *_decode_arrays(payload, off, 2)))
        elif kind == "f":
            out.append(("f", idx))
    return out


def replay_into(store, records) -> int:
    """Re-apply journal records through the store's columnar entry points.

    Records at or below the store's op-index watermark are skipped
    (prefix-idempotence); returns the number of records applied."""
    applied = 0
    for rec in records:
        kind, idx = rec[0], rec[1]
        if idx <= store.wal_index:
            continue
        if kind == "b":
            store._write_arrays(rec[3], rec[4], rec[5])
        elif kind == "i":
            store.ingest_batch(rec[2], rec[3], rec[4], rec[5])
        elif kind == "r":
            store.multi_get(rec[2])
        elif kind == "s":
            store.multi_scan(rec[2], rec[3])
        elif kind == "f":
            store.flush()
        store.wal_index = idx
        applied += 1
    return applied
