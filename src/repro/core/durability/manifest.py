"""Versioned MANIFEST: an append-only log of ``VersionEdit`` records
(DESIGN.md §9).

The MANIFEST is the durable root of a store directory.  Every metadata
transition appends one edit: file adds/drops (flush, compaction), value-
file registry changes and GC inheritance-chain updates (``chain_update`` /
``retire_value_file``), sequence-number watermarks, WAL segment rolls, and
checkpoints (which name the snapshot file recovery restores before
replaying the WAL tail).  Edits are JSON payloads in the shared CRC
framing (``records.py``); a torn tail is silently dropped on read, exactly
like a real MANIFEST whose writer died mid-append.

Recovery treats ``config`` / ``checkpoint`` / ``wal_segment`` edits as
load-bearing; the structural edits double as an audit log of the store's
file topology (asserted round-trippable by the hypothesis property in
``tests/test_durability.py``).
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

from .records import append_record, scan_records

# Edit kinds the core emits.  The codec is schema-free (kind + JSON data),
# so custom engines can log their own kinds without touching this module.
EDIT_KINDS = (
    "config",              # engine/fleet configuration at creation
    "wal_segment",         # a WAL segment was opened: {epoch, file}
    "watermark",           # sequence-number watermark: {seq, next_vid}
    "checkpoint",          # snapshot written: {file, seq, wal_epoch}
    "add_file",            # kSST added: {fid, level, nbytes}
    "drop_file",           # kSST dropped by compaction: {fid}
    "add_value_file",      # vSST registered: {fid, nbytes, temperature}
    "retire_value_file",   # vSST left the registry: {fid}
    "chain_update",        # GC inheritance: {retired: [...], group: [...]}
    "fleet_checkpoint",    # ShardedStore checkpoint: scheduler state + epoch
    "migration_begin",     # shard split/merge started: {kind, src, dst, ...}
    "migration_end",       # migration finalized: {kind, src, dst, epoch, ...}
    "replica_promote",     # failover: {shard, replica, applied}
)


@dataclasses.dataclass(frozen=True)
class VersionEdit:
    kind: str
    data: dict

    def encode(self) -> bytes:
        return json.dumps({"k": self.kind, "d": self.data},
                          sort_keys=True).encode()

    @classmethod
    def decode(cls, payload: bytes) -> "VersionEdit":
        obj = json.loads(payload)
        return cls(kind=obj["k"], data=obj["d"])


class ManifestWriter:
    """Append-only MANIFEST writer (flushed per edit: the manifest is the
    durability root, a buffered edit is a lost edit)."""

    def __init__(self, path: Path | str):
        self.path = Path(path)
        self._fh = open(self.path, "ab")
        # host-side file size (bytes), surfaced by the health sampler
        # (repro.obs, DESIGN.md §11)
        self.bytes_written = self._fh.tell()

    def append(self, edit: VersionEdit) -> None:
        append_record(self._fh, "e", edit.encode())
        self._fh.flush()
        self.bytes_written = self._fh.tell()

    def edit(self, kind: str, **data) -> None:
        self.append(VersionEdit(kind, data))

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.flush()
            self._fh.close()


def read_manifest(path: Path | str) -> list[VersionEdit]:
    """All intact edits in append order (torn tail dropped)."""
    return [VersionEdit.decode(payload)
            for _, key, payload in scan_records(path) if key == b"e"]
