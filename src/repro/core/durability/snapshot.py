"""Store snapshots: full-state checkpoint encode/restore (DESIGN.md §9).

A snapshot captures *everything* a ``Store`` needs to resume byte-identical
to an uninterrupted run: the engine config, sequence/vid watermarks, every
reachable SSTable (level files, the value-file registry in insertion order,
and tables referenced only through GC inheritance groups), the inheritance
graph itself (with GCGroup identity sharing preserved), memtable and
immutable contents, both caches' LRU order and hit counters, the simulated
device's per-category clocks and byte counters, the stats oracle's runs,
and — for ``scavenger_adaptive`` — the tracker's decayed sketches, lifetime
histograms, and the GC score cache.  Restoring then replaying the WAL tail
therefore reproduces the reference run's ``stats()`` to the last byte
(asserted by the crash matrix in ``tests/test_durability.py``).

On disk a snapshot is one record log in the shared CRC framing
(``records.py``): a JSON ``meta`` record, one packed-array record per
column, and an ``end`` completeness marker (a snapshot without it was torn
mid-write and is rejected, so recovery falls back to the previous one).
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import numpy as np

from ..engine.config import EngineConfig
from ..engine.io import DeviceModel, SimIO
from ..engine.memtable import Memtable
from ..engine.tables import KIND_VALUE, SSTable
from .records import append_record, pack_array, scan_records, unpack_array

FORMAT = 1

_MT_COLS = ("keys", "seqs", "ety", "vids", "vsz", "vf")
_MT_DTYPES = (np.uint64, np.uint64, np.uint8, np.uint64, np.int64, np.int64)
_TBL_COLS = ("keys", "seqs", "etype", "vids", "vsizes", "vfiles")


# ============================================================== capture
def _memtable_arrays(mt: Memtable) -> dict[str, np.ndarray]:
    n = len(mt.entries)
    keys = np.fromiter(mt.entries.keys(), np.uint64, count=n)
    vals = list(mt.entries.values())
    cols = [keys] + [
        np.fromiter((v[i] for v in vals), dt, count=n)
        for i, dt in enumerate(_MT_DTYPES[1:])]
    return dict(zip(_MT_COLS, cols))


def _collect_tables(store) -> dict[int, SSTable]:
    tables: dict[int, SSTable] = {}
    for t in store.version.all_kssts():
        tables[t.fid] = t
    for fid, t in store.version.value_files.items():
        tables[fid] = t
    for g in store.chains.values():
        for t in g.files:                 # may include retired tables
            tables.setdefault(t.fid, t)
    return tables


def snapshot_state(store) -> tuple[dict, dict]:
    """-> (meta, arrays): the complete serializable state of a Store."""
    assert not store.in_batch_write and not store.in_gc, \
        "checkpoint inside a write batch or GC run"
    arrays: dict[str, np.ndarray] = {}

    tables = _collect_tables(store)
    tmeta = []
    for fid, t in tables.items():
        ent = {"fid": fid, "kind": t.kind, "layout": t.layout,
               "is_hot": bool(t.is_hot), "temperature": int(t.temperature),
               "compensated_extra": int(t.compensated_extra),
               "merged_into": t.merged_into}
        if t.kind == KIND_VALUE:
            ent["garbage_bytes"] = int(t.garbage_bytes)
            ent["live_refs"] = int(t.live_refs)
        tmeta.append(ent)
        for c in _TBL_COLS:
            arrays[f"t{fid}_{c}"] = getattr(t, c)

    # GC inheritance groups, identity-shared (one GCGroup per GC run is
    # referenced by every candidate it retired)
    groups: list[list[int]] = []
    gid_of: dict[int, int] = {}
    chain_of: dict[str, int] = {}
    for fid, g in store.chains.items():
        gid = gid_of.get(id(g))
        if gid is None:
            gid = len(groups)
            gid_of[id(g)] = gid
            groups.append([t.fid for t in g.files])
        chain_of[str(fid)] = gid

    for name, mt in [("mt", store.memtable)] + [
            (f"imm{i}", m) for i, m in enumerate(store.immutables)]:
        for c, a in _memtable_arrays(mt).items():
            arrays[f"{name}_{c}"] = a

    o = store.latest
    for c, a in (("bkeys", o.bkeys), ("bvids", o.bvids),
                 ("bvsizes", o.bvsizes), ("dkeys", o.dkeys),
                 ("dvids", o.dvids), ("dvsizes", o.dvsizes)):
        arrays[f"or_{c}"] = a

    adaptive = None
    tracker = getattr(store.strategy, "tracker", None)
    if tracker is not None:
        adaptive = {
            "ops": float(tracker.ops),
            "writes_clock": float(tracker.writes.clock),
            "reads_clock": float(tracker.reads.clock),
            "soon_cache": {str(fid): list(v) for fid, v in
                           getattr(store.strategy, "_soon_cache",
                                   {}).items()},
        }
        arrays["ad_wcounts"] = tracker.writes.counts
        arrays["ad_rcounts"] = tracker.reads.counts
        arrays["ad_lt_last"] = tracker.lifetime.last_write
        arrays["ad_lt_hist"] = tracker.lifetime.hist

    io = store.io
    dev = dataclasses.asdict(io.device)
    meta = {
        "format": FORMAT,
        # state_dict, not asdict: the live observer hook (repro.obs) is
        # process state, never snapshot payload
        "cfg": store.cfg.state_dict(),
        "seq": int(store.seq),
        "next_vid": int(store.next_vid),
        "wal_index": int(store.wal_index),
        "compact_cursor": {str(k): v for k, v in
                           store.compact_cursor.items()},
        "counters": {
            "user_write_bytes": int(store.user_write_bytes),
            "n_user_ops": int(store.n_user_ops),
            "n_compactions": int(store.n_compactions),
            "n_gc_runs": int(store.n_gc_runs),
            "gc_reclaimed_bytes": int(store.gc_reclaimed_bytes),
            "stall_us": float(store.stall_us),
            "oracle_valid_bytes": int(store.latest.valid_bytes),
        },
        "io": {
            "lanes": dict(io.lanes),
            "read_bytes": dict(io.read_bytes),
            "write_bytes": dict(io.write_bytes),
            "read_ops": dict(io.read_ops),
            "write_ops": dict(io.write_ops),
            "time_us": dict(io.time_us),
            "device": dev,
        },
        "cache": {
            "low": [[k[0], k[1], k[2], nb]
                    for k, nb in store.cache._low.items()],
            "high": [[k[0], k[1], k[2], nb]
                     for k, nb in store.cache._high.items()],
            "hits": int(store.cache.hits),
            "misses": int(store.cache.misses),
        },
        "dropcache": {
            "keys": list(store.dropcache._lru.keys()),
            "record_count": int(store.dropcache.record_count),
        },
        "tables": tmeta,
        "version": {
            "levels": [[t.fid for t in lvl]
                       for lvl in store.version.levels],
            "value_files": list(store.version.value_files.keys()),
            "chain": {str(k): v for k, v in store.version._chain.items()},
        },
        "chains": {"groups": groups, "chain_of": chain_of},
        "n_immutables": len(store.immutables),
        "adaptive": adaptive,
    }
    return meta, arrays


def write_snapshot(store, path: Path | str) -> Path:
    meta, arrays = snapshot_state(store)
    path = Path(path)
    with open(path, "wb") as fh:
        append_record(fh, "meta", json.dumps(meta, sort_keys=True).encode())
        for name, a in arrays.items():
            append_record(fh, f"a:{name}", pack_array(np.asarray(a)))
        append_record(fh, "end", b"")
        fh.flush()
    return path


# ============================================================== restore
def read_snapshot(path: Path | str) -> tuple[dict, dict]:
    meta, arrays, complete = None, {}, False
    for _, key, payload in scan_records(path):
        if key == b"meta":
            meta = json.loads(payload)
        elif key.startswith(b"a:"):
            arrays[key[2:].decode()] = unpack_array(payload)
        elif key == b"end":
            complete = True
    if meta is None or not complete:
        raise IOError(f"truncated or corrupt snapshot: {path}")
    return meta, arrays


def _restore_memtable(cfg, arrays, prefix: str) -> Memtable:
    mt = Memtable(cfg)
    cols = [arrays[f"{prefix}_{c}"] for c in _MT_COLS]
    keys = cols[0]
    vals = list(zip(*(c.tolist() for c in cols[1:])))
    total = 0
    for k, v in zip(keys.tolist(), vals):
        mt.entries[k] = v
        total += mt._entry_bytes(v[1], v[3])
    mt.bytes = total
    return mt


def restore_store(meta, arrays, io: SimIO | None = None, cls=None):
    """Rebuild a live Store (or ``cls`` subclass) from a decoded snapshot."""
    from ..store import Store          # lazy: snapshot <- store cycle
    from ..values.resolve import GCGroup

    if meta.get("format") != FORMAT:
        raise ValueError(f"unsupported snapshot format {meta.get('format')}")
    cfg = EngineConfig(**meta["cfg"])
    if io is None:
        dev = dict(meta["io"]["device"])
        dev["lane_parallelism"] = dict(dev["lane_parallelism"])
        io = SimIO(DeviceModel(**dev))
    store = (cls or Store)(cfg, io=io)

    # ---- io ----
    mio = meta["io"]
    io.lanes.update(mio["lanes"])
    for field in ("read_bytes", "write_bytes", "read_ops", "write_ops",
                  "time_us"):
        getattr(io, field).update(mio[field])

    # ---- tables ----
    tables: dict[int, SSTable] = {}
    max_fid = 0
    for ent in meta["tables"]:
        fid = int(ent["fid"])
        cols = [arrays[f"t{fid}_{c}"] for c in _TBL_COLS]
        t = SSTable(cfg, ent["kind"], ent["layout"], *cols,
                    is_hot=ent["is_hot"], temperature=ent["temperature"])
        t.fid = fid
        t.compensated_extra = int(ent["compensated_extra"])
        t.merged_into = ent["merged_into"]
        if ent["kind"] == KIND_VALUE:
            t.garbage_bytes = int(ent["garbage_bytes"])
            t.live_refs = int(ent["live_refs"])
        tables[fid] = t
        max_fid = max(max_fid, fid)
    # keep the process-global fid counter ahead of every restored fid so
    # post-recovery allocations preserve creation order (BlobDB ages files
    # by fid)
    SSTable._next_fid = max(SSTable._next_fid, max_fid + 1)

    v = store.version
    for i, fids in enumerate(meta["version"]["levels"]):
        v.levels[i] = [tables[f] for f in fids]
    v.value_files = {f: tables[f] for f in meta["version"]["value_files"]}
    v._chain = {int(k): vv for k, vv in meta["version"]["chain"].items()}

    groups = [GCGroup([tables[f] for f in fids])
              for fids in meta["chains"]["groups"]]
    store.chains = {int(fid): groups[gid]
                    for fid, gid in meta["chains"]["chain_of"].items()}

    # ---- memtables ----
    store.memtable = _restore_memtable(cfg, arrays, "mt")
    store.immutables = [_restore_memtable(cfg, arrays, f"imm{i}")
                        for i in range(meta["n_immutables"])]

    # ---- caches ----
    for pool, items in (("_low", meta["cache"]["low"]),
                        ("_high", meta["cache"]["high"])):
        d = getattr(store.cache, pool)
        total = 0
        for fid, stream, block, nb in items:
            d[(int(fid), stream, int(block))] = int(nb)
            total += int(nb)
        setattr(store.cache, "low_bytes" if pool == "_low" else "high_bytes",
                total)
    store.cache.hits = int(meta["cache"]["hits"])
    store.cache.misses = int(meta["cache"]["misses"])
    for k in meta["dropcache"]["keys"]:
        store.dropcache._lru[int(k)] = None
    store.dropcache.record_count = int(meta["dropcache"]["record_count"])

    # ---- oracle ----
    o = store.latest
    o.bkeys, o.bvids, o.bvsizes = (arrays["or_bkeys"], arrays["or_bvids"],
                                   arrays["or_bvsizes"])
    o.dkeys, o.dvids, o.dvsizes = (arrays["or_dkeys"], arrays["or_dvids"],
                                   arrays["or_dvsizes"])

    # ---- scalars ----
    c = meta["counters"]
    store.seq = int(meta["seq"])
    store.next_vid = int(meta["next_vid"])
    store.wal_index = int(meta["wal_index"])
    store.compact_cursor = {int(k): vv for k, vv in
                            meta["compact_cursor"].items()}
    store.user_write_bytes = c["user_write_bytes"]
    store.n_user_ops = c["n_user_ops"]
    store.n_compactions = c["n_compactions"]
    store.n_gc_runs = c["n_gc_runs"]
    store.gc_reclaimed_bytes = c["gc_reclaimed_bytes"]
    store.stall_us = c["stall_us"]
    o.valid_bytes = c["oracle_valid_bytes"]

    # ---- adaptive tracker ----
    ad = meta.get("adaptive")
    tracker = getattr(store.strategy, "tracker", None)
    if ad is not None and tracker is not None:
        tracker.ops = ad["ops"]
        tracker.writes.counts = arrays["ad_wcounts"]
        tracker.writes.clock = ad["writes_clock"]
        tracker.reads.counts = arrays["ad_rcounts"]
        tracker.reads.clock = ad["reads_clock"]
        tracker.lifetime.last_write = arrays["ad_lt_last"]
        tracker.lifetime.hist = arrays["ad_lt_hist"]
        store.strategy._soon_cache = {int(k): tuple(vv) for k, vv in
                                      ad["soon_cache"].items()}
    return store


def restore(path: Path | str, io: SimIO | None = None, cls=None):
    meta, arrays = read_snapshot(path)
    return restore_store(meta, arrays, io=io, cls=cls)
