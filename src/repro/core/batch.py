"""WriteBatch: columnar, atomically-applied group of puts/deletes
(DESIGN.md §3).

The batch is the unit of the group-commit write path (``Store.write``):
one admission/quota check, one sequence-number range, one WAL append, and
chunked vectorized memtable insertion.  Ops are kept as parallel NumPy
columns (kind, key, vsize) so the whole batch crosses the Python/engine
boundary in a single call — the scalar ``Store.put``/``Store.delete`` are
thin shims over a one-record batch.

Ordering semantics match RocksDB's WriteBatch: records apply in append
order, so a later put/delete of the same key within one batch wins.
"""

from __future__ import annotations

import numpy as np

OP_PUT = 0
OP_DELETE = 1


class ScalarOps:
    """Scalar one-record shims over the batched columnar API.

    Mixed into ``Store`` and ``ShardedStore``; hosts need
    ``_write_arrays`` / ``multi_get`` / ``multi_scan``.
    """

    def put(self, key: int, vsize: int) -> int:
        """Write key with a value of ``vsize`` bytes; returns the vid."""
        vids = self._write_arrays(np.array([OP_PUT], np.uint8),
                                  np.array([key], np.uint64),
                                  np.array([vsize], np.int64))
        return int(vids[0])

    def delete(self, key: int) -> None:
        self._write_arrays(np.array([OP_DELETE], np.uint8),
                           np.array([key], np.uint64),
                           np.array([0], np.int64))

    def get(self, key: int):
        """-> vid or None."""
        res = self.multi_get(np.array([key], np.uint64))
        return int(res["vid"][0]) if res["found"][0] else None

    def scan(self, start_key: int, count: int):
        """Range query: returns up to ``count`` (key, vid) pairs in order."""
        return self.multi_scan(np.array([start_key], np.int64), count)[0]


class WriteBatch:
    __slots__ = ("_kinds", "_keys", "_vsizes")

    def __init__(self):
        self._kinds: list[np.ndarray] = []
        self._keys: list[np.ndarray] = []
        self._vsizes: list[np.ndarray] = []

    # ------------------------------------------------------------- building
    def put(self, key: int, vsize: int) -> "WriteBatch":
        return self.puts(np.array([key], np.uint64),
                         np.array([vsize], np.int64))

    def delete(self, key: int) -> "WriteBatch":
        return self.deletes(np.array([key], np.uint64))

    def puts(self, keys: np.ndarray, vsizes: np.ndarray) -> "WriteBatch":
        """Append a column of puts; ``keys`` and ``vsizes`` must align."""
        keys = np.asarray(keys, np.uint64).ravel()
        vsizes = np.asarray(vsizes, np.int64).ravel()
        if len(keys) != len(vsizes):
            raise ValueError("keys and vsizes must have equal length")
        self._kinds.append(np.full(len(keys), OP_PUT, np.uint8))
        self._keys.append(keys)
        self._vsizes.append(vsizes)
        return self

    def deletes(self, keys: np.ndarray) -> "WriteBatch":
        keys = np.asarray(keys, np.uint64).ravel()
        self._kinds.append(np.full(len(keys), OP_DELETE, np.uint8))
        self._keys.append(keys)
        self._vsizes.append(np.zeros(len(keys), np.int64))
        return self

    # ------------------------------------------------------------ consuming
    def __len__(self) -> int:
        return sum(len(k) for k in self._keys)

    def arrays(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """-> (kinds u8, keys u64, vsizes i64) in append order."""
        if not self._keys:
            z = np.zeros(0, np.uint64)
            return np.zeros(0, np.uint8), z, np.zeros(0, np.int64)
        return (np.concatenate(self._kinds), np.concatenate(self._keys),
                np.concatenate(self._vsizes))

    def clear(self) -> None:
        self._kinds.clear()
        self._keys.clear()
        self._vsizes.clear()
