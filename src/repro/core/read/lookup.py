"""Vectorized point-lookup machinery (the read layer's hot path,
DESIGN.md §7).

``lookup_entries`` walks memtables -> L0 (newest first) -> L1..Ln for a
whole key column: batched columnar memtable probes (``Memtable.get_batch``),
one bloom/``find`` pass per touched SSTable, block-cache I/O accounting per
unique (stream, block) — no per-key Python anywhere on the path.

Eligible batches route through the fused ``lookup_probe`` kernel
(``core/accel.py``, DESIGN.md §12): the bloom bit test, the sorted-run
membership/rank, and the per-level file assignment run as one jitted call
per probed structure, byte-identical to the host path below.
"""

from __future__ import annotations

import numpy as np

from .. import accel
from ..engine.cache import BlockCache
from ..engine.keys import BloomFilter, hash_family
from ..engine.tables import ETYPE_NONE, ETYPE_REF, SSTable


def read_block(store, t: SSTable, stream: str, block_id: int, cat: str,
               priority: int, nbytes: int | None = None) -> None:
    """Cache-aware block read: hit -> CPU cost only, miss -> random I/O."""
    ck = (t.fid, stream, int(block_id))
    if store.cache.get(ck):
        store.io.cache_hit(cat)
        return
    if nbytes is None:
        s = int(stream[1])
        nbytes = t.data_block_bytes(s, block_id)
    store.io.rand_read(int(nbytes), cat)
    store.cache.put(ck, int(nbytes), priority)


def read_entry_blocks(store, t: SSTable, pos: np.ndarray, ety: np.ndarray,
                      cat: str) -> None:
    """Charge data-block reads for entries at ``pos`` in kSST/vSST ``t``.

    DTable routes REF entries to (high-priority) KF blocks and inline
    records to KV blocks — the paper's GC-Lookup optimisation.

    The dtable dedup deliberately stays a *set* of (stream, block) pairs
    over the hit positions: its iteration order fixes the LRU insertion
    order of the touched blocks, which the pre-refactor parity goldens
    (tests/test_refactor_parity.py) lock in byte-for-byte."""
    if t.layout == "dtable":
        streams = np.where(ety == ETYPE_REF, 0, 1)
        for s, b in {(int(s), int(t.block_of[p]))
                     for s, p in zip(streams, pos)}:
            pri = BlockCache.PRI_HIGH if s == 0 else BlockCache.PRI_LOW
            read_block(store, t, f"d{s}", b, cat, pri,
                       t.data_block_bytes(s, b))
    else:
        for b in np.unique(t.block_of[pos]).tolist():
            read_block(store, t, "d0", b, cat, BlockCache.PRI_LOW,
                       t.data_block_bytes(0, b))


def lookup_entries(store, keys: np.ndarray, cat: str) -> dict:
    """Vectorized newest-wins point lookup for a batch of keys.

    Returns parallel arrays: found / etype / vid / vsize / vfile."""
    n = len(keys)
    out = {
        "found": np.zeros(n, bool),
        "etype": np.full(n, ETYPE_NONE, np.uint8),
        "vid": np.zeros(n, np.uint64),
        "vsize": np.zeros(n, np.int64),
        "vfile": np.full(n, -1, np.int64),
    }
    unresolved = np.ones(n, bool)

    # ---- memtables, newest first: batched columnar probes ----
    for mt in [store.memtable] + list(reversed(store.immutables)):
        if not unresolved.any():
            break
        rows = np.nonzero(unresolved)[0]
        probe = accel.memtable_probe(store, mt, keys[rows])
        found, _, ety, vids, vsz, vf = (probe if probe is not None
                                        else mt.get_batch(keys[rows]))
        if not found.any():
            continue
        hit = rows[found]
        out["found"][hit] = True
        out["etype"][hit] = ety[found]
        out["vid"][hit] = vids[found]
        out["vsize"][hit] = vsz[found]
        out["vfile"][hit] = vf[found]
        unresolved[hit] = False

    # raw bloom hashes depend only on the key column: hash once, reuse
    # against every probed table's filter
    kraw = hash_family(keys, BloomFilter.k_for(store.cfg.filter_bits_per_key))

    def probe_file(t: SSTable, rows: np.ndarray):
        fused = accel.table_probe(store, t, keys[rows], kraw[:, rows])
        may = (t.bloom.may_contain(keys[rows], raw=kraw[:, rows])
               if fused is None else fused[0])
        if not may.any():
            return
        rows = rows[may]
        read_block(store, t, "i", 0, cat, BlockCache.PRI_HIGH,
                   t.index_block_bytes())
        pos = t.find(keys[rows]) if fused is None else fused[1][may]
        hit = pos >= 0
        if hit.any():
            hrows, hpos = rows[hit], pos[hit]
            read_entry_blocks(store, t, hpos, t.etype[hpos], cat)
            out["found"][hrows] = True
            out["etype"][hrows] = t.etype[hpos]
            out["vid"][hrows] = t.vids[hpos]
            out["vsize"][hrows] = t.vsizes[hpos]
            out["vfile"][hrows] = t.vfiles[hpos]
            unresolved[hrows] = False

    for t in reversed(store.version.levels[0]):
        if not unresolved.any():
            break
        probe_file(t, np.nonzero(unresolved)[0])
    for lvl in range(1, store.cfg.max_levels):
        if not unresolved.any():
            break
        files = store.version.levels[lvl]
        if not files:
            continue
        rows = np.nonzero(unresolved)[0]
        fidx = accel.assign_files(store, lvl, keys[rows])
        if fidx is None:
            fidx = store.version.assign_files(lvl, keys[rows])
        for fi in np.unique(fidx[fidx >= 0]):
            probe_file(files[fi], rows[fidx == fi])
    return out
