"""Batched range-scan merge planning (DESIGN.md §7).

A scan merges key-sorted pools from every live source (memtable snapshots,
immutables, every level's overlapping files), newest-wins by (key, seq)
lexsort.  Per-source fetch limits adapt upward across retries: dead entries
(tombstones, superseded versions) may eat slots, requiring a refill.
"""

from __future__ import annotations

import numpy as np

from ..engine import io as sio
from .lookup import read_entry_blocks
from ..engine.tables import ETYPE_REF, ETYPE_TOMB
from ..values.fetch import read_values_batch


def scan_retry(store, start_key: int, count: int):
    """Retry wrapper: grow per-source limits until the result is complete."""
    limit = count
    for _ in range(store.cfg.scan_retry_rounds):
        out, min_excluded = scan_once(store, start_key, count, limit)
        complete = min_excluded is None or (
            len(out) >= count and out[-1][0] < min_excluded)
        if complete:
            return out
        limit *= store.cfg.scan_retry_growth
    return out


def scan_once(store, start_key: int, count: int, limit: int):
    cfg = store.cfg
    excluded = []       # first key beyond each truncated source
    pools = []
    start = np.uint64(max(0, start_key))
    for mt in [store.memtable] + store.immutables:
        mk, seqs, ety, vids, vsz, vf = mt.snapshot()
        a = int(np.searchsorted(mk, start))
        if a + limit < len(mk):
            excluded.append(int(mk[a + limit]))
        b = min(a + limit, len(mk))
        if a >= b:
            continue
        sel = slice(a, b)
        pools.append((None, mk[sel], seqs[sel], ety[sel], vids[sel],
                      vsz[sel], vf[sel], None))
    for lvl in range(cfg.max_levels):
        for t in store.version.levels[lvl]:
            a = int(np.searchsorted(t.keys, start))
            b = min(a + limit, t.n)
            if a + limit < t.n:
                excluded.append(int(t.keys[a + limit]))
            if a >= b:
                continue
            pos = np.arange(a, b, dtype=np.int64)
            pools.append((t, t.keys[pos], t.seqs[pos], t.etype[pos],
                          t.vids[pos], t.vsizes[pos], t.vfiles[pos], pos))
    min_excluded = min(excluded) if excluded else None
    if not pools:
        return [], min_excluded
    keys = np.concatenate([p[1] for p in pools])
    seqs = np.concatenate([p[2] for p in pools])
    ety = np.concatenate([p[3] for p in pools])
    vids = np.concatenate([p[4] for p in pools])
    vsz = np.concatenate([p[5] for p in pools])
    vf = np.concatenate([p[6] for p in pools])
    src = np.concatenate([np.full(len(p[1]), i, np.int64)
                          for i, p in enumerate(pools)])
    pos_all = np.concatenate([
        p[7] if p[7] is not None else np.full(len(p[1]), -1, np.int64)
        for p in pools])
    order = np.lexsort((np.iinfo(np.uint64).max - seqs, keys))
    keys, ety, vids, vsz, vf, src, pos_all = (
        a[order] for a in (keys, ety, vids, vsz, vf, src, pos_all))
    first = np.ones(len(keys), bool)
    first[1:] = keys[1:] != keys[:-1]
    live = first & (ety != ETYPE_TOMB)
    take = np.nonzero(live)[0][:count]

    # ---- I/O: data blocks for chosen rows, value fetches for refs ----
    for i_pool in np.unique(src[take]):
        p = pools[i_pool]
        if p[0] is None:
            continue
        t = p[0]
        rows = take[src[take] == i_pool]
        read_entry_blocks(store, t, pos_all[rows], ety[rows], sio.CAT_SCAN)
    ref_rows = take[ety[take] == ETYPE_REF]
    if len(ref_rows):
        read_values_batch(store, keys[ref_rows], vids[ref_rows],
                          vf[ref_rows], vsz[ref_rows], sio.CAT_SCAN)
    store.pump()
    return (list(zip(keys[take].tolist(), vids[take].tolist())),
            min_excluded)
