"""Read layer: vectorized lookups and scan merge planning (DESIGN.md §7)."""

from .lookup import lookup_entries, read_block, read_entry_blocks
from .scan import scan_once, scan_retry

__all__ = ["lookup_entries", "read_block", "read_entry_blocks",
           "scan_once", "scan_retry"]
