"""KVStore facade: five selectable engines over one substrate.

``Store(EngineConfig(engine=...))`` gives RocksDB-, BlobDB-, Titan-,
TerarkDB- or Scavenger-semantics over the same deterministic simulated
device, so every paper comparison is apples-to-apples.

Scheduling model (see DESIGN.md §3): user operations advance the foreground
lane; flush/compaction/GC jobs run on a sequential background lane that
models 16 background threads saturating one SSD.  Background debt surfaces
as foreground write stalls through the standard RocksDB triggers (immutable
memtable cap, L0 slowdown/stop) — this is what reproduces the paper's
delayed-compaction -> hidden-garbage -> space-amplification chain.

All reads return the value's ``vid`` (the identity the store wrote into both
the index entry and the value record — the stand-in for real value bytes);
tests compare vids against an external oracle.
"""

from __future__ import annotations

import numpy as np

from . import compaction as comp
from . import gc as gcmod
from .engine import io as sio
from .engine.cache import BlockCache, DropCache
from .engine.config import EngineConfig
from .engine.io import SimIO
from .engine.memtable import Memtable
from .engine.tables import (ETYPE_INLINE, ETYPE_REF, ETYPE_TOMB, SSTable,
                            build_vsst)
from .engine.version import Version

MAX_IMMUTABLES = 2
DELAYED_WRITE_RATE = 16.0   # MB/s, RocksDB default under slowdown


class Store:
    def __init__(self, cfg: EngineConfig, io: SimIO | None = None):
        self.cfg = cfg
        self.io = io or SimIO()
        self.cache = BlockCache(cfg.cache_bytes, cfg.cache_high_frac)
        self.dropcache = DropCache(cfg.dropcache_keys)
        self.version = Version(cfg.max_levels)
        self.memtable = Memtable(cfg)
        self.immutables: list[Memtable] = []
        self.chains: dict[int, gcmod.GCGroup] = {}
        self.seq = 0
        self.next_vid = 1
        self.in_gc = False
        self.compact_cursor: dict[int, int] = {}
        self._last_bg = "gc"

        # stats / bookkeeping
        self.latest: dict[int, tuple] = {}   # key -> (vid, vsize): oracle for
        self.valid_bytes = 0                 # space-amp denominators only
        self.user_write_bytes = 0
        self.n_user_ops = 0
        self.n_compactions = 0
        self.n_gc_runs = 0
        self.gc_reclaimed_bytes = 0
        self.stall_us = 0.0

    # ================================================================== API
    def put(self, key: int, vsize: int) -> int:
        """Write key with a value of ``vsize`` bytes; returns the vid."""
        self._write_pressure()
        self.seq += 1
        vid = self.next_vid
        self.next_vid += 1
        rec = self.cfg.key_bytes + vsize + 12
        self.io.seq_write(rec, sio.CAT_WAL)
        self.user_write_bytes += rec
        self.n_user_ops += 1
        self.memtable.put(key, self.seq, vid, vsize)
        prev = self.latest.get(key)
        if prev is not None:
            self.valid_bytes -= prev[1]
        self.latest[key] = (vid, vsize)
        self.valid_bytes += vsize
        self._after_write(rec)
        return vid

    def delete(self, key: int) -> None:
        self._write_pressure()
        self.seq += 1
        rec = self.cfg.key_bytes + 12
        self.io.seq_write(rec, sio.CAT_WAL)
        self.user_write_bytes += rec
        self.n_user_ops += 1
        self.memtable.delete(key, self.seq)
        prev = self.latest.pop(key, None)
        if prev is not None:
            self.valid_bytes -= prev[1]
        self._after_write(rec)

    def get(self, key: int):
        """-> vid or None."""
        self.n_user_ops += 1
        res = self.lookup_entries(np.array([key], np.uint64),
                                  sio.CAT_FG_READ)
        self.pump()
        if not res["found"][0] or res["etype"][0] == ETYPE_TOMB:
            return None
        if res["etype"][0] == ETYPE_INLINE:
            return int(res["vid"][0])
        return self.read_value(key, int(res["vid"][0]),
                               int(res["vfile"][0]), int(res["vsize"][0]),
                               sio.CAT_FG_READ)

    def scan(self, start_key: int, count: int):
        """Range query: returns up to ``count`` (key, vid) pairs in order.

        Per-source fetch limits adapt upward: dead entries (tombstones,
        superseded versions) may eat slots, requiring a refill."""
        self.n_user_ops += 1
        limit = count
        for _ in range(32):
            out, min_excluded = self._scan_once(start_key, count, limit)
            complete = min_excluded is None or (
                len(out) >= count and out[-1][0] < min_excluded)
            if complete:
                return out
            limit *= 4
        return out

    def _scan_once(self, start_key: int, count: int, limit: int):
        cfg = self.cfg
        excluded = []       # first key beyond each truncated source
        pools = []
        for mt in [self.memtable] + self.immutables:
            mk = sorted(k for k in mt.entries if k >= start_key)
            if len(mk) > limit:
                excluded.append(mk[limit])
            mk = mk[:limit]
            if not mk:
                continue
            rows = [mt.entries[k] for k in mk]
            pools.append((None,
                          np.array(mk, np.uint64),
                          np.array([r[0] for r in rows], np.uint64),
                          np.array([r[1] for r in rows], np.uint8),
                          np.array([r[2] for r in rows], np.uint64),
                          np.array([r[3] for r in rows], np.int64),
                          np.array([r[4] for r in rows], np.int64),
                          None))
        for lvl in range(cfg.max_levels):
            for t in self.version.levels[lvl]:
                a = int(np.searchsorted(t.keys, np.uint64(start_key)))
                b = min(a + limit, t.n)
                if a + limit < t.n:
                    excluded.append(int(t.keys[a + limit]))
                if a >= b:
                    continue
                pos = np.arange(a, b, dtype=np.int64)
                pools.append((t, t.keys[pos], t.seqs[pos], t.etype[pos],
                              t.vids[pos], t.vsizes[pos], t.vfiles[pos], pos))
        min_excluded = min(excluded) if excluded else None
        if not pools:
            return [], min_excluded
        keys = np.concatenate([p[1] for p in pools])
        seqs = np.concatenate([p[2] for p in pools])
        ety = np.concatenate([p[3] for p in pools])
        vids = np.concatenate([p[4] for p in pools])
        vsz = np.concatenate([p[5] for p in pools])
        vf = np.concatenate([p[6] for p in pools])
        src = np.concatenate([np.full(len(p[1]), i, np.int64)
                              for i, p in enumerate(pools)])
        pos_all = np.concatenate([
            p[7] if p[7] is not None else np.full(len(p[1]), -1, np.int64)
            for p in pools])
        order = np.lexsort((np.iinfo(np.uint64).max - seqs, keys))
        keys, ety, vids, vsz, vf, src, pos_all = (
            a[order] for a in (keys, ety, vids, vsz, vf, src, pos_all))
        first = np.ones(len(keys), bool)
        first[1:] = keys[1:] != keys[:-1]
        live = first & (ety != ETYPE_TOMB)
        take = np.nonzero(live)[0][:count]

        # ---- I/O: data blocks for chosen rows, value fetches for refs ----
        for i_pool in np.unique(src[take]):
            p = pools[i_pool]
            if p[0] is None:
                continue
            t = p[0]
            rows = take[src[take] == i_pool]
            self._read_entry_blocks(t, pos_all[rows], ety[rows],
                                    sio.CAT_SCAN)
        ref_rows = take[ety[take] == ETYPE_REF]
        if len(ref_rows):
            self._read_values_batch(keys[ref_rows], vids[ref_rows],
                                    vf[ref_rows], vsz[ref_rows],
                                    sio.CAT_SCAN)
        self.pump()
        return (list(zip(keys[take].tolist(), vids[take].tolist())),
                min_excluded)

    # ===================================================== background lanes
    def next_compact_job(self):
        """Work-finder for the flush/compaction pool (16 threads)."""
        if self.immutables:
            return ("flush",)
        pick = comp.pick_compaction(self)
        if pick is not None:
            return ("compact", pick)
        return None

    def next_gc_job(self):
        """Work-finder for the dedicated GC pool (1-2 threads — Titan/
        TerarkDB defaults; GC lags ingest, which is the source of the
        paper's space-amplification backlog)."""
        if self.cfg.gc_scheme not in ("inherit", "writeback"):
            return None
        cands = gcmod.gc_candidates(self, self._gc_threshold())
        if cands:
            return ("gc", gcmod.gc_batch(self, cands))
        return None

    def run_job(self, job, lane: str) -> None:
        prev_lane = self.io.lane
        self.io.lane = lane
        try:
            if job[0] == "flush":
                self._flush_job()
            elif job[0] == "compact":
                comp.run_compaction(self, *job[1])
            else:
                gcmod.run_gc(self, job[1])
        finally:
            self.io.lane = prev_lane

    def pump(self) -> None:
        """Run background jobs that fit before the foreground clock."""
        while self.io.bg_clock_us < self.io.fg_clock_us:
            job = self.next_compact_job()
            if job is None:
                break
            self.run_job(job, "bg")
        while self.io.gc_clock_us < self.io.fg_clock_us:
            job = self.next_gc_job()
            if job is None:
                break
            self.run_job(job, "gc")

    def _stall_while(self, cond, prefer_gc: bool = False) -> None:
        """Foreground blocked on background progress."""
        t0 = self.io.fg_clock_us
        while cond():
            if prefer_gc:
                job, lane = self.next_gc_job(), "gc"
                if job is None:
                    job, lane = self.next_compact_job(), "bg"
            else:
                job, lane = self.next_compact_job(), "bg"
                if job is None:
                    job, lane = self.next_gc_job(), "gc"
            if job is None:
                break
            self.io.lanes[lane] = max(self.io.lanes[lane],
                                      self.io.fg_clock_us)
            self.run_job(job, lane)
            self.io.lanes["fg"] = max(self.io.fg_clock_us,
                                      self.io.lanes[lane])
        self.stall_us += self.io.fg_clock_us - t0

    def settle(self) -> None:
        """Let background catch up to the foreground clock (no fg time)."""
        self.pump()

    def drain(self) -> None:
        """Run ALL pending background work and synchronize lanes."""
        while True:
            job = self.next_compact_job()
            lane = "bg"
            if job is None:
                job, lane = self.next_gc_job(), "gc"
            if job is None:
                break
            self.run_job(job, lane)
        m = max(self.io.lanes.values())
        for k in self.io.lanes:
            self.io.lanes[k] = m

    # ------------------------------------------------------ write pressure
    def _after_write(self, rec_bytes: int) -> None:
        if self.memtable.full:
            self.immutables.append(self.memtable)
            self.memtable = Memtable(self.cfg)
        self.pump()
        self._stall_while(lambda: len(self.immutables) > MAX_IMMUTABLES)
        self._stall_while(
            lambda: len(self.version.levels[0]) >= self.cfg.l0_stop)
        if len(self.version.levels[0]) >= self.cfg.l0_slowdown:
            delay = rec_bytes / DELAYED_WRITE_RATE   # us at MB/s
            self.io.stall(delay)
            self.stall_us += delay
            self.pump()

    def _write_pressure(self) -> None:
        """Space-aware throttling (paper §III-D)."""
        cfg = self.cfg
        if cfg.space_quota_bytes is None:
            return
        space = self.version.total_bytes()
        soft = cfg.soft_quota_frac * cfg.space_quota_bytes
        if space < soft:
            return
        if space >= cfg.space_quota_bytes:
            seen = 0

            def over():
                nonlocal seen
                seen += 1
                return (seen < 256
                        and self.version.total_bytes()
                        >= cfg.space_quota_bytes)
            self._stall_while(over, prefer_gc=True)
        else:
            self.io.stall(cfg.slowdown_us_per_write)
            self.stall_us += cfg.slowdown_us_per_write
            self.pump()

    def _gc_threshold(self) -> float:
        cfg = self.cfg
        if cfg.space_quota_bytes is None:
            return cfg.gc_garbage_ratio
        space = self.version.total_bytes()
        if space >= cfg.soft_quota_frac * cfg.space_quota_bytes:
            return cfg.gc_aggressive_ratio
        return cfg.gc_garbage_ratio

    # ================================================================ flush
    def _flush_job(self) -> None:
        if not self.immutables:
            return
        mt = self.immutables.pop(0)
        cfg = self.cfg
        keys, seqs, ety, vids, vsz, vf = mt.sorted_arrays()
        if cfg.kv_separated:
            sep = (ety == ETYPE_INLINE) & (vsz >= cfg.sep_threshold)
            if sep.any():
                idx = np.nonzero(sep)[0]
                _, fids = self.build_value_files(keys[idx], vids[idx],
                                                 vsz[idx], sio.CAT_FLUSH)
                ety = ety.copy()
                vf = vf.copy()
                ety[idx] = ETYPE_REF
                vf[idx] = fids
        t = SSTable(cfg, "k", cfg.ksst_layout, keys, seqs, ety, vids, vsz, vf)
        t.compensated_extra = int(vsz[ety == ETYPE_REF].sum())
        self.io.seq_write(t.file_bytes, sio.CAT_FLUSH)
        self.version.add_l0(t)

    def flush(self) -> None:
        """Force-rotate the memtable and drain all background work."""
        if len(self.memtable):
            self.immutables.append(self.memtable)
            self.memtable = Memtable(self.cfg)
        self.drain()

    # ======================================================= lookup machinery
    def lookup_entries(self, keys: np.ndarray, cat: str) -> dict:
        """Vectorized newest-wins point lookup for a batch of keys.

        Walks memtables -> L0 (newest first) -> L1..Ln with bloom filters and
        block-cache I/O accounting.  Returns parallel arrays."""
        n = len(keys)
        out = {
            "found": np.zeros(n, bool),
            "etype": np.full(n, 255, np.uint8),
            "vid": np.zeros(n, np.uint64),
            "vsize": np.zeros(n, np.int64),
            "vfile": np.full(n, -1, np.int64),
        }
        unresolved = np.ones(n, bool)
        tables = [self.memtable] + list(reversed(self.immutables))
        for i, k in enumerate(keys.tolist()):
            for mt in tables:
                e = mt.get(k)
                if e is not None:
                    out["found"][i] = True
                    out["etype"][i] = e[1]
                    out["vid"][i] = e[2]
                    out["vsize"][i] = e[3]
                    out["vfile"][i] = e[4]
                    unresolved[i] = False
                    break

        def probe_file(t: SSTable, rows: np.ndarray):
            may = t.bloom.may_contain(keys[rows])
            if not may.any():
                return
            rows = rows[may]
            self.read_block(t, "i", 0, cat, BlockCache.PRI_HIGH,
                            t.index_block_bytes())
            pos = t.find(keys[rows])
            hit = pos >= 0
            if hit.any():
                hrows, hpos = rows[hit], pos[hit]
                self._read_entry_blocks(t, hpos, t.etype[hpos], cat)
                out["found"][hrows] = True
                out["etype"][hrows] = t.etype[hpos]
                out["vid"][hrows] = t.vids[hpos]
                out["vsize"][hrows] = t.vsizes[hpos]
                out["vfile"][hrows] = t.vfiles[hpos]
                unresolved[hrows] = False

        for t in reversed(self.version.levels[0]):
            if not unresolved.any():
                break
            probe_file(t, np.nonzero(unresolved)[0])
        for lvl in range(1, self.cfg.max_levels):
            if not unresolved.any():
                break
            files = self.version.levels[lvl]
            if not files:
                continue
            rows = np.nonzero(unresolved)[0]
            fidx = self.version.assign_files(lvl, keys[rows])
            for fi in np.unique(fidx[fidx >= 0]):
                probe_file(files[fi], rows[fidx == fi])
        return out

    def _read_entry_blocks(self, t: SSTable, pos: np.ndarray,
                           ety: np.ndarray, cat: str) -> None:
        """Charge data-block reads for entries at ``pos`` in kSST/vSST ``t``.

        DTable routes REF entries to (high-priority) KF blocks and inline
        records to KV blocks — the paper's GC-Lookup optimisation."""
        if t.layout == "dtable":
            streams = np.where(ety == ETYPE_REF, 0, 1)
            for s, b in {(int(s), int(t.block_of[p]))
                         for s, p in zip(streams, pos)}:
                pri = BlockCache.PRI_HIGH if s == 0 else BlockCache.PRI_LOW
                self.read_block(t, f"d{s}", b, cat, pri,
                                t.data_block_bytes(s, b))
        else:
            for b in np.unique(t.block_of[pos]).tolist():
                self.read_block(t, "d0", b, cat, BlockCache.PRI_LOW,
                                t.data_block_bytes(0, b))

    def read_block(self, t: SSTable, stream: str, block_id: int, cat: str,
                   priority: int, nbytes: int | None = None) -> None:
        ck = (t.fid, stream, int(block_id))
        if self.cache.get(ck):
            self.io.cache_hit(cat)
            return
        if nbytes is None:
            s = int(stream[1])
            nbytes = t.data_block_bytes(s, block_id)
        self.io.rand_read(int(nbytes), cat)
        self.cache.put(ck, int(nbytes), priority)

    # ========================================================== value store
    def resolve_value_file(self, fid: int, key: int,
                           vid: int) -> SSTable | None:
        """Follow GC inheritance chains to the live file holding (key, vid)."""
        guard = 0
        while True:
            t = self.version.value_files.get(fid)
            if t is not None:
                return t
            g = self.chains.get(fid)
            if g is None:
                return None
            nt = g.locate(key, vid)
            if nt is None:
                return None
            fid = nt.fid
            guard += 1
            if guard > 10_000:
                raise RuntimeError("inheritance chain cycle")

    def read_value(self, key: int, vid: int, vfile: int, vsize: int,
                   cat: str):
        t = self.resolve_value_file(vfile, key, vid)
        assert t is not None, f"value file for key {key} lost"
        pos = int(t.find(np.array([key], np.uint64))[0])
        assert pos >= 0 and int(t.vids[pos]) == vid, "stale locator"
        rec = int(t.rec_bytes[pos])
        if t.layout == "rtable":
            self.read_block(t, "ib", int(t.index_block_of[pos]), cat,
                            BlockCache.PRI_HIGH, t.index_block_bytes())
            self.read_block(t, "rec", pos, cat, BlockCache.PRI_LOW, rec)
        else:
            self.read_block(t, "i", 0, cat, BlockCache.PRI_HIGH,
                            t.index_block_bytes())
            b = int(t.block_of[pos])
            self.read_block(t, "d0", b, cat, BlockCache.PRI_LOW,
                            max(rec, t.data_block_bytes(0, b)))
        return vid

    def _read_values_batch(self, keys, vids, vfiles, vsizes, cat) -> None:
        """Coalesced value fetches for scans."""
        by_file: dict[int, list[int]] = {}
        for k, vid, vf in zip(keys.tolist(), vids.tolist(), vfiles.tolist()):
            t = self.resolve_value_file(int(vf), int(k), int(vid))
            if t is None:
                continue
            pos = int(t.find(np.array([k], np.uint64))[0])
            if pos >= 0:
                by_file.setdefault(t.fid, []).append(pos)
        for fid, poss in by_file.items():
            t = self.version.value_files[fid]
            if t.layout == "rtable":
                for p in sorted(set(poss)):
                    self.read_block(t, "rec", p, cat, BlockCache.PRI_LOW,
                                    int(t.rec_bytes[p]))
            else:
                for b in np.unique(t.block_of[np.array(poss)]).tolist():
                    self.read_block(t, "d0", b, cat, BlockCache.PRI_LOW,
                                    t.data_block_bytes(0, b))

    def build_value_files(self, keys, vids, vsizes, cat: str):
        """Build vSST(s) from sorted records, hot/cold-split when enabled.

        Returns (files, fid_per_record)."""
        cfg = self.cfg
        n = len(keys)
        fid_per_rec = np.zeros(n, np.int64)
        files: list[SSTable] = []
        if n == 0:
            return files, fid_per_rec
        if cfg.hotcold_write:
            hot = self.dropcache.is_hot(keys)
            classes = [(hot, True), (~hot, False)]
        else:
            classes = [(np.ones(n, bool), False)]
        for mask, is_hot in classes:
            idx = np.nonzero(mask)[0]
            if len(idx) == 0:
                continue
            rec = cfg.value_rec_bytes(vsizes[idx]).astype(np.int64)
            cum = np.cumsum(rec) - rec
            fno = cum // cfg.vsst_bytes
            for f in np.unique(fno):
                m = idx[fno == f]
                t = build_vsst(cfg, keys[m], np.full(len(m), self.seq,
                                                     np.uint64),
                               vids[m], vsizes[m], is_hot=is_hot)
                self.version.add_value_file(t)
                self.io.seq_write(t.file_bytes, cat)
                fid_per_rec[m] = t.fid
                files.append(t)
        return files, fid_per_rec

    # ===================================================== garbage exposure
    def expose_garbage(self, keys, ety, vids, vsizes, vfiles) -> None:
        """Entries dropped during compaction expose value-store garbage
        (Hidden -> Exposed, paper §II-D)."""
        cfg = self.cfg
        refm = ety == ETYPE_REF
        if not refm.any():
            return
        keys, vids, vsizes, vfiles = (keys[refm], vids[refm], vsizes[refm],
                                      vfiles[refm])
        for k, vid, vsz, vf in zip(keys.tolist(), vids.tolist(),
                                   vsizes.tolist(), vfiles.tolist()):
            t = self.version.value_files.get(int(vf))
            if t is None:
                t = self.resolve_value_file(int(vf), int(k), int(vid))
                if t is None:
                    continue        # record already dropped by a GC
            pos = int(t.find(np.array([k], np.uint64))[0])
            if pos < 0 or int(t.vids[pos]) != vid:
                continue
            rec = int(t.rec_bytes[pos])
            t.garbage_bytes += rec
            if cfg.gc_scheme == "compaction":
                t.live_refs -= 1
                if t.live_refs <= 0:
                    self.version.retire_value_file(t.fid, None)
                    self.cache.erase_file(t.fid)

    # ============================================= BlobDB relocation (§II-C)
    def blobdb_relocate(self, kept):
        """During compaction, rewrite values whose blob files are old or
        garbage-heavy; blob files die only when fully exhausted."""
        cfg = self.cfg
        keys, seqs, ety, vids, vsz, vf = kept
        refs = np.nonzero(ety == ETYPE_REF)[0]
        if len(refs) == 0:
            return kept
        live = sorted(self.version.value_files)
        if not live:
            return kept
        cutoff_i = live[int(len(live) * cfg.blobdb_age_cutoff)] \
            if len(live) > 1 else live[0]
        reloc_rows = []
        for i in refs.tolist():
            t = self.version.value_files.get(int(vf[i]))
            if t is None:
                continue
            # RocksDB BlobDB default: relocation by age cutoff only
            # (garbage-ratio forcing is disabled) — blob files must exhaust
            # their data through compaction before being reclaimed (§II-C).
            if t.fid <= cutoff_i:
                reloc_rows.append(i)
        if not reloc_rows:
            return kept
        rows = np.array(reloc_rows, np.int64)
        # read old values
        for i in rows.tolist():
            t = self.version.value_files[int(vf[i])]
            self.io.rand_read(int(cfg.value_rec_bytes(int(vsz[i]))),
                              sio.CAT_GC_READ)
        new_files, nfids = self.build_value_files(keys[rows], vids[rows],
                                                  vsz[rows], sio.CAT_GC_WRITE)
        # retire refs from the old files
        for i, nf in zip(rows.tolist(), nfids.tolist()):
            t = self.version.value_files.get(int(vf[i]))
            if t is not None:
                pos = int(t.find(np.array([keys[i]], np.uint64))[0])
                if pos >= 0 and int(t.vids[pos]) == int(vids[i]):
                    t.garbage_bytes += int(t.rec_bytes[pos])
                    t.live_refs -= 1
                    if t.live_refs <= 0:
                        self.version.retire_value_file(t.fid, None)
                        self.cache.erase_file(t.fid)
            vf[i] = nf
        return (keys, seqs, ety, vids, vsz, vf)

    # ============================================================ writeback
    def writeback_index(self, key: int, vid: int, vsize: int,
                        vfile: int) -> None:
        """Titan Write-Index: new locator through the foreground write path.

        Each writeback is a Put() — WAL append + memtable insert competing
        with foreground writes for the WAL/commit path; charged at the
        unamortized per-op cost (this is why the paper measures ~38% of
        Titan's GC latency in this step)."""
        self.seq += 1
        rec = self.cfg.ref_rec_bytes()
        self.io.seq_write(rec, sio.CAT_GC_WRITE_INDEX)
        self.io.stall(self.io.device.seq_op_us, sio.CAT_GC_WRITE_INDEX)
        self.memtable.put_ref(key, self.seq, vid, vsize, vfile)
        if self.memtable.full:
            self.immutables.append(self.memtable)
            self.memtable = Memtable(self.cfg)

    # ================================================================ stats
    def space_bytes(self) -> int:
        return self.version.total_bytes()

    def space_amplification(self) -> float:
        return self.space_bytes() / max(self.valid_bytes, 1)

    def s_index(self) -> float:
        """Space amp of the index LSM-tree: total kSST / last-level kSST."""
        last = self.version.last_nonempty_level()
        lb = self.version.level_bytes(last)
        tot = self.version.ksst_total_bytes()
        return tot / max(lb, 1)

    def exposed_over_valid(self) -> float:
        ref_valid = max(self.valid_value_bytes(), 1)
        return self.version.value_garbage_bytes() / ref_valid

    def valid_value_bytes(self) -> int:
        """Bytes of live (non-garbage) data in the value store."""
        return sum(t.total_value_bytes - t.garbage_bytes
                   for t in self.version.value_files.values())

    def hidden_garbage_bytes(self) -> int:
        """Value bytes referenced by stale index entries whose records are
        still physically present (not yet exposed/reclaimed) — the paper's
        G_H.  Uses the stats oracle ``latest`` — measurement only, never an
        engine decision input."""
        hidden = 0
        seen: set = set()
        for t in self.version.all_kssts():
            refm = t.etype == ETYPE_REF
            if not refm.any():
                continue
            for k, vid, vsz, vf in zip(t.keys[refm].tolist(),
                                       t.vids[refm].tolist(),
                                       t.vsizes[refm].tolist(),
                                       t.vfiles[refm].tolist()):
                cur = self.latest.get(k)
                if cur is not None and cur[0] == vid:
                    continue                      # live, not garbage
                if (k, vid) in seen:
                    continue
                seen.add((k, vid))
                vt = self.resolve_value_file(int(vf), int(k), int(vid))
                if vt is None:
                    continue                      # already reclaimed by GC
                hidden += vsz
        return hidden

    def stats(self) -> dict:
        wal = self.io.write_bytes.get(sio.CAT_WAL, 0)
        return {
            "engine": self.cfg.engine,
            "clock_s": self.io.clock_us / 1e6,
            "space_bytes": self.space_bytes(),
            "valid_bytes": self.valid_bytes,
            "space_amp": self.space_amplification(),
            "s_index": self.s_index(),
            "exposed_over_valid": self.exposed_over_valid(),
            "write_amp": (self.io.total_write_bytes() - wal)
            / max(self.user_write_bytes, 1),
            "read_bytes": self.io.total_read_bytes(),
            "write_bytes": self.io.total_write_bytes(),
            "n_compactions": self.n_compactions,
            "n_gc_runs": self.n_gc_runs,
            "cache_hit_ratio": self.cache.hit_ratio(),
            "stall_s": self.stall_us / 1e6,
            "gc_time_s": self.io.gc_time_us() / 1e6,
        }
