"""KVStore facade: five selectable engines over one substrate.

``Store(EngineConfig(engine=...))`` gives RocksDB-, BlobDB-, Titan-,
TerarkDB- or Scavenger-semantics over the same deterministic simulated
device, so every paper comparison is apples-to-apples.

Scheduling model (see DESIGN.md §3): user operations advance the foreground
lane; flush/compaction/GC jobs run on a sequential background lane that
models 16 background threads saturating one SSD.  Background debt surfaces
as foreground write stalls through the standard RocksDB triggers (immutable
memtable cap, L0 slowdown/stop) — this is what reproduces the paper's
delayed-compaction -> hidden-garbage -> space-amplification chain.

All reads return the value's ``vid`` (the identity the store wrote into both
the index entry and the value record — the stand-in for real value bytes);
tests compare vids against an external oracle.
"""

from __future__ import annotations

import numpy as np

from . import compaction as comp
from . import gc as gcmod
from .batch import OP_PUT, ScalarOps, WriteBatch
from .engine import io as sio
from .engine.cache import BlockCache, DropCache
from .engine.config import EngineConfig
from .engine.io import SimIO
from .engine.memtable import Memtable
from .engine.tables import (ETYPE_INLINE, ETYPE_REF, ETYPE_TOMB, SSTable,
                            build_vsst)
from .engine.version import Version

MAX_IMMUTABLES = 2
DELAYED_WRITE_RATE = 16.0   # MB/s, RocksDB default under slowdown


class Store(ScalarOps):
    def __init__(self, cfg: EngineConfig, io: SimIO | None = None):
        self.cfg = cfg
        self.io = io or SimIO()
        self.cache = BlockCache(cfg.cache_bytes, cfg.cache_high_frac)
        self.dropcache = DropCache(cfg.dropcache_keys)
        self.version = Version(cfg.max_levels)
        self.memtable = Memtable(cfg)
        self.immutables: list[Memtable] = []
        self.chains: dict[int, gcmod.GCGroup] = {}
        self.seq = 0
        self.next_vid = 1
        self.in_gc = False
        self.in_batch_write = False
        self.compact_cursor: dict[int, int] = {}
        self._last_bg = "gc"
        # When this store is a shard of a ShardedStore, the fleet scheduler
        # owns background scheduling: pump() delegates to it so GC/compaction
        # service is ranked across the whole fleet, not per shard.
        self.scheduler = None

        # stats / bookkeeping
        self.latest: dict[int, tuple] = {}   # key -> (vid, vsize): oracle for
        self.valid_bytes = 0                 # space-amp denominators only
        self.user_write_bytes = 0
        self.n_user_ops = 0
        self.n_compactions = 0
        self.n_gc_runs = 0
        self.gc_reclaimed_bytes = 0
        self.stall_us = 0.0

    # ================================================================== API
    # The public API is batched and columnar (write / multi_get /
    # multi_scan); scalar put/get/delete/scan are the one-record ScalarOps
    # shims shared with ShardedStore.

    # ------------------------------------------------------- batched writes
    def write(self, batch: WriteBatch) -> np.ndarray:
        """Apply a WriteBatch atomically: one admission check, one
        sequence-number range, one group-committed WAL append, chunked
        vectorized memtable insertion.  Returns the vid per record (0 for
        deletes), in batch order."""
        kinds, keys, vsizes = batch.arrays()
        return self._write_arrays(kinds, keys, vsizes)

    def _write_arrays(self, kinds: np.ndarray, keys: np.ndarray,
                      vsizes: np.ndarray) -> np.ndarray:
        cfg = self.cfg
        n = len(keys)
        vids_out = np.zeros(n, np.uint64)
        if n == 0:
            return vids_out
        self._write_pressure()
        is_put = kinds == OP_PUT
        recs = np.where(is_put, cfg.key_bytes + vsizes + 12,
                        cfg.key_bytes + 12).astype(np.int64)
        total = int(recs.sum())
        seqs = np.uint64(self.seq + 1) + np.arange(n, dtype=np.uint64)
        self.seq += n
        nput = int(is_put.sum())
        vids_out[is_put] = (np.uint64(self.next_vid)
                            + np.arange(nput, dtype=np.uint64))
        self.next_vid += nput
        self.io.seq_write(total, sio.CAT_WAL)   # one group-committed append
        self.user_write_bytes += total
        self.n_user_ops += n

        ety = np.where(is_put, ETYPE_INLINE, ETYPE_TOMB).astype(np.uint8)
        vsz = np.where(is_put, vsizes, 0).astype(np.int64)
        vf = np.full(n, -1, np.int64)
        entry_bytes = self.memtable.entry_bytes_batch(ety, vsz)
        self.in_batch_write = True
        try:
            i = 0
            while i < n:
                i += self.memtable.put_batch(keys[i:], seqs[i:], ety[i:],
                                             vids_out[i:], vsz[i:], vf[i:],
                                             entry_bytes[i:])
                if self.memtable.full and i < n:
                    self.immutables.append(self.memtable)
                    self.memtable = Memtable(cfg)
                    self.pump()
                    self._stall_while(
                        lambda: len(self.immutables) > MAX_IMMUTABLES)
        finally:
            self.in_batch_write = False

        # stats oracle: the last record per key wins (batch order = seq
        # order); intermediate updates cancel out of valid_bytes exactly as
        # they would applied one by one
        last: dict[int, int] = {}
        for j, k in enumerate(keys.tolist()):
            last[k] = j
        for k, j in last.items():
            if is_put[j]:
                prev = self.latest.get(k)
                if prev is not None:
                    self.valid_bytes -= prev[1]
                self.latest[k] = (int(vids_out[j]), int(vsz[j]))
                self.valid_bytes += int(vsz[j])
            else:
                prev = self.latest.pop(k, None)
                if prev is not None:
                    self.valid_bytes -= prev[1]
        self._after_write(total)
        return vids_out

    # -------------------------------------------------------- batched reads
    def multi_get(self, keys: np.ndarray) -> dict:
        """Columnar point lookups for a whole key array.

        Pushes the batch through the vectorized ``lookup_entries`` path and
        coalesces vSST record fetches into adjacent runs (the lazy-read GC's
        run-coalescing, §III-B.1); the batch issues at NVMe queue depth
        ``min(len(keys), fg_qd_max)``, amortizing per-op latency floors.
        Returns parallel arrays: ``found`` bool, ``vid``/``vsize`` (0 where
        not found), ``etype``."""
        keys = np.atleast_1d(np.asarray(keys, np.uint64))
        n = len(keys)
        self.n_user_ops += n
        with self.io.batched(n):
            res = self.lookup_entries(keys, sio.CAT_FG_READ)
            live = res["found"] & (res["etype"] != ETYPE_TOMB)
            refs = np.nonzero(live & (res["etype"] == ETYPE_REF))[0]
            if len(refs):
                self._read_values_batch(keys[refs], res["vid"][refs],
                                        res["vfile"][refs],
                                        res["vsize"][refs], sio.CAT_FG_READ,
                                        strict=True)
        self.pump()
        return {"found": live,
                "vid": np.where(live, res["vid"], 0).astype(np.uint64),
                "vsize": np.where(live, res["vsize"], 0),
                "etype": res["etype"]}

    def multi_scan(self, starts: np.ndarray, count) -> list:
        """Batched range queries: one result list of (key, vid) pairs per
        start key, each up to ``count`` entries (scalar or per-start
        array).  Scans share one deep-queue I/O window, so block fetches
        amortize across the batch."""
        starts = np.atleast_1d(np.asarray(starts)).astype(np.int64)
        counts = np.broadcast_to(np.asarray(count, np.int64),
                                 starts.shape)
        self.n_user_ops += len(starts)
        out = []
        with self.io.batched(len(starts)):
            for s, c in zip(starts.tolist(), counts.tolist()):
                out.append(self._scan_retry(int(s), int(c)))
        self.pump()
        return out

    def _scan_retry(self, start_key: int, count: int):
        """Per-source fetch limits adapt upward: dead entries (tombstones,
        superseded versions) may eat slots, requiring a refill."""
        limit = count
        for _ in range(32):
            out, min_excluded = self._scan_once(start_key, count, limit)
            complete = min_excluded is None or (
                len(out) >= count and out[-1][0] < min_excluded)
            if complete:
                return out
            limit *= 4
        return out

    def _scan_once(self, start_key: int, count: int, limit: int):
        cfg = self.cfg
        excluded = []       # first key beyond each truncated source
        pools = []
        for mt in [self.memtable] + self.immutables:
            mk = sorted(k for k in mt.entries if k >= start_key)
            if len(mk) > limit:
                excluded.append(mk[limit])
            mk = mk[:limit]
            if not mk:
                continue
            rows = [mt.entries[k] for k in mk]
            pools.append((None,
                          np.array(mk, np.uint64),
                          np.array([r[0] for r in rows], np.uint64),
                          np.array([r[1] for r in rows], np.uint8),
                          np.array([r[2] for r in rows], np.uint64),
                          np.array([r[3] for r in rows], np.int64),
                          np.array([r[4] for r in rows], np.int64),
                          None))
        for lvl in range(cfg.max_levels):
            for t in self.version.levels[lvl]:
                a = int(np.searchsorted(t.keys, np.uint64(start_key)))
                b = min(a + limit, t.n)
                if a + limit < t.n:
                    excluded.append(int(t.keys[a + limit]))
                if a >= b:
                    continue
                pos = np.arange(a, b, dtype=np.int64)
                pools.append((t, t.keys[pos], t.seqs[pos], t.etype[pos],
                              t.vids[pos], t.vsizes[pos], t.vfiles[pos], pos))
        min_excluded = min(excluded) if excluded else None
        if not pools:
            return [], min_excluded
        keys = np.concatenate([p[1] for p in pools])
        seqs = np.concatenate([p[2] for p in pools])
        ety = np.concatenate([p[3] for p in pools])
        vids = np.concatenate([p[4] for p in pools])
        vsz = np.concatenate([p[5] for p in pools])
        vf = np.concatenate([p[6] for p in pools])
        src = np.concatenate([np.full(len(p[1]), i, np.int64)
                              for i, p in enumerate(pools)])
        pos_all = np.concatenate([
            p[7] if p[7] is not None else np.full(len(p[1]), -1, np.int64)
            for p in pools])
        order = np.lexsort((np.iinfo(np.uint64).max - seqs, keys))
        keys, ety, vids, vsz, vf, src, pos_all = (
            a[order] for a in (keys, ety, vids, vsz, vf, src, pos_all))
        first = np.ones(len(keys), bool)
        first[1:] = keys[1:] != keys[:-1]
        live = first & (ety != ETYPE_TOMB)
        take = np.nonzero(live)[0][:count]

        # ---- I/O: data blocks for chosen rows, value fetches for refs ----
        for i_pool in np.unique(src[take]):
            p = pools[i_pool]
            if p[0] is None:
                continue
            t = p[0]
            rows = take[src[take] == i_pool]
            self._read_entry_blocks(t, pos_all[rows], ety[rows],
                                    sio.CAT_SCAN)
        ref_rows = take[ety[take] == ETYPE_REF]
        if len(ref_rows):
            self._read_values_batch(keys[ref_rows], vids[ref_rows],
                                    vf[ref_rows], vsz[ref_rows],
                                    sio.CAT_SCAN)
        self.pump()
        return (list(zip(keys[take].tolist(), vids[take].tolist())),
                min_excluded)

    # ===================================================== background lanes
    def next_compact_job(self):
        """Work-finder for the flush/compaction pool (16 threads)."""
        if self.immutables:
            return ("flush",)
        pick = comp.pick_compaction(self)
        if pick is not None:
            return ("compact", pick)
        return None

    def next_gc_job(self):
        """Work-finder for the dedicated GC pool (1-2 threads — Titan/
        TerarkDB defaults; GC lags ingest, which is the source of the
        paper's space-amplification backlog)."""
        if self.cfg.gc_scheme not in ("inherit", "writeback"):
            return None
        if self.in_batch_write:
            # A WriteBatch applies atomically over one preassigned seq
            # range; GC (whose Titan writebacks mint fresh seqs) must not
            # interleave with it or a written-back locator could outrank a
            # not-yet-inserted batch record.  GC resumes at batch end.
            return None
        cands = gcmod.gc_candidates(self, self._gc_threshold())
        if cands:
            return ("gc", gcmod.gc_batch(self, cands))
        return None

    def run_job(self, job, lane: str) -> None:
        prev_lane = self.io.lane
        self.io.lane = lane
        try:
            if job[0] == "flush":
                self._flush_job()
            elif job[0] == "compact":
                comp.run_compaction(self, *job[1])
            else:
                gcmod.run_gc(self, job[1])
        finally:
            self.io.lane = prev_lane

    def pump(self) -> None:
        """Run background jobs that fit before the foreground clock."""
        if self.scheduler is not None:
            self.scheduler.pump()
            return
        while self.io.bg_clock_us < self.io.fg_clock_us:
            job = self.next_compact_job()
            if job is None:
                break
            self.run_job(job, "bg")
        while self.io.gc_clock_us < self.io.fg_clock_us:
            job = self.next_gc_job()
            if job is None:
                break
            self.run_job(job, "gc")

    def _stall_while(self, cond, prefer_gc: bool = False) -> None:
        """Foreground blocked on background progress."""
        t0 = self.io.fg_clock_us
        while cond():
            if prefer_gc:
                job, lane = self.next_gc_job(), "gc"
                if job is None:
                    job, lane = self.next_compact_job(), "bg"
            else:
                job, lane = self.next_compact_job(), "bg"
                if job is None:
                    job, lane = self.next_gc_job(), "gc"
            if job is None:
                break
            self.io.lanes[lane] = max(self.io.lanes[lane],
                                      self.io.fg_clock_us)
            self.run_job(job, lane)
            self.io.lanes["fg"] = max(self.io.fg_clock_us,
                                      self.io.lanes[lane])
        self.stall_us += self.io.fg_clock_us - t0

    def settle(self) -> None:
        """Let background catch up to the foreground clock (no fg time)."""
        self.pump()

    def drain(self) -> None:
        """Run ALL pending background work and synchronize lanes."""
        while True:
            job = self.next_compact_job()
            lane = "bg"
            if job is None:
                job, lane = self.next_gc_job(), "gc"
            if job is None:
                break
            self.run_job(job, lane)
        m = max(self.io.lanes.values())
        for k in self.io.lanes:
            self.io.lanes[k] = m

    # ------------------------------------------------------ write pressure
    def _after_write(self, rec_bytes: int) -> None:
        if self.memtable.full:
            self.immutables.append(self.memtable)
            self.memtable = Memtable(self.cfg)
        self.pump()
        self._stall_while(lambda: len(self.immutables) > MAX_IMMUTABLES)
        self._stall_while(
            lambda: len(self.version.levels[0]) >= self.cfg.l0_stop)
        if len(self.version.levels[0]) >= self.cfg.l0_slowdown:
            delay = rec_bytes / DELAYED_WRITE_RATE   # us at MB/s
            self.io.stall(delay)
            self.stall_us += delay
            self.pump()

    def _write_pressure(self) -> None:
        """Space-aware throttling (paper §III-D)."""
        cfg = self.cfg
        if cfg.space_quota_bytes is None:
            return
        space = self.version.total_bytes()
        soft = cfg.soft_quota_frac * cfg.space_quota_bytes
        if space < soft:
            return
        if space >= cfg.space_quota_bytes:
            seen = 0

            def over():
                nonlocal seen
                seen += 1
                return (seen < 256
                        and self.version.total_bytes()
                        >= cfg.space_quota_bytes)
            self._stall_while(over, prefer_gc=True)
        else:
            self.io.stall(cfg.slowdown_us_per_write)
            self.stall_us += cfg.slowdown_us_per_write
            self.pump()

    def _gc_threshold(self) -> float:
        cfg = self.cfg
        if cfg.space_quota_bytes is None:
            return cfg.gc_garbage_ratio
        space = self.version.total_bytes()
        if space >= cfg.soft_quota_frac * cfg.space_quota_bytes:
            return cfg.gc_aggressive_ratio
        return cfg.gc_garbage_ratio

    # ================================================================ flush
    def _flush_job(self) -> None:
        if not self.immutables:
            return
        mt = self.immutables.pop(0)
        cfg = self.cfg
        keys, seqs, ety, vids, vsz, vf = mt.sorted_arrays()
        if cfg.kv_separated:
            sep = (ety == ETYPE_INLINE) & (vsz >= cfg.sep_threshold)
            if sep.any():
                idx = np.nonzero(sep)[0]
                _, fids = self.build_value_files(keys[idx], vids[idx],
                                                 vsz[idx], sio.CAT_FLUSH)
                ety = ety.copy()
                vf = vf.copy()
                ety[idx] = ETYPE_REF
                vf[idx] = fids
        t = SSTable(cfg, "k", cfg.ksst_layout, keys, seqs, ety, vids, vsz, vf)
        t.compensated_extra = int(vsz[ety == ETYPE_REF].sum())
        self.io.seq_write(t.file_bytes, sio.CAT_FLUSH)
        self.version.add_l0(t)

    def flush(self) -> None:
        """Force-rotate the memtable and drain all background work."""
        if len(self.memtable):
            self.immutables.append(self.memtable)
            self.memtable = Memtable(self.cfg)
        self.drain()

    # ======================================================= lookup machinery
    def lookup_entries(self, keys: np.ndarray, cat: str) -> dict:
        """Vectorized newest-wins point lookup for a batch of keys.

        Walks memtables -> L0 (newest first) -> L1..Ln with bloom filters and
        block-cache I/O accounting.  Returns parallel arrays."""
        n = len(keys)
        out = {
            "found": np.zeros(n, bool),
            "etype": np.full(n, 255, np.uint8),
            "vid": np.zeros(n, np.uint64),
            "vsize": np.zeros(n, np.int64),
            "vfile": np.full(n, -1, np.int64),
        }
        unresolved = np.ones(n, bool)
        tables = [self.memtable] + list(reversed(self.immutables))
        for i, k in enumerate(keys.tolist()):
            for mt in tables:
                e = mt.get(k)
                if e is not None:
                    out["found"][i] = True
                    out["etype"][i] = e[1]
                    out["vid"][i] = e[2]
                    out["vsize"][i] = e[3]
                    out["vfile"][i] = e[4]
                    unresolved[i] = False
                    break

        def probe_file(t: SSTable, rows: np.ndarray):
            may = t.bloom.may_contain(keys[rows])
            if not may.any():
                return
            rows = rows[may]
            self.read_block(t, "i", 0, cat, BlockCache.PRI_HIGH,
                            t.index_block_bytes())
            pos = t.find(keys[rows])
            hit = pos >= 0
            if hit.any():
                hrows, hpos = rows[hit], pos[hit]
                self._read_entry_blocks(t, hpos, t.etype[hpos], cat)
                out["found"][hrows] = True
                out["etype"][hrows] = t.etype[hpos]
                out["vid"][hrows] = t.vids[hpos]
                out["vsize"][hrows] = t.vsizes[hpos]
                out["vfile"][hrows] = t.vfiles[hpos]
                unresolved[hrows] = False

        for t in reversed(self.version.levels[0]):
            if not unresolved.any():
                break
            probe_file(t, np.nonzero(unresolved)[0])
        for lvl in range(1, self.cfg.max_levels):
            if not unresolved.any():
                break
            files = self.version.levels[lvl]
            if not files:
                continue
            rows = np.nonzero(unresolved)[0]
            fidx = self.version.assign_files(lvl, keys[rows])
            for fi in np.unique(fidx[fidx >= 0]):
                probe_file(files[fi], rows[fidx == fi])
        return out

    def _read_entry_blocks(self, t: SSTable, pos: np.ndarray,
                           ety: np.ndarray, cat: str) -> None:
        """Charge data-block reads for entries at ``pos`` in kSST/vSST ``t``.

        DTable routes REF entries to (high-priority) KF blocks and inline
        records to KV blocks — the paper's GC-Lookup optimisation."""
        if t.layout == "dtable":
            streams = np.where(ety == ETYPE_REF, 0, 1)
            for s, b in {(int(s), int(t.block_of[p]))
                         for s, p in zip(streams, pos)}:
                pri = BlockCache.PRI_HIGH if s == 0 else BlockCache.PRI_LOW
                self.read_block(t, f"d{s}", b, cat, pri,
                                t.data_block_bytes(s, b))
        else:
            for b in np.unique(t.block_of[pos]).tolist():
                self.read_block(t, "d0", b, cat, BlockCache.PRI_LOW,
                                t.data_block_bytes(0, b))

    def read_block(self, t: SSTable, stream: str, block_id: int, cat: str,
                   priority: int, nbytes: int | None = None) -> None:
        ck = (t.fid, stream, int(block_id))
        if self.cache.get(ck):
            self.io.cache_hit(cat)
            return
        if nbytes is None:
            s = int(stream[1])
            nbytes = t.data_block_bytes(s, block_id)
        self.io.rand_read(int(nbytes), cat)
        self.cache.put(ck, int(nbytes), priority)

    # ========================================================== value store
    def resolve_value_file(self, fid: int, key: int,
                           vid: int) -> SSTable | None:
        """Follow GC inheritance chains to the live file holding (key, vid)."""
        guard = 0
        while True:
            t = self.version.value_files.get(fid)
            if t is not None:
                return t
            g = self.chains.get(fid)
            if g is None:
                return None
            nt = g.locate(key, vid)
            if nt is None:
                return None
            fid = nt.fid
            guard += 1
            if guard > 10_000:
                raise RuntimeError("inheritance chain cycle")

    def _read_values_batch(self, keys, vids, vfiles, vsizes, cat,
                           strict: bool = False) -> None:
        """Coalesced value fetches for multi_get / scans.

        Groups records by live vSST, reads each file's index blocks once,
        then fetches records as adjacent-position runs — one random I/O per
        run instead of one per record (the same run-coalescing the lazy-read
        GC applies, §III-B.1).  Cache bookkeeping stays per record so the
        one-record case charges exactly one read per block.

        ``strict`` (multi_get): every entry won a newest-wins lookup, so an
        unresolvable file or vid mismatch means GC dropped live data.  Scans
        stay lenient: a truncated ``_scan_once`` pass can surface a
        superseded REF whose record GC already reclaimed — ``_scan_retry``
        re-runs it with a larger limit."""
        by_file: dict[int, set[int]] = {}
        for k, vid, vf in zip(keys.tolist(), vids.tolist(), vfiles.tolist()):
            t = self.resolve_value_file(int(vf), int(k), int(vid))
            if strict:
                assert t is not None, f"value file for key {k} lost"
            elif t is None:
                continue
            pos = int(t.find(np.array([k], np.uint64))[0])
            if strict:
                assert pos >= 0 and int(t.vids[pos]) == vid, "stale locator"
            elif pos < 0:
                continue
            by_file.setdefault(t.fid, set()).add(pos)
        for fid, posset in by_file.items():
            t = self.version.value_files[fid]
            pos = np.array(sorted(posset), np.int64)
            if t.layout == "rtable":
                for b in np.unique(t.index_block_of[pos]).tolist():
                    self.read_block(t, "ib", b, cat, BlockCache.PRI_HIGH,
                                    t.index_block_bytes())
                runs = np.split(pos, np.nonzero(np.diff(pos) != 1)[0] + 1)
                for r in runs:
                    nbytes = 0
                    for p in r.tolist():
                        ck = (t.fid, "rec", p)
                        if self.cache.get(ck):
                            self.io.cache_hit(cat)
                        else:
                            rb = int(t.rec_bytes[p])
                            nbytes += rb
                            self.cache.put(ck, rb, BlockCache.PRI_LOW)
                    if nbytes:
                        self.io.rand_read(nbytes, cat)
            else:
                self.read_block(t, "i", 0, cat, BlockCache.PRI_HIGH,
                                t.index_block_bytes())
                blocks = t.block_of[pos]
                for b in np.unique(blocks).tolist():
                    m = pos[blocks == b]
                    nb = max(int(t.rec_bytes[m].max()),
                             t.data_block_bytes(0, b))
                    self.read_block(t, "d0", b, cat, BlockCache.PRI_LOW, nb)

    def build_value_files(self, keys, vids, vsizes, cat: str):
        """Build vSST(s) from sorted records, hot/cold-split when enabled.

        Returns (files, fid_per_record)."""
        cfg = self.cfg
        n = len(keys)
        fid_per_rec = np.zeros(n, np.int64)
        files: list[SSTable] = []
        if n == 0:
            return files, fid_per_rec
        if cfg.hotcold_write:
            hot = self.dropcache.is_hot(keys)
            classes = [(hot, True), (~hot, False)]
        else:
            classes = [(np.ones(n, bool), False)]
        for mask, is_hot in classes:
            idx = np.nonzero(mask)[0]
            if len(idx) == 0:
                continue
            rec = cfg.value_rec_bytes(vsizes[idx]).astype(np.int64)
            cum = np.cumsum(rec) - rec
            fno = cum // cfg.vsst_bytes
            for f in np.unique(fno):
                m = idx[fno == f]
                t = build_vsst(cfg, keys[m], np.full(len(m), self.seq,
                                                     np.uint64),
                               vids[m], vsizes[m], is_hot=is_hot)
                self.version.add_value_file(t)
                self.io.seq_write(t.file_bytes, cat)
                fid_per_rec[m] = t.fid
                files.append(t)
        return files, fid_per_rec

    # ===================================================== garbage exposure
    def expose_garbage(self, keys, ety, vids, vsizes, vfiles) -> None:
        """Entries dropped during compaction expose value-store garbage
        (Hidden -> Exposed, paper §II-D)."""
        cfg = self.cfg
        refm = ety == ETYPE_REF
        if not refm.any():
            return
        keys, vids, vsizes, vfiles = (keys[refm], vids[refm], vsizes[refm],
                                      vfiles[refm])
        for k, vid, vsz, vf in zip(keys.tolist(), vids.tolist(),
                                   vsizes.tolist(), vfiles.tolist()):
            t = self.version.value_files.get(int(vf))
            if t is None:
                t = self.resolve_value_file(int(vf), int(k), int(vid))
                if t is None:
                    continue        # record already dropped by a GC
            pos = int(t.find(np.array([k], np.uint64))[0])
            if pos < 0 or int(t.vids[pos]) != vid:
                continue
            rec = int(t.rec_bytes[pos])
            t.garbage_bytes += rec
            if cfg.gc_scheme == "compaction":
                t.live_refs -= 1
                if t.live_refs <= 0:
                    self.version.retire_value_file(t.fid, None)
                    self.cache.erase_file(t.fid)

    # ============================================= BlobDB relocation (§II-C)
    def blobdb_relocate(self, kept):
        """During compaction, rewrite values whose blob files are old or
        garbage-heavy; blob files die only when fully exhausted."""
        cfg = self.cfg
        keys, seqs, ety, vids, vsz, vf = kept
        refs = np.nonzero(ety == ETYPE_REF)[0]
        if len(refs) == 0:
            return kept
        live = sorted(self.version.value_files)
        if not live:
            return kept
        cutoff_i = live[int(len(live) * cfg.blobdb_age_cutoff)] \
            if len(live) > 1 else live[0]
        reloc_rows = []
        for i in refs.tolist():
            t = self.version.value_files.get(int(vf[i]))
            if t is None:
                continue
            # RocksDB BlobDB default: relocation by age cutoff only
            # (garbage-ratio forcing is disabled) — blob files must exhaust
            # their data through compaction before being reclaimed (§II-C).
            if t.fid <= cutoff_i:
                reloc_rows.append(i)
        if not reloc_rows:
            return kept
        rows = np.array(reloc_rows, np.int64)
        # read old values
        for i in rows.tolist():
            t = self.version.value_files[int(vf[i])]
            self.io.rand_read(int(cfg.value_rec_bytes(int(vsz[i]))),
                              sio.CAT_GC_READ)
        new_files, nfids = self.build_value_files(keys[rows], vids[rows],
                                                  vsz[rows], sio.CAT_GC_WRITE)
        # retire refs from the old files
        for i, nf in zip(rows.tolist(), nfids.tolist()):
            t = self.version.value_files.get(int(vf[i]))
            if t is not None:
                pos = int(t.find(np.array([keys[i]], np.uint64))[0])
                if pos >= 0 and int(t.vids[pos]) == int(vids[i]):
                    t.garbage_bytes += int(t.rec_bytes[pos])
                    t.live_refs -= 1
                    if t.live_refs <= 0:
                        self.version.retire_value_file(t.fid, None)
                        self.cache.erase_file(t.fid)
            vf[i] = nf
        return (keys, seqs, ety, vids, vsz, vf)

    # ============================================================ writeback
    def writeback_index(self, key: int, vid: int, vsize: int,
                        vfile: int) -> None:
        """Titan Write-Index for one locator (shim over the batched path)."""
        self.writeback_index_batch(np.array([key], np.uint64),
                                   np.array([vid], np.uint64),
                                   np.array([vsize], np.int64),
                                   np.array([vfile], np.int64))

    def writeback_index_batch(self, keys, vids, vsizes, vfiles) -> None:
        """Titan Write-Index: new locators through the foreground write
        path, group-committed as one WriteBatch (Titan batches its GC index
        rewrites internally).

        The WAL append is batched, but each writeback still pays the
        per-record commit-queue cost competing with foreground writes —
        this unamortized step is why the paper measures ~38% of Titan's GC
        latency in Write-Index."""
        n = len(keys)
        if n == 0:
            return
        rec = self.cfg.ref_rec_bytes()
        seqs = np.uint64(self.seq + 1) + np.arange(n, dtype=np.uint64)
        self.seq += n
        self.io.seq_write(n * rec, sio.CAT_GC_WRITE_INDEX)
        self.io.stall(n * self.io.device.seq_op_us, sio.CAT_GC_WRITE_INDEX)
        keys = np.asarray(keys, np.uint64)
        ety = np.full(n, ETYPE_REF, np.uint8)
        vids = np.asarray(vids, np.uint64)
        vsz = np.asarray(vsizes, np.int64)
        vf = np.asarray(vfiles, np.int64)
        i = 0
        while i < n:
            i += self.memtable.put_batch(keys[i:], seqs[i:], ety[i:],
                                         vids[i:], vsz[i:], vf[i:])
            if self.memtable.full:
                self.immutables.append(self.memtable)
                self.memtable = Memtable(self.cfg)

    # ================================================================ stats
    def space_bytes(self) -> int:
        return self.version.total_bytes()

    def space_amplification(self) -> float:
        return self.space_bytes() / max(self.valid_bytes, 1)

    def s_index(self) -> float:
        """Space amp of the index LSM-tree: total kSST / last-level kSST."""
        last = self.version.last_nonempty_level()
        lb = self.version.level_bytes(last)
        tot = self.version.ksst_total_bytes()
        return tot / max(lb, 1)

    def exposed_over_valid(self) -> float:
        ref_valid = max(self.valid_value_bytes(), 1)
        return self.version.value_garbage_bytes() / ref_valid

    def valid_value_bytes(self) -> int:
        """Bytes of live (non-garbage) data in the value store."""
        return sum(t.total_value_bytes - t.garbage_bytes
                   for t in self.version.value_files.values())

    def hidden_garbage_bytes(self) -> int:
        """Value bytes referenced by stale index entries whose records are
        still physically present (not yet exposed/reclaimed) — the paper's
        G_H.  Uses the stats oracle ``latest`` — measurement only, never an
        engine decision input."""
        hidden = 0
        seen: set = set()
        for t in self.version.all_kssts():
            refm = t.etype == ETYPE_REF
            if not refm.any():
                continue
            for k, vid, vsz, vf in zip(t.keys[refm].tolist(),
                                       t.vids[refm].tolist(),
                                       t.vsizes[refm].tolist(),
                                       t.vfiles[refm].tolist()):
                cur = self.latest.get(k)
                if cur is not None and cur[0] == vid:
                    continue                      # live, not garbage
                if (k, vid) in seen:
                    continue
                seen.add((k, vid))
                vt = self.resolve_value_file(int(vf), int(k), int(vid))
                if vt is None:
                    continue                      # already reclaimed by GC
                hidden += vsz
        return hidden

    def stats(self) -> dict:
        wal = self.io.write_bytes.get(sio.CAT_WAL, 0)
        return {
            "engine": self.cfg.engine,
            "clock_s": self.io.clock_us / 1e6,
            "space_bytes": self.space_bytes(),
            "valid_bytes": self.valid_bytes,
            "user_write_bytes": self.user_write_bytes,
            "space_amp": self.space_amplification(),
            "s_index": self.s_index(),
            "exposed_over_valid": self.exposed_over_valid(),
            "write_amp": (self.io.total_write_bytes() - wal)
            / max(self.user_write_bytes, 1),
            "read_bytes": self.io.total_read_bytes(),
            "write_bytes": self.io.total_write_bytes(),
            "n_compactions": self.n_compactions,
            "n_gc_runs": self.n_gc_runs,
            "cache_hit_ratio": self.cache.hit_ratio(),
            "stall_s": self.stall_us / 1e6,
            "gc_time_s": self.io.gc_time_us() / 1e6,
        }
