"""KVStore facade: seven selectable engines over one layered substrate.

``Store(EngineConfig(engine=...))`` gives RocksDB-, BlobDB-, Titan-,
TerarkDB-, Scavenger-, hybrid- or adaptive-Scavenger-semantics over the
same deterministic simulated device, so every paper comparison is
apples-to-apples.

The facade owns scheduling and the write path; everything else is layered
(DESIGN.md §7):

  * ``read/``    — vectorized point lookups + scan merge planning
  * ``values/``  — vSST build, coalesced fetch planning, inheritance-chain
                   resolution, garbage exposure
  * ``engines/`` — one pluggable strategy object per engine (flush
                   separation, GC scheme, relocation/writeback hooks,
                   compaction scoring), resolved from a registry

Scheduling model (see DESIGN.md §3): user operations advance the foreground
lane; flush/compaction/GC jobs run on a sequential background lane that
models 16 background threads saturating one SSD.  Background debt surfaces
as foreground write stalls through the standard RocksDB triggers (immutable
memtable cap, L0 slowdown/stop) — this is what reproduces the paper's
delayed-compaction -> hidden-garbage -> space-amplification chain.

All reads return the value's ``vid`` (the identity the store wrote into both
the index entry and the value record — the stand-in for real value bytes);
tests compare vids against an external oracle.
"""

from __future__ import annotations

import numpy as np

from ..obs import NULL_OBSERVER
from . import compaction as comp
from . import gc as gcmod
from .batch import OP_PUT, ScalarOps, WriteBatch
from .engine import io as sio
from .engine.cache import BlockCache, DropCache
from .engine.config import EngineConfig
from .engine.io import SimIO
from .engine.memtable import Memtable
from .engine.tables import ETYPE_INLINE, ETYPE_REF, ETYPE_TOMB, SSTable
from .engine.version import Version
from .engines import make_strategy
from .oracle import LatestOracle
from .read import lookup as rlookup
from .read import scan as rscan
from .values import build as vbuild
from .values import fetch as vfetch
from .values import garbage as vgarbage
from .values import resolve as vresolve

__all__ = ["Store"]


class Store(ScalarOps):
    def __init__(self, cfg: EngineConfig, io: SimIO | None = None,
                 durability_dir=None):
        self.cfg = cfg
        self.strategy = make_strategy(cfg)
        self.io = io or SimIO()
        self.cache = BlockCache(cfg.cache_bytes, cfg.cache_high_frac)
        self.dropcache = DropCache(cfg.dropcache_keys)
        self.version = Version(cfg.max_levels)
        self.memtable = Memtable(cfg)
        self.immutables: list[Memtable] = []
        self.chains: dict[int, vresolve.GCGroup] = {}
        self.seq = 0
        self.next_vid = 1
        self.in_gc = False
        self.in_batch_write = False
        self.compact_cursor: dict[int, int] = {}
        self._last_bg = "gc"
        # When this store is a shard of a ShardedStore, the fleet scheduler
        # owns background scheduling: pump() delegates to it so GC/compaction
        # service is ranked across the whole fleet, not per shard.
        self.scheduler = None
        # Durability (DESIGN.md §9): off by default — None costs one
        # attribute check per event and zero simulated device time.
        self.durability = None
        self.wal_index = 0              # monotone journal-record watermark
        self._crash_hooks: dict | None = None
        if durability_dir is not None:
            from .durability import Durability
            self.durability = Durability.create(durability_dir, cfg)

        # stats / bookkeeping
        self.latest = LatestOracle()         # measurement-only oracle for
        #                                      space-amp denominators
        self.user_write_bytes = 0
        self.n_user_ops = 0
        self.n_compactions = 0
        self.n_gc_runs = 0
        self.gc_reclaimed_bytes = 0
        self.stall_us = 0.0

        # Observability (repro.obs, DESIGN.md §11): one hook object for
        # spans/metrics/health.  The default NullObserver no-ops every hook
        # and never touches the simulated device, so observer-off runs stay
        # byte-identical to the goldens.
        self.obs = cfg.observer if cfg.observer is not None else NULL_OBSERVER
        self.obs_label = self.obs.register_store(self)

    @property
    def valid_bytes(self) -> int:
        return self.latest.valid_bytes

    # ================================================================== API
    # The public API is batched and columnar (write / multi_get /
    # multi_scan); scalar put/get/delete/scan are the one-record ScalarOps
    # shims shared with ShardedStore.

    # ------------------------------------------------------- batched writes
    def write(self, batch: WriteBatch) -> np.ndarray:
        """Apply a WriteBatch atomically: one admission check, one
        sequence-number range, one group-committed WAL append, chunked
        vectorized memtable insertion.  Returns the vid per record (0 for
        deletes), in batch order."""
        kinds, keys, vsizes = batch.arrays()
        return self._write_arrays(kinds, keys, vsizes)

    def _write_arrays(self, kinds: np.ndarray, keys: np.ndarray,
                      vsizes: np.ndarray) -> np.ndarray:
        cfg = self.cfg
        n = len(keys)
        vids_out = np.zeros(n, np.uint64)
        if n == 0:
            return vids_out
        # the span covers every foreground advance this batch causes —
        # admission stalls, the WAL append, memtable stalls, delayed-write
        # throttling — so fg-track spans tile the fg lane clock (§11)
        with self.obs.span(self, "write", n=n):
            self._write_pressure()
            is_put = kinds == OP_PUT
            recs = np.where(is_put,
                            cfg.key_bytes + vsizes + cfg.wal_rec_overhead,
                            cfg.key_bytes
                            + cfg.wal_rec_overhead).astype(np.int64)
            total = int(recs.sum())
            seqs = np.uint64(self.seq + 1) + np.arange(n, dtype=np.uint64)
            self.seq += n
            nput = int(is_put.sum())
            vids_out[is_put] = (np.uint64(self.next_vid)
                                + np.arange(nput, dtype=np.uint64))
            self.next_vid += nput
            self.io.seq_write(total, sio.CAT_WAL)  # one group-committed
            #                                        append
            self.obs.instant(self, "wal_append", nbytes=total, n=n)
            if self.durability is not None:
                # host-side persistence of the same batch the simulated WAL
                # append just charged; costs zero simulated time (§9)
                self.wal_index += 1
                self.durability.log_batch(self.wal_index, self.seq - n + 1,
                                          kinds, keys, vsizes)
            if self._crash_hooks is not None:
                self._crashpoint("after_wal")
            self.user_write_bytes += total
            self.n_user_ops += n

            ety = np.where(is_put, ETYPE_INLINE, ETYPE_TOMB).astype(np.uint8)
            vsz = np.where(is_put, vsizes, 0).astype(np.int64)
            vf = np.full(n, -1, np.int64)
            entry_bytes = self.memtable.entry_bytes_batch(ety, vsz)
            self.in_batch_write = True
            try:
                i = 0
                while i < n:
                    i += self.memtable.put_batch(keys[i:], seqs[i:], ety[i:],
                                                 vids_out[i:], vsz[i:],
                                                 vf[i:], entry_bytes[i:])
                    if self.memtable.full and i < n:
                        self.immutables.append(self.memtable)
                        self.memtable = Memtable(cfg)
                        self.pump()
                        self._stall_while(
                            lambda: len(self.immutables) > cfg.max_immutables,
                            trigger="memtable_stall")
            finally:
                self.in_batch_write = False

            self.latest.apply_batch(is_put, keys, vids_out, vsz)
            # workload observation (adaptive tracker; no-op for paper
            # engines, costs no simulated time)
            self.strategy.observe_batch(self, "write", keys, vsz)
            self._after_write(total)
        self.obs.on_op(self, "put_batch_n", n)
        self.obs.on_op(self, "put_batch_bytes", total)
        self.obs.tick(self)
        return vids_out

    def ingest_batch(self, kinds: np.ndarray, keys: np.ndarray,
                     vids: np.ndarray, vsizes: np.ndarray) -> None:
        """Apply records that already own their value identity: shard
        migration copy-in, migration delta replay, and replica-log replay
        (DESIGN.md §14).

        Same simulated device costs and memtable path as ``write`` — one
        group-committed WAL append, chunked insertion, write-pressure
        stalls — and fresh sequence numbers, but the given ``vids`` are
        preserved (the fleet promises reads return the vid the original
        ``write`` minted, wherever the key now lives) and nothing is
        counted as a *user* write (``user_write_bytes`` feeds write-amp
        denominators; migrated bytes are amplification, not ingest)."""
        cfg = self.cfg
        kinds = np.asarray(kinds, np.uint8)
        keys = np.asarray(keys, np.uint64)
        vids = np.asarray(vids, np.uint64)
        vsizes = np.asarray(vsizes, np.int64)
        n = len(keys)
        if n == 0:
            return
        if self.durability is not None:
            self.wal_index += 1
            self.durability.log_ingest(self.wal_index, kinds, keys, vids,
                                       vsizes)
        with self.obs.span(self, "ingest", n=n):
            is_put = kinds == OP_PUT
            recs = np.where(is_put,
                            cfg.key_bytes + vsizes + cfg.wal_rec_overhead,
                            cfg.key_bytes
                            + cfg.wal_rec_overhead).astype(np.int64)
            total = int(recs.sum())
            seqs = np.uint64(self.seq + 1) + np.arange(n, dtype=np.uint64)
            self.seq += n
            if is_put.any():
                # keep future mints ahead of every preserved vid so an
                # ingested record and a later local write never collide on
                # the same (key, vid)
                self.next_vid = max(self.next_vid,
                                    int(vids[is_put].max()) + 1)
            self.io.seq_write(total, sio.CAT_WAL)
            self.obs.instant(self, "ingest_append", nbytes=total, n=n)
            ety = np.where(is_put, ETYPE_INLINE, ETYPE_TOMB).astype(np.uint8)
            vsz = np.where(is_put, vsizes, 0).astype(np.int64)
            use_vids = np.where(is_put, vids, 0).astype(np.uint64)
            vf = np.full(n, -1, np.int64)
            entry_bytes = self.memtable.entry_bytes_batch(ety, vsz)
            self.in_batch_write = True
            try:
                i = 0
                while i < n:
                    i += self.memtable.put_batch(keys[i:], seqs[i:], ety[i:],
                                                 use_vids[i:], vsz[i:],
                                                 vf[i:], entry_bytes[i:])
                    if self.memtable.full and i < n:
                        self.immutables.append(self.memtable)
                        self.memtable = Memtable(cfg)
                        self.pump()
                        self._stall_while(
                            lambda: len(self.immutables) > cfg.max_immutables,
                            trigger="memtable_stall")
            finally:
                self.in_batch_write = False
            self.latest.apply_batch(is_put, keys, use_vids, vsz)
            self.strategy.observe_batch(self, "write", keys, vsz)
            self._after_write(total)
        self.obs.on_op(self, "ingest_batch_n", n)
        self.obs.on_op(self, "ingest_batch_bytes", total)
        self.obs.tick(self)

    # -------------------------------------------------------- batched reads
    def multi_get(self, keys: np.ndarray) -> dict:
        """Columnar point lookups for a whole key array.

        Pushes the batch through the vectorized ``lookup_entries`` path and
        coalesces vSST record fetches into adjacent runs (the lazy-read GC's
        run-coalescing, §III-B.1); the batch issues at NVMe queue depth
        ``min(len(keys), fg_qd_max)``, amortizing per-op latency floors.
        Returns parallel arrays: ``found`` bool, ``vid``/``vsize`` (0 where
        not found), ``etype``."""
        keys = np.atleast_1d(np.asarray(keys, np.uint64))
        n = len(keys)
        if self.durability is not None:
            # reads are journaled too: under the two-lane clock they move
            # background scheduling, so byte-identical recovery must replay
            # them (DESIGN.md §9)
            self.wal_index += 1
            self.durability.log_reads(self.wal_index, keys)
        self.n_user_ops += n
        with self.obs.span(self, "multi_get", n=n), self.io.batched(n):
            res = self.lookup_entries(keys, sio.CAT_FG_READ)
            live = res["found"] & (res["etype"] != ETYPE_TOMB)
            refs = np.nonzero(live & (res["etype"] == ETYPE_REF))[0]
            if len(refs):
                self._read_values_batch(keys[refs], res["vid"][refs],
                                        res["vfile"][refs],
                                        res["vsize"][refs], sio.CAT_FG_READ,
                                        strict=True)
        self.strategy.observe_batch(self, "read", keys)
        self.pump()
        self.obs.on_op(self, "get_batch_n", n)
        self.obs.tick(self)
        return {"found": live,
                "vid": np.where(live, res["vid"], 0).astype(np.uint64),
                "vsize": np.where(live, res["vsize"], 0),
                "etype": res["etype"]}

    def multi_scan(self, starts: np.ndarray, count) -> list:
        """Batched range queries: one result list of (key, vid) pairs per
        start key, each up to ``count`` entries (scalar or per-start
        array).  Scans share one deep-queue I/O window, so block fetches
        amortize across the batch."""
        starts = np.atleast_1d(np.asarray(starts)).astype(np.int64)
        counts = np.broadcast_to(np.asarray(count, np.int64),
                                 starts.shape)
        if self.durability is not None:
            self.wal_index += 1
            self.durability.log_scans(self.wal_index, starts, counts)
        self.n_user_ops += len(starts)
        out = []
        with self.obs.span(self, "multi_scan", n=len(starts)), \
                self.io.batched(len(starts)):
            for s, c in zip(starts.tolist(), counts.tolist()):
                out.append(rscan.scan_retry(self, int(s), int(c)))
        self.pump()
        self.obs.tick(self)
        return out

    # ===================================================== background lanes
    def next_compact_job(self):
        """Work-finder for the flush/compaction pool (16 threads)."""
        if self.immutables:
            return ("flush",)
        pick = comp.pick_compaction(self)
        if pick is not None:
            return ("compact", pick)
        return None

    def next_gc_job(self):
        """Work-finder for the dedicated GC pool (1-2 threads — Titan/
        TerarkDB defaults; GC lags ingest, which is the source of the
        paper's space-amplification backlog)."""
        if not self.strategy.wants_standalone_gc():
            return None
        if self.in_batch_write:
            # A WriteBatch applies atomically over one preassigned seq
            # range; GC (whose Titan writebacks mint fresh seqs) must not
            # interleave with it or a written-back locator could outrank a
            # not-yet-inserted batch record.  GC resumes at batch end.
            return None
        cands = gcmod.gc_candidates(self, self._gc_threshold())
        if cands:
            return ("gc", gcmod.gc_batch(self, cands))
        return None

    def _job_pick(self, kind: str) -> str:
        """Policy decision that selects work of this kind (ledger §13)."""
        if kind == "flush":
            return "memtable_rotation"
        if kind == "compact":
            return ("compensated_size" if self.cfg.compensated_compaction
                    else "physical_size")
        return ("adaptive_dead_byte" if self.cfg.adaptive_enabled
                else "garbage_ratio")

    def run_job(self, job, lane: str, trigger: str = "lane_budget",
                policy: str | None = None) -> None:
        prev_lane = self.io.lane
        self.io.lane = lane
        cause = {"trigger": trigger, "pick": self._job_pick(job[0])}
        if policy is not None:
            cause["policy"] = policy
        try:
            # span on the job's lane: an injected CrashPoint still records
            # the partial span (the with-block exits), keeping lane tiling
            with self.obs.span(self, job[0], lane=lane, cause=cause):
                if job[0] == "flush":
                    self._flush_job()
                elif job[0] == "compact":
                    comp.run_compaction(self, *job[1])
                else:
                    gcmod.run_gc(self, job[1])
        finally:
            self.io.lane = prev_lane

    def pump(self) -> None:
        """Run background jobs that fit before the foreground clock."""
        if self.scheduler is not None:
            self.scheduler.pump()
            return
        while self.io.bg_clock_us < self.io.fg_clock_us:
            job = self.next_compact_job()
            if job is None:
                break
            self.run_job(job, "bg", trigger="lane_budget")
        while self.io.gc_clock_us < self.io.fg_clock_us:
            job = self.next_gc_job()
            if job is None:
                break
            self.run_job(job, "gc", trigger="lane_budget")

    def _stall_while(self, cond, prefer_gc: bool = False,
                     trigger: str = "write_stall") -> None:
        """Foreground blocked on background progress."""
        t0 = self.io.fg_clock_us
        while cond():
            if prefer_gc:
                job, lane = self.next_gc_job(), "gc"
                if job is None:
                    job, lane = self.next_compact_job(), "bg"
            else:
                job, lane = self.next_compact_job(), "bg"
                if job is None:
                    job, lane = self.next_gc_job(), "gc"
            if job is None:
                break
            t_lane = self.io.lanes[lane]
            self.io.lanes[lane] = max(t_lane, self.io.fg_clock_us)
            # the bg/gc jump is outside any job span — record it so the
            # lane track still tiles; the fg jump below is inside the
            # caller's write span, which already covers it (§11)
            self.obs.lane_sync(self, lane, t_lane)
            self.run_job(job, lane, trigger=trigger)
            self.io.lanes["fg"] = max(self.io.fg_clock_us,
                                      self.io.lanes[lane])
        stalled = self.io.fg_clock_us - t0
        self.stall_us += stalled
        self.obs.on_stall(self, stalled, "write_stall")

    def settle(self) -> None:
        """Let background catch up to the foreground clock (no fg time)."""
        self.pump()

    def drain(self) -> None:
        """Run ALL pending background work and synchronize lanes."""
        while True:
            job = self.next_compact_job()
            lane = "bg"
            if job is None:
                job, lane = self.next_gc_job(), "gc"
            if job is None:
                break
            self.run_job(job, lane, trigger="drain")
        m = max(self.io.lanes.values())
        for k in self.io.lanes:
            t0 = self.io.lanes[k]
            self.io.lanes[k] = m
            self.obs.lane_sync(self, k, t0)

    # ========================================= durability (DESIGN.md §9)
    def checkpoint(self, path=None):
        """Write a full-state snapshot.

        With a durable store (``durability_dir``) and no ``path``: snapshot
        into the store directory, roll the WAL, and record the checkpoint
        in the MANIFEST.  With ``path``: write a standalone snapshot file
        (restorable via ``Store.open(path)``), usable without a durable
        directory."""
        if path is not None:
            from .durability import snapshot as dsnap
            self.obs.instant(self, "checkpoint", path=str(path))
            return dsnap.write_snapshot(self, path)
        if self.durability is None:
            raise ValueError("store has no durability directory; pass a "
                             "snapshot path or open with durability_dir")
        self.obs.instant(self, "checkpoint", seq=int(self.seq))
        return self.durability.checkpoint(self)

    @classmethod
    def open(cls, path, io: SimIO | None = None,
             observer=None) -> "Store":
        """Recover a store: restore the latest checkpoint snapshot, then
        replay the WAL tail through the columnar write path (``path`` may
        also be a bare snapshot file — restore only).  ``observer``
        attaches an Observer before replay so the recovery emits a replay
        timeline (DESIGN.md §11)."""
        from .durability import recover_store
        return recover_store(path, io=io, cls=cls, observer=observer)

    def close(self) -> None:
        """Flush and close durable logs (no-op for in-memory stores)."""
        if self.durability is not None:
            self.durability.close()

    def _log_edit(self, kind: str, **data) -> None:
        """Append a MANIFEST VersionEdit (no-op when durability is off).

        The host-side byte cost of the edit is reported to the observer
        (ledger §13: MANIFEST bytes decompose by cause like device bytes)."""
        if self.durability is not None:
            before = self.durability.manifest.bytes_written
            self.durability.log_edit(kind, **data)
            self.obs.on_edit(self, kind,
                             self.durability.manifest.bytes_written - before)

    def arm_crash(self, point: str, hits: int = 1) -> None:
        """Crash-injection: raise ``CrashPoint`` at the ``hits``-th pass
        through the named hook (see ``durability.CRASH_POINTS``)."""
        from .durability import CRASH_POINTS
        if point not in CRASH_POINTS:
            raise ValueError(f"unknown crash point {point!r} "
                             f"(want one of {CRASH_POINTS})")
        if self._crash_hooks is None:
            self._crash_hooks = {}
        self._crash_hooks[point] = int(hits)

    def _crashpoint(self, point: str) -> None:
        hooks = self._crash_hooks
        if hooks is None:
            return
        left = hooks.get(point)
        if left is None:
            return
        if left <= 1:
            del hooks[point]            # disarm: the process died here once
            from .durability import CrashPoint
            raise CrashPoint(point)
        hooks[point] = left - 1

    # ------------------------------------------------------ write pressure
    def _after_write(self, rec_bytes: int) -> None:
        cfg = self.cfg
        if self.memtable.full:
            self.immutables.append(self.memtable)
            self.memtable = Memtable(cfg)
        self.pump()
        self._stall_while(lambda: len(self.immutables) > cfg.max_immutables,
                          trigger="memtable_stall")
        self._stall_while(
            lambda: len(self.version.levels[0]) >= cfg.l0_stop,
            trigger="l0_stop")
        if len(self.version.levels[0]) >= cfg.l0_slowdown:
            delay = rec_bytes / cfg.delayed_write_rate   # us at MB/s
            self.io.stall(delay)
            self.stall_us += delay
            self.obs.on_stall(self, delay, "delayed_write")
            self.pump()

    def _write_pressure(self) -> None:
        """Space-aware throttling (paper §III-D)."""
        cfg = self.cfg
        if cfg.space_quota_bytes is None:
            return
        space = self.version.total_bytes()
        soft = cfg.soft_quota_frac * cfg.space_quota_bytes
        if space < soft:
            return
        if space >= cfg.space_quota_bytes:
            seen = 0

            def over():
                nonlocal seen
                seen += 1
                return (seen < cfg.quota_stall_rounds
                        and self.version.total_bytes()
                        >= cfg.space_quota_bytes)
            self._stall_while(over, prefer_gc=True, trigger="quota_stall")
        else:
            self.io.stall(cfg.slowdown_us_per_write)
            self.stall_us += cfg.slowdown_us_per_write
            self.obs.on_stall(self, cfg.slowdown_us_per_write,
                              "quota_slowdown")
            self.pump()

    def _gc_threshold(self) -> float:
        cfg = self.cfg
        if cfg.space_quota_bytes is None:
            return cfg.gc_garbage_ratio
        space = self.version.total_bytes()
        if space >= cfg.soft_quota_frac * cfg.space_quota_bytes:
            return cfg.gc_aggressive_ratio
        return cfg.gc_garbage_ratio

    # ================================================================ flush
    def _flush_job(self) -> None:
        if not self.immutables:
            return
        mt = self.immutables.pop(0)
        cfg = self.cfg
        keys, seqs, ety, vids, vsz, vf = mt.sorted_arrays()
        sep = self.strategy.separation_mask(self, keys, ety, vsz)
        if sep is not None and sep.any():
            idx = np.nonzero(sep)[0]
            _, fids = self.build_value_files(keys[idx], vids[idx],
                                             vsz[idx], sio.CAT_FLUSH)
            ety = ety.copy()
            vf = vf.copy()
            ety[idx] = ETYPE_REF
            vf[idx] = fids
        t = SSTable(cfg, "k", cfg.ksst_layout, keys, seqs, ety, vids, vsz, vf)
        t.compensated_extra = int(vsz[ety == ETYPE_REF].sum())
        self.io.seq_write(t.file_bytes, sio.CAT_FLUSH)
        self._crashpoint("mid_flush")   # vSSTs cut, kSST not yet live
        self.version.add_l0(t)
        self._log_edit("add_file", fid=t.fid, level=0, nbytes=t.file_bytes)

    def rotate_memtable(self) -> None:
        """Force the active memtable immutable (no background work)."""
        if len(self.memtable):
            self.immutables.append(self.memtable)
            self.memtable = Memtable(self.cfg)

    def flush(self) -> None:
        """Force-rotate the memtable and drain all background work."""
        if self.durability is not None:
            self.wal_index += 1
            self.durability.log_flush(self.wal_index)
        self.rotate_memtable()
        self.drain()

    # ======================================================= lookup machinery
    def lookup_entries(self, keys: np.ndarray, cat: str) -> dict:
        """Vectorized newest-wins point lookup (read layer)."""
        return rlookup.lookup_entries(self, keys, cat)

    def _read_entry_blocks(self, t: SSTable, pos: np.ndarray,
                           ety: np.ndarray, cat: str) -> None:
        rlookup.read_entry_blocks(self, t, pos, ety, cat)

    def read_block(self, t: SSTable, stream: str, block_id: int, cat: str,
                   priority: int, nbytes: int | None = None) -> None:
        rlookup.read_block(self, t, stream, block_id, cat, priority, nbytes)

    # ========================================================== value store
    def resolve_value_file(self, fid: int, key: int,
                           vid: int) -> SSTable | None:
        """Follow GC inheritance chains to the live file holding (key, vid)."""
        return vresolve.resolve_value_file(self, fid, key, vid)

    def _read_values_batch(self, keys, vids, vfiles, vsizes, cat,
                           strict: bool = False) -> None:
        vfetch.read_values_batch(self, keys, vids, vfiles, vsizes, cat,
                                 strict=strict)

    def build_value_files(self, keys, vids, vsizes, cat: str):
        """Build vSST(s) from sorted records (values layer).

        Returns (files, fid_per_record)."""
        return vbuild.build_value_files(self, keys, vids, vsizes, cat)

    # ===================================================== garbage exposure
    def expose_garbage(self, keys, ety, vids, vsizes, vfiles) -> None:
        """Entries dropped during compaction expose value-store garbage
        (Hidden -> Exposed, paper §II-D)."""
        vgarbage.expose_garbage(self, keys, ety, vids, vsizes, vfiles)

    # ============================================================ writeback
    def writeback_index(self, key: int, vid: int, vsize: int,
                        vfile: int) -> None:
        """Titan Write-Index for one locator (shim over the batched path)."""
        self.writeback_index_batch(np.array([key], np.uint64),
                                   np.array([vid], np.uint64),
                                   np.array([vsize], np.int64),
                                   np.array([vfile], np.int64))

    def writeback_index_batch(self, keys, vids, vsizes, vfiles) -> None:
        """Titan Write-Index: new locators through the foreground write
        path, group-committed as one WriteBatch (Titan batches its GC index
        rewrites internally).

        The WAL append is batched, but each writeback still pays the
        per-record commit-queue cost competing with foreground writes —
        this unamortized step is why the paper measures ~38% of Titan's GC
        latency in Write-Index."""
        n = len(keys)
        if n == 0:
            return
        rec = self.cfg.ref_rec_bytes()
        seqs = np.uint64(self.seq + 1) + np.arange(n, dtype=np.uint64)
        self.seq += n
        self.io.seq_write(n * rec, sio.CAT_GC_WRITE_INDEX)
        self.io.stall(n * self.io.device.seq_op_us, sio.CAT_GC_WRITE_INDEX)
        keys = np.asarray(keys, np.uint64)
        ety = np.full(n, ETYPE_REF, np.uint8)
        vids = np.asarray(vids, np.uint64)
        vsz = np.asarray(vsizes, np.int64)
        vf = np.asarray(vfiles, np.int64)
        i = 0
        while i < n:
            i += self.memtable.put_batch(keys[i:], seqs[i:], ety[i:],
                                         vids[i:], vsz[i:], vf[i:])
            if self.memtable.full:
                self.immutables.append(self.memtable)
                self.memtable = Memtable(self.cfg)

    # ================================================================ stats
    def space_bytes(self) -> int:
        return self.version.total_bytes()

    def space_amplification(self) -> float:
        return self.space_bytes() / max(self.valid_bytes, 1)

    def s_index(self) -> float:
        """Space amp of the index LSM-tree: total kSST / last-level kSST."""
        last = self.version.last_nonempty_level()
        lb = self.version.level_bytes(last)
        tot = self.version.ksst_total_bytes()
        return tot / max(lb, 1)

    def exposed_over_valid(self) -> float:
        ref_valid = max(self.valid_value_bytes(), 1)
        return self.version.value_garbage_bytes() / ref_valid

    def valid_value_bytes(self) -> int:
        """Bytes of live (non-garbage) data in the value store."""
        return sum(t.total_value_bytes - t.garbage_bytes
                   for t in self.version.value_files.values())

    def hidden_garbage_bytes(self) -> int:
        """Value bytes referenced by stale index entries whose records are
        still physically present (not yet exposed/reclaimed) — the paper's
        G_H.  Uses the stats oracle ``latest`` — measurement only, never an
        engine decision input.  Vectorized: one oracle lookup + one chain
        resolution for the whole REF column."""
        cols = [(t.keys[m], t.vids[m], t.vsizes[m], t.vfiles[m])
                for t in self.version.all_kssts()
                if (m := (t.etype == ETYPE_REF)).any()]
        if not cols:
            return 0
        keys = np.concatenate([c[0] for c in cols])
        vids = np.concatenate([c[1] for c in cols])
        vsz = np.concatenate([c[2] for c in cols])
        vf = np.concatenate([c[3] for c in cols])
        found, lvids, _ = self.latest.lookup_batch(keys)
        stale = ~(found & (lvids == vids))      # live version is not garbage
        if not stale.any():
            return 0
        keys, vids, vsz, vf = keys[stale], vids[stale], vsz[stale], vf[stale]
        # de-duplicate (key, vid), keeping the FIRST occurrence in table
        # order (a Titan writeback can leave two locators for one record;
        # the scalar walk resolved whichever it met first)
        order = np.lexsort((np.arange(len(keys)), vids, keys))
        k, v = keys[order], vids[order]
        first = np.ones(len(k), bool)
        first[1:] = (k[1:] != k[:-1]) | (v[1:] != v[:-1])
        rows = np.sort(order[first])
        heads = vresolve.resolve_value_fids(self, vf[rows], keys[rows],
                                            vids[rows])
        return int(vsz[rows][heads >= 0].sum())

    def stats(self) -> dict:
        wal = self.io.write_bytes.get(sio.CAT_WAL, 0)
        return {
            "engine": self.cfg.engine,
            "clock_s": self.io.clock_us / 1e6,
            "space_bytes": self.space_bytes(),
            "valid_bytes": self.valid_bytes,
            "user_write_bytes": self.user_write_bytes,
            "space_amp": self.space_amplification(),
            "s_index": self.s_index(),
            "exposed_over_valid": self.exposed_over_valid(),
            "write_amp": (self.io.total_write_bytes() - wal)
            / max(self.user_write_bytes, 1),
            "read_bytes": self.io.total_read_bytes(),
            "write_bytes": self.io.total_write_bytes(),
            "n_compactions": self.n_compactions,
            "n_gc_runs": self.n_gc_runs,
            "cache_hit_ratio": self.cache.hit_ratio(),
            "stall_s": self.stall_us / 1e6,
            "gc_time_s": self.io.gc_time_us() / 1e6,
        }
