"""Kernel dispatch for the batched hot paths (DESIGN.md §12).

The read/value/adaptive layers stay written against their NumPy host
implementations; this module routes eligible batches through the jitted
``repro.kernels`` ops instead.  Every routed op is byte-identical to its
host path on the engine's integer columns (and ulp-identical on the
float64 sketch state — see ``kernels/segment_reduce``), so routing is a
pure performance decision: ``EngineConfig.use_kernels`` turns it on,
``kernel_min_batch`` keeps tiny probes on the host where dispatch
overhead would dominate, and ``kernel_interpret`` picks the execution
mode (``kernels.common.resolve_mode``).

Every routed call returns ``None`` when it declines (kernels off, batch
too small, or keys outside the u32 dictionary-encoding range) — callers
fall back to the host path, which produces the same bytes.  Wall-clock
spent inside routed ops is emitted to the observer as a ``kernel_<op>_us``
histogram per fused op class (real host microseconds, not simulated time —
the one obs metric measured on the wall clock).
"""

from __future__ import annotations

import contextlib
import time

import numpy as np

# kernels pad sorted runs with 0xFFFFFFFE: keys must stay strictly below
U32_KEY_LIMIT = np.uint64(0xFFFFFFFE)


class KernelPolicy:
    """Resolved per-config routing decision (cached on the config)."""

    __slots__ = ("enabled", "min_batch", "window", "_interpret", "_mode")

    def __init__(self, enabled: bool, min_batch: int = 0, window=None,
                 interpret=None):
        self.enabled = bool(enabled)
        self.min_batch = int(min_batch)
        self.window = window
        self._interpret = interpret
        self._mode = None

    @property
    def mode(self) -> str:
        if self._mode is None:   # lazy: resolving imports jax
            from repro.kernels.common import resolve_mode
            self._mode = resolve_mode(self._interpret)
        return self._mode

    def ready(self, n: int) -> bool:
        return self.enabled and n >= self.min_batch


OFF_POLICY = KernelPolicy(False)


def policy_of(cfg) -> KernelPolicy:
    pol = getattr(cfg, "_kernel_policy", None)
    if pol is None:
        pol = (KernelPolicy(True, cfg.kernel_min_batch,
                            cfg.coalesce_window, cfg.kernel_interpret)
               if cfg.use_kernels else OFF_POLICY)
        cfg._kernel_policy = pol
    return pol


def _fits_u32(*arrays) -> bool:
    """All key columns inside the kernels' u32 dictionary-encoding range
    (sorted columns are checked by their last element upstream)."""
    for a in arrays:
        if len(a) and int(a.max()) >= int(U32_KEY_LIMIT):
            return False
    return True


def _emit(store, opclass: str, t0: float) -> None:
    store.obs.on_op(store, f"kernel_{opclass}_us",
                    (time.perf_counter() - t0) * 1e6)


@contextlib.contextmanager
def op_timer(store, opclass: str):
    """Time a fused-op region (host + kernel work) into the observer's
    ``kernel_<opclass>_us`` histogram; no-op while kernels are off."""
    if not policy_of(store.cfg).enabled:
        yield
        return
    t0 = time.perf_counter()
    try:
        yield
    finally:
        _emit(store, opclass, t0)


# ------------------------------------------------------------ read path
def memtable_probe(store, mt, keys):
    """Kernel-routed ``Memtable.get_batch``; None -> host path."""
    pol = policy_of(store.cfg)
    if not pol.ready(len(keys)):
        return None
    mk, seqs, ety, vids, vsz, vf = mt.snapshot()
    n = len(mk)
    if n == 0 or int(mk[-1]) >= int(U32_KEY_LIMIT) or not _fits_u32(keys):
        return None
    from repro import kernels
    t0 = time.perf_counter()
    found, rank = kernels.rank_probe(keys, mk, mode=pol.mode)
    _emit(store, "lookup_probe", t0)
    safe = np.where(rank < n, rank, 0)   # host get_batch's gather guard
    return (found, seqs[safe], ety[safe], vids[safe], vsz[safe], vf[safe])


def table_probe(store, t, keys, kraw):
    """Fused bloom + ``SSTable.find`` for one table; None -> host path.

    ``kraw`` is the hoisted (k, Q) u64 ``hash_family`` column slice; the
    modulo to the table's filter size runs on the host (kernels stay in
    u32 lanes) and the resulting bit indices feed the fused probe."""
    pol = policy_of(store.cfg)
    if not pol.ready(len(keys)):
        return None
    if t.n == 0 or int(t.keys[-1]) >= int(U32_KEY_LIMIT) \
            or not _fits_u32(keys):
        return None
    from repro import kernels
    t0 = time.perf_counter()
    bf = t.bloom
    bit_idx = (kraw % np.uint64(bf.nbits)).astype(np.uint32).T   # (Q, k)
    # pass the stable u64 backing words: ops caches the padded device copy
    # against this array's identity (a .view here would defeat the cache)
    may, found, rank = kernels.lookup_probe(keys, t.keys, bit_idx, bf.bits,
                                            mode=pol.mode)
    _emit(store, "lookup_probe", t0)
    return may, np.where(found, rank, -1)


def assign_files(store, lvl: int, keys):
    """Kernel-routed ``Version.assign_files``; None -> host path."""
    pol = policy_of(store.cfg)
    if not pol.ready(len(keys)):
        return None
    mins, maxs = store.version.level_bounds(lvl)
    if (len(mins) == 0 or int(maxs[-1]) >= int(U32_KEY_LIMIT)
            or not _fits_u32(keys)):
        return None
    from repro import kernels
    t0 = time.perf_counter()
    fidx = kernels.interval_rank(keys, mins, maxs, mode=pol.mode)
    _emit(store, "lookup_probe", t0)
    return fidx


# ----------------------------------------------------------- value path
def table_find(store, t, keys):
    """Kernel-routed ``SSTable.find``; None -> host path."""
    pol = policy_of(store.cfg)
    if not pol.ready(len(keys)):
        return None
    if t.n == 0 or int(t.keys[-1]) >= int(U32_KEY_LIMIT) \
            or not _fits_u32(keys):
        return None
    from repro import kernels
    t0 = time.perf_counter()
    found, rank = kernels.rank_probe(keys, t.keys, mode=pol.mode)
    _emit(store, "lookup_probe", t0)
    return np.where(found, rank, -1)


def plan_runs(store, ranks, pos):
    """Kernel-routed fetch planning: sort by (file-rank, position), dedup,
    mark adjacency runs (capped at ``coalesce_window`` kept records when
    configured).  None -> host ``np.unique`` + ``np.split`` planning."""
    pol = policy_of(store.cfg)
    if not pol.ready(len(ranks)):
        return None
    from repro import kernels
    t0 = time.perf_counter()
    out = kernels.run_coalesce(ranks, pos, window=pol.window, mode=pol.mode)
    _emit(store, "run_coalesce", t0)
    return out
