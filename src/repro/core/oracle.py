"""Columnar last-write-wins stats oracle (DESIGN.md §7).

``LatestOracle`` tracks key -> (vid, vsize) for *measurement only* (space-
amplification denominators, hidden-garbage accounting) — never as an engine
decision input.  State is two key-sorted columnar runs — a large ``base``
and a small ``delta`` that absorbs writes (deletes as vid-0 tombstones) —
merged when the delta outgrows ~sqrt(base), the classic two-run LSM shape.
A whole WriteBatch applies in one vectorized pass over O(batch + delta)
elements (amortized, *not* O(total keys)), replacing the per-key dict loop
the monolithic store carried on its hottest write path while keeping every
lookup a pair of ``searchsorted`` calls.
"""

from __future__ import annotations

import numpy as np

_TOMB_VID = np.uint64(0)        # live vids start at 1 (Store.next_vid)

# delta-run compaction trigger: delta > max(_DELTA_MIN, sqrt(base) * 16)
_DELTA_MIN = 1024
_DELTA_SQRT_MULT = 16


def _probe(keys: np.ndarray, ks: np.ndarray) -> tuple:
    """found mask + safe gather positions of ``ks`` in sorted ``keys``."""
    pos = np.searchsorted(keys, ks)
    ok = pos < len(keys)
    safe = np.where(ok, pos, 0)
    if len(keys):
        ok &= keys[safe] == ks
    else:
        ok = np.zeros(len(ks), bool)
    return ok, safe


class LatestOracle:
    __slots__ = ("bkeys", "bvids", "bvsizes",
                 "dkeys", "dvids", "dvsizes", "valid_bytes")

    def __init__(self):
        self.bkeys = np.zeros(0, np.uint64)
        self.bvids = np.zeros(0, np.uint64)
        self.bvsizes = np.zeros(0, np.int64)
        self.dkeys = np.zeros(0, np.uint64)
        self.dvids = np.zeros(0, np.uint64)
        self.dvsizes = np.zeros(0, np.int64)
        self.valid_bytes = 0        # sum of live value sizes

    def __len__(self) -> int:
        live_delta = int((self.dvids != _TOMB_VID).sum())
        in_base, _ = _probe(self.bkeys, self.dkeys)
        return len(self.bkeys) - int(in_base.sum()) + live_delta

    # ------------------------------------------------------------- lookups
    def lookup_batch(self, keys: np.ndarray) -> tuple:
        """Vectorized lookup: (found, vids, vsizes) parallel arrays (zeros
        where not found).  Delta wins over base; tombstones are misses."""
        ks = np.asarray(keys, np.uint64)
        in_d, dsafe = _probe(self.dkeys, ks)
        in_b, bsafe = _probe(self.bkeys, ks)
        use_b = in_b & ~in_d
        vids = np.zeros(len(ks), np.uint64)
        vsz = np.zeros(len(ks), np.int64)
        if len(self.dkeys):
            use_d = in_d & (self.dvids[dsafe] != _TOMB_VID)
            vids[use_d] = self.dvids[dsafe[use_d]]
            vsz[use_d] = self.dvsizes[dsafe[use_d]]
        else:
            use_d = in_d
        if len(self.bkeys):
            vids[use_b] = self.bvids[bsafe[use_b]]
            vsz[use_b] = self.bvsizes[bsafe[use_b]]
        return use_d | use_b, vids, vsz

    def get(self, key: int) -> tuple[int, int] | None:
        """-> (vid, vsize) of the live version, or None."""
        found, vids, vsz = self.lookup_batch(np.array([key], np.uint64))
        if not found[0]:
            return None
        return int(vids[0]), int(vsz[0])

    # -------------------------------------------------------------- writes
    def apply_batch(self, is_put: np.ndarray, keys: np.ndarray,
                    vids: np.ndarray, vsizes: np.ndarray) -> None:
        """Apply a WriteBatch column: the last record per key wins (batch
        order = seq order); intermediate updates cancel out of
        ``valid_bytes`` exactly as they would applied one by one."""
        n = len(keys)
        if n == 0:
            return
        order = np.lexsort((np.arange(n), keys))
        sk = keys[order]
        last = np.ones(n, bool)
        last[:-1] = sk[1:] != sk[:-1]
        rows = order[last]                  # one row per key, key-sorted
        bk, bput = keys[rows], is_put[rows]
        bvid, bvsz = vids[rows], vsizes[rows]

        prev_found, _, prev_vsz = self.lookup_batch(bk)
        self.valid_bytes -= int(prev_vsz[prev_found].sum())
        self.valid_bytes += int(bvsz[bput].sum())

        # fold the batch into the delta run (batch replaces delta rows;
        # deletes become tombstones so they still mask the base)
        in_d, dsafe = _probe(self.dkeys, bk)
        keep = np.ones(len(self.dkeys), bool)
        keep[dsafe[in_d]] = False
        nvid = np.where(bput, bvid, _TOMB_VID)
        nvsz = np.where(bput, bvsz, 0)
        dk = np.concatenate([self.dkeys[keep], bk])
        o = np.argsort(dk, kind="stable")
        self.dkeys = dk[o]
        self.dvids = np.concatenate([self.dvids[keep], nvid])[o]
        self.dvsizes = np.concatenate([self.dvsizes[keep], nvsz])[o]

        # amortized compaction: delta stays ~sqrt(base)-sized, so per-batch
        # work is O(batch + sqrt(total)) instead of O(total keys)
        if len(self.dkeys) > max(_DELTA_MIN,
                                 int(len(self.bkeys) ** 0.5)
                                 * _DELTA_SQRT_MULT):
            self._compact()

    def _compact(self) -> None:
        in_b, bsafe = _probe(self.bkeys, self.dkeys)
        keep = np.ones(len(self.bkeys), bool)
        keep[bsafe[in_b]] = False
        live = self.dvids != _TOMB_VID
        bk = np.concatenate([self.bkeys[keep], self.dkeys[live]])
        o = np.argsort(bk, kind="stable")
        self.bkeys = bk[o]
        self.bvids = np.concatenate([self.bvids[keep], self.dvids[live]])[o]
        self.bvsizes = np.concatenate([self.bvsizes[keep],
                                       self.dvsizes[live]])[o]
        self.dkeys = np.zeros(0, np.uint64)
        self.dvids = np.zeros(0, np.uint64)
        self.dvsizes = np.zeros(0, np.int64)
