"""Hot / warm / cold key classification from decayed write rates
(DESIGN.md §8).

Cut-points are *relative* to the mean decayed write rate over active keys
(``EngineConfig.temp_hot_mult`` / ``temp_cold_mult``), so classification
adapts to workload intensity without absolute tuning: under a Zipfian
update stream the head keys sit far above the mean (hot) and the long tail
far below (cold); under uniform traffic everything lands warm and the
temperature split degenerates gracefully to one partition.

The classes drive temperature-partitioned vSSTs
(``values/build.py``): hot records group with hot records so their files
turn to garbage together (GC finds little valid data to rewrite), and cold
records stop riding along through rewrite after rewrite.
"""

from __future__ import annotations

import numpy as np

# canonical definitions live in the table substrate (SSTable.temperature);
# re-exported here as the adaptive layer's public names
from ..engine.tables import TEMP_COLD, TEMP_HOT, TEMP_WARM

__all__ = ["TEMP_COLD", "TEMP_WARM", "TEMP_HOT", "TemperatureMap"]

_EPS_RATE = 1e-12       # division guard when no writes have been observed


class TemperatureMap:
    __slots__ = ("tracker", "hot_mult", "cold_mult")

    def __init__(self, tracker, hot_mult: float, cold_mult: float):
        if not (0 <= cold_mult < hot_mult):
            raise ValueError("need 0 <= temp_cold_mult < temp_hot_mult")
        self.tracker = tracker
        self.hot_mult = float(hot_mult)
        self.cold_mult = float(cold_mult)

    def classify(self, keys: np.ndarray) -> np.ndarray:
        """-> int8 array of TEMP_COLD / TEMP_WARM / TEMP_HOT per key."""
        rate = self.tracker.write_rate(keys)
        base = max(self.tracker.mean_write_rate(), _EPS_RATE)
        return np.where(rate >= self.hot_mult * base, TEMP_HOT,
                        np.where(rate <= self.cold_mult * base,
                                 TEMP_COLD, TEMP_WARM)).astype(np.int8)
