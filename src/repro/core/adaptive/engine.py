"""``scavenger_adaptive``: the seventh registered engine (DESIGN.md §8).

Scavenger's feature set (compensated compaction, lazy read, decoupled
index, hot/cold write) plus the workload-adaptive layer this package adds
(Scavenger+ direction, arXiv:2508.13935):

  * **observation** — ``observe_batch`` feeds the ``AccessTracker`` from
    the batched write/read hot paths;
  * **adaptive GC candidate choice** — ``gc_candidate_score`` discounts a
    vSST's garbage ratio by the byte-weighted probability that its records
    die within ``adaptive_gc_horizon_ops`` anyway (predicted dead-byte
    yield): files whose live values are about to be overwritten are
    deferred, so GC stops rewriting bytes that were dying on their own,
    and the same score ranks GC jobs fleet-wide in the ``FleetScheduler``;
  * **temperature segregation** — ``rewrite_temperature`` partitions flush
    and GC-survivor vSSTs hot/warm/cold via the ``TemperatureMap``, so cold
    values stop being rewritten over and over and hot files die wholesale.

With ``adaptive_enabled=False`` every hook falls back to the inherited
default and the engine is byte-identical to plain ``scavenger``
(``tests/test_adaptive.py`` locks this against the refactor-parity
goldens).
"""

from __future__ import annotations

import numpy as np

from .. import accel
from ..engines.paper import ScavengerEngine
from ..engines.registry import register_engine
from .temperature import TemperatureMap
from .tracker import AccessTracker

# prediction-cache pruning: sweep dead fids once the cache outgrows the
# live vSST set by this factor (floored so tiny stores don't thrash)
_SOON_CACHE_SLACK = 4
_SOON_CACHE_MIN = 8


@register_engine
class AdaptiveScavengerEngine(ScavengerEngine):
    name = "scavenger_adaptive"
    adaptive_enabled = True

    def __init__(self, cfg):
        super().__init__(cfg)
        if cfg.adaptive_enabled:
            self.tracker = AccessTracker.from_config(cfg)
            self.tempmap = TemperatureMap(self.tracker, cfg.temp_hot_mult,
                                          cfg.temp_cold_mult)
        else:
            self.tracker = None
            self.tempmap = None
        self._soon_cache: dict[int, tuple[float, float]] = {}

    # ------------------------------------------------------------- observe
    def observe_batch(self, store, kind: str, keys, vsizes=None) -> None:
        if self.tracker is None:
            return
        with accel.op_timer(store, "segment_reduce"):
            if kind == "write":
                self.tracker.observe_writes(keys)
            else:
                self.tracker.observe_reads(keys)

    # ---------------------------------------------------------- GC scoring
    def gc_candidate_score(self, store, t) -> float:
        g = t.garbage_ratio()
        if self.tracker is None or g <= 0.0:
            return g
        soon = self._soon_dead_frac(store, t)
        return g * (1.0 - self.cfg.adaptive_defer_weight * soon)

    def _soon_dead_frac(self, store, t) -> float:
        """Byte-weighted probability that the file's *live* records are
        overwritten within the GC horizon.

        The tracker cannot tell which of the file's records are already
        garbage, but the predicted soon-dead mass covers the dead ones too
        (their keys are the churners), so subtracting the known garbage
        bytes from the prediction — and normalizing by live bytes — keeps a
        file's own garbage from inflating its deferral discount.  The raw
        prediction is cached per file on the tracker's op clock (vSSTs are
        immutable, only the prediction window moves); the garbage
        adjustment uses the current ``garbage_bytes`` every call."""
        now = self.tracker.ops
        ent = self._soon_cache.get(t.fid)
        if ent is not None and now - ent[0] < self.cfg.adaptive_score_refresh_ops:
            pred_dead = ent[1]
        else:
            horizon = self.cfg.adaptive_gc_horizon_ops
            # unknown groups predict an infinite residual -> p_dead == 0
            resid = self.tracker.residual_lifetime(t.keys, default=np.inf)
            p = 1.0 - 0.5 ** (horizon / np.maximum(resid, 1.0))
            pred_dead = float((p * t.rec_bytes).sum())
            if len(self._soon_cache) > _SOON_CACHE_SLACK * max(
                    len(store.version.value_files), _SOON_CACHE_MIN):
                live_files = store.version.value_files
                self._soon_cache = {fid: v
                                    for fid, v in self._soon_cache.items()
                                    if fid in live_files}
            self._soon_cache[t.fid] = (now, pred_dead)
        live_bytes = max(int(t.rec_bytes.sum()) - t.garbage_bytes, 1)
        return min(1.0, max(0.0, (pred_dead - t.garbage_bytes) / live_bytes))

    # ------------------------------------------------- vSST temperature
    def rewrite_temperature(self, store, keys) -> np.ndarray | None:
        if self.tempmap is None:
            return None
        return self.tempmap.classify(keys)
