"""AccessTracker: the store-facing facade of the adaptive subsystem
(DESIGN.md §8).

One tracker per store (owned by the ``scavenger_adaptive`` strategy) keeps:

  * a decayed write-frequency sketch and a decayed read-frequency sketch
    (``DecaySketch``) over individual keys;
  * a ``LifetimeEstimator`` over key-groups
    (``group_of = splitmix64(key) % adaptive_groups``).

It is fed from the two foreground hot paths — ``WriteBatch`` apply and
``multi_get`` — through the ``EngineStrategy.observe_batch`` hook, one
columnar call per batch (zero per-key Python loops).  The tracker's clock is
the user-op count, *not* simulated device time: decay half-lives are then
workload-relative (``EngineConfig.scaled`` sizes them from the key count)
and observation costs no simulated I/O, so disabled-tracker runs are
byte-identical.

Consumers that derive expensive summaries from tracker state (GC candidate
scores) cache them against ``ops``, the tracker's op clock.
"""

from __future__ import annotations

import numpy as np

from .. import accel
from ..engine.keys import splitmix64
from .lifetime import LifetimeEstimator
from .sketch import DecaySketch

_WRITES_SEED = 0x5CA7       # distinct hash families for the two sketches
_READS_SEED = 0xADAF


class AccessTracker:
    __slots__ = ("n_groups", "writes", "reads", "lifetime", "ops")

    def __init__(self, n_groups: int, sketch_width: int, sketch_depth: int,
                 half_life_ops: float | None,
                 residual_floor: float = 0.1, policy=None):
        self.n_groups = int(n_groups)
        self.writes = DecaySketch(sketch_width, sketch_depth,
                                  half_life_ops, seed=_WRITES_SEED,
                                  policy=policy)
        self.reads = DecaySketch(sketch_width, sketch_depth,
                                 half_life_ops, seed=_READS_SEED,
                                 policy=policy)
        self.lifetime = LifetimeEstimator(n_groups, half_life_ops,
                                          residual_floor=residual_floor,
                                          policy=policy)
        self.ops = 0.0

    @classmethod
    def from_config(cls, cfg) -> "AccessTracker":
        return cls(cfg.adaptive_groups, cfg.adaptive_sketch_width,
                   cfg.adaptive_sketch_depth, cfg.adaptive_half_life_ops,
                   residual_floor=cfg.adaptive_residual_floor,
                   policy=accel.policy_of(cfg))

    # ------------------------------------------------------------- observe
    def group_of(self, keys: np.ndarray) -> np.ndarray:
        ks = np.asarray(keys, np.uint64)
        return (splitmix64(ks) % np.uint64(self.n_groups)).astype(np.int64)

    def observe_writes(self, keys: np.ndarray) -> None:
        """One put/delete column (deletes end a lifetime like overwrites)."""
        n = len(keys)
        if n == 0:
            return
        self.ops += n
        self.writes.decay_to(self.ops)
        self.reads.decay_to(self.ops)
        self.writes.add(keys)
        self.lifetime.observe(self.group_of(keys), self.ops)

    def observe_reads(self, keys: np.ndarray) -> None:
        n = len(keys)
        if n == 0:
            return
        self.ops += n
        self.writes.decay_to(self.ops)
        self.reads.decay_to(self.ops)
        self.reads.add(keys)

    # ------------------------------------------------------------- queries
    def write_rate(self, keys: np.ndarray) -> np.ndarray:
        """Decayed write-count estimate per key (the hotness signal)."""
        return self.writes.estimate(keys)

    def read_rate(self, keys: np.ndarray) -> np.ndarray:
        return self.reads.estimate(keys)

    def mean_write_rate(self) -> float:
        """Mean decayed write count over active keys (temperature baseline)."""
        return self.writes.total_mass() / max(self.writes.active_slots(), 1)

    def residual_lifetime(self, keys: np.ndarray,
                          default: float = np.inf) -> np.ndarray:
        """Predicted ops until each key's current value is overwritten."""
        return self.lifetime.residual(self.group_of(keys), self.ops, default)
