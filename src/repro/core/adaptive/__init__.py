"""Workload-aware hotness & lifetime tracking (adaptive subsystem).

Scavenger's critique of existing KV-separated GC strategies is that they
"lack thorough consideration of workload characteristics": GC triggers on a
static garbage-ratio threshold, blind to *which* live values are about to
die and which will be rewritten over and over.  This package closes that
gap with a columnar observation pipeline (DESIGN.md §8):

  * ``DecaySketch``       — exponentially-decayed count-min frequency sketch
                            (vectorized batch updates, conservative: never
                            under-counts).
  * ``LifetimeEstimator`` — per-key-group update-interval histograms turned
                            into predicted residual value lifetimes
                            (lifetime-aware GC à la DumpKV, arXiv:2406.01250).
  * ``AccessTracker``     — ties the sketches and estimator to the store's
                            op stream (``WriteBatch`` apply / ``multi_get``),
                            zero per-key Python loops.
  * ``TemperatureMap``    — classifies keys hot/warm/cold from decayed write
                            rates, driving temperature-partitioned vSSTs.
  * ``engine``            — the ``scavenger_adaptive`` strategy composing it
                            all through the ``EngineStrategy`` hook surface.

Everything here is *observation plus policy*: it consumes the op stream and
influences GC candidate choice and vSST partitioning, but costs no simulated
device time and — when disabled — leaves every engine byte-identical.
"""

from .lifetime import LifetimeEstimator
from .sketch import DecaySketch
from .temperature import TEMP_COLD, TEMP_HOT, TEMP_WARM, TemperatureMap
from .tracker import AccessTracker

__all__ = ["AccessTracker", "DecaySketch", "LifetimeEstimator",
           "TemperatureMap", "TEMP_COLD", "TEMP_WARM", "TEMP_HOT"]
