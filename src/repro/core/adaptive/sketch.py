"""Exponentially-decayed count-min frequency sketch (DESIGN.md §8).

A ``DecaySketch`` estimates per-key event rates from a stream of columnar
batches in O(depth * width) memory.  Two properties matter to callers:

  * **Conservative**: with decay disabled the estimate for any key is
    >= its true event count (count-min over-counts on collisions, never
    under-counts) — ``tests/test_adaptive.py`` locks this against an exact
    oracle.
  * **Decay monotonicity**: advancing the op clock without adding events
    can only lower estimates (each row scales by ``0.5 ** (d / half_life)``),
    so a key that stops being written cools off on a half-life schedule —
    this is what makes a *shifting* hotspot reclassify instead of sticking.

Updates are vectorized (``np.add.at`` per row, ``depth`` is a small
constant): a whole key column crosses in one call, zero per-key loops.
"""

from __future__ import annotations

import numpy as np

from ..engine.keys import splitmix64

_MIN_MASS = 1e-9        # decayed mass below this counts as an empty slot


def normalize_half_life(half_life: float | None) -> float | None:
    """Shared decay-window normalization: None / inf / <= 0 all mean
    "no decay" (used by DecaySketch and LifetimeEstimator so the two stay
    in lockstep on what "disabled" means)."""
    if half_life and np.isfinite(half_life) and half_life > 0:
        return float(half_life)
    return None


class DecaySketch:
    __slots__ = ("width", "depth", "half_life", "counts", "clock", "_seeds")

    def __init__(self, width: int, depth: int = 2,
                 half_life: float | None = None, seed: int = 0):
        if width < 1 or depth < 1:
            raise ValueError("sketch width and depth must be >= 1")
        self.width = int(width)
        self.depth = int(depth)
        self.half_life = normalize_half_life(half_life)
        self.counts = np.zeros((self.depth, self.width), np.float64)
        self.clock = 0.0
        self._seeds = splitmix64(
            np.uint64(seed) + np.arange(1, self.depth + 1, dtype=np.uint64))

    # ---------------------------------------------------------------- decay
    def decay_to(self, clock: float) -> None:
        """Advance the op clock, scaling all counters by the elapsed decay."""
        d = float(clock) - self.clock
        if d <= 0:
            return
        self.clock = float(clock)
        if self.half_life is not None:
            self.counts *= 0.5 ** (d / self.half_life)

    # --------------------------------------------------------------- update
    def _rows(self, keys: np.ndarray) -> np.ndarray:
        """(depth, n) column indices for a key column."""
        ks = np.asarray(keys, np.uint64)
        return (splitmix64(ks[None, :] ^ self._seeds[:, None])
                % np.uint64(self.width)).astype(np.int64)

    def add(self, keys: np.ndarray, weights=None) -> None:
        """Add one event (or ``weights``) per key, vectorized."""
        if len(keys) == 0:
            return
        w = (np.ones(len(keys), np.float64) if weights is None
             else np.asarray(weights, np.float64))
        idx = self._rows(keys)
        for r in range(self.depth):
            np.add.at(self.counts[r], idx[r], w)

    # -------------------------------------------------------------- queries
    def estimate(self, keys: np.ndarray) -> np.ndarray:
        """Decayed event-count estimate per key (count-min: min over rows)."""
        if len(keys) == 0:
            return np.zeros(0, np.float64)
        idx = self._rows(keys)
        est = self.counts[0][idx[0]]
        for r in range(1, self.depth):
            est = np.minimum(est, self.counts[r][idx[r]])
        return est

    def total_mass(self) -> float:
        """Total decayed event mass (row 0 — every row sums the same adds)."""
        return float(self.counts[0].sum())

    def active_slots(self) -> int:
        """Occupied row-0 slots — a lower bound on distinct active keys."""
        return int(np.count_nonzero(self.counts[0] > _MIN_MASS))
