"""Exponentially-decayed count-min frequency sketch (DESIGN.md §8).

A ``DecaySketch`` estimates per-key event rates from a stream of columnar
batches in O(depth * width) memory.  Two properties matter to callers:

  * **Conservative**: with decay disabled the estimate for any key is
    >= its true event count (count-min over-counts on collisions, never
    under-counts) — ``tests/test_adaptive.py`` locks this against an exact
    oracle.
  * **Decay monotonicity**: advancing the op clock without adding events
    can only lower estimates (each row scales by ``0.5 ** (d / half_life)``),
    so a key that stops being written cools off on a half-life schedule —
    this is what makes a *shifting* hotspot reclassify instead of sticking.

Updates are vectorized (one ``np.bincount`` per row, ``depth`` is a small
constant): a whole key column crosses in one call, zero per-key loops.
Eligible batches route the row updates through the ``segment_sum`` kernel
and the count-min gather through ``gather_min64`` (DESIGN.md §12): both
are bit-identical to the host path — unit-count adds accumulate as one
integer-valued float add per slot either way, and the estimate's min runs
as a lexicographic (hi, lo) u32 bit-pattern compare, exact for the
sketch's non-negative float64 counters.
"""

from __future__ import annotations

import numpy as np

from ..engine.keys import splitmix64

_MIN_MASS = 1e-9        # decayed mass below this counts as an empty slot


def normalize_half_life(half_life: float | None) -> float | None:
    """Shared decay-window normalization: None / inf / <= 0 all mean
    "no decay" (used by DecaySketch and LifetimeEstimator so the two stay
    in lockstep on what "disabled" means)."""
    if half_life and np.isfinite(half_life) and half_life > 0:
        return float(half_life)
    return None


class DecaySketch:
    __slots__ = ("width", "depth", "half_life", "counts", "clock", "_seeds",
                 "policy")

    def __init__(self, width: int, depth: int = 2,
                 half_life: float | None = None, seed: int = 0,
                 policy=None):
        if width < 1 or depth < 1:
            raise ValueError("sketch width and depth must be >= 1")
        self.width = int(width)
        self.depth = int(depth)
        self.half_life = normalize_half_life(half_life)
        self.counts = np.zeros((self.depth, self.width), np.float64)
        self.clock = 0.0
        self._seeds = splitmix64(
            np.uint64(seed) + np.arange(1, self.depth + 1, dtype=np.uint64))
        self.policy = policy    # KernelPolicy (core/accel.py) or None

    # ---------------------------------------------------------------- decay
    def decay_to(self, clock: float) -> None:
        """Advance the op clock, scaling all counters by the elapsed decay."""
        d = float(clock) - self.clock
        if d <= 0:
            return
        self.clock = float(clock)
        if self.half_life is not None:
            self.counts *= 0.5 ** (d / self.half_life)

    # --------------------------------------------------------------- update
    def _rows(self, keys: np.ndarray) -> np.ndarray:
        """(depth, n) column indices for a key column."""
        ks = np.asarray(keys, np.uint64)
        return (splitmix64(ks[None, :] ^ self._seeds[:, None])
                % np.uint64(self.width)).astype(np.int64)

    def add(self, keys: np.ndarray, weights=None) -> None:
        """Add one event (or ``weights``) per key, vectorized.

        Unit-count adds accumulate occurrence counts first and add each
        slot's total as a single integer-valued float — the exact shape of
        the kernel's ``counts += segment_sum`` update, so the host and
        kernel paths stay bit-identical."""
        if len(keys) == 0:
            return
        idx = self._rows(keys)
        if weights is not None:
            w = np.asarray(weights, np.float64)
            for r in range(self.depth):
                np.add.at(self.counts[r], idx[r], w)
            return
        pol = self.policy
        if pol is not None and pol.ready(len(keys)):
            from repro import kernels
            flat = (idx + np.arange(self.depth)[:, None] * self.width).ravel()
            seg = kernels.segment_sum(flat, self.depth * self.width,
                                      mode=pol.mode)
            self.counts += seg.reshape(self.depth, self.width)
        else:
            for r in range(self.depth):
                self.counts[r] += np.bincount(idx[r], minlength=self.width)

    # -------------------------------------------------------------- queries
    def estimate(self, keys: np.ndarray) -> np.ndarray:
        """Decayed event-count estimate per key (count-min: min over rows)."""
        if len(keys) == 0:
            return np.zeros(0, np.float64)
        idx = self._rows(keys)
        pol = self.policy
        if pol is not None and pol.ready(len(keys)):
            from repro import kernels
            # (depth, width) f64 -> little-endian (lo, hi) u32 planes;
            # lexicographic pair-min == numeric min for non-negative doubles
            v = self.counts.view(np.uint32).reshape(self.depth, self.width, 2)
            oh, ol = kernels.gather_min64(v[..., 1], v[..., 0],
                                          idx.T, mode=pol.mode)
            return ((oh.astype(np.uint64) << np.uint64(32))
                    | ol.astype(np.uint64)).view(np.float64)
        est = self.counts[0][idx[0]]
        for r in range(1, self.depth):
            est = np.minimum(est, self.counts[r][idx[r]])
        return est

    def total_mass(self) -> float:
        """Total decayed event mass (row 0 — every row sums the same adds)."""
        return float(self.counts[0].sum())

    def active_slots(self) -> int:
        """Occupied row-0 slots — a lower bound on distinct active keys."""
        return int(np.count_nonzero(self.counts[0] > _MIN_MASS))
