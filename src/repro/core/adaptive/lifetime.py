"""Residual value-lifetime prediction from update-interval histograms
(DESIGN.md §8).

DumpKV (arXiv:2406.01250) shows that knowing *when* a value will die lets
GC skip rewrites that are about to become garbage anyway.  We estimate
lifetimes per **key-group** (``splitmix64(key) % n_groups`` — group-level
stats stay robust under key-space churn and bound memory): every observed
write to a group contributes its inter-update interval, in user ops, to a
decayed log2-bucket histogram; the histogram's mean is the group's expected
value lifetime, and the residual for a value of known age follows from it.

All updates are columnar: one ``np.unique`` + fancy-indexing pass per
observed batch (an in-batch repeat of a group is a ~0-interval update; one
observation per group per batch keeps the histogram meaningful at any batch
size).
"""

from __future__ import annotations

import numpy as np

from .sketch import normalize_half_life

N_BUCKETS = 32          # log2 interval buckets: covers up to 2^31 ops
BUCKET_CENTER = 1.5     # midpoint multiplier for bucket [2^b, 2^(b+1))
_EPS_MASS = 1e-12       # division guard for empty histograms
_MIN_MASS = 1e-9        # below this a group counts as unobserved


class LifetimeEstimator:
    __slots__ = ("n_groups", "half_life", "residual_floor", "last_write",
                 "hist", "_centers", "policy")

    def __init__(self, n_groups: int, half_life: float | None = None,
                 residual_floor: float = 0.1, policy=None):
        if n_groups < 1:
            raise ValueError("n_groups must be >= 1")
        self.n_groups = int(n_groups)
        self.half_life = normalize_half_life(half_life)
        self.residual_floor = float(residual_floor)
        self.last_write = np.full(self.n_groups, -1.0, np.float64)
        self.hist = np.zeros((self.n_groups, N_BUCKETS), np.float64)
        # bucket b holds intervals in [2^b, 2^(b+1)); center = 1.5 * 2^b
        self._centers = BUCKET_CENTER * 2.0 ** np.arange(N_BUCKETS,
                                                         dtype=np.float64)
        self.policy = policy    # KernelPolicy (core/accel.py) or None

    # ------------------------------------------------------------- observe
    def observe(self, groups: np.ndarray, now: float) -> None:
        """Record one write-interval observation per distinct group."""
        if len(groups) == 0:
            return
        ug = np.unique(np.asarray(groups, np.int64))
        prev = self.last_write[ug]
        has = prev >= 0
        sel = ug[has]
        if len(sel):
            iv = np.maximum(now - prev[has], 1.0)
            b = np.clip(np.log2(iv).astype(np.int64), 0, N_BUCKETS - 1)
            if self.half_life is not None:
                # lazy per-group decay: scale by time since last observation
                self.hist[sel] *= (0.5 ** (iv / self.half_life))[:, None]
            pol = self.policy
            if pol is not None and pol.ready(len(sel)):
                # one-hot bucket rows via segment_sum; adding the zero
                # columns is exact (x + 0.0 == x for the non-negative hist)
                from repro import kernels
                flat = np.arange(len(sel)) * N_BUCKETS + b
                seg = kernels.segment_sum(flat, len(sel) * N_BUCKETS,
                                          mode=pol.mode)
                self.hist[sel] += seg.reshape(-1, N_BUCKETS)
            else:
                self.hist[sel, b] += 1.0
        self.last_write[ug] = now

    # ------------------------------------------------------------- queries
    def mean_interval(self, groups: np.ndarray,
                      default: float = np.inf) -> np.ndarray:
        """Expected update interval (ops) per group; ``default`` where the
        group has no observations yet (treat unknown as cold)."""
        g = np.asarray(groups, np.int64)
        h = self.hist[g]
        w = h.sum(axis=1)
        mean = (h @ self._centers) / np.maximum(w, _EPS_MASS)
        return np.where(w > _MIN_MASS, mean, default)

    def residual(self, groups: np.ndarray, now: float,
                 default: float = np.inf) -> np.ndarray:
        """Predicted remaining ops until each group's values are next
        overwritten.

        Within the predicted interval: the mean interval less the age,
        floored at ``residual_floor`` of the mean (updates are not
        clockwork; a live hot group's residual never hits zero).  *Past* it, the prediction
        has been falsified — the group stopped updating on schedule (e.g. a
        hotspot moved away) — so the residual grows with the age instead:
        values that keep surviving are expected to keep surviving, and GC
        stops deferring files full of retired-hotspot data."""
        g = np.asarray(groups, np.int64)
        m = self.mean_interval(g, default)
        age = np.where(self.last_write[g] >= 0,
                       now - self.last_write[g], 0.0)
        return np.where(age > m, age,
                        np.maximum(m - age, self.residual_floor * m))
