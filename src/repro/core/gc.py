"""Garbage collection orchestration (paper §II-C, §III-B; DESIGN.md §7).

``run_gc`` is the scheme-agnostic skeleton — read candidates, GC-Lookup,
validity, lazy value read, write, retire — with every scheme-specific step
delegated to the store's engine strategy (``repro.core.engines``):

  * inherit (TerarkDB / Scavenger / hybrid): no index writeback; GC output
    files inherit from the candidates they merged; reads resolve via the
    chain (``repro.core.values.resolve``).
      - TerarkDB read step: full vSST scan through the block cache.
      - Scavenger read step ("lazy read", §III-B.1): RTable dense-index
        blocks only, then — after GC-Lookup — only the *valid* records,
        coalesced into runs.
      - Scavenger write step (§III-B.3): hotness-aware hot/cold vSST split
        via DropCache.
  * writeback (Titan): full blob scan with *uncached* reads, validity by
    exact locator, valid records rewritten and the new locator written back
    through the foreground path (Write-Index) — extra WAL/memtable/compaction
    load, the paper's ~38% GC-latency step.
  * compaction (BlobDB): no standalone GC — relocation happens inside
    compaction (``engines/paper.py:BlobDBEngine.on_compaction_kept``); blob
    files are reclaimed only once every reference has been rewritten or
    dropped.
"""

from __future__ import annotations

import numpy as np

from .engine import io as sio
from .engine.tables import ETYPE_REF, SSTable
# Re-exported for compatibility: chain machinery lives in the values layer.
from .values.resolve import GCGroup, resolve_value_fids   # noqa: F401


def gc_candidates(store, threshold: float) -> list[SSTable]:
    """Eligible candidate vSSTs, best first.

    Eligibility and ranking go through the engine strategy's
    ``gc_candidate_score`` — the raw garbage ratio for the paper engines
    (static-threshold policy), predicted dead-byte yield for
    ``scavenger_adaptive`` (DESIGN.md §8)."""
    strat = store.strategy
    scores = {t.fid: strat.gc_candidate_score(store, t)
              for t in store.version.value_files.values() if t.n > 0}
    cands = [t for t in store.version.value_files.values()
             if t.n > 0 and scores[t.fid] >= threshold]
    cands.sort(key=lambda t: scores[t.fid], reverse=True)
    return cands


def gc_batch(store, cands: list[SSTable]) -> list[SSTable]:
    """Batch candidates per GC run: up to ``gc_batch_files`` target-size
    outputs worth of input (one run models TerarkDB's multi-file GC job)."""
    budget = store.cfg.gc_batch_files * store.cfg.vsst_bytes
    batch, acc = [], 0
    for t in cands:
        batch.append(t)
        acc += t.file_bytes
        if acc >= budget or len(batch) >= store.cfg.gc_batch_cap:
            break
    return batch


def has_pending(store, threshold: float) -> bool:
    if not store.strategy.wants_standalone_gc():
        return False
    return bool(gc_candidates(store, threshold))


def run_gc(store, candidates: list[SSTable]) -> None:
    strat = store.strategy
    store.in_gc = True
    try:
        # ---------------------------------------------------- 1. Read phase
        for t in candidates:
            strat.gc_read_candidate(store, t)

        # ------------------------------------------------ 2. GC-Lookup phase
        all_keys = np.concatenate([t.keys for t in candidates])
        all_vids = np.concatenate([t.vids for t in candidates])
        all_vsz = np.concatenate([t.vsizes for t in candidates])
        cand_of = np.concatenate([np.full(t.n, i, np.int64)
                                  for i, t in enumerate(candidates)])
        res = store.lookup_entries(all_keys, sio.CAT_GC_LOOKUP)

        valid = res["found"] & (res["etype"] == ETYPE_REF) & \
            (res["vid"] == all_vids)
        valid = strat.gc_refine_valid(store, candidates, cand_of, res,
                                      all_keys, all_vids, valid)

        # ------------------------------------- 3. lazy value read (Scavenger)
        strat.gc_value_read(store, candidates, cand_of, valid)

        # ---------------------------------------------------- 4. Write phase
        vkeys = all_keys[valid]
        vvids = all_vids[valid]
        vvsz = all_vsz[valid]
        order = np.argsort(vkeys, kind="stable")
        vkeys, vvids, vvsz = vkeys[order], vvids[order], vvsz[order]
        new_files, new_fid_per_rec = store.build_value_files(
            vkeys, vvids, vvsz, sio.CAT_GC_WRITE)
        store._crashpoint("gc_pre_chain")    # outputs written, chains /
        #                                      registry not yet updated

        # --------------------------------- 5. retire candidates / writeback
        strat.gc_finalize(store, candidates, new_files, vkeys, vvids, vvsz,
                          new_fid_per_rec)
        store._crashpoint("gc_post_chain")   # chain update durable in the
        #                                      MANIFEST, run counter not yet

        store.n_gc_runs += 1
        rewrite = sum(t.file_bytes for t in new_files)
        reclaimed = sum(t.file_bytes for t in candidates) - rewrite
        store.gc_reclaimed_bytes += reclaimed
        # per-job observability (DESIGN.md §11): the distribution of
        # rewrite/reclaim bytes per GC run is the paper's Fig.3 axis
        store.obs.on_op(store, "gc_rewrite_bytes", rewrite)
        store.obs.on_op(store, "gc_reclaimed_bytes", reclaimed)
        store.obs.on_op(store, "gc_input_files", len(candidates))
        # space-event ledger (§13): rewrite/reclaim bytes by cause
        store.obs.on_space(store, "gc_rewrite", rewrite)
        store.obs.on_space(store, "gc_reclaim", reclaimed)
    finally:
        store.in_gc = False
