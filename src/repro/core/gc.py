"""Garbage collection schemes (paper §II-C, §III-B).

  * inherit (TerarkDB / Scavenger): no index writeback; GC output files
    inherit from the candidates they merged; reads resolve via the chain.
      - TerarkDB read step: full vSST scan through the block cache.
      - Scavenger read step ("lazy read", §III-B.1): RTable dense-index
        blocks only, then — after GC-Lookup — only the *valid* records,
        coalesced into runs.
      - Scavenger write step (§III-B.3): hotness-aware hot/cold vSST split
        via DropCache.
  * writeback (Titan): full blob scan with *uncached* reads, validity by
    exact locator, valid records rewritten and the new locator written back
    through the foreground path (Write-Index) — extra WAL/memtable/compaction
    load, the paper's ~38% GC-latency step.
  * compaction (BlobDB): no standalone GC — relocation happens inside
    compaction (see ``Store.blobdb_relocate``); blob files are reclaimed only
    once every reference has been rewritten or dropped.
"""

from __future__ import annotations

import numpy as np

from .engine import io as sio
from .engine.cache import BlockCache
from .engine.tables import ETYPE_REF, SSTable


class GCGroup:
    """Inheritance target: the set of output files of one GC run."""

    __slots__ = ("files",)

    def __init__(self, files: list[SSTable]):
        self.files = files

    def locate_batch(self, keys: np.ndarray, vids: np.ndarray) -> np.ndarray:
        """Vectorized locate: fid of the group file holding each (key, vid),
        -1 where no file does.  One ``find`` per file for the whole column
        (files win in list order, matching the scalar walk)."""
        keys = np.asarray(keys, np.uint64)
        vids = np.asarray(vids, np.uint64)
        out = np.full(len(keys), -1, np.int64)
        unresolved = np.ones(len(keys), bool)
        for t in self.files:
            if not unresolved.any():
                break
            rows = np.nonzero(unresolved)[0]
            pos = t.find(keys[rows])
            ok = pos >= 0
            safe = np.where(ok, pos, 0)
            ok &= t.vids[safe] == vids[rows]
            hit = rows[ok]
            out[hit] = t.fid
            unresolved[hit] = False
        return out

    def locate(self, key: int, vid: int) -> SSTable | None:
        fid = int(self.locate_batch(np.array([key], np.uint64),
                                    np.array([vid], np.uint64))[0])
        if fid < 0:
            return None
        for t in self.files:
            if t.fid == fid:
                return t
        return None


def resolve_value_fids(store, vfiles: np.ndarray, keys: np.ndarray,
                       vids: np.ndarray) -> np.ndarray:
    """Vectorized ``Store.resolve_value_file``: follow inheritance chains
    for a whole locator column, one grouped ``locate_batch`` per chain hop
    instead of a Python per-record walk.  Returns the live fid per row, -1
    where the record was already dropped by a GC."""
    cur = np.asarray(vfiles, np.int64).copy()
    keys = np.asarray(keys, np.uint64)
    vids = np.asarray(vids, np.uint64)
    n = len(cur)
    out = np.full(n, -1, np.int64)
    active = np.ones(n, bool)
    # live-set snapshot is safe: resolution is pure metadata, no file is
    # added or retired while chains are walked
    live = store.version.value_files
    live_fids = np.fromiter(live.keys(), np.int64, count=len(live))
    for _ in range(10_000):
        rows = np.nonzero(active)[0]
        if len(rows) == 0:
            return out
        at_live = np.isin(cur[rows], live_fids)
        out[rows[at_live]] = cur[rows[at_live]]
        active[rows[at_live]] = False
        rows = rows[~at_live]
        if len(rows) == 0:
            return out
        for f in np.unique(cur[rows]).tolist():
            grp = rows[cur[rows] == f]
            g = store.chains.get(int(f))
            if g is None:
                active[grp] = False         # file gone, no inheritor
                continue
            nxt = g.locate_batch(keys[grp], vids[grp])
            dead = nxt < 0
            active[grp[dead]] = False       # dropped during that GC
            cur[grp[~dead]] = nxt[~dead]
    raise RuntimeError("inheritance chain cycle")


def gc_candidates(store, threshold: float) -> list[SSTable]:
    cands = [t for t in store.version.value_files.values()
             if t.garbage_ratio() >= threshold and t.n > 0]
    cands.sort(key=lambda t: t.garbage_ratio(), reverse=True)
    return cands


def gc_batch(store, cands: list[SSTable]) -> list[SSTable]:
    """Batch candidates per GC run: up to ``gc_batch_files`` target-size
    outputs worth of input (one run models TerarkDB's multi-file GC job)."""
    budget = store.cfg.gc_batch_files * store.cfg.vsst_bytes
    batch, acc = [], 0
    for t in cands:
        batch.append(t)
        acc += t.file_bytes
        if acc >= budget or len(batch) >= store.cfg.gc_batch_cap:
            break
    return batch


def has_pending(store, threshold: float) -> bool:
    if store.cfg.gc_scheme in ("none", "compaction"):
        return False
    return bool(gc_candidates(store, threshold))


def run_gc(store, candidates: list[SSTable]) -> None:
    cfg = store.cfg
    io = store.io
    store.in_gc = True
    try:
        # ---------------------------------------------------- 1. Read phase
        for t in candidates:
            if cfg.lazy_read and t.layout == "rtable":
                # Lazy read: dense-index blocks only (§III-B.1).
                for b in range(t.n_index_blocks):
                    store.read_block(t, "ib", b, sio.CAT_GC_READ,
                                     BlockCache.PRI_HIGH,
                                     t.index_block_bytes())
            elif cfg.gc_scheme == "writeback":
                # Titan: direct (uncached) full-file scan.
                if cfg.readahead_gc:
                    io.seq_read(t.data_bytes, sio.CAT_GC_READ)
                else:
                    for b in range(t.n_data_blocks):
                        io.rand_read(t.data_block_bytes(0, b),
                                     sio.CAT_GC_READ)
            else:
                # TerarkDB: full scan through the block cache.
                if cfg.readahead_gc:
                    io.seq_read(t.data_bytes, sio.CAT_GC_READ)
                else:
                    for b in range(t.n_data_blocks):
                        store.read_block(t, "d0", b, sio.CAT_GC_READ,
                                         BlockCache.PRI_LOW)

        # ------------------------------------------------ 2. GC-Lookup phase
        all_keys = np.concatenate([t.keys for t in candidates])
        all_vids = np.concatenate([t.vids for t in candidates])
        all_vsz = np.concatenate([t.vsizes for t in candidates])
        all_rec = np.concatenate([t.rec_bytes for t in candidates])
        cand_of = np.concatenate([np.full(t.n, i, np.int64)
                                  for i, t in enumerate(candidates)])
        res = store.lookup_entries(all_keys, sio.CAT_GC_LOOKUP)

        valid = res["found"] & (res["etype"] == ETYPE_REF) & \
            (res["vid"] == all_vids)
        if cfg.gc_scheme == "inherit":
            # resolve the entry's file number through inheritance chains and
            # compare with the candidate being collected (§II-B).  Fast path:
            # the entry usually points directly at the (live) candidate; the
            # rest resolve in one grouped vectorized pass.
            cand_fids = np.array([t.fid for t in candidates], np.int64)
            direct = res["vfile"] == cand_fids[cand_of]
            chained = np.nonzero(valid & ~direct)[0]
            if len(chained):
                heads = resolve_value_fids(store, res["vfile"][chained],
                                           all_keys[chained],
                                           all_vids[chained])
                valid[chained] &= heads == cand_fids[cand_of[chained]]
        else:  # writeback: exact locator match
            cand_fids = np.array([t.fid for t in candidates], np.int64)
            valid &= res["vfile"] == cand_fids[cand_of]

        # ------------------------------------- 3. lazy value read (Scavenger)
        if cfg.lazy_read:
            for ci, t in enumerate(candidates):
                pos = np.nonzero(valid & (cand_of == ci))[0]
                if len(pos) == 0:
                    continue
                local = pos - int(np.searchsorted(cand_of, ci, side="left"))
                runs = np.split(local, np.nonzero(np.diff(local) != 1)[0] + 1)
                for r in runs:
                    nbytes = int(t.rec_bytes[r].sum())
                    if cfg.readahead_gc:
                        io.seq_read(nbytes, sio.CAT_GC_READ)
                    else:
                        io.rand_read(nbytes, sio.CAT_GC_READ)

        # ---------------------------------------------------- 4. Write phase
        vkeys = all_keys[valid]
        vvids = all_vids[valid]
        vvsz = all_vsz[valid]
        order = np.argsort(vkeys, kind="stable")
        vkeys, vvids, vvsz = vkeys[order], vvids[order], vvsz[order]
        new_files, new_fid_per_rec = store.build_value_files(
            vkeys, vvids, vvsz, sio.CAT_GC_WRITE)

        # --------------------------------- 5. retire candidates / writeback
        if cfg.gc_scheme == "inherit":
            group = GCGroup(new_files)
            for t in candidates:
                store.version.retire_value_file(t.fid, None)
                store.chains[t.fid] = group
                store.cache.erase_file(t.fid)
        else:  # titan writeback: index rewrites as one batched write
            store.writeback_index_batch(vkeys, vvids, vvsz, new_fid_per_rec)
            for t in candidates:
                store.version.retire_value_file(t.fid, None)
                store.cache.erase_file(t.fid)

        store.n_gc_runs += 1
        store.gc_reclaimed_bytes += sum(t.file_bytes for t in candidates) \
            - sum(t.file_bytes for t in new_files)
    finally:
        store.in_gc = False
