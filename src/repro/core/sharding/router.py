"""Shard routers: vectorized key -> shard assignment, scatter plans, and
live topology changes — split/merge with epoch-stamped routing
(DESIGN.md §6, §14).

Both routers are *slice tables* over a 64-bit routing domain: ``cuts`` is
the ascending list of slice upper bounds (exclusive; the last cut is the
domain size) and ``owners[i]`` is the shard position owning slice ``i``.
Every live shard owns exactly one contiguous slice, so a migration moves
one contiguous sub-range between exactly two shards.

  * range — the routing domain is the dense keyspace ``[0, key_space)``
    and a key routes as itself: a scan touches the owning slice and spills
    into successor slices in key order.  Keys at or beyond ``key_space``
    (e.g. YCSB insert appends) land in the last slice.
  * hash  — the routing domain is the full ``splitmix64`` image
    ``[0, 2^64)``: uniform load regardless of key skew, but keys
    interleave across shards, so range scans fan out to every shard and
    merge (see ``ShardedStore.multi_scan``).  Splits cut the *hashed*
    domain, so a split moves keys only between the split shard and the
    new one (hash-range partitioning).

Topology changes (``split`` / ``merge``) bump ``epoch`` — a monotone
counter the dispatch loops in ``ShardedStore`` snapshot before scattering
a batch: an in-flight batch that raced a finalizing migration observes
the bump and re-dispatches its unwritten rows under the new table
(DESIGN.md §14).

``scatter`` produces one permutation that groups a key column by shard;
results are written back through the same permutation so callers always
see original batch order (gather-with-original-order reassembly).
"""

from __future__ import annotations

import numpy as np

from ..engine.keys import splitmix64

POLICIES = ("hash", "range")

HASH_DOMAIN = 1 << 64           # image of splitmix64


class SliceRouter:
    """Base: an ordered slice table over an integer routing domain."""

    policy = "?"

    def __init__(self, n_shards: int, domain: int):
        n = int(n_shards)
        if n < 1:
            raise ValueError("n_shards must be >= 1")
        self.domain = int(domain)
        if self.domain < n:
            raise ValueError("routing domain must be >= n_shards")
        self.cuts = [(i + 1) * self.domain // n for i in range(n)]
        self.owners = list(range(n))
        self.epoch = 0
        self._rebuild()

    # ------------------------------------------------------------- routing
    def route(self, keys: np.ndarray) -> np.ndarray:
        """Map keys to routing-domain values (uint64)."""
        raise NotImplementedError

    def _rebuild(self) -> None:
        # bounds exclude the final cut (== domain, which may not fit u64);
        # searchsorted then sends every value past the last bound to the
        # last slice — this is also what routes overflow keys (range
        # policy keys >= key_space) to the last slice
        self._bounds = np.array(self.cuts[:-1], np.uint64)
        self._owners = np.array(self.owners, np.int64)

    def slice_of(self, keys: np.ndarray) -> np.ndarray:
        return np.searchsorted(self._bounds, self.route(keys),
                               side="right").astype(np.int64)

    def shard_of(self, keys: np.ndarray) -> np.ndarray:
        return self._owners[self.slice_of(keys)]

    # ------------------------------------------------------------ topology
    @property
    def n_slices(self) -> int:
        return len(self.cuts)

    def slice_bounds(self, sl: int) -> tuple[int, int]:
        """[lo, hi) routing-domain bounds of slice ``sl``."""
        return (0 if sl == 0 else self.cuts[sl - 1], self.cuts[sl])

    def slice_of_shard(self, pos: int) -> int:
        """Slice owned by shard position ``pos`` (exactly one, by
        construction)."""
        return self.owners.index(pos)

    def shard_range(self, pos: int) -> tuple[int, int]:
        return self.slice_bounds(self.slice_of_shard(pos))

    def split(self, pos: int, cut: int, new_pos: int) -> None:
        """Split shard ``pos``'s slice at routing-domain value ``cut``:
        ``pos`` keeps [lo, cut), the shard at ``new_pos`` takes
        [cut, hi).  Bumps the epoch."""
        sl = self.slice_of_shard(pos)
        lo, hi = self.slice_bounds(sl)
        if not lo < cut < hi:
            raise ValueError(f"cut {cut} outside slice ({lo}, {hi})")
        if new_pos in self.owners:
            raise ValueError(f"shard position {new_pos} already owns a "
                             "slice")
        self.cuts.insert(sl, int(cut))
        self.owners.insert(sl + 1, int(new_pos))
        self.epoch += 1
        self._rebuild()

    def merge(self, victim_pos: int, into_pos: int) -> None:
        """Remove ``victim_pos``'s slice, absorbing its range into the
        adjacent slice owned by ``into_pos``.  Bumps the epoch."""
        sv = self.slice_of_shard(victim_pos)
        si = self.slice_of_shard(into_pos)
        if abs(sv - si) != 1:
            raise ValueError(
                f"shards {victim_pos} and {into_pos} own non-adjacent "
                f"slices {sv} and {si}; only adjacent slices merge")
        # dropping the lower slice's cut extends the other over its range
        del self.cuts[min(sv, si)]
        del self.owners[sv]
        self.epoch += 1
        self._rebuild()

    def renumber_removed(self, pos: int) -> None:
        """A shard position was deleted from the fleet's shard list:
        shift every owner above it down by one (no epoch bump — callers
        bump via the merge that preceded the removal)."""
        self.owners = [o - 1 if o > pos else o for o in self.owners]
        self._rebuild()

    def neighbors(self, pos: int) -> list[int]:
        """Shard positions owning slices adjacent to ``pos``'s (merge
        candidates)."""
        sl = self.slice_of_shard(pos)
        out = []
        if sl > 0:
            out.append(self.owners[sl - 1])
        if sl + 1 < len(self.owners):
            out.append(self.owners[sl + 1])
        return out

    # ---------------------------------------------------------- durability
    def state_dict(self) -> dict:
        return {"policy": self.policy, "domain": self.domain,
                "cuts": list(self.cuts), "owners": list(self.owners),
                "epoch": self.epoch}

    def load_state(self, st: dict) -> None:
        if st["policy"] != self.policy or int(st["domain"]) != self.domain:
            raise ValueError(f"router state {st['policy']}/{st['domain']} "
                             f"does not match {self.policy}/{self.domain}")
        self.cuts = [int(c) for c in st["cuts"]]
        self.owners = [int(o) for o in st["owners"]]
        self.epoch = int(st["epoch"])
        self._rebuild()


class HashRouter(SliceRouter):
    policy = "hash"

    def __init__(self, n_shards: int):
        super().__init__(n_shards, HASH_DOMAIN)

    def route(self, keys: np.ndarray) -> np.ndarray:
        return splitmix64(np.asarray(keys, np.uint64))


class RangeRouter(SliceRouter):
    policy = "range"

    def __init__(self, n_shards: int, key_space: int):
        if int(key_space) < int(n_shards):
            raise ValueError("key_space must be >= n_shards")
        super().__init__(n_shards, key_space)

    def route(self, keys: np.ndarray) -> np.ndarray:
        return np.asarray(keys, np.uint64)

    def shard_start(self, shard: int) -> int:
        """Lowest key owned by ``shard`` (scan-continuation entry point)."""
        return self.shard_range(shard)[0]


def make_router(policy: str, n_shards: int, key_space: int | None = None):
    if policy == "hash":
        return HashRouter(n_shards)
    if policy == "range":
        if key_space is None:
            raise ValueError("range policy requires key_space "
                             "(upper bound of the dense key domain)")
        return RangeRouter(n_shards, key_space)
    raise ValueError(f"unknown shard policy {policy!r} (want one of "
                     f"{POLICIES})")


def restore_router(state: dict):
    """Rebuild a router from ``state_dict`` output (fleet recovery)."""
    if state["policy"] == "hash":
        r = HashRouter(len(state["owners"]))
    else:
        r = RangeRouter(len(state["owners"]), state["domain"])
    r.load_state(state)
    return r


def scatter(shard_of: np.ndarray, n_shards: int):
    """Group a routed column by shard.

    Returns ``(order, starts, ends)``: ``order`` is a stable permutation
    putting rows of the same shard adjacent (original relative order kept,
    so per-shard sub-batches preserve WriteBatch append semantics);
    ``order[starts[s]:ends[s]]`` are the original-row indices of shard
    ``s``.  Writing results back through those indices restores original
    batch order.
    """
    order = np.argsort(shard_of, kind="stable")
    srt = shard_of[order]
    ids = np.arange(n_shards, dtype=np.int64)
    starts = np.searchsorted(srt, ids, side="left")
    ends = np.searchsorted(srt, ids, side="right")
    return order, starts, ends
