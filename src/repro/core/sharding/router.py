"""Shard routers: vectorized key -> shard assignment and scatter plans
(DESIGN.md §6).

Two placement policies:

  * hash  — ``splitmix64(key) % n_shards``: uniform load regardless of key
    skew, but keys interleave across shards, so range scans must fan out to
    every shard and merge (see ``ShardedStore.multi_scan``).
  * range — the keyspace ``[0, key_space)`` is cut into ``n_shards`` equal
    contiguous slices: a scan touches the owning shard and spills into at
    most the next shard(s), and per-shard key locality is preserved.  Keys
    at or beyond ``key_space`` (e.g. YCSB insert appends) land in the last
    shard.

``scatter`` produces one permutation that groups a key column by shard;
results are written back through the same permutation so callers always see
original batch order (gather-with-original-order reassembly).
"""

from __future__ import annotations

import numpy as np

from ..engine.keys import splitmix64

POLICIES = ("hash", "range")


class HashRouter:
    policy = "hash"

    def __init__(self, n_shards: int):
        self.n_shards = int(n_shards)

    def shard_of(self, keys: np.ndarray) -> np.ndarray:
        ks = np.asarray(keys, np.uint64)
        return (splitmix64(ks) % np.uint64(self.n_shards)).astype(np.int64)


class RangeRouter:
    policy = "range"

    def __init__(self, n_shards: int, key_space: int):
        self.n_shards = int(n_shards)
        self.key_space = int(key_space)
        if self.key_space < self.n_shards:
            raise ValueError("key_space must be >= n_shards")
        # upper bound (exclusive) of shard i is bounds[i]; last is implicit
        self.bounds = np.array(
            [(i + 1) * self.key_space // self.n_shards
             for i in range(self.n_shards - 1)], np.uint64)

    def shard_of(self, keys: np.ndarray) -> np.ndarray:
        ks = np.asarray(keys, np.uint64)
        return np.searchsorted(self.bounds, ks, side="right").astype(np.int64)

    def shard_start(self, shard: int) -> int:
        """Lowest key owned by ``shard`` (scan-continuation entry point)."""
        return 0 if shard == 0 else int(self.bounds[shard - 1])


def make_router(policy: str, n_shards: int, key_space: int | None = None):
    if policy == "hash":
        return HashRouter(n_shards)
    if policy == "range":
        if key_space is None:
            raise ValueError("range policy requires key_space "
                             "(upper bound of the dense key domain)")
        return RangeRouter(n_shards, key_space)
    raise ValueError(f"unknown shard policy {policy!r} (want one of "
                     f"{POLICIES})")


def scatter(shard_of: np.ndarray, n_shards: int):
    """Group a routed column by shard.

    Returns ``(order, starts, ends)``: ``order`` is a stable permutation
    putting rows of the same shard adjacent (original relative order kept,
    so per-shard sub-batches preserve WriteBatch append semantics);
    ``order[starts[s]:ends[s]]`` are the original-row indices of shard
    ``s``.  Writing results back through those indices restores original
    batch order.
    """
    order = np.argsort(shard_of, kind="stable")
    srt = shard_of[order]
    ids = np.arange(n_shards, dtype=np.int64)
    starts = np.searchsorted(srt, ids, side="left")
    ends = np.searchsorted(srt, ids, side="right")
    return order, starts, ends
