"""N-way shard replication + primary failover (DESIGN.md §14).

Each primary shard owns a ``ShardReplicator``: every shard-level op the
fleet dispatches to the primary — write batches, vid-preserving ingests,
reads, scans, flushes — is appended to a per-shard replication log in the
WAL record format (``durability/wal.py``), then applied to ``N`` replica
Stores through ``replay_into``.  Replicas are plain standalone ``Store``
objects on their own simulated devices, off the fleet's client critical
path: replica ``rank r`` lags the log tail by ``r * replica_lag_ops``
records (rank 0 is synchronous), modelling a replication pipeline whose
followers are progressively further behind.

Because vid minting and background scheduling are pure functions of the
per-shard op stream (§9), a replica that has applied the full log is
byte-identical to a fresh Store replaying that log — the golden-parity
contract ``tests/test_elastic_fleet.py`` locks down after failover.

``fail_primary`` promotes the most-caught-up replica: replay the log tail
it hasn't applied, swap it into the fleet (scheduler slot, observer
registration, durability directory), and log a ``replica_promote`` edit.
When the fleet is durable the log is additionally persisted to
``replog-<shard>-<epoch>.log`` segments beside the fleet WAL; a crash
loses replica *lag state*, not data — recovery re-seeds replicas from the
recovered primary via an in-memory snapshot round trip.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np

from ..durability.snapshot import restore_store, snapshot_state
from ..durability.wal import WalWriter, replay_into


class ShardReplicator:
    """Replication log + replica set for one primary shard."""

    def __init__(self, cfg, count: int, lag_ops: int,
                 durability_root: Path | None = None, shard_id: int = 0,
                 wal_epoch: int = 0):
        from ..store import Store      # lazy: sharding <- store cycle
        # replicas are independent machines: fresh SimIO each, no observer
        # (the fleet's ledger tracks primaries only)
        rcfg = dataclasses.replace(cfg, observer=None)
        self.replicas = [Store(rcfg) for _ in range(count)]
        self.log: list[tuple] = []
        self.applied = [0] * count
        self.lag = [r * lag_ops for r in range(count)]
        self._idx = 0
        self._wal: WalWriter | None = None
        if durability_root is not None and count:
            self._wal = WalWriter(
                Path(durability_root)
                / f"replog-{shard_id:02d}-{wal_epoch:06d}.log")

    # ------------------------------------------------------------- logging
    def log_batch(self, kinds, keys, vsizes) -> None:
        self._idx += 1
        self.log.append(("b", self._idx, 0, np.asarray(kinds, np.uint8),
                         np.asarray(keys, np.uint64),
                         np.asarray(vsizes, np.int64)))
        if self._wal is not None:
            self._wal.append_batch(self._idx, 0, kinds, keys, vsizes)

    def log_ingest(self, kinds, keys, vids, vsizes) -> None:
        self._idx += 1
        self.log.append(("i", self._idx, np.asarray(kinds, np.uint8),
                         np.asarray(keys, np.uint64),
                         np.asarray(vids, np.uint64),
                         np.asarray(vsizes, np.int64)))
        if self._wal is not None:
            self._wal.append_ingest(self._idx, kinds, keys, vids, vsizes)

    def log_reads(self, keys) -> None:
        self._idx += 1
        self.log.append(("r", self._idx, np.asarray(keys, np.uint64)))
        if self._wal is not None:
            self._wal.append_reads(self._idx, keys)

    def log_scans(self, starts, counts) -> None:
        self._idx += 1
        self.log.append(("s", self._idx, np.asarray(starts, np.int64),
                         np.asarray(counts, np.int64)))
        if self._wal is not None:
            self._wal.append_scans(self._idx, starts, counts)

    def log_flush(self) -> None:
        self._idx += 1
        self.log.append(("f", self._idx))
        if self._wal is not None:
            self._wal.append_flush(self._idx)

    # ------------------------------------------------------------ applying
    def poll(self) -> None:
        """Advance each replica to its lag-bounded target position."""
        for r, rep in enumerate(self.replicas):
            target = len(self.log) - self.lag[r]
            if target > self.applied[r]:
                replay_into(rep, self.log[self.applied[r]:target])
                self.applied[r] = target

    def best(self) -> int:
        """Rank of the most-caught-up replica (ties -> lowest rank)."""
        if not self.replicas:
            raise ValueError("no replicas to promote")
        return max(range(len(self.replicas)),
                   key=lambda r: (self.applied[r], -r))

    def promote(self, rank: int):
        """Catch the replica up on the full log and remove it from the
        replica set; the caller swaps it in as the new primary."""
        rep = self.replicas[rank]
        replay_into(rep, self.log[self.applied[rank]:])
        self.replicas.pop(rank)
        self.applied.pop(rank)
        self.lag.pop(rank)
        return rep

    def reseed_from(self, primary) -> None:
        """Rebuild every replica as a byte-identical clone of ``primary``
        (post-recovery: the persisted replog's lag state is not restored —
        a crash loses lag, not data; DESIGN.md §14)."""
        meta, arrays = snapshot_state(primary)
        self.replicas = [restore_store(meta, arrays)
                         for _ in self.replicas]
        for rep in self.replicas:
            # the clone inherits the primary's journal watermark; replica
            # log indexes restart at 1, so reset it or replay skips them
            rep.wal_index = 0
            rep.durability = None
        self.log.clear()
        self.applied = [0] * len(self.replicas)
        self._idx = 0

    def close(self) -> None:
        if self._wal is not None:
            self._wal.close()
