"""ShardedStore: N independent Store shards behind one batched Store API
(DESIGN.md §6), with live elasticity — online shard split/merge, N-way
replication, and primary failover (DESIGN.md §14).

The keyspace is partitioned across shards by a router (hash or range,
``router.py``); the PR-1 batched API (``write`` / ``multi_get`` /
``multi_scan``) is routed by one vectorized scatter-by-shard pass and
results are reassembled in original batch order.  Background GC/compaction
service is *not* per-shard: every shard's ``pump()`` delegates to one
``FleetScheduler`` (``fleet.py``) that ranks pending jobs fleet-wide under
shared lane and space budgets.

Semantics:

  * A ``WriteBatch`` splits into per-shard sub-batches, each applied
    atomically by its shard (one seq range / WAL append per shard touched).
    Records of the same key always land on the same shard, so last-write-
    wins inside a batch is preserved.
  * ``multi_scan`` is exact under the range policy (owning shard, spilling
    into successor *slices* in cut order until ``count`` is filled); under
    the hash policy keys interleave across shards, so each scan fans out to
    every shard and merges — correct but N-fold the I/O (this is why range
    is the policy for scan-heavy workloads).
  * ``n_shards=1`` is byte-identical to a plain ``Store`` — same clocks,
    stats, and scheduling decisions (asserted by ``tests/test_sharding.py``
    on all engines).  Elasticity off keeps every fleet byte-identical to
    the pre-elastic ShardedStore.

Elasticity (§14): an ``ElasticityManager`` (``migrate.py``) gets one step
per fleet op — always *between* shard sub-batches, never inside one — so
router-epoch bumps only happen at dispatch boundaries.  Dispatch is
epoch-stamped: each write/read worklist snapshots ``router.epoch``, and a
bump observed mid-batch re-routes the not-yet-applied rows
(``redispatches`` counts these).  All shard-level ops flow through the
``_shard_*`` wrappers, which also feed each primary's replication log
(``replica.py``) so ``fail_primary`` can promote a caught-up replica.

Stats aggregate across shards: sums for byte/op counters (including
merge-retired shards, whose history remains part of the fleet's), ratios
recomputed from fleet-wide numerators/denominators, ``clock_s`` as the max
shard clock (shards run concurrently).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..batch import ScalarOps, WriteBatch
from ..engine.config import EngineConfig
from ..engine.tables import ETYPE_NONE
from ..store import Store
from .fleet import FleetScheduler
from .migrate import ElasticityManager
from .replica import ShardReplicator
from .router import HashRouter, make_router, restore_router, scatter


class FleetClock:
    """Read-only SimIO facade over the shard SimIOs (Runner/benchmark
    contract): clocks are the slowest shard's (shards run concurrently);
    byte/op/time counters sum across shards."""

    def __init__(self, shards):
        self._shards = shards

    @property
    def clock_us(self) -> float:
        return max(s.io.clock_us for s in self._shards)

    @property
    def fg_clock_us(self) -> float:
        return max(s.io.fg_clock_us for s in self._shards)

    def _summed(self, field: str) -> dict:
        out: dict = {}
        for s in self._shards:
            for k, v in getattr(s.io, field).items():
                out[k] = out.get(k, 0) + v
        return out

    @property
    def time_us(self) -> dict:
        return self._summed("time_us")

    @property
    def read_bytes(self) -> dict:
        return self._summed("read_bytes")

    @property
    def write_bytes(self) -> dict:
        return self._summed("write_bytes")

    @property
    def read_ops(self) -> dict:
        return self._summed("read_ops")

    @property
    def write_ops(self) -> dict:
        return self._summed("write_ops")

    def total_read_bytes(self) -> int:
        return sum(s.io.total_read_bytes() for s in self._shards)

    def total_write_bytes(self) -> int:
        return sum(s.io.total_write_bytes() for s in self._shards)

    def gc_time_us(self) -> float:
        return sum(s.io.gc_time_us() for s in self._shards)


class ShardedStore(ScalarOps):
    def __init__(self, cfg: EngineConfig, n_shards: int = 1,
                 shard_policy: str = "range", key_space: int | None = None,
                 scheduler: str = "fleet", aging_rate: float = 0.05,
                 durability_dir=None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.cfg = cfg
        self.n_shards = int(n_shards)
        self.shard_policy = shard_policy
        self.key_space = key_space
        self.scheduler_policy = scheduler
        self.aging_rate = float(aging_rate)
        # fleet-wide space quota: shards run quota-free, the fleet enforces
        # the shared budget (single-shard stores keep Store's own path so
        # n_shards=1 stays byte-identical to Store)
        fleet_quota = None
        shard_cfg = cfg
        if self.n_shards > 1 and cfg.space_quota_bytes is not None:
            fleet_quota = cfg.space_quota_bytes
            shard_cfg = dataclasses.replace(cfg, space_quota_bytes=None)
        self._shard_cfg = shard_cfg
        self.shards = [Store(dataclasses.replace(shard_cfg))
                       for _ in range(self.n_shards)]
        # stable identity per shard machine (durability dir, replication
        # log, migration edits): survives position shifts from merges
        self.next_shard_id = self.n_shards
        for i, s in enumerate(self.shards):
            s.shard_id = i
        # merge-retired shards: out of routing/scheduling, kept for fleet
        # counter continuity (their history happened on this fleet)
        self.retired: list[Store] = []
        self._all_shards = list(self.shards)    # live + retired, for io
        elastic_on = (cfg.elastic_split_frac is not None
                      or cfg.elastic_merge_frac > 0)
        if self.n_shards == 1 and key_space is None and not elastic_on:
            self.router = HashRouter(1)
        else:
            self.router = make_router(shard_policy, self.n_shards,
                                      key_space)
        self.fleet = FleetScheduler(
            self.shards, policy=scheduler, aging_rate=aging_rate,
            space_quota_bytes=fleet_quota,
            soft_quota_frac=cfg.soft_quota_frac)
        self.io = FleetClock(self._all_shards)
        # Fleet-level observability hook (DESIGN.md §11): shares the shards'
        # observer (same ref after dataclasses.replace) but is NOT registered
        # as a store — FleetClock has no lanes to tile; per-shard spans carry
        # the timing, the fleet only emits fleet-scoped op metrics.
        self.obs = self.shards[0].obs
        self.obs_label = "fleet"
        # Elasticity bookkeeping (§14)
        self.migrations: list[dict] = []
        self.redispatches = 0
        self._crash_hooks: dict | None = None
        # Fleet durability (DESIGN.md §9): one fleet-level op journal (the
        # scheduler is fleet-wide, so replay must re-route batches through
        # the fleet, not per shard) + one manifest/snapshot dir per shard.
        self.durability = None
        self.wal_index = 0
        if durability_dir is not None:
            from ..durability import Durability
            from pathlib import Path
            root = Path(durability_dir)
            self.durability = Durability.create(
                root, cfg, wal=True,
                meta={"fleet": {"n_shards": self.n_shards,
                                "shard_policy": shard_policy,
                                "key_space": key_space,
                                "scheduler": scheduler,
                                "aging_rate": aging_rate}})
            for s in self.shards:
                s.durability = Durability.create(
                    root / f"shard-{s.shard_id:02d}", s.cfg, wal=False)
        # N-way replication (§14): one replicator per live primary
        self.replicators: dict[int, ShardReplicator] = {}
        if cfg.replica_count > 0:
            for s in self.shards:
                self.replicators[s.shard_id] = self._make_replicator(s)
        self.elastic = ElasticityManager(self)

    def _make_replicator(self, shard) -> ShardReplicator:
        root = self.durability.root if self.durability is not None else None
        epoch = self.durability.epoch if self.durability is not None else 0
        return ShardReplicator(
            shard.cfg, self.cfg.replica_count, self.cfg.replica_lag_ops,
            durability_root=root, shard_id=shard.shard_id, wal_epoch=epoch)

    # ================================================================== API
    # (scalar put/get/delete/scan come from the shared ScalarOps shims)

    # ------------------------------------------------- shard-op dispatchers
    # Every op a primary shard runs flows through these wrappers: they feed
    # the shard's replication log, give the elasticity manager its write
    # mirror + traffic signal, and are the units the epoch-stamped dispatch
    # loops retry (§14).
    def _rep(self, pos: int) -> ShardReplicator | None:
        if not self.replicators:
            return None
        return self.replicators.get(self.shards[pos].shard_id)

    def _shard_write(self, pos, kinds, keys, vsizes) -> np.ndarray:
        vids = self.shards[pos]._write_arrays(kinds, keys, vsizes)
        rep = self._rep(pos)
        if rep is not None:
            rep.log_batch(kinds, keys, vsizes)
            rep.poll()
        if self.elastic is not None:
            self.elastic.note_write(pos, kinds, keys, vids, vsizes)
            self.elastic.note_traffic(pos, len(keys))
        return vids

    def _shard_ingest(self, pos, kinds, keys, vids, vsizes) -> None:
        self.shards[pos].ingest_batch(kinds, keys, vids, vsizes)
        rep = self._rep(pos)
        if rep is not None:
            rep.log_ingest(kinds, keys, vids, vsizes)
            rep.poll()

    def _shard_get(self, pos, keys) -> dict:
        res = self.shards[pos].multi_get(keys)
        rep = self._rep(pos)
        if rep is not None:
            rep.log_reads(keys)
            rep.poll()
        if self.elastic is not None:
            self.elastic.note_traffic(pos, len(keys))
        return res

    def _shard_scan(self, pos, starts, counts) -> list:
        res = self.shards[pos].multi_scan(starts, counts)
        rep = self._rep(pos)
        if rep is not None:
            rep.log_scans(starts, counts)
            rep.poll()
        if self.elastic is not None:
            self.elastic.note_traffic(pos, len(starts))
        return res

    def _elastic_tick(self) -> None:
        """One elastic step per fleet op, taken *before* routing so a
        resulting epoch bump can never strand an in-flight sub-batch; inert
        (a no-op branch) when elasticity is off."""
        if self.elastic is not None:
            self.elastic.step()

    # ------------------------------------------------------- batched writes
    def write(self, batch: WriteBatch) -> np.ndarray:
        kinds, keys, vsizes = batch.arrays()
        return self._write_arrays(kinds, keys, vsizes)

    def _write_arrays(self, kinds, keys, vsizes) -> np.ndarray:
        n = len(keys)
        if n == 0:
            return np.zeros(0, np.uint64)
        if self.durability is not None:
            # fleet journal: replay re-routes through the fleet so the
            # scheduler sees the same global op stream (DESIGN.md §9).
            # seq_base is 0: sequence numbers are per-shard, the fleet has
            # no single seq domain (replay keys off the op index alone)
            self.wal_index += 1
            self.durability.log_batch(self.wal_index, 0,
                                      kinds, keys, vsizes)
        self._elastic_tick()
        self._fleet_write_pressure()
        if len(self.shards) == 1:
            return self._shard_write(0, kinds, keys, vsizes)
        vids_out = np.zeros(n, np.uint64)
        pending = np.arange(n)
        while len(pending):
            # epoch-stamped dispatch: route against one router snapshot; a
            # bump observed mid-batch (migration finalized under our feet)
            # invalidates the remaining sub-batches, which re-route (§14)
            e0 = self.router.epoch
            sid = self.router.shard_of(keys[pending])
            order, starts, ends = scatter(sid, len(self.shards))
            done = np.zeros(len(pending), bool)
            for s in range(len(self.shards)):
                rows = order[starts[s]:ends[s]]
                if len(rows) == 0:
                    continue
                idx = pending[rows]
                vids_out[idx] = self._shard_write(
                    s, kinds[idx], keys[idx], vsizes[idx])
                done[rows] = True
                if self.router.epoch != e0:
                    break
            pending = pending[~done]
            if len(pending):
                self.redispatches += 1
        return vids_out

    def _fleet_write_pressure(self) -> None:
        """Space-aware throttling against the shared fleet quota (the
        fleet analogue of ``Store._write_pressure``)."""
        quota = self.fleet.space_quota_bytes
        if quota is None:
            return
        space = self.fleet.space_bytes()
        if space < self.fleet.soft_quota_frac * quota:
            return
        if space >= quota:
            # writers stall while the globally best GC jobs force-run; the
            # foreground time each job adds (run_one syncs the owning
            # shard's lanes to its fg clock) is charged as stall, matching
            # Store._stall_while's accounting
            before = [s.io.fg_clock_us for s in self.shards]
            for _ in range(self.shards[0].cfg.quota_stall_rounds):
                if self.fleet.space_bytes() < quota:
                    break
                if not self.fleet.run_one(prefer_gc=True):
                    break
            for s, b in zip(self.shards, before):
                stalled = s.io.fg_clock_us - b
                s.stall_us += stalled
                s.obs.on_stall(s, stalled, "write_stall")
        else:
            # one slowdown per write call (Store semantics), charged to the
            # shard holding the fleet wall clock so aggregate stall_s stays
            # comparable between --shards 1 and --shards N runs
            s = max(self.shards, key=lambda s: s.io.fg_clock_us)
            with s.obs.span(s, "quota_slowdown",
                            cause={"trigger": "quota_stall"}):
                s.io.stall(s.cfg.slowdown_us_per_write)
            s.stall_us += s.cfg.slowdown_us_per_write
            s.obs.on_stall(s, s.cfg.slowdown_us_per_write, "quota_slowdown")
            self.fleet.pump()

    # -------------------------------------------------------- batched reads
    def multi_get(self, keys: np.ndarray) -> dict:
        keys = np.atleast_1d(np.asarray(keys, np.uint64))
        if self.durability is not None:
            self.wal_index += 1
            self.durability.log_reads(self.wal_index, keys)
        self._elastic_tick()
        if len(self.shards) == 1:
            return self._shard_get(0, keys)
        n = len(keys)
        out = {"found": np.zeros(n, bool),
               "vid": np.zeros(n, np.uint64),
               "vsize": np.zeros(n, np.int64),
               "etype": np.full(n, ETYPE_NONE, np.uint8)}
        pending = np.arange(n)
        while len(pending):
            e0 = self.router.epoch
            sid = self.router.shard_of(keys[pending])
            order, starts, ends = scatter(sid, len(self.shards))
            done = np.zeros(len(pending), bool)
            for s in range(len(self.shards)):
                rows = order[starts[s]:ends[s]]
                if len(rows) == 0:
                    continue
                idx = pending[rows]
                res = self._shard_get(s, keys[idx])
                for f in out:
                    out[f][idx] = res[f]
                done[rows] = True
                if self.router.epoch != e0:
                    break
            pending = pending[~done]
            if len(pending):
                self.redispatches += 1
        return out

    def multi_scan(self, starts: np.ndarray, count) -> list:
        starts = np.atleast_1d(np.asarray(starts)).astype(np.int64)
        counts = np.broadcast_to(np.asarray(count, np.int64), starts.shape)
        if self.durability is not None:
            self.wal_index += 1
            self.durability.log_scans(self.wal_index, starts, counts)
        self._elastic_tick()
        while True:
            e0 = self.router.epoch
            if len(self.shards) == 1:
                out = self._shard_scan(0, starts, counts)
            elif self.router.policy == "hash":
                out = self._multi_scan_fanout(starts, counts)
            else:
                out = self._multi_scan_range(starts, counts)
            if self.router.epoch == e0:
                return out
            # a migration finalized mid-scan: the slice walk below may have
            # consulted a stale topology — re-run the whole (idempotent)
            # scan against the new epoch
            self.redispatches += 1

    def _multi_scan_fanout(self, starts, counts) -> list:
        """Hash policy: keys interleave across shards, so every scan asks
        every shard and merges (keys are disjoint across shards, so the
        merge is a sort-by-key concat truncated to count)."""
        per_shard = [self._shard_scan(s, starts, counts)
                     for s in range(len(self.shards))]
        out = []
        for i, c in enumerate(counts.tolist()):
            merged = sorted(
                (pair for res in per_shard for pair in res[i]))
            out.append(merged[:int(c)])
        return out

    def _multi_scan_range(self, starts, counts) -> list:
        """Range policy: scan the owning shard, spill into successor
        *slices* in cut order (every key of a later slice is larger) until
        count is filled.  Spills walk the slice table — not shard indexes,
        which stop tracking key order once a split appends a shard (§14) —
        all still-unfilled scans batched into one multi_scan per successor
        so the deep-queue I/O window is kept."""
        router = self.router
        u_starts = starts.astype(np.uint64)
        sid = router.shard_of(u_starts)
        sl = router.slice_of(u_starts)
        order, s_starts, s_ends = scatter(sid, len(self.shards))
        out: list = [None] * len(starts)
        for s in range(len(self.shards)):
            rows = order[s_starts[s]:s_ends[s]]
            if len(rows) == 0:
                continue
            res = self._shard_scan(s, starts[rows], counts[rows])
            for r, got in zip(rows.tolist(), res):
                out[r] = got
        cnt = counts.tolist()
        for j in range(1, router.n_slices):
            need = [i for i in range(len(starts))
                    if sl[i] < j and len(out[i]) < cnt[i]]
            if not need:
                continue
            rem = np.array([cnt[i] - len(out[i]) for i in need], np.int64)
            more = self._shard_scan(router.owners[j], starts[need], rem)
            for i, got in zip(need, more):
                out[i] = out[i] + got
        return out

    # ===================================================== background lanes
    def pump(self) -> None:
        self.fleet.pump()

    def settle(self) -> None:
        self.fleet.pump()

    def drain(self) -> None:
        """Run all pending work: any in-flight migration completes first
        (a drained fleet has a settled topology), then the fleet drains."""
        if self.elastic is not None:
            self.elastic.quiesce()
        self.fleet.drain()

    def flush(self) -> None:
        """Force-rotate every shard's memtable, then drain the fleet."""
        if self.durability is not None:
            self.wal_index += 1
            self.durability.log_flush(self.wal_index)
        for pos in range(len(self.shards)):
            rep = self._rep(pos)
            if rep is not None:
                rep.log_flush()
                rep.poll()
            self.shards[pos].rotate_memtable()
        self.fleet.drain()

    # ======================================= elastic topology (DESIGN.md §14)
    def _spawn_shard(self) -> int:
        """Create and attach a fresh shard (split destination); returns its
        fleet position."""
        from ..durability import Durability
        s = Store(dataclasses.replace(self._shard_cfg))
        s.shard_id = self.next_shard_id
        self.next_shard_id += 1
        self.shards.append(s)
        self._all_shards.append(s)
        self.fleet.add_shard(s)
        self.n_shards = len(self.shards)
        if self.durability is not None:
            sdir = self.durability.root / f"shard-{s.shard_id:02d}"
            if (sdir / Durability.MANIFEST).exists():
                # journal replay re-derives splits over dirs the pre-crash
                # run already created: re-attach, don't re-create
                s.durability = Durability.attach(sdir, wal=False)
            else:
                s.durability = Durability.create(sdir, s.cfg, wal=False)
        if self.cfg.replica_count > 0:
            self.replicators[s.shard_id] = self._make_replicator(s)
        return len(self.shards) - 1

    def _retire_shard(self, pos: int) -> None:
        """Detach a drained merge victim from routing/scheduling.  The
        Store object stays in the fleet's counter aggregation (its history
        happened here); its durability dir is frozen."""
        victim = self.shards.pop(pos)
        self.retired.append(victim)
        self.fleet.remove_shard(pos)
        self.router.renumber_removed(pos)
        self.n_shards = len(self.shards)
        rep = self.replicators.pop(victim.shard_id, None)
        if rep is not None:
            rep.close()
        victim.scheduler = None
        if victim.durability is not None:
            victim.durability.close()
            victim.durability = None

    def split_shard(self, pos: int, cut: int | None = None) -> int | None:
        """Synchronously split shard ``pos``'s slice at ``cut`` (default:
        median live routing value): checkpoint-copy, re-route, delta-replay
        (§14).  Returns the new shard's position, or None if no valid cut
        exists."""
        if not self.elastic.begin_split(pos, cut):
            return None
        dst = self.elastic.mig.dst_pos
        self.elastic.quiesce()
        return dst

    def merge_shards(self, victim: int, into: int | None = None) -> bool:
        """Synchronously drain shard ``victim`` into the adjacent-slice
        shard ``into`` (default: the emptier neighbor) and retire it."""
        if not self.elastic.begin_merge(victim, into):
            return False
        self.elastic.quiesce()
        return True

    def fail_primary(self, pos: int) -> Store:
        """Kill shard ``pos``'s primary and promote its most-caught-up
        replica: replay the log tail the replica hasn't applied, swap it
        into the fleet (scheduler slot, observer, durability dir), and log
        a ``replica_promote`` edit (§14).  The failed machine's counters
        die with it; the promoted store's history is the replayed op
        stream."""
        prim = self.shards[pos]
        rep = self.replicators.get(prim.shard_id)
        if rep is None or not rep.replicas:
            raise ValueError(f"shard {pos} has no replicas to promote "
                             "(cfg.replica_count)")
        self._crashpoint("pre_promote")
        rank = rep.best()
        applied = rep.applied[rank]
        promoted = rep.promote(rank)
        promoted.shard_id = prim.shard_id
        promoted.scheduler = self.fleet
        self.shards[pos] = promoted
        self.fleet.shards[pos] = promoted
        self._all_shards[self._all_shards.index(prim)] = promoted
        prim.scheduler = None
        promoted.obs = self.obs
        promoted.obs_label = self.obs.register_store(promoted)
        if self.durability is not None:
            from ..durability import Durability
            if prim.durability is not None:
                prim.durability.close()
                prim.durability = None
            promoted.durability = Durability.attach(
                self.durability.root / f"shard-{promoted.shard_id:02d}",
                wal=False)
        self._log_fleet_edit("replica_promote", shard=promoted.shard_id,
                             replica=rank, applied=applied,
                             tail=len(rep.log) - applied)
        self.obs.instant(promoted, "replica_promote",
                         shard=promoted.shard_id, replica=rank)
        return promoted

    # ------------------------------------------------------ crash injection
    def arm_crash(self, point: str, hits: int = 1) -> None:
        """Crash-injection at the fleet-level hooks (migration/failover
        points of ``durability.CRASH_POINTS``, §14)."""
        from ..durability import CRASH_POINTS
        if point not in CRASH_POINTS:
            raise ValueError(f"unknown crash point {point!r} "
                             f"(want one of {CRASH_POINTS})")
        if self._crash_hooks is None:
            self._crash_hooks = {}
        self._crash_hooks[point] = int(hits)

    def _crashpoint(self, point: str) -> None:
        hooks = self._crash_hooks
        if hooks is None:
            return
        left = hooks.get(point)
        if left is None:
            return
        if left <= 1:
            del hooks[point]            # disarm: the process died here once
            from ..durability import CrashPoint
            raise CrashPoint(point)
        hooks[point] = left - 1

    def _log_fleet_edit(self, kind: str, **data) -> None:
        """Append a fleet-MANIFEST VersionEdit, byte cost reported to the
        observer ledger (the fleet analogue of ``Store._log_edit``)."""
        if self.durability is not None:
            before = self.durability.manifest.bytes_written
            self.durability.log_edit(kind, **data)
            self.obs.on_edit(self.shards[0], kind,
                             self.durability.manifest.bytes_written - before)

    # ========================================= durability (DESIGN.md §9)
    def checkpoint(self) -> None:
        """Fleet checkpoint: snapshot every shard, bump the fleet epoch,
        roll the fleet journal, and record scheduler state + watermarks +
        topology (router state, shard ids) in the fleet MANIFEST (per-shard
        manifests record their own checkpoint edits).  An in-flight
        migration is quiesced first — checkpoints only describe settled
        topologies (§14)."""
        if self.durability is None:
            raise ValueError("ShardedStore has no durability directory")
        if self.elastic is not None:
            self.elastic.quiesce()
        # record the exact snapshot files in the fleet edit: a crash
        # between the per-shard snapshots and the fleet edit must not let
        # recovery pair newer shard snapshots with an older fleet
        # watermark (that would double-apply the WAL tail)
        snaps = [s.durability.checkpoint(s).name for s in self.shards]
        self.fleet.epoch += 1
        self.durability.roll_segment()
        self.durability.log_edit(
            "fleet_checkpoint", epoch=self.fleet.epoch,
            wal_epoch=self.durability.epoch, wal_index=self.wal_index,
            shard_snaps=snaps, scheduler=self.fleet.state_dict(),
            router=self.router.state_dict(),
            shard_ids=[s.shard_id for s in self.shards],
            next_shard_id=self.next_shard_id)

    def close(self) -> None:
        for rep in self.replicators.values():
            rep.close()
        if self.durability is not None:
            self.durability.close()
            for s in self.shards:
                s.close()

    @classmethod
    def open(cls, path, observer=None) -> "ShardedStore":
        """Recover a fleet: rebuild the ShardedStore from the fleet
        MANIFEST, restore the checkpointed topology (router state + one
        snapshot per live shard id) plus the scheduler state at the same
        fleet epoch, then replay the fleet journal tail through the fleet
        write path — re-deriving any migrations the tail triggers, exactly
        as the original run did (§14).  With ``n_shards=1`` the result is
        byte-identical to single-``Store`` recovery (``tests/
        test_durability.py``).

        Replicas are not recovered from the persisted replication logs:
        after replay they are re-seeded as clones of their recovered
        primaries (a crash loses replica *lag state*, not data, §14).

        ``observer`` (repro.obs, DESIGN.md §11) attaches to every recovered
        shard before replay so the replayed ops emit spans."""
        from pathlib import Path
        from ..durability import (Durability, read_manifest, read_wal,
                                  replay_into, snapshot as dsnap)
        root = Path(path)
        edits = read_manifest(root / Durability.MANIFEST)
        if not edits:
            raise FileNotFoundError(f"no durable fleet at {root}")
        cfg_edit = next(e for e in edits if e.kind == "config")
        fl = cfg_edit.data["fleet"]
        self = cls(EngineConfig(**cfg_edit.data["cfg"]),
                   n_shards=fl["n_shards"], shard_policy=fl["shard_policy"],
                   key_space=fl["key_space"], scheduler=fl["scheduler"],
                   aging_rate=fl["aging_rate"])
        # replicators re-seed after replay; drop the fresh ones so replay
        # doesn't feed logs that get discarded anyway
        for rep in self.replicators.values():
            rep.close()
        self.replicators = {}
        ckpts = [e for e in edits if e.kind == "fleet_checkpoint"]
        wal_from = 0
        if ckpts:
            ck = ckpts[-1]
            snaps = ck.data["shard_snaps"]
            sids = [int(x) for x in
                    ck.data.get("shard_ids", range(len(snaps)))]
            if "router" in ck.data:
                self.router = restore_router(ck.data["router"])
            self.next_shard_id = int(ck.data.get("next_shard_id",
                                                 len(sids)))
            new_shards = []
            for sid, snap in zip(sids, snaps):
                # restore the snapshot the fleet edit names, NOT the
                # shard's newest one — a crash mid-fleet-checkpoint leaves
                # newer shard snapshots with no matching fleet watermark
                shard = dsnap.restore(root / f"shard-{sid:02d}" / snap)
                shard.shard_id = sid
                shard.scheduler = self.fleet
                new_shards.append(shard)
            # rebuild topology in place: FleetClock/scheduler hold refs to
            # these lists
            self.shards[:] = new_shards
            self._all_shards[:] = new_shards
            self.fleet.shards[:] = new_shards
            self.n_shards = len(new_shards)
            self.fleet.load_state(ck.data["scheduler"])
            self.wal_index = int(ck.data["wal_index"])
            wal_from = int(ck.data["wal_epoch"])
        if observer is not None:
            for s in self.shards:
                s.obs = observer
                s.obs_label = observer.register_store(s)
            self.obs = observer
            # fleet recovery timeline, mirroring durability.recover_store:
            # fleet-level instants land on shard 0's track, the per-shard
            # snapshot restores on each shard's own
            self.obs.instant(self.shards[0], "recovery_begin",
                             src=str(root))
            if ckpts:
                for i, s in enumerate(self.shards):
                    self.obs.instant(s, "checkpoint_restored",
                                     file=ck.data["shard_snaps"][i],
                                     wal_epoch=wal_from)
        for e in edits:
            if e.kind == "wal_segment" and int(e.data["epoch"]) >= wal_from:
                records = read_wal(root / e.data["file"])
                self.obs.instant(self.shards[0], "replay_segment",
                                 file=e.data["file"],
                                 n_records=len(records))
                replay_into(self, records)
        self.obs.instant(self.shards[0], "recovery_end",
                         wal_index=int(self.wal_index))
        self.durability = Durability.attach(root, wal=True)
        for s in self.shards:
            sdir = root / f"shard-{s.shard_id:02d}"
            if (sdir / Durability.MANIFEST).exists():
                s.durability = Durability.attach(sdir, wal=False)
            else:
                # replay re-derived a split the pre-crash run never got to
                # persist a directory for
                s.durability = Durability.create(sdir, s.cfg, wal=False)
        if self.cfg.replica_count > 0:
            for s in self.shards:
                rep = self._make_replicator(s)
                rep.reseed_from(s)
                self.replicators[s.shard_id] = rep
        return self

    # ================================================================ stats
    # Byte/op counters span live + merge-retired shards (that history
    # happened on this fleet); space metrics span live shards only (the
    # retired copy of moved data is garbage, not fleet space).
    @property
    def valid_bytes(self) -> int:
        return sum(s.valid_bytes for s in self.shards)

    @property
    def user_write_bytes(self) -> int:
        return sum(s.user_write_bytes for s in self.shards + self.retired)

    @property
    def n_gc_runs(self) -> int:
        return sum(s.n_gc_runs for s in self.shards + self.retired)

    @property
    def n_compactions(self) -> int:
        return sum(s.n_compactions for s in self.shards + self.retired)

    @property
    def stall_us(self) -> float:
        return sum(s.stall_us for s in self.shards + self.retired)

    def space_bytes(self) -> int:
        return sum(s.space_bytes() for s in self.shards)

    def space_amplification(self) -> float:
        return self.space_bytes() / max(self.valid_bytes, 1)

    def s_index(self) -> float:
        """Fleet index space-amp: total kSST bytes over total last-level
        bytes (aggregated numerator/denominator, not a mean of ratios)."""
        tot = sum(s.version.ksst_total_bytes() for s in self.shards)
        last = sum(s.version.level_bytes(s.version.last_nonempty_level())
                   for s in self.shards)
        return tot / max(last, 1)

    def exposed_over_valid(self) -> float:
        garbage = sum(s.version.value_garbage_bytes() for s in self.shards)
        ref_valid = max(sum(s.valid_value_bytes() for s in self.shards), 1)
        return garbage / ref_valid

    def valid_value_bytes(self) -> int:
        return sum(s.valid_value_bytes() for s in self.shards)

    def hidden_garbage_bytes(self) -> int:
        return sum(s.hidden_garbage_bytes() for s in self.shards)

    def migrated_bytes(self) -> int:
        return sum(m["bytes"] for m in self.migrations)

    def stats(self) -> dict:
        from ..engine import io as sio
        allstores = self.shards + self.retired
        ss = [s.stats() for s in allstores]
        wal = sum(s.io.write_bytes.get(sio.CAT_WAL, 0) for s in allstores)
        write_bytes = sum(st["write_bytes"] for st in ss)
        hits = sum(s.cache.hits for s in allstores)
        lookups = hits + sum(s.cache.misses for s in allstores)
        return {
            "engine": self.cfg.engine,
            "n_shards": len(self.shards),
            "shard_policy": self.shard_policy,
            "scheduler": self.fleet.policy,
            "clock_s": max(st["clock_s"] for st in ss),
            "space_bytes": self.space_bytes(),
            "valid_bytes": self.valid_bytes,
            "user_write_bytes": self.user_write_bytes,
            "space_amp": self.space_amplification(),
            "s_index": self.s_index(),
            "exposed_over_valid": self.exposed_over_valid(),
            "write_amp": (write_bytes - wal)
            / max(self.user_write_bytes, 1),
            "read_bytes": sum(st["read_bytes"] for st in ss),
            "write_bytes": write_bytes,
            "n_compactions": self.n_compactions,
            "n_gc_runs": self.n_gc_runs,
            "cache_hit_ratio": hits / lookups if lookups else 0.0,
            "stall_s": self.stall_us / 1e6,
            "gc_time_s": sum(st["gc_time_s"] for st in ss),
            "shard_space_amp": [st["space_amp"]
                                for st in ss[:len(self.shards)]],
            "router_epoch": self.router.epoch,
            "n_migrations": len(self.migrations),
        }
