"""ShardedStore: N independent Store shards behind one batched Store API
(DESIGN.md §6).

The keyspace is partitioned across shards by a router (hash or range,
``router.py``); the PR-1 batched API (``write`` / ``multi_get`` /
``multi_scan``) is routed by one vectorized scatter-by-shard pass and
results are reassembled in original batch order.  Background GC/compaction
service is *not* per-shard: every shard's ``pump()`` delegates to one
``FleetScheduler`` (``fleet.py``) that ranks pending jobs fleet-wide under
shared lane and space budgets.

Semantics:

  * A ``WriteBatch`` splits into per-shard sub-batches, each applied
    atomically by its shard (one seq range / WAL append per shard touched).
    Records of the same key always land on the same shard, so last-write-
    wins inside a batch is preserved.
  * ``multi_scan`` is exact under the range policy (owning shard, spilling
    into successor shards until ``count`` is filled); under the hash policy
    keys interleave across shards, so each scan fans out to every shard and
    merges — correct but N-fold the I/O (this is why range is the policy
    for scan-heavy workloads).
  * ``n_shards=1`` is byte-identical to a plain ``Store`` — same clocks,
    stats, and scheduling decisions (asserted by ``tests/test_sharding.py``
    on all five engines).

Stats aggregate across shards: sums for byte/op counters, ratios recomputed
from fleet-wide numerators/denominators, ``clock_s`` as the max shard clock
(shards run concurrently).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..batch import ScalarOps, WriteBatch
from ..engine.config import EngineConfig
from ..engine.tables import ETYPE_NONE
from ..store import Store
from .fleet import FleetScheduler
from .router import HashRouter, make_router, scatter


class FleetClock:
    """Read-only SimIO facade over the shard SimIOs (Runner/benchmark
    contract): clocks are the slowest shard's (shards run concurrently);
    byte/op/time counters sum across shards."""

    def __init__(self, shards):
        self._shards = shards

    @property
    def clock_us(self) -> float:
        return max(s.io.clock_us for s in self._shards)

    @property
    def fg_clock_us(self) -> float:
        return max(s.io.fg_clock_us for s in self._shards)

    def _summed(self, field: str) -> dict:
        out: dict = {}
        for s in self._shards:
            for k, v in getattr(s.io, field).items():
                out[k] = out.get(k, 0) + v
        return out

    @property
    def time_us(self) -> dict:
        return self._summed("time_us")

    @property
    def read_bytes(self) -> dict:
        return self._summed("read_bytes")

    @property
    def write_bytes(self) -> dict:
        return self._summed("write_bytes")

    @property
    def read_ops(self) -> dict:
        return self._summed("read_ops")

    @property
    def write_ops(self) -> dict:
        return self._summed("write_ops")

    def total_read_bytes(self) -> int:
        return sum(s.io.total_read_bytes() for s in self._shards)

    def total_write_bytes(self) -> int:
        return sum(s.io.total_write_bytes() for s in self._shards)

    def gc_time_us(self) -> float:
        return sum(s.io.gc_time_us() for s in self._shards)


class ShardedStore(ScalarOps):
    def __init__(self, cfg: EngineConfig, n_shards: int = 1,
                 shard_policy: str = "range", key_space: int | None = None,
                 scheduler: str = "fleet", aging_rate: float = 0.05,
                 durability_dir=None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.cfg = cfg
        self.n_shards = int(n_shards)
        self.shard_policy = shard_policy
        self.key_space = key_space
        self.aging_rate = float(aging_rate)
        # fleet-wide space quota: shards run quota-free, the fleet enforces
        # the shared budget (single-shard stores keep Store's own path so
        # n_shards=1 stays byte-identical to Store)
        fleet_quota = None
        shard_cfg = cfg
        if self.n_shards > 1 and cfg.space_quota_bytes is not None:
            fleet_quota = cfg.space_quota_bytes
            shard_cfg = dataclasses.replace(cfg, space_quota_bytes=None)
        self.shards = [Store(dataclasses.replace(shard_cfg))
                       for _ in range(self.n_shards)]
        self.router = (HashRouter(1) if self.n_shards == 1
                       else make_router(shard_policy, self.n_shards,
                                        key_space))
        self.fleet = FleetScheduler(
            self.shards, policy=scheduler, aging_rate=aging_rate,
            space_quota_bytes=fleet_quota,
            soft_quota_frac=cfg.soft_quota_frac)
        self.io = FleetClock(self.shards)
        # Fleet-level observability hook (DESIGN.md §11): shares the shards'
        # observer (same ref after dataclasses.replace) but is NOT registered
        # as a store — FleetClock has no lanes to tile; per-shard spans carry
        # the timing, the fleet only emits fleet-scoped op metrics.
        self.obs = self.shards[0].obs
        self.obs_label = "fleet"
        # Fleet durability (DESIGN.md §9): one fleet-level op journal (the
        # scheduler is fleet-wide, so replay must re-route batches through
        # the fleet, not per shard) + one manifest/snapshot dir per shard.
        self.durability = None
        self.wal_index = 0
        if durability_dir is not None:
            from ..durability import Durability
            from pathlib import Path
            root = Path(durability_dir)
            self.durability = Durability.create(
                root, cfg, wal=True,
                meta={"fleet": {"n_shards": self.n_shards,
                                "shard_policy": shard_policy,
                                "key_space": key_space,
                                "scheduler": scheduler,
                                "aging_rate": aging_rate}})
            for i, s in enumerate(self.shards):
                s.durability = Durability.create(
                    root / f"shard-{i:02d}", s.cfg, wal=False)

    # ================================================================== API
    # (scalar put/get/delete/scan come from the shared ScalarOps shims)

    # ------------------------------------------------------- batched writes
    def write(self, batch: WriteBatch) -> np.ndarray:
        kinds, keys, vsizes = batch.arrays()
        return self._write_arrays(kinds, keys, vsizes)

    def _write_arrays(self, kinds, keys, vsizes) -> np.ndarray:
        n = len(keys)
        if n == 0:
            return np.zeros(0, np.uint64)
        if self.durability is not None:
            # fleet journal: replay re-routes through the fleet so the
            # scheduler sees the same global op stream (DESIGN.md §9).
            # seq_base is 0: sequence numbers are per-shard, the fleet has
            # no single seq domain (replay keys off the op index alone)
            self.wal_index += 1
            self.durability.log_batch(self.wal_index, 0,
                                      kinds, keys, vsizes)
        self._fleet_write_pressure()
        if self.n_shards == 1:
            return self.shards[0]._write_arrays(kinds, keys, vsizes)
        sid = self.router.shard_of(keys)
        order, starts, ends = scatter(sid, self.n_shards)
        vids_out = np.zeros(n, np.uint64)
        for s in range(self.n_shards):
            rows = order[starts[s]:ends[s]]
            if len(rows) == 0:
                continue
            vids_out[rows] = self.shards[s]._write_arrays(
                kinds[rows], keys[rows], vsizes[rows])
        return vids_out

    def _fleet_write_pressure(self) -> None:
        """Space-aware throttling against the shared fleet quota (the
        fleet analogue of ``Store._write_pressure``)."""
        quota = self.fleet.space_quota_bytes
        if quota is None:
            return
        space = self.fleet.space_bytes()
        if space < self.fleet.soft_quota_frac * quota:
            return
        if space >= quota:
            # writers stall while the globally best GC jobs force-run; the
            # foreground time each job adds (run_one syncs the owning
            # shard's lanes to its fg clock) is charged as stall, matching
            # Store._stall_while's accounting
            before = [s.io.fg_clock_us for s in self.shards]
            for _ in range(self.shards[0].cfg.quota_stall_rounds):
                if self.fleet.space_bytes() < quota:
                    break
                if not self.fleet.run_one(prefer_gc=True):
                    break
            for s, b in zip(self.shards, before):
                stalled = s.io.fg_clock_us - b
                s.stall_us += stalled
                s.obs.on_stall(s, stalled, "write_stall")
        else:
            # one slowdown per write call (Store semantics), charged to the
            # shard holding the fleet wall clock so aggregate stall_s stays
            # comparable between --shards 1 and --shards N runs
            s = max(self.shards, key=lambda s: s.io.fg_clock_us)
            with s.obs.span(s, "quota_slowdown",
                            cause={"trigger": "quota_stall"}):
                s.io.stall(s.cfg.slowdown_us_per_write)
            s.stall_us += s.cfg.slowdown_us_per_write
            s.obs.on_stall(s, s.cfg.slowdown_us_per_write, "quota_slowdown")
            self.fleet.pump()

    # -------------------------------------------------------- batched reads
    def multi_get(self, keys: np.ndarray) -> dict:
        keys = np.atleast_1d(np.asarray(keys, np.uint64))
        if self.durability is not None:
            self.wal_index += 1
            self.durability.log_reads(self.wal_index, keys)
        if self.n_shards == 1:
            return self.shards[0].multi_get(keys)
        n = len(keys)
        sid = self.router.shard_of(keys)
        order, starts, ends = scatter(sid, self.n_shards)
        out = {"found": np.zeros(n, bool),
               "vid": np.zeros(n, np.uint64),
               "vsize": np.zeros(n, np.int64),
               "etype": np.full(n, ETYPE_NONE, np.uint8)}
        for s in range(self.n_shards):
            rows = order[starts[s]:ends[s]]
            if len(rows) == 0:
                continue
            res = self.shards[s].multi_get(keys[rows])
            for f in out:
                out[f][rows] = res[f]
        return out

    def multi_scan(self, starts: np.ndarray, count) -> list:
        starts = np.atleast_1d(np.asarray(starts)).astype(np.int64)
        counts = np.broadcast_to(np.asarray(count, np.int64), starts.shape)
        if self.durability is not None:
            self.wal_index += 1
            self.durability.log_scans(self.wal_index, starts, counts)
        if self.n_shards == 1:
            return self.shards[0].multi_scan(starts, counts)
        if self.router.policy == "hash":
            return self._multi_scan_fanout(starts, counts)
        return self._multi_scan_range(starts, counts)

    def _multi_scan_fanout(self, starts, counts) -> list:
        """Hash policy: keys interleave across shards, so every scan asks
        every shard and merges (keys are disjoint across shards, so the
        merge is a sort-by-key concat truncated to count)."""
        per_shard = [s.multi_scan(starts, counts) for s in self.shards]
        out = []
        for i, c in enumerate(counts.tolist()):
            merged = sorted(
                (pair for res in per_shard for pair in res[i]))
            out.append(merged[:int(c)])
        return out

    def _multi_scan_range(self, starts, counts) -> list:
        """Range policy: scan the owning shard, spill into successor shards
        (whose every key is larger) until count is filled.  Spills walk the
        shards in order, all still-unfilled scans batched into one
        multi_scan per successor shard so the deep-queue I/O window is
        kept."""
        sid = self.router.shard_of(starts.astype(np.uint64))
        order, s_starts, s_ends = scatter(sid, self.n_shards)
        out: list = [None] * len(starts)
        for s in range(self.n_shards):
            rows = order[s_starts[s]:s_ends[s]]
            if len(rows) == 0:
                continue
            res = self.shards[s].multi_scan(starts[rows], counts[rows])
            for r, got in zip(rows.tolist(), res):
                out[r] = got
        cnt = counts.tolist()
        for sh in range(1, self.n_shards):
            need = [i for i in range(len(starts))
                    if sid[i] < sh and len(out[i]) < cnt[i]]
            if not need:
                continue
            rem = np.array([cnt[i] - len(out[i]) for i in need], np.int64)
            more = self.shards[sh].multi_scan(starts[need], rem)
            for i, got in zip(need, more):
                out[i] = out[i] + got
        return out

    # ===================================================== background lanes
    def pump(self) -> None:
        self.fleet.pump()

    def settle(self) -> None:
        self.fleet.pump()

    def drain(self) -> None:
        self.fleet.drain()

    def flush(self) -> None:
        """Force-rotate every shard's memtable, then drain the fleet."""
        if self.durability is not None:
            self.wal_index += 1
            self.durability.log_flush(self.wal_index)
        for s in self.shards:
            s.rotate_memtable()
        self.fleet.drain()

    # ========================================= durability (DESIGN.md §9)
    def checkpoint(self) -> None:
        """Fleet checkpoint: snapshot every shard, bump the fleet epoch,
        roll the fleet journal, and record scheduler state + watermarks in
        the fleet MANIFEST (per-shard manifests record their own
        checkpoint edits)."""
        if self.durability is None:
            raise ValueError("ShardedStore has no durability directory")
        # record the exact snapshot files in the fleet edit: a crash
        # between the per-shard snapshots and the fleet edit must not let
        # recovery pair newer shard snapshots with an older fleet
        # watermark (that would double-apply the WAL tail)
        snaps = [s.durability.checkpoint(s).name for s in self.shards]
        self.fleet.epoch += 1
        self.durability.roll_segment()
        self.durability.log_edit(
            "fleet_checkpoint", epoch=self.fleet.epoch,
            wal_epoch=self.durability.epoch, wal_index=self.wal_index,
            shard_snaps=snaps, scheduler=self.fleet.state_dict())

    def close(self) -> None:
        if self.durability is not None:
            self.durability.close()
            for s in self.shards:
                s.close()

    @classmethod
    def open(cls, path, observer=None) -> "ShardedStore":
        """Recover a fleet: rebuild the ShardedStore from the fleet
        MANIFEST, restore every shard's latest snapshot plus the scheduler
        state at the same fleet epoch, then replay the fleet journal tail
        through the fleet write path.  With ``n_shards=1`` the result is
        byte-identical to single-``Store`` recovery (``tests/
        test_durability.py``).

        ``observer`` (repro.obs, DESIGN.md §11) attaches to every recovered
        shard before replay so the replayed ops emit spans."""
        from pathlib import Path
        from ..durability import (Durability, read_manifest, read_wal,
                                  replay_into, snapshot as dsnap)
        root = Path(path)
        edits = read_manifest(root / Durability.MANIFEST)
        if not edits:
            raise FileNotFoundError(f"no durable fleet at {root}")
        cfg_edit = next(e for e in edits if e.kind == "config")
        fl = cfg_edit.data["fleet"]
        self = cls(EngineConfig(**cfg_edit.data["cfg"]),
                   n_shards=fl["n_shards"], shard_policy=fl["shard_policy"],
                   key_space=fl["key_space"], scheduler=fl["scheduler"],
                   aging_rate=fl["aging_rate"])
        ckpts = [e for e in edits if e.kind == "fleet_checkpoint"]
        wal_from = 0
        if ckpts:
            ck = ckpts[-1]
            for i in range(self.n_shards):
                sdir = root / f"shard-{i:02d}"
                # restore the snapshot the fleet edit names, NOT the
                # shard's newest one — a crash mid-fleet-checkpoint leaves
                # newer shard snapshots with no matching fleet watermark
                shard = dsnap.restore(sdir / ck.data["shard_snaps"][i])
                shard.scheduler = self.fleet
                self.shards[i] = shard
                self.fleet.shards[i] = shard
            self.io = FleetClock(self.shards)
            self.fleet.load_state(ck.data["scheduler"])
            self.wal_index = int(ck.data["wal_index"])
            wal_from = int(ck.data["wal_epoch"])
        if observer is not None:
            for s in self.shards:
                s.obs = observer
                s.obs_label = observer.register_store(s)
            self.obs = observer
            # fleet recovery timeline, mirroring durability.recover_store:
            # fleet-level instants land on shard 0's track, the per-shard
            # snapshot restores on each shard's own
            self.obs.instant(self.shards[0], "recovery_begin",
                             src=str(root))
            if ckpts:
                for i, s in enumerate(self.shards):
                    self.obs.instant(s, "checkpoint_restored",
                                     file=ck.data["shard_snaps"][i],
                                     wal_epoch=wal_from)
        for e in edits:
            if e.kind == "wal_segment" and int(e.data["epoch"]) >= wal_from:
                records = read_wal(root / e.data["file"])
                self.obs.instant(self.shards[0], "replay_segment",
                                 file=e.data["file"],
                                 n_records=len(records))
                replay_into(self, records)
        self.obs.instant(self.shards[0], "recovery_end",
                         wal_index=int(self.wal_index))
        self.durability = Durability.attach(root, wal=True)
        for i, s in enumerate(self.shards):
            s.durability = Durability.attach(root / f"shard-{i:02d}",
                                             wal=False)
        return self

    # ================================================================ stats
    @property
    def valid_bytes(self) -> int:
        return sum(s.valid_bytes for s in self.shards)

    @property
    def user_write_bytes(self) -> int:
        return sum(s.user_write_bytes for s in self.shards)

    @property
    def n_gc_runs(self) -> int:
        return sum(s.n_gc_runs for s in self.shards)

    @property
    def n_compactions(self) -> int:
        return sum(s.n_compactions for s in self.shards)

    @property
    def stall_us(self) -> float:
        return sum(s.stall_us for s in self.shards)

    def space_bytes(self) -> int:
        return sum(s.space_bytes() for s in self.shards)

    def space_amplification(self) -> float:
        return self.space_bytes() / max(self.valid_bytes, 1)

    def s_index(self) -> float:
        """Fleet index space-amp: total kSST bytes over total last-level
        bytes (aggregated numerator/denominator, not a mean of ratios)."""
        tot = sum(s.version.ksst_total_bytes() for s in self.shards)
        last = sum(s.version.level_bytes(s.version.last_nonempty_level())
                   for s in self.shards)
        return tot / max(last, 1)

    def exposed_over_valid(self) -> float:
        garbage = sum(s.version.value_garbage_bytes() for s in self.shards)
        ref_valid = max(sum(s.valid_value_bytes() for s in self.shards), 1)
        return garbage / ref_valid

    def valid_value_bytes(self) -> int:
        return sum(s.valid_value_bytes() for s in self.shards)

    def hidden_garbage_bytes(self) -> int:
        return sum(s.hidden_garbage_bytes() for s in self.shards)

    def stats(self) -> dict:
        from ..engine import io as sio
        ss = [s.stats() for s in self.shards]
        wal = sum(s.io.write_bytes.get(sio.CAT_WAL, 0) for s in self.shards)
        write_bytes = sum(st["write_bytes"] for st in ss)
        hits = sum(s.cache.hits for s in self.shards)
        lookups = hits + sum(s.cache.misses for s in self.shards)
        return {
            "engine": self.cfg.engine,
            "n_shards": self.n_shards,
            "shard_policy": self.shard_policy,
            "scheduler": self.fleet.policy,
            "clock_s": max(st["clock_s"] for st in ss),
            "space_bytes": self.space_bytes(),
            "valid_bytes": self.valid_bytes,
            "user_write_bytes": self.user_write_bytes,
            "space_amp": self.space_amplification(),
            "s_index": self.s_index(),
            "exposed_over_valid": self.exposed_over_valid(),
            "write_amp": (write_bytes - wal)
            / max(self.user_write_bytes, 1),
            "read_bytes": sum(st["read_bytes"] for st in ss),
            "write_bytes": write_bytes,
            "n_compactions": self.n_compactions,
            "n_gc_runs": self.n_gc_runs,
            "cache_hit_ratio": hits / lookups if lookups else 0.0,
            "stall_s": self.stall_us / 1e6,
            "gc_time_s": sum(st["gc_time_s"] for st in ss),
            "shard_space_amp": [st["space_amp"] for st in ss],
        }
