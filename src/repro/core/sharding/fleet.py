"""Fleet scheduler: space-aware GC/compaction scheduling across shards
(DESIGN.md §6).

A ``ShardedStore``'s shards share one device, so background service is a
*fleet* resource: the total flush/compaction (bg) and GC lane time available
equals the total foreground time the fleet has generated (the same
``lane_clock < fg_clock`` pacing ``Store.pump`` applies to a single store,
summed over shards).  What the scheduler controls is *where* that budget is
spent:

  * ``fleet`` (default) — global ranking.  GC jobs are ranked by the top
    candidate's garbage ratio across all shards (most garbage reclaimed per
    unit of lane time first); compaction jobs by the level score, which under
    ``compensated_compaction`` is the paper's compensated-size score (§III-C)
    applied fleet-wide.  Per-shard starvation aging adds
    ``aging_rate * rounds_waited`` to a shard's priority so cold shards are
    eventually serviced; aging only reorders eligible jobs, it never
    manufactures work below the local trigger.
  * ``round_robin`` — the per-instance baseline: shards are serviced in
    rotation, each running its own best local job, blind to fleet-wide
    garbage distribution.  ``benchmarks/sharding.py`` measures the space-
    amplification gap between the two under a skewed (one hot shard)
    workload.

A shared *space* budget (fleet quota) rides on top: when fleet space crosses
the soft quota every shard's GC threshold drops to the aggressive ratio, and
at the hard quota writers stall while the scheduler force-runs the globally
best GC jobs (``run_one``) until space is back under quota.

With one shard both policies degenerate to exactly ``Store.pump``'s
behaviour — job choice, order, and clock accounting are byte-identical
(``tests/test_sharding.py`` asserts this on all five engines).
"""

from __future__ import annotations

from .. import compaction as comp
from .. import gc as gcmod

SCHEDULERS = ("fleet", "round_robin")


class FleetScheduler:
    def __init__(self, shards, policy: str = "fleet",
                 aging_rate: float = 0.05,
                 space_quota_bytes: int | None = None,
                 soft_quota_frac: float = 0.9):
        if policy not in SCHEDULERS:
            raise ValueError(f"unknown scheduler policy {policy!r} "
                             f"(want one of {SCHEDULERS})")
        self.shards = list(shards)
        self.policy = policy
        self.aging_rate = float(aging_rate)
        self.space_quota_bytes = space_quota_bytes
        self.soft_quota_frac = float(soft_quota_frac)
        n = len(self.shards)
        self.compact_wait = [0] * n
        self.gc_wait = [0] * n
        self._rr_compact = 0
        self._rr_gc = 0
        self._pumping = False
        # fleet epoch: bumped at every fleet checkpoint so recovery can tie
        # per-shard snapshots to one consistent cut (DESIGN.md §9)
        self.epoch = 0
        for s in self.shards:
            s.scheduler = self

    # ---------------------------------------------------------- durability
    def state_dict(self) -> dict:
        """Scheduler state a fleet checkpoint persists: starvation-aging
        counters, round-robin cursors, and the fleet epoch — so recovered
        scheduling decisions continue exactly where the fleet left off."""
        return {"epoch": self.epoch,
                "compact_wait": list(self.compact_wait),
                "gc_wait": list(self.gc_wait),
                "rr_compact": self._rr_compact,
                "rr_gc": self._rr_gc}

    def load_state(self, st: dict) -> None:
        self.epoch = int(st["epoch"])
        self.compact_wait = [int(x) for x in st["compact_wait"]]
        self.gc_wait = [int(x) for x in st["gc_wait"]]
        self._rr_compact = int(st["rr_compact"])
        self._rr_gc = int(st["rr_gc"])

    # ------------------------------------------------------------ topology
    def add_shard(self, shard) -> None:
        """Attach a freshly spawned shard (split destination) to fleet
        scheduling (DESIGN.md §14)."""
        self.shards.append(shard)
        self.compact_wait.append(0)
        self.gc_wait.append(0)
        shard.scheduler = self

    def remove_shard(self, pos: int) -> None:
        """Detach a retired shard (merge victim) from fleet scheduling;
        positions above ``pos`` shift down (DESIGN.md §14)."""
        self.shards.pop(pos)
        self.compact_wait.pop(pos)
        self.gc_wait.pop(pos)
        n = max(1, len(self.shards))
        self._rr_compact %= n
        self._rr_gc %= n

    # ------------------------------------------------------------- budgets
    def total_fg_us(self) -> float:
        return sum(s.io.lanes["fg"] for s in self.shards)

    def total_bg_us(self) -> float:
        return sum(s.io.lanes["bg"] for s in self.shards)

    def total_gc_us(self) -> float:
        return sum(s.io.lanes["gc"] for s in self.shards)

    def space_bytes(self) -> int:
        return sum(s.version.total_bytes() for s in self.shards)

    def over_soft_quota(self) -> bool:
        return (self.space_quota_bytes is not None
                and self.space_bytes()
                >= self.soft_quota_frac * self.space_quota_bytes)

    def gc_threshold(self, shard, aggressive: bool | None = None) -> float:
        """Shard's GC trigger, aggressive fleet-wide above the soft quota.

        ``aggressive`` lets ``_pick_gc`` evaluate fleet space once per pick
        instead of once per shard (space_bytes walks every shard's files)."""
        if aggressive is None:
            aggressive = self.over_soft_quota()
        if aggressive:
            return shard.cfg.gc_aggressive_ratio
        return shard._gc_threshold()

    # ------------------------------------------------------- job selection
    def _pick_compact(self):
        """-> (shard_idx, job) or None.  Flushes outrank compactions (memtable
        backlog stalls the foreground hardest); compactions rank by level
        score — the compensated-size score when the engine compensates."""
        shards = self.shards
        if self.policy == "round_robin":
            n = len(shards)
            for off in range(n):
                i = (self._rr_compact + off) % n
                job = shards[i].next_compact_job()
                if job is not None:
                    self._rr_compact = i + 1
                    return i, job
            return None
        flushable = [i for i, s in enumerate(shards) if s.immutables]
        if flushable:
            i = max(flushable, key=lambda i: len(shards[i].immutables))
            self.compact_wait[i] = 0
            return i, ("flush",)
        best, best_prio = None, 0.0
        eligible = []
        for i, s in enumerate(shards):
            scores, base_level = comp.level_scores(s)
            score, level = max(scores, key=lambda sc: sc[0])
            if score < 1.0:
                continue
            eligible.append(i)
            prio = score + self.aging_rate * self.compact_wait[i]
            if best is None or prio > best_prio:
                best, best_prio = (i, ("compact", (level, base_level))), prio
        if best is None:
            return None
        for i in eligible:
            self.compact_wait[i] = (0 if i == best[0]
                                    else self.compact_wait[i] + 1)
        return best

    def _shard_gc_candidates(self, shard, aggressive: bool | None = None):
        if not shard.strategy.wants_standalone_gc():
            return None
        if shard.in_batch_write:
            # same fence as Store.next_gc_job: GC must not interleave with a
            # half-applied WriteBatch on that shard
            return None
        cands = gcmod.gc_candidates(shard,
                                    self.gc_threshold(shard, aggressive))
        return cands or None

    def _pick_gc(self):
        """-> (shard_idx, job) or None.  Jobs rank by the shard's top
        candidate GC score — the engine strategy's ``gc_candidate_score``:
        raw garbage ratio (reclaimed bytes per lane time) for the paper
        engines, tracker-driven predicted dead-byte yield for
        ``scavenger_adaptive`` — plus starvation aging."""
        shards = self.shards
        aggressive = self.over_soft_quota()
        if self.policy == "round_robin":
            n = len(shards)
            for off in range(n):
                i = (self._rr_gc + off) % n
                cands = self._shard_gc_candidates(shards[i], aggressive)
                if cands:
                    self._rr_gc = i + 1
                    return i, ("gc", gcmod.gc_batch(shards[i], cands))
            return None
        best, best_prio, best_cands = None, 0.0, None
        eligible = []
        for i, s in enumerate(shards):
            cands = self._shard_gc_candidates(s, aggressive)
            if not cands:
                continue
            eligible.append(i)
            prio = (s.strategy.gc_candidate_score(s, cands[0])
                    + self.aging_rate * self.gc_wait[i])
            if best is None or prio > best_prio:
                best, best_prio, best_cands = i, prio, cands
        if best is None:
            return None
        for i in eligible:
            self.gc_wait[i] = 0 if i == best else self.gc_wait[i] + 1
        return best, ("gc", gcmod.gc_batch(shards[best], best_cands))

    # ------------------------------------------------------------ service
    def pump(self) -> None:
        """Run background jobs that fit in the fleet lane budgets.

        Same two-phase structure as ``Store.pump`` — flush/compaction lane
        first, then the GC lane — with job *choice* globalized."""
        if self._pumping:
            return
        self._pumping = True
        try:
            while self.total_bg_us() < self.total_fg_us():
                picked = self._pick_compact()
                if picked is None:
                    break
                self.shards[picked[0]].run_job(picked[1], "bg",
                                               trigger="lane_budget",
                                               policy=self.policy)
            while self.total_gc_us() < self.total_fg_us():
                picked = self._pick_gc()
                if picked is None:
                    break
                self.shards[picked[0]].run_job(picked[1], "gc",
                                               trigger="lane_budget",
                                               policy=self.policy)
        finally:
            self._pumping = False

    def run_one(self, prefer_gc: bool = False) -> bool:
        """Force-run the single globally best job, ignoring lane budgets
        (the fleet analogue of the job step inside ``Store._stall_while``;
        used when writers stall on the fleet space quota).  The owning
        shard's lane is synced to its foreground clock so the job charges
        real stall time.  Returns False when no job exists anywhere."""
        order = (self._pick_gc, self._pick_compact) if prefer_gc \
            else (self._pick_compact, self._pick_gc)
        lanes = ("gc", "bg") if prefer_gc else ("bg", "gc")
        for pick, lane in zip(order, lanes):
            picked = pick()
            if picked is None:
                continue
            shard = self.shards[picked[0]]
            t_lane = shard.io.lanes[lane]
            shard.io.lanes[lane] = max(t_lane, shard.io.fg_clock_us)
            # both jumps happen outside any shard span (run_one is called
            # from the fleet quota path, before per-shard write dispatch),
            # so each is recorded for lane tiling (DESIGN.md §11)
            shard.obs.lane_sync(shard, lane, t_lane)
            shard.run_job(picked[1], lane, trigger="quota_stall",
                          policy=self.policy)
            t_fg = shard.io.fg_clock_us
            shard.io.lanes["fg"] = max(t_fg, shard.io.lanes[lane])
            shard.obs.lane_sync(shard, "fg", t_fg)
            return True
        return False

    def drain(self) -> None:
        """Run ALL pending background work fleet-wide, then synchronize
        every shard's lanes (the fleet analogue of ``Store.drain``)."""
        while True:
            picked, lane = self._pick_compact(), "bg"
            if picked is None:
                picked, lane = self._pick_gc(), "gc"
            if picked is None:
                break
            self.shards[picked[0]].run_job(picked[1], lane, trigger="drain",
                                           policy=self.policy)
        for s in self.shards:
            m = max(s.io.lanes.values())
            for k in s.io.lanes:
                t0 = s.io.lanes[k]
                s.io.lanes[k] = m
                s.obs.lane_sync(s, k, t0)
