"""Live shard migration: online split/merge with epoch-fenced re-routing
(DESIGN.md §14).

A migration moves one contiguous routing-domain range between exactly two
shards and runs in four phases, driven one chunk at a time from
``FleetScheduler.pump`` so copy I/O interleaves with foreground service:

  1. **begin**   — pick the moving range (split: the upper half of the
     source's slice at the median live routing value; merge: the victim's
     whole slice), spawn the destination shard (split only), and log a
     ``migration_begin`` MANIFEST edit.
  2. **copy**    — sweep the source's live keys through the normal read
     path (``multi_scan`` for the key column, ``multi_get`` for value
     identity + size) and ingest them into the destination with their
     vids preserved (``Store.ingest_batch``).  The router is untouched:
     readers and writers still go to the source, and every user write
     into the moving range is mirrored into the migration *delta*.
  3. **re-route + delta replay** — bump the router epoch (new traffic now
     routes to the destination) and replay the delta.  This is the only
     window where writes to the moving range would block; its duration is
     the migration's *fence* downtime, reported per migration and gated
     by ``benchmarks/elasticity.py``.
  4. **cleanup** — tombstone the moved keys on the source (split), or
     retire the drained victim shard (merge), and log ``migration_end``.

Everything the migration itself does is *derived* work: it is never
journaled to the fleet WAL, because replaying the user-op stream from the
same state re-derives the same migrations deterministically (the same
recovery argument as flush/compaction/GC, DESIGN.md §9).  Crash points
``mid_migration_copy`` / ``pre_reroute`` / ``mid_delta_replay`` fire at
the phase boundaries for the crash matrix in
``tests/test_elastic_fleet.py``.

All migration I/O runs under a pinned ``origin="migration"`` ledger cause
on the store doing the work, so migrated bytes decompose in
``repro.obs blame`` (§13).
"""

from __future__ import annotations

import numpy as np

from ..batch import OP_DELETE, OP_PUT


class Migration:
    """State of one in-flight range move (src -> dst, [lo, hi))."""

    __slots__ = ("kind", "src_pos", "dst_pos", "lo", "hi", "hi_inf",
                 "cursor", "seen", "delta", "records", "bytes",
                 "copy_done", "fence_us")

    def __init__(self, kind: str, src_pos: int, dst_pos: int, lo: int,
                 hi: int, hi_inf: bool, cursor: int):
        self.kind = kind
        self.src_pos = src_pos
        self.dst_pos = dst_pos
        self.lo = lo
        self.hi = hi
        # hi == routing domain: the moving slice is the last one, which
        # also owns every overflow value at or past the domain bound —
        # treat hi as +inf so those keys move too
        self.hi_inf = hi_inf
        self.cursor = cursor            # next key the copy sweep scans from
        self.seen: set[int] = set()     # keys copied (cleanup tombstones)
        self.delta: list[tuple] = []    # writes mirrored during the copy
        self.records = 0
        self.bytes = 0
        self.copy_done = False
        self.fence_us = 0.0

    def in_range(self, route_vals: np.ndarray) -> np.ndarray:
        m = route_vals >= np.uint64(self.lo)
        if not self.hi_inf:
            m &= route_vals < np.uint64(self.hi)
        return m


class ElasticityManager:
    """Watches per-shard space/traffic shares against the EngineConfig
    elasticity thresholds and drives migrations chunk-by-chunk from the
    fleet scheduler's pump (DESIGN.md §14)."""

    def __init__(self, store):
        self.store = store
        cfg = store.cfg
        self.auto = (cfg.elastic_split_frac is not None
                     or cfg.elastic_merge_frac > 0)
        self.mig: Migration | None = None
        self._migrating = False         # suppress traffic/delta recursion
        self._ops_seen = 0
        self._last_eval = 0
        self._traffic: dict[int, int] = {}   # shard_id -> window op count

    # ---------------------------------------------------------- accounting
    def note_traffic(self, pos: int, n: int) -> None:
        if self._migrating:
            return                      # copy reads are not user traffic
        self._ops_seen += n
        if self.auto:
            sid = self.store.shards[pos].shard_id
            self._traffic[sid] = self._traffic.get(sid, 0) + n

    def note_write(self, pos: int, kinds, keys, vids, vsizes) -> None:
        """Mirror user writes landing in an in-flight migration's moving
        range into the delta (replayed at finalize)."""
        mig = self.mig
        if mig is None or self._migrating or pos != mig.src_pos:
            return
        m = mig.in_range(self.store.router.route(keys))
        if not m.any():
            return
        mig.delta.append((np.asarray(kinds, np.uint8)[m],
                          np.asarray(keys, np.uint64)[m],
                          np.asarray(vids, np.uint64)[m],
                          np.asarray(vsizes, np.int64)[m]))
        puts = m & (np.asarray(kinds, np.uint8) == OP_PUT)
        mig.seen.update(np.asarray(keys, np.uint64)[puts].tolist())

    # ------------------------------------------------------------ stepping
    def step(self) -> None:
        """One unit of elastic work: a copy chunk / the finalize of the
        active migration, else a (cooldown-gated) trigger evaluation."""
        if self.mig is not None:
            if self.mig.copy_done:
                self._finalize()
            else:
                self._copy_chunk()
            return
        self._maybe_trigger()

    def quiesce(self) -> None:
        """Run the active migration to completion (checkpoint/drain
        barrier: scheduler state and snapshots are only taken between
        migrations)."""
        while self.mig is not None:
            self.step()

    # ------------------------------------------------------------ triggers
    def _shares(self):
        shards = self.store.shards
        space = [s.version.total_bytes() for s in shards]
        tot_space = sum(space)
        tot_traffic = sum(self._traffic.values())
        out = []
        for pos, s in enumerate(shards):
            sh = space[pos] / tot_space if tot_space else 0.0
            if tot_traffic:
                sh = max(sh, self._traffic.get(s.shard_id, 0) / tot_traffic)
            out.append(sh)
        return out

    def _maybe_trigger(self) -> None:
        if not self.auto:
            return
        cfg = self.store.cfg
        if self._ops_seen - self._last_eval < cfg.elastic_cooldown_ops:
            return
        self._last_eval = self._ops_seen
        shares = self._shares()
        self._traffic.clear()           # next window starts fresh
        if not shares:
            return
        if cfg.elastic_split_frac is not None \
                and len(shares) < cfg.elastic_max_shards:
            pos = max(range(len(shares)), key=shares.__getitem__)
            if shares[pos] > cfg.elastic_split_frac \
                    and self.begin_split(pos):
                return
        if cfg.elastic_merge_frac > 0 and len(shares) > 1:
            pos = min(range(len(shares)), key=shares.__getitem__)
            if shares[pos] < cfg.elastic_merge_frac:
                self.begin_merge(pos)

    # -------------------------------------------------------------- begin
    def _split_cut(self, src, lo: int, hi: int, hi_inf: bool) -> int | None:
        """Median live routing value of the source inside [lo, hi) — the
        balance point a split cuts at.  Reads engine-internal table/
        memtable metadata (never the stats oracle)."""
        cols = [t.keys for t in src.version.all_kssts()]
        for mt in [src.memtable] + src.immutables:
            n = len(mt.entries)
            if n:
                cols.append(np.fromiter(mt.entries.keys(), np.uint64,
                                        count=n))
        if not cols:
            return None
        rv = self.store.router.route(np.concatenate(cols))
        m = rv >= np.uint64(lo)
        if not hi_inf:
            m &= rv < np.uint64(hi)
        rv = rv[m]
        if len(rv) == 0:
            return None
        # exact integer median via partition (np.median would round-trip
        # uint64 through float64 and lose low bits of the hash domain)
        cut = int(np.partition(rv, len(rv) // 2)[len(rv) // 2])
        cut = max(lo + 1, min(cut, hi - 1))
        if not lo < cut < hi:
            return None
        return cut

    def begin_split(self, pos: int, cut: int | None = None) -> bool:
        """Start splitting shard ``pos``'s slice: the upper part [cut, hi)
        moves to a freshly spawned shard.  Returns False when no valid cut
        exists or a migration is already running."""
        st = self.store
        if self.mig is not None:
            return False
        src = st.shards[pos]
        sl = st.router.slice_of_shard(pos)
        lo, hi = st.router.slice_bounds(sl)
        hi_inf = hi >= st.router.domain
        if cut is None:
            cut = self._split_cut(src, lo, hi, hi_inf)
            if cut is None:
                return False
        elif not lo < cut < hi:
            raise ValueError(f"cut {cut} outside shard {pos}'s slice "
                             f"({lo}, {hi})")
        dst_pos = st._spawn_shard()
        cursor = cut if st.router.policy == "range" else 0
        self.mig = Migration("split", pos, dst_pos, cut, hi, hi_inf, cursor)
        st._log_fleet_edit("migration_begin", mig="split",
                           src=src.shard_id,
                           dst=st.shards[dst_pos].shard_id,
                           lo=cut, hi=hi)
        st.obs.instant(src, "migration_begin", kind="split", lo=cut, hi=hi)
        return True

    def begin_merge(self, victim: int, into: int | None = None) -> bool:
        """Start draining shard ``victim`` into the adjacent-slice shard
        ``into`` (default: the emptier neighbor); the victim retires when
        the move finalizes."""
        st = self.store
        if self.mig is not None or len(st.shards) < 2:
            return False
        neighbors = st.router.neighbors(victim)
        if into is None:
            into = min(neighbors,
                       key=lambda p: st.shards[p].version.total_bytes())
        elif into not in neighbors:
            raise ValueError(f"shard {into} is not slice-adjacent to "
                             f"{victim} (neighbors: {neighbors})")
        lo, hi = st.router.shard_range(victim)
        hi_inf = hi >= st.router.domain
        cursor = lo if st.router.policy == "range" else 0
        self.mig = Migration("merge", victim, into, lo, hi, hi_inf, cursor)
        st._log_fleet_edit("migration_begin", mig="merge",
                           src=st.shards[victim].shard_id,
                           dst=st.shards[into].shard_id, lo=lo, hi=hi)
        st.obs.instant(st.shards[victim], "migration_begin", kind="merge",
                       lo=lo, hi=hi)
        return True

    # --------------------------------------------------------------- copy
    def _copy_chunk(self) -> None:
        """Copy up to ``migration_chunk_records`` live keys src -> dst
        through the normal read path, vids preserved."""
        st, mig = self.store, self.mig
        cfg = st.cfg
        src = st.shards[mig.src_pos]
        dst = st.shards[mig.dst_pos]
        chunk = cfg.migration_chunk_records
        self._migrating = True
        try:
            with st.obs.cause(src, origin="migration"):
                res = st._shard_scan(
                    mig.src_pos, np.array([mig.cursor], np.int64),
                    np.array([chunk], np.int64))
            pairs = res[0]
            if not pairs:
                mig.copy_done = True
                return
            ks = np.array([k for k, _ in pairs], np.uint64)
            rv = st.router.route(ks)
            sel = ks[mig.in_range(rv)]
            end_reached = (not mig.hi_inf
                           and st.router.policy == "range"
                           and bool((rv >= np.uint64(mig.hi)).any()))
            if len(sel):
                with st.obs.cause(src, origin="migration"):
                    got = st._shard_get(mig.src_pos, sel)
                live = got["found"]
                sel = sel[live]
                if len(sel):
                    vids = got["vid"][live]
                    vsz = got["vsize"][live].astype(np.int64)
                    kinds = np.full(len(sel), OP_PUT, np.uint8)
                    with st.obs.cause(dst, origin="migration"):
                        st._shard_ingest(mig.dst_pos, kinds, sel, vids, vsz)
                    mig.seen.update(sel.tolist())
                    mig.records += len(sel)
                    mig.bytes += int(
                        (cfg.key_bytes + vsz + cfg.wal_rec_overhead).sum())
            st._crashpoint("mid_migration_copy")
            mig.cursor = int(ks[-1]) + 1
            if len(pairs) < chunk or end_reached:
                mig.copy_done = True
        finally:
            self._migrating = False

    # ------------------------------------------------------------ finalize
    def _finalize(self) -> None:
        """Re-route (epoch bump), replay the delta inside the write fence,
        clean up the source, and retire the victim on a merge."""
        st, mig = self.store, self.mig
        cfg = st.cfg
        src = st.shards[mig.src_pos]
        dst = st.shards[mig.dst_pos]
        st._crashpoint("pre_reroute")
        if mig.kind == "split":
            st.router.split(mig.src_pos, mig.lo, mig.dst_pos)
        else:
            st.router.merge(mig.src_pos, mig.dst_pos)
        # -- fence window: writes to the moved range block on delta replay
        t0 = dst.io.fg_clock_us
        st._crashpoint("mid_delta_replay")
        self._migrating = True
        try:
            for kinds, ks, vids, vsz in mig.delta:
                with st.obs.cause(dst, origin="migration"):
                    st._shard_ingest(mig.dst_pos, kinds, ks, vids, vsz)
                mig.records += len(ks)
                mig.bytes += int((cfg.key_bytes + vsz
                                  + cfg.wal_rec_overhead).sum())
            mig.fence_us = dst.io.fg_clock_us - t0
            st.obs.instant(dst, "migration_fence", us=mig.fence_us)
            if mig.kind == "split" and mig.seen:
                # tombstone the moved keys on the source: stale records
                # become garbage the normal compaction/GC pipeline reclaims
                moved = np.array(sorted(mig.seen), np.uint64)
                zeros = np.zeros(len(moved), np.int64)
                chunk = cfg.migration_chunk_records
                for i in range(0, len(moved), chunk):
                    part = moved[i:i + chunk]
                    kinds = np.full(len(part), OP_DELETE, np.uint8)
                    with st.obs.cause(src, origin="migration"):
                        st._shard_ingest(
                            mig.src_pos, kinds, part,
                            np.zeros(len(part), np.uint64),
                            zeros[:len(part)])
        finally:
            self._migrating = False
        src_id, dst_id = src.shard_id, dst.shard_id
        if mig.kind == "merge":
            st._retire_shard(mig.src_pos)
        st._log_fleet_edit("migration_end", mig=mig.kind, src=src_id,
                           dst=dst_id, epoch=st.router.epoch,
                           records=mig.records, nbytes=mig.bytes,
                           fence_us=mig.fence_us)
        st.obs.instant(dst, "migration_end", kind=mig.kind,
                       records=mig.records, nbytes=mig.bytes)
        st.migrations.append({
            "kind": mig.kind, "src": src_id, "dst": dst_id,
            "lo": mig.lo, "hi": mig.hi, "records": mig.records,
            "bytes": mig.bytes, "fence_us": mig.fence_us,
            "epoch": st.router.epoch})
        self.mig = None
        self._last_eval = self._ops_seen
