"""Sharding subsystem: ShardedStore + fleet-level GC/compaction scheduler.

``ShardedStore`` partitions the keyspace across N independent ``Store``
shards (hash or range routing) behind the same batched columnar API, and
replaces per-shard ``pump()`` with a ``FleetScheduler`` that ranks GC jobs
by garbage ratio and compaction jobs by compensated-size score across the
whole fleet, under shared I/O-lane and space budgets.  See DESIGN.md §6.
"""

from .fleet import SCHEDULERS, FleetScheduler
from .router import POLICIES, HashRouter, RangeRouter, make_router, scatter
from .store import ShardedStore

__all__ = ["ShardedStore", "FleetScheduler", "SCHEDULERS", "POLICIES",
           "HashRouter", "RangeRouter", "make_router", "scatter"]
