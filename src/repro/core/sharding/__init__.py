"""Sharding subsystem: ShardedStore + fleet-level GC/compaction scheduler
+ live elasticity.

``ShardedStore`` partitions the keyspace across N independent ``Store``
shards (hash or range routing) behind the same batched columnar API, and
replaces per-shard ``pump()`` with a ``FleetScheduler`` that ranks GC jobs
by garbage ratio and compaction jobs by compensated-size score across the
whole fleet, under shared I/O-lane and space budgets.  See DESIGN.md §6.

Elasticity (DESIGN.md §14): routers are slice tables supporting online
split/merge with epoch-stamped re-dispatch (``router.py``), migrations run
checkpoint-copy → re-route → delta-replay (``migrate.py``), and each
primary journals its op stream to N replica Stores so ``fail_primary``
can promote the most-caught-up one (``replica.py``).
"""

from .fleet import SCHEDULERS, FleetScheduler
from .migrate import ElasticityManager, Migration
from .replica import ShardReplicator
from .router import (POLICIES, HashRouter, RangeRouter, SliceRouter,
                     make_router, restore_router, scatter)
from .store import ShardedStore

__all__ = ["ShardedStore", "FleetScheduler", "SCHEDULERS", "POLICIES",
           "HashRouter", "RangeRouter", "SliceRouter", "make_router",
           "restore_router", "scatter", "ElasticityManager", "Migration",
           "ShardReplicator"]
