"""Value-store layer: vSST build, coalesced fetch planning, inheritance
resolution, garbage exposure (see DESIGN.md §7)."""

from .build import build_value_files
from .fetch import read_values_batch
from .garbage import expose_garbage
from .resolve import GCGroup, resolve_value_fids, resolve_value_file

__all__ = ["GCGroup", "build_value_files", "expose_garbage",
           "read_values_batch", "resolve_value_fids", "resolve_value_file"]
