"""Garbage exposure: entries dropped during compaction expose value-store
garbage (Hidden -> Exposed, paper §II-D; DESIGN.md §7).

Vectorized: one chain-resolution pass for the whole dropped column, one
``find`` + vid-match per touched vSST.  Rows are *not* de-duplicated —
each dropped index entry exposes its record exactly once, matching the
scalar semantics (a Titan writeback can leave two entries for one record;
both expose)."""

from __future__ import annotations

import numpy as np

from ..engine.tables import ETYPE_REF
from .resolve import resolve_value_fids


def expose_garbage(store, keys, ety, vids, vsizes, vfiles) -> None:
    cfg = store.cfg
    refm = ety == ETYPE_REF
    if not refm.any():
        return
    keys = np.asarray(keys, np.uint64)[refm]
    vids = np.asarray(vids, np.uint64)[refm]
    vfiles = np.asarray(vfiles, np.int64)[refm]
    fids = resolve_value_fids(store, vfiles, keys, vids)
    ok = fids >= 0                      # record already dropped by a GC
    if not ok.any():
        return
    fsel, ksel, vsel = fids[ok], keys[ok], vids[ok]
    uniq, first = np.unique(fsel, return_index=True)
    # one vSST per unique fid — structure-bounded  # scavlint: allow-loop
    for fid in uniq[np.argsort(first)].tolist():    # first-occurrence order
        t = store.version.value_files.get(fid)
        if t is None:
            continue    # defensive: fids were resolved against the live
            #             set and each file is visited once, so this does
            #             not trigger today
        m = fsel == fid
        pos = t.find(ksel[m])
        hit = pos >= 0
        safe = np.where(hit, pos, 0)
        hit &= t.vids[safe] == vsel[m]
        nhit = int(hit.sum())
        if nhit == 0:
            continue
        exposed = int(t.rec_bytes[pos[hit]].sum())
        t.garbage_bytes += exposed
        store.obs.on_space(store, "expose", exposed)
        if cfg.gc_scheme == "compaction":
            t.live_refs -= nhit
            if t.live_refs <= 0:
                store.version.retire_value_file(t.fid, None)
                store._log_edit("retire_value_file", fid=t.fid)
                store.obs.on_space(store, "retire", t.file_bytes)
                store.cache.erase_file(t.fid)
