"""Coalesced value fetch planning for multi_get / scans (paper §III-B.1;
DESIGN.md §7, §12).

Vectorized planning: one inheritance-chain resolution pass for the whole
locator column, one ``find`` per touched vSST (not per record), record
fetches coalesced into adjacent-position runs — one random I/O per run,
optionally capped at ``EngineConfig.coalesce_window`` records per run.
Per-record *state* (cache residency, LRU order) is inherently per-entry
and is handled by the cache layer's batched probe
(``BlockCache.probe_records``) — that loop is the one per-record step the
byte-parity contract keeps.

Eligible batches plan through the ``run_coalesce`` kernel
(``core/accel.py``): one jitted sort/dedup/run-mark pass over the whole
(file-rank, position) column in first-occurrence file order, replacing the
per-file ``np.unique`` + ``np.split`` below with identical output.
"""

from __future__ import annotations

import numpy as np

from .. import accel
from ..engine.cache import BlockCache
from .resolve import resolve_value_fids


def split_runs(posu: np.ndarray, window: int | None) -> list[np.ndarray]:
    """Adjacent-position runs of a sorted unique position column, each
    capped at ``window`` records when set (the host-side planner the
    ``run_coalesce`` kernel mirrors)."""
    runs = np.split(posu, np.nonzero(np.diff(posu) != 1)[0] + 1)
    if window:
        runs = [c for r in runs
                for c in np.split(r, np.arange(window, len(r), window))]
    return runs


def read_values_batch(store, keys, vids, vfiles, vsizes, cat,
                      strict: bool = False) -> None:
    """Charge the I/O for fetching value records of resolved entries.

    ``strict`` (multi_get): every entry won a newest-wins lookup, so an
    unresolvable file or vid mismatch means GC dropped live data.  Scans
    stay lenient: a truncated scan pass can surface a superseded REF whose
    record GC already reclaimed — the scan retry loop re-runs it with a
    larger limit."""
    n = len(keys)
    if n == 0:
        return
    keys = np.asarray(keys, np.uint64)
    vids = np.asarray(vids, np.uint64)
    fids = resolve_value_fids(store, vfiles, keys, vids)
    if strict:
        assert (fids >= 0).all(), "value file for live key lost"
    ok = fids >= 0
    if not ok.any():
        return
    fsel, ksel, vsel = fids[ok], keys[ok], vids[ok]
    uniq, first = np.unique(fsel, return_index=True)
    order = uniq[np.argsort(first)]                 # first-occurrence order
    window = store.cfg.coalesce_window
    pos_per_file = []
    # one ``find`` per unique vSST — structure-bounded  # scavlint: allow-loop
    for fid in order.tolist():
        t = store.version.value_files[fid]
        m = fsel == fid
        pos = accel.table_find(store, t, ksel[m])
        if pos is None:
            pos = t.find(ksel[m])
        if strict:
            assert (pos >= 0).all() and (t.vids[pos] == vsel[m]).all(), \
                "stale locator"
        else:
            pos = pos[pos >= 0]
        pos_per_file.append(pos)
    cat_rank = np.repeat(np.arange(len(order)),
                         [len(p) for p in pos_per_file])
    cat_pos = (np.concatenate(pos_per_file) if pos_per_file
               else np.zeros(0, np.int64))
    plan = accel.plan_runs(store, cat_rank, cat_pos)
    # one vSST per unique fid — structure-bounded  # scavlint: allow-loop
    for i, fid in enumerate(order.tolist()):        # first-occurrence order
        t = store.version.value_files[fid]
        if plan is None:
            posu = np.unique(pos_per_file[i])
            starts = None
        else:
            r_s, p_s, keep, start = plan
            sel = keep & (r_s == i)
            posu = p_s[sel]
            starts = start[sel]
        if len(posu) == 0:
            continue
        if t.layout == "rtable":
            for b in np.unique(t.index_block_of[posu]).tolist():
                store.read_block(t, "ib", b, cat, BlockCache.PRI_HIGH,
                                 t.index_block_bytes())
            runs = (split_runs(posu, window) if starts is None
                    else np.split(posu, np.nonzero(starts)[0][1:]))
            for r in runs:
                rb = t.rec_bytes[r]
                hits = store.cache.probe_records(t.fid, "rec", r, rb,
                                                 BlockCache.PRI_LOW)
                nh = int(hits.sum())
                if nh:
                    store.io.cache_hit(cat, nh)
                nbytes = int(rb[~hits].sum())
                if nbytes:
                    store.io.rand_read(nbytes, cat)
        else:
            store.read_block(t, "i", 0, cat, BlockCache.PRI_HIGH,
                             t.index_block_bytes())
            blocks = t.block_of[posu]
            for b in np.unique(blocks).tolist():
                mm = posu[blocks == b]
                nb = max(int(t.rec_bytes[mm].max()),
                         t.data_block_bytes(0, b))
                store.read_block(t, "d0", b, cat, BlockCache.PRI_LOW, nb)
