"""Coalesced value fetch planning for multi_get / scans (paper §III-B.1;
DESIGN.md §7).

Vectorized planning: one inheritance-chain resolution pass for the whole
locator column, one ``find`` per touched vSST (not per record), record
fetches coalesced into adjacent-position runs — one random I/O per run.
Per-record *state* (cache residency, LRU order) is inherently per-entry
and is handled by the cache layer's batched probe
(``BlockCache.probe_records``) — that loop is the one per-record step the
byte-parity contract keeps.
"""

from __future__ import annotations

import numpy as np

from ..engine.cache import BlockCache
from .resolve import resolve_value_fids


def read_values_batch(store, keys, vids, vfiles, vsizes, cat,
                      strict: bool = False) -> None:
    """Charge the I/O for fetching value records of resolved entries.

    ``strict`` (multi_get): every entry won a newest-wins lookup, so an
    unresolvable file or vid mismatch means GC dropped live data.  Scans
    stay lenient: a truncated scan pass can surface a superseded REF whose
    record GC already reclaimed — the scan retry loop re-runs it with a
    larger limit."""
    n = len(keys)
    if n == 0:
        return
    keys = np.asarray(keys, np.uint64)
    vids = np.asarray(vids, np.uint64)
    fids = resolve_value_fids(store, vfiles, keys, vids)
    if strict:
        assert (fids >= 0).all(), "value file for live key lost"
    ok = fids >= 0
    if not ok.any():
        return
    fsel, ksel, vsel = fids[ok], keys[ok], vids[ok]
    uniq, first = np.unique(fsel, return_index=True)
    # one vSST per unique fid — structure-bounded  # scavlint: allow-loop
    for fid in uniq[np.argsort(first)].tolist():    # first-occurrence order
        t = store.version.value_files[fid]
        m = fsel == fid
        pos = t.find(ksel[m])
        if strict:
            assert (pos >= 0).all() and (t.vids[pos] == vsel[m]).all(), \
                "stale locator"
            posu = np.unique(pos)
        else:
            posu = np.unique(pos[pos >= 0])
        if len(posu) == 0:
            continue
        if t.layout == "rtable":
            for b in np.unique(t.index_block_of[posu]).tolist():
                store.read_block(t, "ib", b, cat, BlockCache.PRI_HIGH,
                                 t.index_block_bytes())
            runs = np.split(posu, np.nonzero(np.diff(posu) != 1)[0] + 1)
            for r in runs:
                rb = t.rec_bytes[r]
                hits = store.cache.probe_records(t.fid, "rec", r, rb,
                                                 BlockCache.PRI_LOW)
                nh = int(hits.sum())
                if nh:
                    store.io.cache_hit(cat, nh)
                nbytes = int(rb[~hits].sum())
                if nbytes:
                    store.io.rand_read(nbytes, cat)
        else:
            store.read_block(t, "i", 0, cat, BlockCache.PRI_HIGH,
                             t.index_block_bytes())
            blocks = t.block_of[posu]
            for b in np.unique(blocks).tolist():
                mm = posu[blocks == b]
                nb = max(int(t.rec_bytes[mm].max()),
                         t.data_block_bytes(0, b))
                store.read_block(t, "d0", b, cat, BlockCache.PRI_LOW, nb)
