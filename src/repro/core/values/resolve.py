"""Inheritance-chain resolution (TerarkDB/Scavenger no-writeback GC,
paper §II-B; DESIGN.md §7).

The index LSM-tree's ``<key, file_number>`` locators stay stable across GC:
a GC output file *inherits* from every candidate it merged (``GCGroup``),
and reads resolve the live head by walking the chain.  Resolution is pure
metadata — no I/O is charged here.
"""

from __future__ import annotations

import numpy as np

from ..engine.tables import SSTable

# hop bound on chain walks — a safety net against metadata corruption, not
# a tunable: real chains are at most a few GC generations deep
_CHAIN_HOP_CAP = 10_000


class GCGroup:
    """Inheritance target: the set of output files of one GC run."""

    __slots__ = ("files",)

    def __init__(self, files: list[SSTable]):
        self.files = files

    def locate_batch(self, keys: np.ndarray, vids: np.ndarray) -> np.ndarray:
        """Vectorized locate: fid of the group file holding each (key, vid),
        -1 where no file does.  One ``find`` per file for the whole column
        (files win in list order, matching the scalar walk)."""
        keys = np.asarray(keys, np.uint64)
        vids = np.asarray(vids, np.uint64)
        out = np.full(len(keys), -1, np.int64)
        unresolved = np.ones(len(keys), bool)
        for t in self.files:
            if not unresolved.any():
                break
            rows = np.nonzero(unresolved)[0]
            pos = t.find(keys[rows])
            ok = pos >= 0
            safe = np.where(ok, pos, 0)
            ok &= t.vids[safe] == vids[rows]
            hit = rows[ok]
            out[hit] = t.fid
            unresolved[hit] = False
        return out


def compress_group(store, g: GCGroup) -> GCGroup:
    """Amortized path compression: splice retired members' successor files
    into the group in place.

    A (key, vid) found in a retired member lives in exactly one file of
    that member's own group (or was dropped), so replacing the retired
    member by its successors — and dropping dead ends — preserves every
    resolution result while bounding chain depth to ~1 hop amortized.
    Pure metadata: no I/O is charged, so accounting is unchanged.

    INVARIANT (required for correctness, upheld by the GC skeleton and
    asserted differentially by tests/test_engines_registry.py's
    compress-vs-reference walk): a (key, vid) record is physically present
    in at most one *live* vSST, and files are retired only inside
    ``gc_finalize`` after their GC outputs are registered in
    ``version.value_files`` and ``store.chains``.  A custom engine strategy
    whose ``gc_finalize`` retires candidates before registering outputs
    would break resolution with or without compression."""
    live = store.version.value_files
    if all(t.fid in live for t in g.files):
        return g
    out: list[SSTable] = []
    seen: set[int] = set()
    stack = list(g.files)
    while stack:
        t = stack.pop(0)
        if t.fid in seen:
            continue
        seen.add(t.fid)
        if t.fid in live:
            out.append(t)
        else:
            g2 = store.chains.get(t.fid)
            if g2 is not None:                  # else: dead end, drop
                stack = list(g2.files) + stack
    g.files = out
    return g


def resolve_value_fids(store, vfiles: np.ndarray, keys: np.ndarray,
                       vids: np.ndarray) -> np.ndarray:
    """Vectorized chain-head resolution: follow inheritance chains for a
    whole locator column, one grouped ``locate_batch`` per chain hop
    instead of a Python per-record walk.  Returns the live fid per row, -1
    where the record was already dropped by a GC."""
    cur = np.asarray(vfiles, np.int64).copy()
    keys = np.asarray(keys, np.uint64)
    vids = np.asarray(vids, np.uint64)
    n = len(cur)
    out = np.full(n, -1, np.int64)
    active = np.ones(n, bool)
    # live-set snapshot is safe: resolution is pure metadata, no file is
    # added or retired while chains are walked
    live = store.version.value_files
    live_fids = np.fromiter(live.keys(), np.int64, count=len(live))
    for _ in range(_CHAIN_HOP_CAP):
        rows = np.nonzero(active)[0]
        if len(rows) == 0:
            return out
        at_live = np.isin(cur[rows], live_fids)
        out[rows[at_live]] = cur[rows[at_live]]
        active[rows[at_live]] = False
        rows = rows[~at_live]
        if len(rows) == 0:
            return out
        for f in np.unique(cur[rows]).tolist():
            grp = rows[cur[rows] == f]
            g = store.chains.get(int(f))
            if g is None:
                active[grp] = False         # file gone, no inheritor
                continue
            nxt = compress_group(store, g).locate_batch(keys[grp],
                                                        vids[grp])
            dead = nxt < 0
            active[grp[dead]] = False       # dropped during that GC
            cur[grp[~dead]] = nxt[~dead]
    raise RuntimeError("inheritance chain cycle")


def resolve_value_file(store, fid: int, key: int, vid: int) -> SSTable | None:
    """Scalar shim: the live vSST holding (key, vid), or None."""
    head = int(resolve_value_fids(store, np.array([fid], np.int64),
                                  np.array([key], np.uint64),
                                  np.array([vid], np.uint64))[0])
    if head < 0:
        return None
    return store.version.value_files.get(head)
