"""vSST construction: cut sorted value records into target-size files,
hot/cold-split when the engine's write policy asks for it (§III-B.3)."""

from __future__ import annotations

import numpy as np

from ..engine.tables import SSTable, build_vsst


def build_value_files(store, keys, vids, vsizes, cat: str):
    """Build vSST(s) from sorted records, hot/cold-split when enabled.

    Returns (files, fid_per_record)."""
    cfg = store.cfg
    n = len(keys)
    fid_per_rec = np.zeros(n, np.int64)
    files: list[SSTable] = []
    if n == 0:
        return files, fid_per_rec
    if cfg.hotcold_write:
        hot = store.dropcache.is_hot(keys)
        classes = [(hot, True), (~hot, False)]
    else:
        classes = [(np.ones(n, bool), False)]
    for mask, is_hot in classes:
        idx = np.nonzero(mask)[0]
        if len(idx) == 0:
            continue
        rec = cfg.value_rec_bytes(vsizes[idx]).astype(np.int64)
        cum = np.cumsum(rec) - rec
        fno = cum // cfg.vsst_bytes
        for f in np.unique(fno):
            m = idx[fno == f]
            t = build_vsst(cfg, keys[m], np.full(len(m), store.seq,
                                                 np.uint64),
                           vids[m], vsizes[m], is_hot=is_hot)
            store.version.add_value_file(t)
            store.io.seq_write(t.file_bytes, cat)
            fid_per_rec[m] = t.fid
            files.append(t)
    return files, fid_per_rec
