"""vSST construction: cut sorted value records into target-size files,
temperature-partitioned when the engine's write policy asks for it.

Partitioning policy, in precedence order:

  * ``EngineStrategy.rewrite_temperature`` (adaptive engines, DESIGN.md §8)
    — three-way hot/warm/cold classes from the decayed write-rate tracker,
    applied at flush separation *and* GC rewrite: hot records group with
    hot records (their files turn to garbage together), cold records stop
    riding along through rewrite after rewrite.
  * ``cfg.hotcold_write`` (Scavenger §III-B.3) — binary DropCache split.
  * neither — one undifferentiated stream.
"""

from __future__ import annotations

import numpy as np

from ..engine.tables import (TEMP_COLD, TEMP_HOT, TEMP_WARM, SSTable,
                             build_vsst)

TEMP_NAMES = {TEMP_HOT: "hot", TEMP_WARM: "warm", TEMP_COLD: "cold"}


def build_value_files(store, keys, vids, vsizes, cat: str):
    """Build vSST(s) from sorted records, temperature-split when enabled.

    Returns (files, fid_per_record)."""
    cfg = store.cfg
    n = len(keys)
    fid_per_rec = np.zeros(n, np.int64)
    files: list[SSTable] = []
    if n == 0:
        return files, fid_per_rec
    temps = store.strategy.rewrite_temperature(store, keys)
    if temps is not None:
        classes = [(temps == c, c) for c in (TEMP_HOT, TEMP_WARM, TEMP_COLD)]
    elif cfg.hotcold_write:
        hot = store.dropcache.is_hot(keys)
        classes = [(hot, TEMP_HOT), (~hot, TEMP_COLD)]
    else:
        classes = [(np.ones(n, bool), TEMP_COLD)]
    for mask, temp in classes:
        idx = np.nonzero(mask)[0]
        if len(idx) == 0:
            continue
        # per-temperature cause scope: vSST writes decompose by
        # temperature class in the attribution ledger (§13)
        with store.obs.cause(store, op="vsst_build", temp=TEMP_NAMES[temp]):
            rec = cfg.value_rec_bytes(vsizes[idx]).astype(np.int64)
            cum = np.cumsum(rec) - rec
            fno = cum // cfg.vsst_bytes
            for f in np.unique(fno):
                m = idx[fno == f]
                t = build_vsst(cfg, keys[m], np.full(len(m), store.seq,
                                                     np.uint64),
                               vids[m], vsizes[m], is_hot=temp == TEMP_HOT,
                               temperature=temp)
                store.version.add_value_file(t)
                store.io.seq_write(t.file_bytes, cat)
                store._log_edit("add_value_file", fid=t.fid,
                                nbytes=t.file_bytes, temperature=int(temp))
                store.obs.on_space(store, "vsst_add", t.file_bytes)
                fid_per_rec[m] = t.fid
                files.append(t)
    return files, fid_per_rec
