"""Engine strategy registry (DESIGN.md §7).

``EngineConfig`` resolves its engine name here; adding an engine is one
``@register_engine`` class in a new module (imported from
``engines/__init__``) — no core-layer edits.
"""

from __future__ import annotations

from .base import EngineStrategy

_REGISTRY: dict[str, type[EngineStrategy]] = {}


def register_engine(cls: type[EngineStrategy]) -> type[EngineStrategy]:
    """Class decorator: register a strategy under its ``name``."""
    if not cls.name or cls.name == "base":
        raise ValueError("engine strategy must set a unique name")
    if cls.name in _REGISTRY:
        raise ValueError(
            f"engine {cls.name!r} is already registered "
            f"(by {_REGISTRY[cls.name].__qualname__}); strategy names "
            f"must be unique")
    _REGISTRY[cls.name] = cls
    return cls


def get_strategy_class(name: str) -> type[EngineStrategy]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered engines: "
            f"{', '.join(sorted(_REGISTRY))}") from None


def make_strategy(cfg) -> EngineStrategy:
    return get_strategy_class(cfg.engine)(cfg)


def available_engines() -> tuple[str, ...]:
    return tuple(_REGISTRY)
