"""Engine strategy layer: one pluggable policy object per engine
(DESIGN.md §7).

``EngineStrategy`` bundles everything that makes the paper's engines differ
while sharing one substrate (memtable / SSTables / simulated device):

  * flush separation policy      -> ``separation_mask``
  * compaction scoring           -> ``level_weight`` / ``file_weight`` /
                                    ``rank_compaction_inputs``
  * relocation / writeback hooks -> ``on_compaction_kept`` / ``gc_finalize``
  * GC scheme                    -> ``gc_read_candidate`` /
                                    ``gc_refine_valid`` / ``gc_value_read``

Class attributes declare the engine's *defaults*: ``EngineConfig`` resolves
any ablation flag left as ``None`` from the registered strategy class, and
validates a ``gc_scheme`` override against ``gc_schemes``.  The default hook
implementations are config-driven (they branch on ``cfg.gc_scheme`` /
``cfg.lazy_read``), so a new engine that simply declares a supported scheme
inherits the correct behaviour without overriding any GC hook — see
``engines/hybrid.py`` for the extension recipe.
"""

from __future__ import annotations

import numpy as np

from ..engine import io as sio
from ..engine.cache import BlockCache
from ..engine.tables import ETYPE_INLINE


class EngineStrategy:
    """Base policy bundle; concrete engines override attributes + hooks."""

    name: str = "base"
    kv_separated: bool = True
    gc_schemes: tuple[str, ...] = ("inherit",)    # first entry = default
    # ablation-flag defaults (EngineConfig fields left as None resolve here)
    compensated_compaction: bool = False
    lazy_read: bool = False
    index_decoupled: bool = False
    hotcold_write: bool = False
    adaptive_enabled: bool = False    # workload tracker (core/adaptive/)

    def __init__(self, cfg):
        self.cfg = cfg

    # ============================================== workload observation
    def observe_batch(self, store, kind: str, keys, vsizes=None) -> None:
        """Foreground-traffic observation hook, called once per columnar
        batch from the write path (``kind="write"``, puts *and* deletes —
        both end a value's lifetime) and ``multi_get`` (``kind="read"``).
        Observation is modeling state only: it must cost no simulated
        device time.  Default: no tracking."""

    def gc_candidate_score(self, store, t) -> float:
        """Score of one vSST as a GC candidate; compared against the GC
        threshold for eligibility and used to rank candidates (and, via the
        ``FleetScheduler``, GC jobs fleet-wide).  Default: the raw garbage
        ratio — the static-threshold policy of the paper engines.  Adaptive
        engines fold in predicted dead-byte yield (``adaptive/engine.py``)."""
        return t.garbage_ratio()

    def rewrite_temperature(self, store, keys) -> np.ndarray | None:
        """Temperature class per record (TEMP_COLD/WARM/HOT) for vSST
        construction, or None to fall back to the binary DropCache hot/cold
        split (``cfg.hotcold_write``).  Drives temperature-partitioned
        vSSTs in ``values/build.py``."""
        return None

    # ==================================================== flush separation
    def separation_mask(self, store, keys: np.ndarray, ety: np.ndarray,
                        vsizes: np.ndarray) -> np.ndarray | None:
        """Mask of flushed entries whose values go to vSSTs (None = none)."""
        if not self.cfg.kv_separated:
            return None
        return (ety == ETYPE_INLINE) & (vsizes >= self.cfg.sep_threshold)

    # =================================================== compaction scoring
    def level_weight(self, version, i: int) -> int:
        """Bytes a level counts for against its target (paper §III-C)."""
        if self.cfg.compensated_compaction:
            return version.level_compensated_bytes(i)
        return version.level_bytes(i)

    def file_weight(self, t) -> int:
        if self.cfg.compensated_compaction:
            return t.compensated_bytes
        return t.file_bytes

    def rank_compaction_inputs(self, store, files: list, level: int) -> list:
        """Order candidate input files for an L>=1 compaction job."""
        if self.cfg.compensated_compaction:
            # push the highest value-density files down first (§III-C)
            return sorted(files, key=lambda t: t.compensated_bytes
                          / max(t.file_bytes, 1), reverse=True)
        cur = store.compact_cursor.get(level, 0) % len(files)
        store.compact_cursor[level] = cur + 1
        return files[cur:] + files[:cur]

    def on_compaction_kept(self, store, kept: tuple) -> tuple:
        """Hook over the surviving merged columns (BlobDB relocation)."""
        return kept

    # ========================================================== GC scheme
    def wants_standalone_gc(self) -> bool:
        return self.cfg.gc_scheme in ("inherit", "writeback")

    def gc_read_candidate(self, store, t) -> None:
        """Read phase for one GC candidate vSST (paper §II-C, §III-B.1)."""
        cfg, io = self.cfg, store.io
        if cfg.lazy_read and t.layout == "rtable":
            # Lazy read: dense-index blocks only (§III-B.1).
            for b in range(t.n_index_blocks):
                store.read_block(t, "ib", b, sio.CAT_GC_READ,
                                 BlockCache.PRI_HIGH, t.index_block_bytes())
        elif cfg.gc_scheme == "writeback":
            # Titan: direct (uncached) full-file scan.
            if cfg.readahead_gc:
                io.seq_read(t.data_bytes, sio.CAT_GC_READ)
            else:
                for b in range(t.n_data_blocks):
                    io.rand_read(t.data_block_bytes(0, b), sio.CAT_GC_READ)
        else:
            # TerarkDB: full scan through the block cache.
            if cfg.readahead_gc:
                io.seq_read(t.data_bytes, sio.CAT_GC_READ)
            else:
                for b in range(t.n_data_blocks):
                    store.read_block(t, "d0", b, sio.CAT_GC_READ,
                                     BlockCache.PRI_LOW)

    def gc_refine_valid(self, store, candidates, cand_of, res, all_keys,
                        all_vids, valid: np.ndarray) -> np.ndarray:
        """Scheme-specific validity: is the entry's locator really *this*
        candidate's record?"""
        from ..values.resolve import resolve_value_fids
        cand_fids = np.array([t.fid for t in candidates], np.int64)
        if self.cfg.gc_scheme == "inherit":
            # resolve the entry's file number through inheritance chains and
            # compare with the candidate being collected (§II-B).  Fast path:
            # the entry usually points directly at the (live) candidate; the
            # rest resolve in one grouped vectorized pass.
            direct = res["vfile"] == cand_fids[cand_of]
            chained = np.nonzero(valid & ~direct)[0]
            if len(chained):
                heads = resolve_value_fids(store, res["vfile"][chained],
                                           all_keys[chained],
                                           all_vids[chained])
                valid[chained] &= heads == cand_fids[cand_of[chained]]
        else:  # writeback: exact locator match
            valid &= res["vfile"] == cand_fids[cand_of]
        return valid

    def gc_value_read(self, store, candidates, cand_of,
                      valid: np.ndarray) -> None:
        """Value-record reads after GC-Lookup (Scavenger lazy read only:
        eager schemes already scanned the whole file)."""
        cfg, io = self.cfg, store.io
        if not cfg.lazy_read:
            return
        for ci, t in enumerate(candidates):
            pos = np.nonzero(valid & (cand_of == ci))[0]
            if len(pos) == 0:
                continue
            local = pos - int(np.searchsorted(cand_of, ci, side="left"))
            runs = np.split(local, np.nonzero(np.diff(local) != 1)[0] + 1)
            for r in runs:
                nbytes = int(t.rec_bytes[r].sum())
                if cfg.readahead_gc:
                    io.seq_read(nbytes, sio.CAT_GC_READ)
                else:
                    io.rand_read(nbytes, sio.CAT_GC_READ)

    def gc_finalize(self, store, candidates, new_files, vkeys, vvids, vvsz,
                    new_fid_per_rec) -> None:
        """Retire candidates; record inheritance or write back locators."""
        from ..values.resolve import GCGroup
        if self.cfg.gc_scheme == "inherit":
            group = GCGroup(new_files)
            for t in candidates:
                store.version.retire_value_file(t.fid, None)
                store.chains[t.fid] = group
                store.cache.erase_file(t.fid)
            store._log_edit("chain_update",
                            retired=[t.fid for t in candidates],
                            group=[t.fid for t in new_files])
        else:  # titan writeback: index rewrites as one batched write
            store.writeback_index_batch(vkeys, vvids, vvsz, new_fid_per_rec)
            for t in candidates:
                store.version.retire_value_file(t.fid, None)
                store.cache.erase_file(t.fid)
        for t in candidates:
            store.obs.on_space(store, "retire", t.file_bytes)
        if store.durability is not None:
            for t in candidates:
                store._log_edit("retire_value_file", fid=t.fid)
