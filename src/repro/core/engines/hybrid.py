"""Hybrid placement engine: size-tiered KV separation (sixth engine).

Following the hybrid-placement line of work (Xanthakis et al., "Parallax:
Balancing Garbage Collection vs I/O Amplification"), values are placed by
size *tier* instead of a single separation threshold:

  * small  (< ``sep_threshold``)          — always inline in the LSM-tree:
    relocating them would cost more index I/O than their bytes save.
  * medium (``sep_threshold`` .. ``hybrid_large_threshold``) — separated
    only when write-*cold*.  Hot medium values stay inline: rewriting them
    through compaction is cheaper than the GC churn their garbage would
    cause in the value store (the GC-vs-I/O-amplification balance).
  * large  (>= ``hybrid_large_threshold``) — always separated: their I/O
    amplification under compaction dominates any GC cost.

Hotness reuses the DropCache write-hotness signal (keys recently
over-written, §III-B.3).  The engine is *pure strategy*: it only overrides
``separation_mask`` and inherits inheritance-GC, compensated compaction,
lazy read and the decoupled index from the shared hook implementations —
zero edits to the core read/values layers (the extension recipe in
DESIGN.md §7).
"""

from __future__ import annotations

from ..engine.tables import ETYPE_INLINE
from .base import EngineStrategy
from .registry import register_engine


@register_engine
class HybridEngine(EngineStrategy):
    name = "hybrid"
    kv_separated = True
    gc_schemes = ("inherit", "writeback")
    compensated_compaction = True
    lazy_read = True
    index_decoupled = True
    hotcold_write = True

    def separation_mask(self, store, keys, ety, vsizes):
        cfg = self.cfg
        inline = ety == ETYPE_INLINE
        large = vsizes >= cfg.hybrid_large_threshold
        medium = (vsizes >= cfg.sep_threshold) & ~large
        cold = ~store.dropcache.is_hot(keys)
        return inline & (large | (medium & cold))
