"""The five paper engines as registered strategies (paper §II, §IV;
DESIGN.md §7).

Each class is *pure declaration*: the shared hook implementations in
``EngineStrategy`` are config-driven, so an engine is its attribute block
plus (for BlobDB) the one hook that genuinely differs.
"""

from __future__ import annotations

import numpy as np

from ..engine import io as sio
from .base import EngineStrategy
from .registry import register_engine


@register_engine
class RocksDBEngine(EngineStrategy):
    """Vanilla leveled LSM-tree: no KV separation, no GC."""

    name = "rocksdb"
    kv_separated = False
    gc_schemes = ("none",)


@register_engine
class BlobDBEngine(EngineStrategy):
    """RocksDB BlobDB: KV separation with compaction-triggered relocation;
    blob files die only when fully exhausted (§II-C)."""

    name = "blobdb"
    kv_separated = True
    gc_schemes = ("compaction",)

    def on_compaction_kept(self, store, kept):
        """During compaction, rewrite values whose blob files are old or
        garbage-heavy; blob files die only when fully exhausted."""
        cfg = self.cfg
        keys, seqs, ety, vids, vsz, vf = kept
        from ..engine.tables import ETYPE_REF
        refs = np.nonzero(ety == ETYPE_REF)[0]
        if len(refs) == 0:
            return kept
        live = sorted(store.version.value_files)
        if not live:
            return kept
        cutoff_i = live[int(len(live) * cfg.blobdb_age_cutoff)] \
            if len(live) > 1 else live[0]
        reloc_rows = []
        for i in refs.tolist():
            t = store.version.value_files.get(int(vf[i]))
            if t is None:
                continue
            # RocksDB BlobDB default: relocation by age cutoff only
            # (garbage-ratio forcing is disabled) — blob files must exhaust
            # their data through compaction before being reclaimed (§II-C).
            if t.fid <= cutoff_i:
                reloc_rows.append(i)
        if not reloc_rows:
            return kept
        rows = np.array(reloc_rows, np.int64)
        # relocation is its own cause class in the attribution ledger
        # (§13): blobdb moves bytes during compaction, not GC.  The
        # age-cutoff pick survives the nested vsst_build op override, so
        # relocated vSST writes stay attributable to relocation.
        with store.obs.cause(store, op="blob_reloc", pick="age_cutoff"):
            # read old values
            for i in rows.tolist():
                t = store.version.value_files[int(vf[i])]
                store.io.rand_read(int(cfg.value_rec_bytes(int(vsz[i]))),
                                   sio.CAT_GC_READ)
            new_files, nfids = store.build_value_files(keys[rows],
                                                       vids[rows], vsz[rows],
                                                       sio.CAT_GC_WRITE)
            # retire refs from the old files
            for i, nf in zip(rows.tolist(), nfids.tolist()):
                t = store.version.value_files.get(int(vf[i]))
                if t is not None:
                    pos = int(t.find(np.array([keys[i]], np.uint64))[0])
                    if pos >= 0 and int(t.vids[pos]) == int(vids[i]):
                        t.garbage_bytes += int(t.rec_bytes[pos])
                        t.live_refs -= 1
                        if t.live_refs <= 0:
                            store.version.retire_value_file(t.fid, None)
                            store.cache.erase_file(t.fid)
                            store._log_edit("retire_value_file", fid=t.fid)
                            store.obs.on_space(store, "retire", t.file_bytes)
                vf[i] = nf
        return (keys, seqs, ety, vids, vsz, vf)


@register_engine
class TitanEngine(EngineStrategy):
    """Titan: standalone GC rewriting locators through the foreground
    write path (Write-Index, §II-C)."""

    name = "titan"
    kv_separated = True
    gc_schemes = ("writeback",)


@register_engine
class TerarkDBEngine(EngineStrategy):
    """TerarkDB: file-number inheritance, no writeback (§II-B).  The
    ``writeback`` scheme is also accepted for ablations."""

    name = "terarkdb"
    kv_separated = True
    gc_schemes = ("inherit", "writeback")


@register_engine
class ScavengerEngine(EngineStrategy):
    """Scavenger: inheritance GC plus the paper's four features (§III):
    compensated compaction, lazy read, decoupled index, hot/cold split."""

    name = "scavenger"
    kv_separated = True
    gc_schemes = ("inherit", "writeback")
    compensated_compaction = True
    lazy_read = True
    index_decoupled = True
    hotcold_write = True
