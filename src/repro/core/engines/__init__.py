"""Pluggable engine strategies (see DESIGN.md §7).

Importing this package registers the built-in engines; registration order
defines the canonical ``available_engines()`` order (the five paper engines
first, then ``hybrid``).
"""

from .base import EngineStrategy
from .registry import (available_engines, get_strategy_class, make_strategy,
                       register_engine)
from . import paper      # noqa: F401  (registers the five paper engines)
from . import hybrid     # noqa: F401  (registers the hybrid engine)
from ..adaptive import engine as _adaptive   # noqa: F401  (scavenger_adaptive)

__all__ = [
    "EngineStrategy", "available_engines", "get_strategy_class",
    "make_strategy", "register_engine",
]
