"""Trainer: AdamW with FSDP-friendly state, gradient accumulation, global
norm clipping, dtype-configurable moments (bf16 moments for the >=100B MoE
configs so optimizer state fits v5e HBM — noted in EXPERIMENTS.md)."""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models.layers import NO_CTX


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    accum_steps: int = 1
    moment_dtype: str = "float32"        # bfloat16 for >=100B configs
    warmup_steps: int = 20


def init_opt_state(params, tcfg: TrainConfig):
    mdt = jnp.dtype(tcfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_opt_state(abstract_params, tcfg: TrainConfig):
    mdt = jnp.dtype(tcfg.moment_dtype)
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, mdt)
    return {"m": jax.tree.map(sds, abstract_params),
            "v": jax.tree.map(sds, abstract_params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def opt_state_shardings(param_shardings, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return {"m": param_shardings, "v": param_shardings,
            "step": NamedSharding(mesh, P())}


def _schedule(tcfg, step):
    warm = jnp.minimum(step / max(tcfg.warmup_steps, 1), 1.0)
    return tcfg.lr * warm


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt_state, tcfg: TrainConfig):
    step = opt_state["step"] + 1
    lr = _schedule(tcfg, step)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, tcfg.grad_clip / (gn + 1e-9))
    mdt = jnp.dtype(tcfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = tcfg.b1 * m.astype(jnp.float32) + (1 - tcfg.b1) * g
        v32 = tcfg.b2 * v.astype(jnp.float32) + (1 - tcfg.b2) * g * g
        mhat = m32 / (1 - tcfg.b1 ** step)
        vhat = v32 / (1 - tcfg.b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + tcfg.eps) \
            + tcfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gn


def make_train_step(model, tcfg: TrainConfig, ctx=NO_CTX,
                    grad_shardings=None):
    """-> train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  Gradient accumulation: the global batch is split into
    ``accum_steps`` microbatches scanned sequentially (bounds activation
    memory for the >=100B configs).

    grad_shardings (§Perf): constraining per-microbatch gradients and the
    accumulator to the parameter shardings lets XLA keep gradients in their
    FSDP-sharded form (reduce-scatter) instead of all-reducing full
    replicas."""

    def constrain(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def loss_fn(params, batch):
        return model.loss(params, batch, ctx)

    def train_step(params, opt_state, batch):
        a = tcfg.accum_steps
        if a > 1:
            micro = jax.tree.map(
                lambda x: x.reshape((a, x.shape[0] // a) + x.shape[1:]),
                batch)

            def body(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grad_acc = jax.tree.map(jnp.add, grad_acc,
                                        constrain(grads))
                return (loss_acc + loss, constrain(grad_acc)), None
            zeros = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0), zeros),
                                            micro)
            loss = loss / a
            grads = jax.tree.map(lambda g: g / a, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = constrain(grads)
        params, opt_state, gn = adamw_update(params, grads, opt_state, tcfg)
        return params, opt_state, {"loss": loss, "grad_norm": gn}

    return train_step
