"""Hot/cold partition kernel (DropCache routing at Flush/GC, §III-B.3).

Scavenger writes hot-update records and cold records to separate vSSTs.
A stable partition is a scatter on CPUs; on TPU we sort a composite key
``(is_cold << log2(n)) | position`` with a gather-free bitonic network,
carrying the record payloads.  Hot records keep their relative order in the
prefix, cold in the suffix — exactly a stable partition.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import bitonic_sort


def _kernel(keys_ref, hot_ref, vid_ref, vsz_ref,
            okeys_ref, ovid_ref, ovsz_ref, count_ref):
    keys = keys_ref[...]
    hot = hot_ref[...]
    n = keys.shape[0]
    pos = jax.lax.broadcasted_iota(jnp.uint32, (n,), 0)
    comp = jnp.where(hot, pos, pos + jnp.uint32(n))
    comp, keys, vid, vsz = bitonic_sort(comp, keys, vid_ref[...],
                                        vsz_ref[...], ascending=True)
    okeys_ref[...] = keys
    ovid_ref[...] = vid
    ovsz_ref[...] = vsz
    count_ref[...] = hot.astype(jnp.uint32).sum()[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def hot_cold_partition_pallas(keys, hot, vids, vsizes, *, interpret=True):
    """All inputs (N,) with N a power of two.  Returns (keys, vids, vsizes)
    stably partitioned hot-first plus the hot count."""
    n = keys.shape[0]
    assert (n & (n - 1)) == 0
    out = jax.ShapeDtypeStruct((n,), jnp.uint32)
    return pl.pallas_call(
        _kernel,
        out_shape=[out, out, out, jax.ShapeDtypeStruct((1,), jnp.uint32)],
        interpret=interpret,
    )(keys, hot, vids, vsizes)
