"""Jitted wrapper for the hot/cold partition kernel."""

from __future__ import annotations

import jax.numpy as jnp

from ..common import interpret_default, next_pow2, pad_to
from .kernel import hot_cold_partition_pallas


def hot_cold_partition(keys, hot, vids, vsizes, *, interpret=None):
    """Stable hot-first partition. Returns (keys, vids, vsizes, n_hot),
    trimmed of padding (pads are cold entries at the very end)."""
    if interpret is None:
        interpret = interpret_default()
    keys = jnp.asarray(keys).astype(jnp.uint32)
    n = keys.shape[0]
    if n == 0:
        z = jnp.zeros((0,), jnp.uint32)
        return z, z, z, jnp.uint32(0)
    npow = next_pow2(n)
    ks = pad_to(keys, npow, 0)
    ht = pad_to(jnp.asarray(hot).astype(bool), npow, False)
    vd = pad_to(jnp.asarray(vids).astype(jnp.uint32), npow, 0)
    vs = pad_to(jnp.asarray(vsizes).astype(jnp.uint32), npow, 0)
    okeys, ovid, ovsz, cnt = hot_cold_partition_pallas(
        ks, ht, vd, vs, interpret=interpret)
    return okeys[:n], ovid[:n], ovsz[:n], cnt[0]
