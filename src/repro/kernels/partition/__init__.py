from .ops import hot_cold_partition
from .ref import hot_cold_partition_ref

__all__ = ["hot_cold_partition", "hot_cold_partition_ref"]
