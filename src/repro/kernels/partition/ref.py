"""Pure-jnp oracle for the partition kernel."""

import jax.numpy as jnp


def hot_cold_partition_ref(keys, hot, vids, vsizes):
    order = jnp.argsort(jnp.where(hot, 0, 1), stable=True)
    return (keys[order], vids[order], vsizes[order],
            hot.astype(jnp.uint32).sum())
