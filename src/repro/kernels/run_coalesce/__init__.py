from .ops import run_coalesce
from .ref import run_coalesce_ref

__all__ = ["run_coalesce", "run_coalesce_ref"]
