"""Dispatching wrapper for the run-coalescing op.

Pads the pair column to a power of two with all-ones rank sentinels
(sorts strictly after every real pair) and trims back after the kernel;
real ranks must stay below the sentinel.
"""

from __future__ import annotations

import jax
import numpy as np

from ..common import U32_MAX, next_pow2, resolve_mode
from .kernel import run_coalesce_pallas
from .ref import run_coalesce_ref

_xla_coalesce = jax.jit(run_coalesce_ref, static_argnames=("window",))


def run_coalesce(rank, pos, *, window=None, mode=None):
    """Plan coalesced I/O runs for (file-rank, record-position) pairs.

    -> numpy (rank_s i64, pos_s i64, keep bool, run_start bool), all (M,)
    sorted by (rank, pos); duplicates have keep False, and run_start marks
    the first kept record of each adjacent run (capped at ``window`` kept
    records per run when set)."""
    if mode is None:
        mode = resolve_mode(None)
    rank = np.asarray(rank)
    pos = np.asarray(pos)
    m = rank.shape[0]
    if m == 0:
        e = np.zeros(0, np.int64)
        return e, e.copy(), np.zeros(0, bool), np.zeros(0, bool)
    assert int(rank.max()) < int(U32_MAX) and int(pos.max()) < int(U32_MAX)
    if window is not None:
        window = int(window)
        assert window >= 1
    mp = max(2, next_pow2(m))
    rp = np.full(mp, U32_MAX, np.uint32)
    rp[:m] = rank
    pp = np.full(mp, U32_MAX, np.uint32)
    pp[:m] = pos
    if mode == "xla":
        r, p, keep, start = _xla_coalesce(rp, pp, window=window)
    else:
        r, p, keep, start = run_coalesce_pallas(
            rp, pp, window=window, interpret=(mode == "interpret"))
    return (np.asarray(r)[:m].astype(np.int64),
            np.asarray(p)[:m].astype(np.int64),
            np.asarray(keep)[:m], np.asarray(start)[:m])
