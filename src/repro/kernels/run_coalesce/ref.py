"""Pure-jnp oracle for the run-coalescing kernel (stable two-pass argsort
in place of the bitonic network; same dedup/run-mark arithmetic)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def run_coalesce_ref(rank, pos, window=None):
    """rank/pos (M,) u32 -> (rank_s, pos_s, keep, run_start), sorted by
    the lexicographic (rank, pos) pair."""
    o1 = jnp.argsort(pos, stable=True)
    o2 = jnp.argsort(rank[o1], stable=True)
    order = o1[o2]
    r, p = rank[order], pos[order]
    m = r.shape[0]
    i0 = jnp.arange(m) == 0
    prev_r = jnp.concatenate([jnp.zeros((1,), r.dtype), r[:-1]])
    prev_p = jnp.concatenate([jnp.zeros((1,), p.dtype), p[:-1]])
    keep = i0 | (r != prev_r) | (p != prev_p)
    start = (i0 | (r != prev_r) | (p - prev_p > jnp.uint32(1))) & keep
    if window is not None:
        kept = jnp.cumsum(keep.astype(jnp.int32))
        base = jax.lax.cummax(jnp.where(start, kept, 0))
        start = start | (keep & ((kept - base) % window == 0))
    return r, p, keep, start
