"""Run-coalescing kernel: sort + dedup + adjacency-run planning for the
value-fetch path (paper §III-B.1, DESIGN.md §12).

The fetch planner turns a column of (file-rank, record-position) pairs
into I/O runs: sort lexicographically, drop duplicate pairs, and start a
new run at every file change or position gap > 1 — plus every ``window``
kept records when a coalesce window caps run length (qd-style bounded
requests).  On TPU the sort is a gather-free bitonic network over the
pair key (``common.bitonic_sort_pairs``) and the run marks come from
shifted compares and Hillis-Steele prefix scans — no gathers anywhere.

Single-block kernel: the bitonic network needs the whole (pow2-padded)
column resident, like ``kernels/partition``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import bitonic_sort_pairs, prefix_max, prefix_sum


def _coalesce_kernel(r_ref, p_ref, rs_ref, ps_ref, keep_ref, start_ref, *,
                     window: int | None):
    r, p = bitonic_sort_pairs(r_ref[...], p_ref[...])
    m = r.shape[0]
    i0 = jax.lax.broadcasted_iota(jnp.int32, (m,), 0) == 0
    prev_r = jnp.concatenate([jnp.zeros((1,), r.dtype), r[:-1]])
    prev_p = jnp.concatenate([jnp.zeros((1,), p.dtype), p[:-1]])
    keep = i0 | (r != prev_r) | (p != prev_p)
    start = (i0 | (r != prev_r) | (p - prev_p > jnp.uint32(1))) & keep
    if window is not None:
        kept = prefix_sum(keep.astype(jnp.int32))
        base = prefix_max(jnp.where(start, kept, 0))
        start = start | (keep & ((kept - base) % window == 0))
    rs_ref[...] = r
    ps_ref[...] = p
    keep_ref[...] = keep
    start_ref[...] = start


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def run_coalesce_pallas(rank, pos, *, window=None, interpret=True):
    """rank/pos (M,) u32, M a power of two (pads sort last via all-ones
    rank sentinel).  -> (rank_s, pos_s u32, keep, run_start bool), all
    (M,) in sorted order."""
    m = rank.shape[0]
    assert (m & (m - 1)) == 0
    spec = pl.BlockSpec((m,), lambda: (0,))
    return pl.pallas_call(
        functools.partial(_coalesce_kernel, window=window),
        in_specs=[spec, spec],
        out_specs=[spec, spec, spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.uint32),
            jax.ShapeDtypeStruct((m,), jnp.uint32),
            jax.ShapeDtypeStruct((m,), jnp.bool_),
            jax.ShapeDtypeStruct((m,), jnp.bool_),
        ],
        interpret=interpret,
    )(rank, pos)
