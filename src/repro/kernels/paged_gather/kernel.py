"""Paged KV-cache gather kernel (serving substrate).

The Scavenger-style paged KV-cache manager stores per-sequence KV blocks in
a global page pool (pages = vSSTs, page table = index LSM-tree; see
DESIGN.md §3/§4).  Attention needs each sequence's pages contiguous.  On TPU
the page-table indirection uses the one supported dynamic-indexing form:
block-level dynamic slices driven by scalar-prefetched indices
(PrefetchScalarGridSpec) — the same pattern as TPU paged attention.

Grid: (batch, pages_per_seq); each step copies one (page_size, head_dim)
page from the pool position named by the page table.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(table_ref, pages_ref, out_ref):
    del table_ref          # consumed by the index_map
    out_ref[...] = pages_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_gather_pallas(page_table, pages, *, interpret=True):
    """page_table (B, P) i32 -> out (B, P*page_size, D) gathering
    pages (N, page_size, D)."""
    b, p = page_table.shape
    n, page_size, d = pages.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, p),
        in_specs=[
            pl.BlockSpec((None, page_size, d),
                         lambda i, j, table: (table[i, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, page_size, d),
                               lambda i, j, table: (i, j, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, p * page_size, d), pages.dtype),
        interpret=interpret,
    )(page_table, pages)
    return out
