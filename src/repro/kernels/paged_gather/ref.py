"""Pure-jnp oracle for the paged gather kernel."""

import jax.numpy as jnp


def page_gather_ref(page_table, pages):
    """page_table (B, P) i32, pages (N, page_size, D)
    -> (B, P*page_size, D)."""
    b, p = page_table.shape
    _, page_size, d = pages.shape
    g = pages[page_table.reshape(-1)]            # (B*P, page_size, D)
    return g.reshape(b, p * page_size, d)
