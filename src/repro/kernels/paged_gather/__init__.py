from .ops import page_gather
from .ref import page_gather_ref

__all__ = ["page_gather", "page_gather_ref"]
