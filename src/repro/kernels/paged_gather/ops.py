"""Jitted wrapper for the paged gather kernel."""

from __future__ import annotations

import jax.numpy as jnp

from ..common import interpret_default
from .kernel import page_gather_pallas


def page_gather(page_table, pages, *, interpret=None):
    """Gather KV pages into per-sequence contiguous buffers.

    page_table (B, P) int32 (entries index ``pages``; unused slots should
    point at a zero page), pages (N, page_size, D).
    Returns (B, P*page_size, D)."""
    if interpret is None:
        interpret = interpret_default()
    page_table = jnp.asarray(page_table).astype(jnp.int32)
    pages = jnp.asarray(pages)
    return page_gather_pallas(page_table, pages, interpret=interpret)
