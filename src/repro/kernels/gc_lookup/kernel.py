"""GC-Lookup kernel: batched validity lookup against a sorted index run.

Paper §III-B.2: GC-Lookup validates every key of a candidate vSST against
the index LSM-tree.  On TPU there is no efficient per-lane gather, so binary
search is replaced by tiled compare-and-reduce: each query tile (Q,1) is
compared against index-run chunks (1,C) streamed through VMEM; equality
one-hots are multiply-reduced to fetch the matched entry's vid/file-number.
O(Q*N) VPU compares beat pointer-chasing on this hardware.

Block layout: grid over query tiles; the sorted run (keys/vids/vfiles) is
resident in VMEM (a 64K-entry run of u32 triples = 768KB, fits v5e VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import QUERY_TILE, TABLE_CHUNK as CHUNK


def _kernel(q_ref, sk_ref, sv_ref, sf_ref, found_ref, vid_ref, vfile_ref):
    q = q_ref[...]                      # (QT, 1) uint32
    n = sk_ref.shape[0]
    nchunks = n // CHUNK

    def body(i, carry):
        found, vid, vfile = carry
        ck = sk_ref[pl.ds(i * CHUNK, CHUNK)]   # (C,)
        cv = sv_ref[pl.ds(i * CHUNK, CHUNK)]
        cf = sf_ref[pl.ds(i * CHUNK, CHUNK)]
        eq = q == ck[None, :]                              # (QT, C)
        found = found | eq.any(axis=1, keepdims=True)
        eqi = eq.astype(jnp.uint32)
        vid = vid + (eqi * cv[None, :]).sum(axis=1, keepdims=True)
        vfile = vfile + (eqi * cf[None, :]).sum(axis=1, keepdims=True)
        return found, vid, vfile

    qt = q.shape[0]
    init = (jnp.zeros((qt, 1), jnp.bool_),
            jnp.zeros((qt, 1), jnp.uint32),
            jnp.zeros((qt, 1), jnp.uint32))
    found, vid, vfile = jax.lax.fori_loop(0, nchunks, body, init)
    found_ref[...] = found
    vid_ref[...] = vid
    vfile_ref[...] = vfile


@functools.partial(jax.jit, static_argnames=("interpret",))
def gc_lookup_pallas(queries, s_keys, s_vids, s_vfiles, *, interpret=True):
    """queries (Q,1) u32; sorted run s_* (N,) u32 (N % CHUNK == 0,
    Q % QUERY_TILE == 0).  Returns (found (Q,1) bool, vid, vfile (Q,1) u32).
    """
    q, n = queries.shape[0], s_keys.shape[0]
    assert q % QUERY_TILE == 0 and n % CHUNK == 0
    grid = (q // QUERY_TILE,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((QUERY_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((QUERY_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((QUERY_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((QUERY_TILE, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, 1), jnp.bool_),
            jax.ShapeDtypeStruct((q, 1), jnp.uint32),
            jax.ShapeDtypeStruct((q, 1), jnp.uint32),
        ],
        interpret=interpret,
    )(queries, s_keys, s_vids, s_vfiles)
