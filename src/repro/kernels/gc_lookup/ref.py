"""Pure-jnp oracle for the GC-Lookup kernel."""

import jax.numpy as jnp


def gc_lookup_ref(queries, s_keys, s_vids, s_vfiles):
    """queries (Q,) u32; sorted run (N,) u32 each.
    -> (found (Q,), vid (Q,), vfile (Q,))."""
    pos = jnp.searchsorted(s_keys, queries)
    pos = jnp.clip(pos, 0, s_keys.shape[0] - 1)
    found = s_keys[pos] == queries
    vid = jnp.where(found, s_vids[pos], 0).astype(jnp.uint32)
    vfile = jnp.where(found, s_vfiles[pos], 0).astype(jnp.uint32)
    return found, vid, vfile
