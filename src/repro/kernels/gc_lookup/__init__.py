from .ops import gc_lookup
from .ref import gc_lookup_ref

__all__ = ["gc_lookup", "gc_lookup_ref"]
