"""Jitted wrapper: padding + dtype handling for the GC-Lookup kernel."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..common import U32_MAX, interpret_default, pad_to, round_up
from .kernel import CHUNK, QUERY_TILE, gc_lookup_pallas


def gc_lookup(queries, s_keys, s_vids, s_vfiles, *, interpret=None):
    """Batched point-lookup of ``queries`` in a sorted (keys, vids, vfiles)
    run.  Accepts engine u64 keys when they fit u32.  Returns numpy-friendly
    (found bool (Q,), vids u32 (Q,), vfiles u32 (Q,))."""
    if interpret is None:
        interpret = interpret_default()
    queries = jnp.asarray(queries)
    s_keys = jnp.asarray(s_keys)
    if queries.dtype == jnp.uint64 or s_keys.dtype == jnp.uint64:
        assert int(jnp.max(s_keys, initial=0)) < 2**32 - 2, \
            "u64 keys must be dictionary-encoded to u32 for TPU kernels"
        queries = queries.astype(jnp.uint32)
        s_keys = s_keys.astype(jnp.uint32)
    q = queries.shape[0]
    n = s_keys.shape[0]
    if q == 0 or n == 0:
        z = jnp.zeros((q,), jnp.uint32)
        return jnp.zeros((q,), bool), z, z
    qp = round_up(q, QUERY_TILE)
    np_ = round_up(n, CHUNK)
    queries_p = pad_to(queries, qp, U32_MAX).reshape(qp, 1)
    sk = pad_to(s_keys, np_, U32_MAX - 1)
    sv = pad_to(jnp.asarray(s_vids).astype(jnp.uint32), np_, 0)
    sf = pad_to(jnp.asarray(s_vfiles).astype(jnp.uint32), np_, 0)
    found, vid, vfile = gc_lookup_pallas(queries_p, sk, sv, sf,
                                         interpret=interpret)
    return (found[:q, 0], vid[:q, 0], vfile[:q, 0])
