from .ops import interval_rank, lookup_probe, rank_probe
from .ref import count_le_ref, lookup_probe_ref, rank_probe_ref

__all__ = ["lookup_probe", "rank_probe", "interval_rank",
           "lookup_probe_ref", "rank_probe_ref", "count_le_ref"]
