"""Pure-jnp oracles for the fused lookup-probe kernel.

Integer-exact by construction: ``searchsorted`` (left) on a sorted run
equals the kernel's count-of-strictly-less rank, and the bloom bit test is
the same shift/mask arithmetic the engine's ``BloomFilter`` runs on u64
words viewed as u32 lanes.  The engine's XLA dispatch mode jit-compiles
these oracles directly (``repro.kernels.common.resolve_mode``).
"""

from __future__ import annotations

import jax.numpy as jnp


def rank_probe_ref(queries, table_keys):
    """queries (Q,) u32 vs sorted run (N,) u32.
    -> (found (Q,) bool, rank (Q,) i32) with rank = #{table < query}."""
    n = table_keys.shape[0]
    rank = jnp.searchsorted(table_keys, queries).astype(jnp.int32)
    if n == 0:
        return jnp.zeros(queries.shape, bool), rank
    safe = jnp.clip(rank, 0, n - 1)
    found = (table_keys[safe] == queries) & (rank < n)
    return found, rank


def lookup_probe_ref(queries, table_keys, bit_idx, words):
    """Fused bloom probe + membership/rank.

    bit_idx (Q, k) u32 pre-modulo'd filter bit indices; words (W,) u32
    filter words (the engine's u64 bit array little-endian-viewed as u32).
    -> (may (Q,) bool, found (Q,) bool, rank (Q,) i32)."""
    w = words[bit_idx >> jnp.uint32(5)]                       # (Q, k)
    bit = ((w >> (bit_idx & jnp.uint32(31))) & jnp.uint32(1))
    may = (bit == jnp.uint32(1)).all(axis=1)
    found, rank = rank_probe_ref(queries, table_keys)
    return may, found, rank


def count_le_ref(queries, mins):
    """#{mins <= query} per query (searchsorted side='right') — the level
    file-assignment rank.  -> (Q,) i32."""
    return jnp.searchsorted(mins, queries, side="right").astype(jnp.int32)
