"""Dispatching wrappers for the fused lookup-probe ops.

Padding contract: queries pad to a pow2 multiple of QUERY_TILE (bounds jit
retracing across ragged batch remainders), sorted runs pad with the
``U32_TABLE_PAD`` sentinel to a pow2 multiple of TABLE_CHUNK, filter words
zero-pad to a pow2 multiple of WORD_CHUNK.  Real keys must stay strictly
below the sentinel (u64 keys are accepted when they fit — the engine's
dictionary-encoding contract).

Dispatch-overhead discipline (the CPU roofline in benchmarks/
kernels_bench.py): per-structure operands — the sorted run, the filter
words, the level bounds — are immutable in the engine, so their padded
device copies are cached via ``common.device_cached``; per-batch operands
are padded host-side in NumPy and handed to the jitted callable as-is
(jit ingests NumPy arguments far cheaper than an eager ``jnp.asarray``
round-trip), and outputs are converted whole before trimming so no eager
device slicing runs.

Modes (``repro.kernels.common.resolve_mode``): "xla" jit-compiles the
ref.py oracle on the padded operands, "interpret"/"pallas" run the Pallas
kernel.  All modes are byte-identical on the integer outputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..common import (QUERY_TILE, TABLE_CHUNK, U32_MAX, U32_TABLE_PAD,
                      WORD_CHUNK, device_cached, next_pow2, resolve_mode,
                      round_up)
from .kernel import count_le_pallas, lookup_probe_pallas, rank_probe_pallas
from .ref import count_le_ref, lookup_probe_ref, rank_probe_ref

_xla_lookup = jax.jit(lookup_probe_ref)
_xla_rank = jax.jit(rank_probe_ref)
_xla_count = jax.jit(count_le_ref)


def _check_u32(a, sorted_run: bool = False) -> np.ndarray:
    """Dictionary-encoding bound check for a key column (sorted runs check
    their last element; query columns scan)."""
    a = np.asarray(a)
    if a.dtype != np.uint32 and a.size:
        top = int(a[-1]) if sorted_run else int(a.max())
        assert top < int(U32_TABLE_PAD), \
            "u64 keys must be dictionary-encoded to u32 for TPU kernels"
    return a


def _pad_q(a, qp) -> np.ndarray:
    out = np.zeros(qp, np.uint32)
    out[:a.shape[0]] = a
    return out


def _run_dev(run: np.ndarray, fill, tag: str):
    """Cached padded device copy of an immutable sorted key column."""
    def build():
        n = run.shape[0]
        p = np.full(max(TABLE_CHUNK, next_pow2(n)), fill, np.uint32)
        p[:n] = run
        return jnp.asarray(p)
    return device_cached(run, tag, build)


def _words_dev(words: np.ndarray):
    """Cached padded device copy of an immutable filter-word column
    (accepts the engine's u64 backing words or raw u32)."""
    def build():
        w = words.view(np.uint32) if words.dtype == np.uint64 \
            else np.asarray(words, np.uint32)
        p = np.zeros(max(WORD_CHUNK, next_pow2(w.shape[0])), np.uint32)
        p[:w.shape[0]] = w
        return jnp.asarray(p)
    return device_cached(words, "words", build)


def lookup_probe(queries, table_keys, bit_idx, words, *, mode=None):
    """Fused bloom + membership/rank probe of one SSTable.

    queries (Q,) and sorted unique table_keys (N,) key columns (u32, or
    u64 that fits); bit_idx (Q, k) u32 pre-modulo'd bloom bit indices;
    words (W,) u32 (or the backing u64) filter words.  -> numpy (may (Q,)
    bool, found (Q,) bool, rank (Q,) i64), rank = searchsorted-left."""
    if mode is None:
        mode = resolve_mode(None)
    queries = _check_u32(queries)
    table_keys = _check_u32(table_keys, sorted_run=True)
    q = queries.shape[0]
    if q == 0:
        return (np.zeros(0, bool), np.zeros(0, bool), np.zeros(0, np.int64))
    k = bit_idx.shape[1]
    qp = round_up(max(QUERY_TILE, next_pow2(q)), QUERY_TILE)
    qs = _pad_q(queries, qp)
    bi = np.zeros((qp, k), np.uint32)
    bi[:q] = bit_idx
    tk = _run_dev(table_keys, U32_TABLE_PAD, "run")
    ws = _words_dev(np.asarray(words))
    if mode == "xla":
        may, found, rank = _xla_lookup(qs, tk, bi, ws)
    else:
        may, found, rank = lookup_probe_pallas(
            qs.reshape(qp, 1), tk, bi, ws, k=k,
            interpret=(mode == "interpret"))
        may, found, rank = may[:, 0], found[:, 0], rank[:, 0]
    return (np.asarray(may)[:q], np.asarray(found)[:q],
            np.asarray(rank)[:q].astype(np.int64))


def rank_probe(queries, table_keys, *, mode=None):
    """Membership/rank probe without a filter (memtable snapshots).
    -> numpy (found (Q,) bool, rank (Q,) i64)."""
    if mode is None:
        mode = resolve_mode(None)
    queries = _check_u32(queries)
    table_keys = _check_u32(table_keys, sorted_run=True)
    q = queries.shape[0]
    if q == 0:
        return np.zeros(0, bool), np.zeros(0, np.int64)
    qp = round_up(max(QUERY_TILE, next_pow2(q)), QUERY_TILE)
    qs = _pad_q(queries, qp)
    tk = _run_dev(table_keys, U32_TABLE_PAD, "run")
    if mode == "xla":
        found, rank = _xla_rank(qs, tk)
    else:
        found, rank = rank_probe_pallas(qs.reshape(qp, 1), tk,
                                        interpret=(mode == "interpret"))
        found, rank = found[:, 0], rank[:, 0]
    return (np.asarray(found)[:q],
            np.asarray(rank)[:q].astype(np.int64))


def interval_rank(queries, mins, maxs, *, mode=None):
    """Index of the covering [min, max] interval per query; -1 if none.

    ``mins`` sorted ascending, intervals disjoint (an LSM level's file
    bounds).  Matches ``searchsorted(mins, q, 'right') - 1`` plus the max
    bound check.  -> numpy (Q,) i64."""
    if mode is None:
        mode = resolve_mode(None)
    queries = _check_u32(queries)
    mins = _check_u32(mins, sorted_run=True)
    q, n = queries.shape[0], mins.shape[0]
    if q == 0 or n == 0:
        return np.full(q, -1, np.int64)
    qp = round_up(max(QUERY_TILE, next_pow2(q)), QUERY_TILE)
    qs = _pad_q(queries, qp)
    # all-ones pad is > any real query, so padded mins never count as <=
    ms = _run_dev(mins, U32_MAX, "mins")
    if mode == "xla":
        cnt = _xla_count(qs, ms)
    else:
        cnt = count_le_pallas(qs.reshape(qp, 1), ms,
                              interpret=(mode == "interpret"))[:, 0]
    fidx = np.asarray(cnt)[:q].astype(np.int64) - 1
    ok = fidx >= 0
    safe = np.where(ok, fidx, 0)
    ok &= queries.astype(np.uint32) <= maxs[safe].astype(np.uint32)
    return np.where(ok, fidx, -1)
