"""Fused lookup-probe kernel: bloom bit test + membership/rank in one pass
(the read layer's per-table hot loop, DESIGN.md §12).

TPU adaptation: the sorted key run streams through VMEM in chunks and each
query tile accumulates ``found`` (equality any) and ``rank`` (count of
strictly-less — exactly ``searchsorted`` left on a sorted run) by
compare-and-reduce; the bloom word fetch is one-hot multiply-reduce over
the u32-viewed filter words, with the k bit indices precomputed on the
host from the engine's hoisted u64 ``hash_family`` column (u64 modulo is
host-side work — kernels stay in u32 lanes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import QUERY_TILE, TABLE_CHUNK, WORD_CHUNK


def _membership(q, tk_ref):
    """(found, rank) for a query tile vs the resident sorted run."""
    n = tk_ref.shape[0]

    def body(i, carry):
        found, rank = carry
        ck = tk_ref[pl.ds(i * TABLE_CHUNK, TABLE_CHUNK)]      # (C,)
        eq = q == ck[None, :]                                 # (QT, C)
        lt = ck[None, :] < q
        found = found | eq.any(axis=1, keepdims=True)
        rank = rank + lt.astype(jnp.int32).sum(axis=1, keepdims=True)
        return found, rank

    init = (jnp.zeros(q.shape, jnp.bool_), jnp.zeros(q.shape, jnp.int32))
    return jax.lax.fori_loop(0, n // TABLE_CHUNK, body, init)


def _bloom_test(q_shape, bit_ref, w_ref, k):
    """AND of k one-hot-fetched word bit tests (k is static: python loop)."""
    w = w_ref.shape[0]
    may = jnp.ones(q_shape, jnp.bool_)
    for j in range(k):
        idx = bit_ref[:, j:j + 1].astype(jnp.uint32)          # (QT, 1)
        word_i = idx >> jnp.uint32(5)
        bit_i = idx & jnp.uint32(31)

        def fetch(c, acc, word_i=word_i):
            chunk = w_ref[pl.ds(c * WORD_CHUNK, WORD_CHUNK)]
            base = (c * WORD_CHUNK
                    + jax.lax.broadcasted_iota(jnp.uint32, (1, WORD_CHUNK),
                                               1))
            sel = (word_i == base).astype(jnp.uint32)          # (QT, WC)
            return acc + (sel * chunk[None, :]).sum(axis=1, keepdims=True)

        word = jax.lax.fori_loop(0, w // WORD_CHUNK, fetch,
                                 jnp.zeros(q_shape, jnp.uint32))
        may = may & (((word >> bit_i) & jnp.uint32(1)) == jnp.uint32(1))
    return may


def _probe_kernel(q_ref, tk_ref, bit_ref, w_ref, may_ref, found_ref,
                  rank_ref, *, k: int):
    q = q_ref[...].astype(jnp.uint32)
    found, rank = _membership(q, tk_ref)
    may_ref[...] = _bloom_test(q.shape, bit_ref, w_ref, k)
    found_ref[...] = found
    rank_ref[...] = rank


def _rank_kernel(q_ref, tk_ref, found_ref, rank_ref):
    q = q_ref[...].astype(jnp.uint32)
    found, rank = _membership(q, tk_ref)
    found_ref[...] = found
    rank_ref[...] = rank


def _count_le_kernel(q_ref, mins_ref, cnt_ref):
    q = q_ref[...].astype(jnp.uint32)
    n = mins_ref.shape[0]

    def body(i, cnt):
        ck = mins_ref[pl.ds(i * TABLE_CHUNK, TABLE_CHUNK)]
        le = ck[None, :] <= q
        return cnt + le.astype(jnp.int32).sum(axis=1, keepdims=True)

    cnt_ref[...] = jax.lax.fori_loop(0, n // TABLE_CHUNK, body,
                                     jnp.zeros(q.shape, jnp.int32))


def _qtile(i):
    return (i, 0)


def _full(i):
    return (0,)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def lookup_probe_pallas(queries, table_keys, bit_idx, words, *, k: int,
                        interpret=True):
    """queries (Q,1) u32; table_keys (N,) sorted u32; bit_idx (Q,k) u32;
    words (W,) u32.  Q % QUERY_TILE == N % TABLE_CHUNK == W % WORD_CHUNK
    == 0.  -> (may, found (Q,1) bool, rank (Q,1) i32)."""
    q, n, w = queries.shape[0], table_keys.shape[0], words.shape[0]
    assert (q % QUERY_TILE == 0 and n % TABLE_CHUNK == 0
            and w % WORD_CHUNK == 0)
    return pl.pallas_call(
        functools.partial(_probe_kernel, k=k),
        grid=(q // QUERY_TILE,),
        in_specs=[
            pl.BlockSpec((QUERY_TILE, 1), _qtile),
            pl.BlockSpec((n,), _full),
            pl.BlockSpec((QUERY_TILE, k), _qtile),
            pl.BlockSpec((w,), _full),
        ],
        out_specs=[
            pl.BlockSpec((QUERY_TILE, 1), _qtile),
            pl.BlockSpec((QUERY_TILE, 1), _qtile),
            pl.BlockSpec((QUERY_TILE, 1), _qtile),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, 1), jnp.bool_),
            jax.ShapeDtypeStruct((q, 1), jnp.bool_),
            jax.ShapeDtypeStruct((q, 1), jnp.int32),
        ],
        interpret=interpret,
    )(queries, table_keys, bit_idx, words)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rank_probe_pallas(queries, table_keys, *, interpret=True):
    """Membership/rank only (memtable probes carry no bloom filter)."""
    q, n = queries.shape[0], table_keys.shape[0]
    assert q % QUERY_TILE == 0 and n % TABLE_CHUNK == 0
    return pl.pallas_call(
        _rank_kernel,
        grid=(q // QUERY_TILE,),
        in_specs=[
            pl.BlockSpec((QUERY_TILE, 1), _qtile),
            pl.BlockSpec((n,), _full),
        ],
        out_specs=[
            pl.BlockSpec((QUERY_TILE, 1), _qtile),
            pl.BlockSpec((QUERY_TILE, 1), _qtile),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, 1), jnp.bool_),
            jax.ShapeDtypeStruct((q, 1), jnp.int32),
        ],
        interpret=interpret,
    )(queries, table_keys)


@functools.partial(jax.jit, static_argnames=("interpret",))
def count_le_pallas(queries, mins, *, interpret=True):
    """Per-query count of run entries <= query (level file assignment)."""
    q, n = queries.shape[0], mins.shape[0]
    assert q % QUERY_TILE == 0 and n % TABLE_CHUNK == 0
    return pl.pallas_call(
        _count_le_kernel,
        grid=(q // QUERY_TILE,),
        in_specs=[
            pl.BlockSpec((QUERY_TILE, 1), _qtile),
            pl.BlockSpec((n,), _full),
        ],
        out_specs=pl.BlockSpec((QUERY_TILE, 1), _qtile),
        out_shape=jax.ShapeDtypeStruct((q, 1), jnp.int32),
        interpret=interpret,
    )(queries, mins)
