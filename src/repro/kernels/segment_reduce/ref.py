"""Pure-jnp oracles for the segment-reduce kernels (scatter-add / fancy
gather — what XLA compiles well on CPU; the Pallas kernels replace them
with one-hot compare-reduce on TPU)."""

from __future__ import annotations

import jax.numpy as jnp


def segment_sum_ref(ids, n_slots: int):
    """ids (P,) i32 (-1 = masked) -> (n_slots,) i32 occurrence counts."""
    ids = ids.astype(jnp.int32)
    valid = (ids >= 0) & (ids < n_slots)
    safe = jnp.clip(ids, 0, max(n_slots - 1, 0))
    return jnp.zeros(n_slots, jnp.int32).at[safe].add(
        valid.astype(jnp.int32))


def gather_min64_ref(hi, lo, idx):
    """hi/lo (D, W) u32 planes, idx (Q, D) i32 -> ((Q,), (Q,)) u32
    lexicographic min over the D fetched (hi, lo) pairs."""
    best_h = hi[0][idx[:, 0]]
    best_l = lo[0][idx[:, 0]]
    for d in range(1, hi.shape[0]):
        h = hi[d][idx[:, d]]
        low = lo[d][idx[:, d]]
        lt = (h < best_h) | ((h == best_h) & (low < best_l))
        best_h = jnp.where(lt, h, best_h)
        best_l = jnp.where(lt, low, best_l)
    return best_h, best_l
