from .ops import gather_min64, segment_sum
from .ref import gather_min64_ref, segment_sum_ref

__all__ = ["segment_sum", "gather_min64",
           "segment_sum_ref", "gather_min64_ref"]
