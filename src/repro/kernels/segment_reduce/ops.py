"""Dispatching wrappers for the segment-reduce ops.

``segment_sum`` pads ids to a pow2 multiple of TABLE_CHUNK (masked with
-1) and the slot extent to a pow2 multiple of SLOT_TILE, so both the jit
cache and the Pallas grid see a bounded family of shapes; callers slice
the trimmed counts.  ``gather_min64`` carries float64 sketch state as
(hi, lo) u32 bit-pattern planes — exact for the sketch's non-negative
counters, no x64 mode needed inside the kernels.
"""

from __future__ import annotations

import jax
import numpy as np

from ..common import (QUERY_TILE, SLOT_TILE, TABLE_CHUNK, U32_MAX,
                      next_pow2, resolve_mode, round_up)
from .kernel import gather_min64_pallas, segment_sum_pallas
from .ref import gather_min64_ref, segment_sum_ref

_xla_seg = jax.jit(segment_sum_ref, static_argnames=("n_slots",))
_xla_gmin = jax.jit(gather_min64_ref)


def segment_sum(ids, n_slots: int, *, mode=None):
    """Occurrence count per slot for an id column (ids outside
    [0, n_slots) are ignored).  -> numpy (n_slots,) i64."""
    if mode is None:
        mode = resolve_mode(None)
    n_slots = int(n_slots)
    ids = np.asarray(ids)
    if ids.shape[0] == 0 or n_slots == 0:
        return np.zeros(n_slots, np.int64)
    sp = round_up(max(SLOT_TILE, next_pow2(n_slots)), SLOT_TILE)
    ip = np.full(max(TABLE_CHUNK, next_pow2(ids.shape[0])), -1, np.int32)
    ip[:ids.shape[0]] = ids
    if mode == "xla":
        counts = _xla_seg(ip, n_slots=sp)
    else:
        counts = segment_sum_pallas(ip, n_slots=sp,
                                    interpret=(mode == "interpret"))[:, 0]
    return np.asarray(counts)[:n_slots].astype(np.int64)


def gather_min64(hi, lo, idx, *, mode=None):
    """Lexicographic (hi, lo) pair minimum over D one-per-row fetches.

    hi/lo (D, W) u32; idx (Q, D) i32 in [0, W).  -> numpy ((Q,), (Q,))
    u32 — the bit-pattern planes of the float64 count-min estimate."""
    if mode is None:
        mode = resolve_mode(None)
    hi = np.asarray(hi)
    lo = np.asarray(lo)
    idx = np.asarray(idx)
    q = idx.shape[0]
    if q == 0:
        return np.zeros(0, np.uint32), np.zeros(0, np.uint32)
    d, w = hi.shape
    wp = round_up(max(TABLE_CHUNK, next_pow2(w)), TABLE_CHUNK)
    qp = round_up(max(QUERY_TILE, next_pow2(q)), QUERY_TILE)
    # pad slots with all-ones (the largest pair) — real idx never lands
    # there, and padded query rows are trimmed anyway
    hp = np.full((d, wp), U32_MAX, np.uint32)
    hp[:, :w] = hi
    lp = np.full((d, wp), U32_MAX, np.uint32)
    lp[:, :w] = lo
    ip = np.zeros((qp, d), np.int32)
    ip[:q] = idx
    if mode == "xla":
        oh, ol = _xla_gmin(hp, lp, ip)
    else:
        oh, ol = gather_min64_pallas(hp, lp, ip,
                                     interpret=(mode == "interpret"))
        oh, ol = oh[:, 0], ol[:, 0]
    return np.asarray(oh)[:q], np.asarray(ol)[:q]
