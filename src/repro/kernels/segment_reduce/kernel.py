"""Segment-reduce kernels for the adaptive tracker (DESIGN.md §8, §12).

Two gather/scatter-free primitives cover the DecaySketch / lifetime
histogram hot path:

  * ``segment_sum`` — integer occurrence counts per slot.  TPUs have no
    vector scatter-add, so the grid walks *output* slot tiles and each
    tile one-hot-matches the whole id column against its slot range
    (compare + reduce, the transpose of the gather-via-matmul trick).
    Counts are exact integers; the host applies them to the float64
    sketch state in one vectorized add, which keeps kernel-on and
    kernel-off arithmetic bit-identical.

  * ``gather_min64`` — count-min estimate reads.  The f64 sketch rows
    arrive as (hi, lo) u32 bit-pattern planes (non-negative IEEE doubles
    order lexicographically by bit pattern), fetched one-hot per depth row
    and min-reduced pairwise — bit-exact against numpy's gather + min.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import QUERY_TILE, SLOT_TILE, TABLE_CHUNK


def _seg_kernel(ids_ref, out_ref):
    i = pl.program_id(0)
    n = ids_ref.shape[0]
    base = (i * SLOT_TILE
            + jax.lax.broadcasted_iota(jnp.int32, (SLOT_TILE, 1), 0))

    def body(c, acc):
        chunk = ids_ref[pl.ds(c * TABLE_CHUNK, TABLE_CHUNK)]   # (C,)
        sel = base == chunk[None, :]                           # (ST, C)
        return acc + sel.astype(jnp.int32).sum(axis=1, keepdims=True)

    out_ref[...] = jax.lax.fori_loop(
        0, n // TABLE_CHUNK, body, jnp.zeros((SLOT_TILE, 1), jnp.int32))


@functools.partial(jax.jit, static_argnames=("n_slots", "interpret"))
def segment_sum_pallas(ids, *, n_slots: int, interpret=True):
    """ids (P,) i32 (-1 = masked), P % TABLE_CHUNK == 0; n_slots the
    static output extent (S % SLOT_TILE == 0).  -> (S, 1) i32 counts."""
    p, s = ids.shape[0], n_slots
    assert p % TABLE_CHUNK == 0 and s % SLOT_TILE == 0
    return pl.pallas_call(
        _seg_kernel,
        grid=(s // SLOT_TILE,),
        in_specs=[pl.BlockSpec((p,), lambda i: (0,))],
        out_specs=pl.BlockSpec((SLOT_TILE, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, 1), jnp.int32),
        interpret=interpret,
    )(ids)


def _gmin_kernel(hi_ref, lo_ref, idx_ref, ohi_ref, olo_ref, *, depth: int):
    w = hi_ref.shape[1]
    best_h = best_l = None
    for d in range(depth):
        idx = idx_ref[:, d:d + 1]                              # (QT, 1) i32

        def fetch(c, carry, idx=idx, d=d):
            ah, al = carry
            ch = hi_ref[d, pl.ds(c * TABLE_CHUNK, TABLE_CHUNK)]
            cl = lo_ref[d, pl.ds(c * TABLE_CHUNK, TABLE_CHUNK)]
            base = (c * TABLE_CHUNK
                    + jax.lax.broadcasted_iota(jnp.int32, (1, TABLE_CHUNK),
                                               1))
            sel = (idx == base).astype(jnp.uint32)             # (QT, C)
            ah = ah + (sel * ch[None, :]).sum(axis=1, keepdims=True)
            al = al + (sel * cl[None, :]).sum(axis=1, keepdims=True)
            return ah, al

        z = jnp.zeros(idx.shape, jnp.uint32)
        h, low = jax.lax.fori_loop(0, w // TABLE_CHUNK, fetch, (z, z))
        if best_h is None:
            best_h, best_l = h, low
        else:
            lt = (h < best_h) | ((h == best_h) & (low < best_l))
            best_h = jnp.where(lt, h, best_h)
            best_l = jnp.where(lt, low, best_l)
    ohi_ref[...] = best_h
    olo_ref[...] = best_l


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_min64_pallas(hi, lo, idx, *, interpret=True):
    """hi/lo (D, W) u32 bit-pattern planes; idx (Q, D) i32 slot indices
    per depth row.  Q % QUERY_TILE == 0, W % TABLE_CHUNK == 0.
    -> ((Q,1), (Q,1)) u32 lexicographic min over depth rows."""
    d, w = hi.shape
    q = idx.shape[0]
    assert q % QUERY_TILE == 0 and w % TABLE_CHUNK == 0
    return pl.pallas_call(
        functools.partial(_gmin_kernel, depth=d),
        grid=(q // QUERY_TILE,),
        in_specs=[
            pl.BlockSpec((d, w), lambda i: (0, 0)),
            pl.BlockSpec((d, w), lambda i: (0, 0)),
            pl.BlockSpec((QUERY_TILE, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((QUERY_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((QUERY_TILE, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, 1), jnp.uint32),
            jax.ShapeDtypeStruct((q, 1), jnp.uint32),
        ],
        interpret=interpret,
    )(hi, lo, idx)
