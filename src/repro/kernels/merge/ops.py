"""Jitted wrapper for the merge kernel: padding to power-of-two halves."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..common import U32_MAX, interpret_default, next_pow2, pad_to
from .kernel import merge_dedup_pallas


def merge_dedup(ak, aseq, avid, bk, bseq, bvid, *, interpret=None):
    """Merge two sorted (keys, seqs, vids) runs with newest-wins dedup.
    Returns (keys, seqs, vids, keep) trimmed of padding; padded sentinel
    entries sort to the end and are removed before returning."""
    if interpret is None:
        interpret = interpret_default()
    ak = jnp.asarray(ak).astype(jnp.uint32)
    bk = jnp.asarray(bk).astype(jnp.uint32)
    na, nb = ak.shape[0], bk.shape[0]
    half = next_pow2(max(na, nb, 1))
    a = [pad_to(ak, half, U32_MAX),
         pad_to(jnp.asarray(aseq).astype(jnp.uint32), half, 0),
         pad_to(jnp.asarray(avid).astype(jnp.uint32), half, 0)]
    b = [pad_to(bk, half, U32_MAX),
         pad_to(jnp.asarray(bseq).astype(jnp.uint32), half, 0),
         pad_to(jnp.asarray(bvid).astype(jnp.uint32), half, 0)]
    keys, seqs, vids, keep = merge_dedup_pallas(*a, *b, interpret=interpret)
    n = na + nb
    return keys[:n], seqs[:n], vids[:n], keep[:n]
