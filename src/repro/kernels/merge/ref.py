"""Pure-jnp oracle for the merge kernel."""

import jax.numpy as jnp


def merge_dedup_ref(ak, aseq, avid, bk, bseq, bvid):
    """Merge two sorted runs with newest-wins dedup.
    -> (keys, seqs, vids, keep) all length len(a)+len(b), sorted by
    (key asc, seq desc); keep marks the surviving copy of each key."""
    keys = jnp.concatenate([ak, bk])
    seqs = jnp.concatenate([aseq, bseq])
    vids = jnp.concatenate([avid, bvid])
    order = jnp.lexsort((jnp.uint32(0xFFFFFFFF) - seqs, keys))
    keys, seqs, vids = keys[order], seqs[order], vids[order]
    first = jnp.concatenate([jnp.ones((1,), bool), keys[1:] != keys[:-1]])
    return keys, seqs, vids, first
