from .ops import merge_dedup
from .ref import merge_dedup_ref

__all__ = ["merge_dedup", "merge_dedup_ref"]
