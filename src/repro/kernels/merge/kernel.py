"""Compaction merge kernel: merge two sorted runs, newest-wins dedup.

The compaction inner loop (paper §II-A) is a k-way heap merge on CPUs.  TPU
adaptation: concat(A, reverse(B)) is a bitonic sequence; a bitonic merge
network (log2(N) fixed-stride compare-exchange passes, gather-free) sorts it
while carrying (seq, vid) payloads in lockstep.  Duplicate keys (one version
per input run) end up adjacent; a neighbour-compare pass emits a keep-mask
that drops the older sequence number.  Output compaction (masked scatter) is
left to XLA outside the kernel — scatters don't vectorize on the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import bitonic_merge


def _kernel(ak_ref, as_ref, av_ref, bk_ref, bs_ref, bv_ref,
            k_ref, s_ref, v_ref, keep_ref):
    ak, a_s, av = ak_ref[...], as_ref[...], av_ref[...]
    bk, b_s, bv = bk_ref[...], bs_ref[...], bv_ref[...]
    keys = jnp.concatenate([ak, bk[::-1]])
    seqs = jnp.concatenate([a_s, b_s[::-1]])
    vids = jnp.concatenate([av, bv[::-1]])
    keys, seqs, vids = bitonic_merge(keys, seqs, vids, ascending=True)
    # newest-wins dedup: equal keys are adjacent (<=2 copies, one per run)
    n = keys.shape[0]
    prev_k = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, keys.dtype),
                              keys[:-1]])
    prev_s = jnp.concatenate([jnp.zeros((1,), seqs.dtype), seqs[:-1]])
    next_k = jnp.concatenate([keys[1:], jnp.full((1,), 0xFFFFFFFF,
                                                 keys.dtype)])
    next_s = jnp.concatenate([seqs[1:], jnp.zeros((1,), seqs.dtype)])
    dup_prev = (keys == prev_k) & (seqs < prev_s)
    dup_next = (keys == next_k) & (seqs <= next_s)
    keep = ~(dup_prev | dup_next)
    k_ref[...] = keys
    s_ref[...] = seqs
    v_ref[...] = vids
    keep_ref[...] = keep


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_dedup_pallas(ak, aseq, avid, bk, bseq, bvid, *, interpret=True):
    """Two sorted runs (padded to equal power-of-two halves) -> merged
    sorted arrays + keep mask.  All inputs u32 (N,)."""
    n = ak.shape[0] + bk.shape[0]
    assert (n & (n - 1)) == 0, "total length must be a power of two"
    out = jax.ShapeDtypeStruct((n,), jnp.uint32)
    return pl.pallas_call(
        _kernel,
        out_shape=[out, out, out, jax.ShapeDtypeStruct((n,), jnp.bool_)],
        interpret=interpret,
    )(ak, aseq, avid, bk, bseq, bvid)
