"""TPU Pallas kernels for the engine's compute hot spots.

Validated in interpret mode on CPU against the pure-jnp oracles in each
package's ref.py; lowered with explicit BlockSpec VMEM tiling for TPU.
The engine routes its batched hot paths here through ``core/accel.py``
(``EngineConfig.use_kernels``, DESIGN.md §12).
"""

from .bloom import bloom_build, bloom_probe, bloom_build_ref, bloom_probe_ref
from .gc_lookup import gc_lookup, gc_lookup_ref
from .lookup_probe import (interval_rank, lookup_probe, lookup_probe_ref,
                           rank_probe, rank_probe_ref)
from .merge import merge_dedup, merge_dedup_ref
from .partition import hot_cold_partition, hot_cold_partition_ref
from .paged_gather import page_gather, page_gather_ref
from .run_coalesce import run_coalesce, run_coalesce_ref
from .segment_reduce import (gather_min64, gather_min64_ref, segment_sum,
                             segment_sum_ref)

__all__ = [
    "bloom_build", "bloom_probe", "bloom_build_ref", "bloom_probe_ref",
    "gc_lookup", "gc_lookup_ref", "merge_dedup", "merge_dedup_ref",
    "hot_cold_partition", "hot_cold_partition_ref",
    "page_gather", "page_gather_ref",
    "lookup_probe", "lookup_probe_ref", "rank_probe", "rank_probe_ref",
    "interval_rank", "run_coalesce", "run_coalesce_ref",
    "segment_sum", "segment_sum_ref", "gather_min64", "gather_min64_ref",
]
