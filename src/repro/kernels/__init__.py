"""TPU Pallas kernels for the engine's compute hot spots.

Validated in interpret mode on CPU against the pure-jnp oracles in each
package's ref.py; lowered with explicit BlockSpec VMEM tiling for TPU.
"""

from .bloom import bloom_build, bloom_probe, bloom_build_ref, bloom_probe_ref
from .gc_lookup import gc_lookup, gc_lookup_ref
from .merge import merge_dedup, merge_dedup_ref
from .partition import hot_cold_partition, hot_cold_partition_ref
from .paged_gather import page_gather, page_gather_ref

__all__ = [
    "bloom_build", "bloom_probe", "bloom_build_ref", "bloom_probe_ref",
    "gc_lookup", "gc_lookup_ref", "merge_dedup", "merge_dedup_ref",
    "hot_cold_partition", "hot_cold_partition_ref",
    "page_gather", "page_gather_ref",
]
