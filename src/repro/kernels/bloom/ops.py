"""Jitted wrappers for bloom build/probe."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine.keys import bloom_params

from ..common import (QUERY_TILE, WORD_CHUNK, interpret_default, pad_to,
                      round_up)
from .kernel import bloom_probe_pallas
from .ref import bloom_build_ref


def bloom_build(keys, bits_per_key: int = 10):
    """Build filter words for a key set; (k, nbits) come from the engine's
    canonical ``bloom_params`` derivation, with nbits further rounded up to
    the kernel's u32 word chunk.  Returns (words u32 (W,), k, nbits)."""
    keys = jnp.asarray(keys).astype(jnp.uint32)
    k, nbits = bloom_params(keys.shape[0], bits_per_key)
    nbits = round_up(nbits, 32 * WORD_CHUNK)
    return bloom_build_ref(keys, k, nbits), k, nbits


def bloom_probe(queries, words, k: int, nbits: int, *, interpret=None):
    """-> bool (Q,) may-contain mask."""
    if interpret is None:
        interpret = interpret_default()
    queries = jnp.asarray(queries).astype(jnp.uint32)
    q = queries.shape[0]
    if q == 0:
        return jnp.zeros((0,), bool)
    qp = round_up(q, QUERY_TILE)
    qs = pad_to(queries, qp, 0).reshape(qp, 1)
    out = bloom_probe_pallas(qs, words, k=k, nbits=nbits,
                             interpret=interpret)
    return out[:q, 0]
