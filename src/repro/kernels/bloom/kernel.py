"""Bloom-filter probe kernel (10 bits/key SSTable filters, paper §IV-A).

TPU adaptation: the filter's u32 words live in VMEM; per-lane word fetch is
done with one-hot multiply-reduce ("gather via compare+reduce") instead of a
gather, then bits are tested with shifts.  k hash probes run in a fori loop
with double hashing (h1 + j*h2), the same family the engine uses.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import MIX1, MIX2, QUERY_TILE, WORD_CHUNK, mix32


def _kernel(q_ref, bits_ref, out_ref, *, k: int, nbits: int):
    q = q_ref[...].astype(jnp.uint32)          # (QT, 1)
    w = bits_ref.shape[0]
    h1 = mix32(q)
    h2 = mix32(q ^ MIX1) | jnp.uint32(1)
    ok = jnp.ones(q.shape, jnp.bool_)

    def probe(j, ok):
        idx = (h1 + jnp.uint32(j) * h2) % jnp.uint32(nbits)   # (QT,1)
        word_i = idx >> jnp.uint32(5)
        bit_i = idx & jnp.uint32(31)

        def fetch(c, acc):
            chunk = bits_ref[pl.ds(c * WORD_CHUNK, WORD_CHUNK)]
            base = (c * WORD_CHUNK
                    + jax.lax.broadcasted_iota(jnp.uint32, (1, WORD_CHUNK),
                                               1))
            sel = (word_i == base).astype(jnp.uint32)          # (QT, WC)
            return acc + (sel * chunk[None, :]).sum(axis=1, keepdims=True)

        word = jax.lax.fori_loop(0, w // WORD_CHUNK, fetch,
                                 jnp.zeros(q.shape, jnp.uint32))
        hit = ((word >> bit_i) & jnp.uint32(1)) == jnp.uint32(1)
        return ok & hit

    out_ref[...] = jax.lax.fori_loop(0, k, probe, ok)


@functools.partial(jax.jit, static_argnames=("k", "nbits", "interpret"))
def bloom_probe_pallas(queries, bits, *, k: int, nbits: int, interpret=True):
    """queries (Q,1) u32, bits (W,) u32 with W % WORD_CHUNK == 0."""
    q = queries.shape[0]
    w = bits.shape[0]
    assert q % QUERY_TILE == 0 and w % WORD_CHUNK == 0
    return pl.pallas_call(
        functools.partial(_kernel, k=k, nbits=nbits),
        grid=(q // QUERY_TILE,),
        in_specs=[
            pl.BlockSpec((QUERY_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((w,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((QUERY_TILE, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, 1), jnp.bool_),
        interpret=interpret,
    )(queries, bits)
