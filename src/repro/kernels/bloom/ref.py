"""Pure-jnp oracle for the bloom kernel (build + probe)."""

import jax.numpy as jnp

from ..common import MIX1, mix32


def bloom_hashes(keys, k: int, nbits: int):
    keys = keys.astype(jnp.uint32)
    h1 = mix32(keys)
    h2 = mix32(keys ^ MIX1) | jnp.uint32(1)
    js = jnp.arange(k, dtype=jnp.uint32)[:, None]
    return (h1[None, :] + js * h2[None, :]) % jnp.uint32(nbits)


def bloom_build_ref(keys, k: int, nbits: int):
    """-> u32 word array of length nbits//32 with key bits set."""
    assert nbits % 32 == 0
    idx = bloom_hashes(keys, k, nbits).ravel()
    words = idx >> jnp.uint32(5)
    bits = jnp.uint32(1) << (idx & jnp.uint32(31))
    return _or_scatter(words, bits, nbits // 32)


def _or_scatter(words, bits, w):
    out = jnp.zeros(w, jnp.uint32)
    for b in range(32):
        m = jnp.uint32(1) << b
        hit = (bits & m) != 0
        contrib = jnp.zeros(w, jnp.uint32).at[words].add(
            jnp.where(hit, jnp.uint32(1), jnp.uint32(0)))
        out = out | jnp.where(contrib > 0, m, jnp.uint32(0))
    return out


def bloom_probe_ref(queries, bits_words, k: int, nbits: int):
    idx = bloom_hashes(queries, k, nbits)          # (k, Q)
    words = bits_words[idx >> jnp.uint32(5)]
    hit = (words >> (idx & jnp.uint32(31))) & jnp.uint32(1)
    return (hit == 1).all(axis=0)
