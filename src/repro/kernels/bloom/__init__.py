from .ops import bloom_build, bloom_probe
from .ref import bloom_build_ref, bloom_probe_ref

__all__ = ["bloom_build", "bloom_probe", "bloom_build_ref",
           "bloom_probe_ref"]
