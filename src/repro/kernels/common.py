"""Shared helpers for the TPU kernels.

TPU adaptation notes (DESIGN.md §3): the engine's u64 keys enter kernels as
32-bit lanes (the workloads' key spaces are dense ints < 2^32; 24B string
keys would be dictionary-encoded to u32 at the table level).  TPU vector
units have no efficient per-lane gather from VMEM, so every kernel is built
from gather-free primitives:

  * membership/rank  -> tiled compare-and-reduce (brute-force compares beat
    pointer chasing on the VPU),
  * bloom word fetch -> one-hot multiply-reduce ("gather via matmul"),
  * merge/sort       -> bitonic compare-exchange networks at fixed strides,
  * page fetch       -> block-level dynamic slices driven by scalar-prefetch
    (the one dynamic-indexing form TPUs do support).
"""

from __future__ import annotations

import functools
import weakref

import jax
import jax.numpy as jnp
import numpy as np

MIX1 = np.uint32(0x85EBCA6B)
MIX2 = np.uint32(0xC2B2AE35)

# ---- canonical VMEM tile sizes (the only place magic tiles may live;
# enforced by the config-discipline scavlint pass) ----
QUERY_TILE = 256        # query rows per grid step (sublane-friendly)
TABLE_CHUNK = 512       # sorted-run chunk streamed per compare-reduce step
WORD_CHUNK = 512        # u32 filter words per one-hot fetch step
SLOT_TILE = 512         # output slots per segment-reduce grid step

# u32 lane sentinels: queries pad with MAX, table runs with MAX-1, so real
# keys must stay strictly below MAX-1 (checked by the ops wrappers)
U32_MAX = np.uint32(0xFFFFFFFF)
U32_TABLE_PAD = np.uint32(0xFFFFFFFE)


def interpret_default() -> bool:
    """Run kernels in interpret mode unless on a real TPU."""
    return jax.default_backend() != "tpu"


# ---- device residency cache for immutable host columns ----
# Host->device transfer dominates CPU dispatch for the big per-structure
# operands (sorted runs, filter words).  The engine's table columns are
# immutable, so their padded device copies are cached against the host
# array's identity and dropped when the host column is garbage collected
# (table eviction / version turnover).
_DEVICE_CACHE: dict = {}


def device_cached(host_arr: np.ndarray, tag: str, build):
    """``build()``'s device array, cached under ``(id(host_arr), tag)``.

    The host array must be treated as immutable by the caller — the cache
    returns the stale device copy otherwise."""
    key = (id(host_arr), tag)
    ent = _DEVICE_CACHE.get(key)
    if ent is not None and ent[0]() is host_arr:
        return ent[1]
    dev = build()
    _DEVICE_CACHE[key] = (weakref.ref(host_arr), dev)
    weakref.finalize(host_arr, _DEVICE_CACHE.pop, key, None)
    return dev


def resolve_mode(kernel_interpret: bool | None) -> str:
    """Map ``EngineConfig.kernel_interpret`` to an execution mode.

    ``None``  -> "pallas" (compiled Mosaic) on a real TPU, "xla" (the
                 jit-compiled pure-jnp oracle graph — same integer math,
                 no interpreter overhead) everywhere else;
    ``True``  -> "interpret" (the Pallas interpreter, for kernel-fidelity
                 runs on CPU);
    ``False`` -> "pallas" (force compiled lowering).

    All three modes are byte-identical on the engine's integer columns —
    the mode only moves where the arithmetic runs.
    """
    if kernel_interpret is None:
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return "interpret" if kernel_interpret else "pallas"


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 finalizer (u32 -> u32), vectorized."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * MIX1
    x = x ^ (x >> 13)
    x = x * MIX2
    return x ^ (x >> 16)


def pad_to(x: jnp.ndarray, n: int, fill) -> jnp.ndarray:
    if x.shape[0] == n:
        return x
    pad = jnp.full((n - x.shape[0],) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1)).bit_length()


def round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def bitonic_merge(keys, *payloads, ascending=True):
    """Merge a bitonic sequence of length 2^k (log fixed-stride passes)."""
    n = keys.shape[0]
    assert (n & (n - 1)) == 0, "power-of-two length required"
    stride = n // 2
    while stride >= 1:
        rows = n // (2 * stride)
        dir_up = jnp.full((rows,), ascending)
        keys, payloads = _cmpx(keys, payloads, stride, dir_up)
        stride //= 2
    return (keys,) + payloads


def _cmpx(keys, payloads, stride, dir_up_row):
    """One compare-exchange pass at fixed ``stride`` (gather-free:
    reshape to (rows, 2, stride) and swap halves).  ``dir_up_row`` is a
    (rows,) bool: ascending rows swap when lo > hi."""
    n = keys.shape[0]
    k2 = keys.reshape(-1, 2, stride)
    lo, hi = k2[:, 0, :], k2[:, 1, :]
    up = dir_up_row[:, None]
    swap = jnp.where(up, lo > hi, lo < hi)
    keys = jnp.stack([jnp.where(swap, hi, lo), jnp.where(swap, lo, hi)],
                     axis=1).reshape(n)
    out_p = []
    for p in payloads:
        p2 = p.reshape(-1, 2, stride)
        plo, phi = p2[:, 0, :], p2[:, 1, :]
        out_p.append(jnp.stack([jnp.where(swap, phi, plo),
                                jnp.where(swap, plo, phi)],
                               axis=1).reshape(n))
    return keys, tuple(out_p)


def _cmpx2(k1, k2, payloads, stride, dir_up_row):
    """Lexicographic compare-exchange on key *pairs* (k1 major, k2 minor)
    at fixed ``stride`` — same gather-free reshape-and-swap as ``_cmpx``."""
    n = k1.shape[0]
    a1, a2 = k1.reshape(-1, 2, stride), k2.reshape(-1, 2, stride)
    lo1, hi1 = a1[:, 0, :], a1[:, 1, :]
    lo2, hi2 = a2[:, 0, :], a2[:, 1, :]
    up = dir_up_row[:, None]
    gt = (lo1 > hi1) | ((lo1 == hi1) & (lo2 > hi2))
    lt = (lo1 < hi1) | ((lo1 == hi1) & (lo2 < hi2))
    swap = jnp.where(up, gt, lt)

    def _sw(lo, hi):
        return jnp.stack([jnp.where(swap, hi, lo), jnp.where(swap, lo, hi)],
                         axis=1).reshape(n)

    return _sw(lo1, hi1), _sw(lo2, hi2), tuple(
        _sw(p.reshape(-1, 2, stride)[:, 0, :],
            p.reshape(-1, 2, stride)[:, 1, :]) for p in payloads)


def bitonic_sort_pairs(k1, k2, *payloads, ascending=True):
    """Bitonic sort by the lexicographic pair key (k1, k2); payloads ride
    along.  Gather-free fixed-stride network, power-of-two length."""
    n = k1.shape[0]
    assert (n & (n - 1)) == 0
    size = 2
    while size <= n:
        stride = size // 2
        while stride >= 1:
            rows = n // (2 * stride)
            row_base = jnp.arange(rows) * (2 * stride)
            dir_up = ((row_base & size) == 0) == ascending
            k1, k2, payloads = _cmpx2(k1, k2, payloads, stride, dir_up)
            stride //= 2
        size *= 2
    return (k1, k2) + payloads


def prefix_sum(x):
    """Inclusive prefix sum via Hillis-Steele shifted adds (gather-free:
    log2(n) fixed-offset slice+concat passes)."""
    n = x.shape[0]
    s = 1
    while s < n:
        x = x + jnp.concatenate([jnp.zeros((s,), x.dtype), x[:-s]])
        s *= 2
    return x


def prefix_max(x):
    """Inclusive running maximum, same shifted-scan shape as prefix_sum."""
    n = x.shape[0]
    s = 1
    while s < n:
        lead = jnp.full((s,), x[0], x.dtype) if n else x
        x = jnp.maximum(x, jnp.concatenate([lead, x[:-s]]))
        s *= 2
    return x


def bitonic_sort(keys, *payloads, ascending=True):
    """Full bitonic sort network (log^2 fixed-stride passes, gather-free)."""
    n = keys.shape[0]
    assert (n & (n - 1)) == 0
    size = 2
    while size <= n:
        stride = size // 2
        while stride >= 1:
            rows = n // (2 * stride)
            row_base = jnp.arange(rows) * (2 * stride)
            dir_up = ((row_base & size) == 0) == ascending
            keys, payloads = _cmpx(keys, payloads, stride, dir_up)
            stride //= 2
        size *= 2
    return (keys,) + payloads
