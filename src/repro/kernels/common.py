"""Shared helpers for the TPU kernels.

TPU adaptation notes (DESIGN.md §3): the engine's u64 keys enter kernels as
32-bit lanes (the workloads' key spaces are dense ints < 2^32; 24B string
keys would be dictionary-encoded to u32 at the table level).  TPU vector
units have no efficient per-lane gather from VMEM, so every kernel is built
from gather-free primitives:

  * membership/rank  -> tiled compare-and-reduce (brute-force compares beat
    pointer chasing on the VPU),
  * bloom word fetch -> one-hot multiply-reduce ("gather via matmul"),
  * merge/sort       -> bitonic compare-exchange networks at fixed strides,
  * page fetch       -> block-level dynamic slices driven by scalar-prefetch
    (the one dynamic-indexing form TPUs do support).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

MIX1 = np.uint32(0x85EBCA6B)
MIX2 = np.uint32(0xC2B2AE35)


def interpret_default() -> bool:
    """Run kernels in interpret mode unless on a real TPU."""
    return jax.default_backend() != "tpu"


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 finalizer (u32 -> u32), vectorized."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * MIX1
    x = x ^ (x >> 13)
    x = x * MIX2
    return x ^ (x >> 16)


def pad_to(x: jnp.ndarray, n: int, fill) -> jnp.ndarray:
    if x.shape[0] == n:
        return x
    pad = jnp.full((n - x.shape[0],) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1)).bit_length()


def round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def bitonic_merge(keys, *payloads, ascending=True):
    """Merge a bitonic sequence of length 2^k (log fixed-stride passes)."""
    n = keys.shape[0]
    assert (n & (n - 1)) == 0, "power-of-two length required"
    stride = n // 2
    while stride >= 1:
        rows = n // (2 * stride)
        dir_up = jnp.full((rows,), ascending)
        keys, payloads = _cmpx(keys, payloads, stride, dir_up)
        stride //= 2
    return (keys,) + payloads


def _cmpx(keys, payloads, stride, dir_up_row):
    """One compare-exchange pass at fixed ``stride`` (gather-free:
    reshape to (rows, 2, stride) and swap halves).  ``dir_up_row`` is a
    (rows,) bool: ascending rows swap when lo > hi."""
    n = keys.shape[0]
    k2 = keys.reshape(-1, 2, stride)
    lo, hi = k2[:, 0, :], k2[:, 1, :]
    up = dir_up_row[:, None]
    swap = jnp.where(up, lo > hi, lo < hi)
    keys = jnp.stack([jnp.where(swap, hi, lo), jnp.where(swap, lo, hi)],
                     axis=1).reshape(n)
    out_p = []
    for p in payloads:
        p2 = p.reshape(-1, 2, stride)
        plo, phi = p2[:, 0, :], p2[:, 1, :]
        out_p.append(jnp.stack([jnp.where(swap, phi, plo),
                                jnp.where(swap, plo, phi)],
                               axis=1).reshape(n))
    return keys, tuple(out_p)


def bitonic_sort(keys, *payloads, ascending=True):
    """Full bitonic sort network (log^2 fixed-stride passes, gather-free)."""
    n = keys.shape[0]
    assert (n & (n - 1)) == 0
    size = 2
    while size <= n:
        stride = size // 2
        while stride >= 1:
            rows = n // (2 * stride)
            row_base = jnp.arange(rows) * (2 * stride)
            dir_up = ((row_base & size) == 0) == ascending
            keys, payloads = _cmpx(keys, payloads, stride, dir_up)
            stride //= 2
        size *= 2
    return (keys,) + payloads
