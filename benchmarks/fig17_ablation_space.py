"""Fig. 17/18: feature ablation on SPACE AMP without a limit.

Paper claims: compensated compaction alone shrinks space amp <=4% (it
exposes garbage that GC must then collect); adding I/O-efficient GC brings
up to 30%; S_index converges to ~1.1 with compensation.
"""

from repro.workloads import fixed, mixed_8k, pareto_1k

from .common import ds_bytes, load_update, row
from .fig16_features import VARIANTS


def run(scale=None):
    rows = []
    for spec in (fixed(8192, ds_bytes(16)), pareto_1k(ds_bytes(8))):
        for name, kw in VARIANTS.items():
            kw = dict(kw)
            engine = kw.pop("engine")
            st = load_update(engine, spec, **kw)
            rows.append(row(f"fig17/{name}/{spec.name}",
                            st["us_per_update"],
                            space_amp=st["space_amp"],
                            s_index=st["s_index"],
                            exposed_over_valid=st["exposed_over_valid"]))
    return rows
