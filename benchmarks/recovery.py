"""Recovery benchmark: restart time vs WAL length vs checkpoint interval.

A durable store (``Store(cfg, durability_dir=...)`` — or a
``ShardedStore`` fleet under ``REPRO_SHARDS``) runs the paper's standard
load + update procedure, checkpointing every ``ckpt_every`` update chunks
(0 = never: recovery replays the entire op journal).  The store is then
recovered with ``Store.open`` / ``ShardedStore.open`` (MANIFEST-then-WAL,
DESIGN.md §9) and the row reports:

  * ``us_per_call``   — simulated us per update of the *original* run
    (the CSV contract's figure; durability must not move it),
  * ``derived``       — wall-clock recovery time, journal records
    replayed, checkpoint count, snapshot size, and ``match``: 1 when the
    recovered ``stats()`` dict equals the live store's byte-for-byte (the
    §9 recovery contract).

More frequent checkpoints → shorter WAL tail → faster recovery but more
snapshot bytes written: the durability space-time trade-off.  Rows append
to the repo-root ``BENCH_recovery.json`` trajectory
(``benchmarks.common.persist_trajectory``).
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time
from pathlib import Path

from repro.core import EngineConfig, ShardedStore, Store
from repro.core.durability import Durability, read_manifest, read_wal
from repro.workloads import Runner, pareto_1k

from .common import (batch_size, ds_bytes, persist_trajectory, row,
                     scale_name, shard_count, shard_policy, trajectory_path)

N_CHUNKS = 8
TRAJECTORY = "BENCH_recovery.json"


def _journal_tail(root: Path) -> tuple[int, int]:
    """(records in the segments recovery replays, checkpoint count)."""
    edits = read_manifest(root / Durability.MANIFEST)
    ckpt_kinds = ("checkpoint", "fleet_checkpoint")
    wal_from = 0
    n_ckpts = 0
    for e in edits:
        if e.kind in ckpt_kinds:
            n_ckpts += 1
            wal_from = int(e.data["wal_epoch"])
    n = sum(len(read_wal(root / e.data["file"])) for e in edits
            if e.kind == "wal_segment" and int(e.data["epoch"]) >= wal_from)
    return n, n_ckpts


def _snapshot_bytes(root: Path) -> int:
    return sum(p.stat().st_size for p in root.rglob("snap-*.ckpt"))


def _one(engine: str, ckpt_every: int) -> dict:
    spec = pareto_1k(ds_bytes(8))
    tmp = Path(tempfile.mkdtemp(prefix="repro-recovery-"))
    try:
        shards = shard_count()
        if shards > 1:
            cfg = EngineConfig.scaled(engine, spec.dataset_bytes // shards,
                                      est_keys=max(64,
                                                   spec.n_keys // shards))
            store = ShardedStore(cfg, n_shards=shards,
                                 shard_policy=shard_policy(),
                                 key_space=spec.n_keys, durability_dir=tmp)
            opener = ShardedStore.open
        else:
            cfg = EngineConfig.scaled(engine, spec.dataset_bytes,
                                      est_keys=spec.n_keys)
            store = Store(cfg, durability_dir=tmp)
            opener = Store.open
        r = Runner(store, spec, batch=batch_size())
        r.load()
        t0 = store.io.clock_us
        per = max(1, spec.n_updates // N_CHUNKS)
        for i in range(N_CHUNKS):
            r.update(per)
            # never checkpoint after the last chunk: the replayed WAL tail
            # is the ops since the last checkpoint, so the sweep shows the
            # recovery-time vs snapshot-bytes trade-off
            if ckpt_every and (i + 1) % ckpt_every == 0 \
                    and i + 1 < N_CHUNKS:
                store.checkpoint()
        us_sim = (store.io.clock_us - t0) / (per * N_CHUNKS)
        live = store.stats()
        store.close()

        wal_records, n_ckpts = _journal_tail(tmp)
        t0 = time.perf_counter()
        recovered = opener(tmp)
        recover_s = time.perf_counter() - t0
        match = int(recovered.stats() == live)
        recovered.close()
        return {
            "us_sim": us_sim,
            "recover_ms": recover_s * 1e3,
            "wal_records": wal_records,
            "n_ckpts": n_ckpts,
            "snap_mb": _snapshot_bytes(tmp) / 2**20,
            "match": match,
        }
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def run(scale: str | None = None) -> list[dict]:
    engines = ("scavenger",) if scale_name() == "quick" \
        else ("scavenger", "titan", "scavenger_adaptive")
    rows = []
    for engine in engines:
        for ckpt_every in (0, 4, 2, 1):      # 0 = replay the whole journal
            m = _one(engine, ckpt_every)
            rows.append(row(
                f"recovery/{engine}/ckpt_every_{ckpt_every or 'never'}",
                m["us_sim"],
                recover_ms=m["recover_ms"], wal_records=m["wal_records"],
                n_ckpts=m["n_ckpts"], snap_mb=m["snap_mb"],
                match=m["match"]))
            assert m["match"] == 1, \
                f"recovered stats diverged for {engine}/{ckpt_every}"
    # honor the same env override every trajectory writer respects
    persist_trajectory("recovery", rows,
                       path=os.environ.get("REPRO_BENCH_TRAJECTORY",
                                           trajectory_path(TRAJECTORY)))
    return rows
