"""Vectorized read/value-path microbenchmark (layered-core refactor row).

Measures the post-refactor hot read path per engine: ``multi_get`` at batch
256 (vectorized ``lookup_entries`` + run-coalesced ``read_values_batch``)
and ``multi_scan``, reporting simulated us/op alongside the wall-clock
us/op that the vectorization targets (``wall_us`` carries the Python-side
planning cost: batched memtable probes, hoisted bloom hashing, one ``find``
per touched file).  ``hybrid`` rides the same registry as the five paper
engines, so the row doubles as a smoke test of the strategy layer.
"""

import time

import numpy as np

from repro.workloads import pareto_1k

from .common import build, ds_bytes, row

BATCH = 256
ENGINES_ROW = ("scavenger", "terarkdb", "hybrid")


def run(scale=None):
    rows = []
    for engine in ENGINES_ROW:
        spec = pareto_1k(dataset_bytes=ds_bytes(8))
        store, r = build(engine, spec)
        r.load()
        r.update(spec.n_keys)
        store.drain()

        rng = np.random.default_rng(123)
        keys = r.keys.sample(rng, BATCH).astype(np.uint64)
        t0, w0 = store.io.fg_clock_us, time.perf_counter()
        reps = 8
        for _ in range(reps):
            store.multi_get(keys)
        us = (store.io.fg_clock_us - t0) / (BATCH * reps)
        wall = (time.perf_counter() - w0) / (BATCH * reps) * 1e6
        rows.append(row(f"read_path/multi_get_{engine}", us, wall_us=wall))

        starts = rng.integers(0, spec.n_keys, 32)
        t0, w0 = store.io.fg_clock_us, time.perf_counter()
        store.multi_scan(starts, 20)
        us_sc = (store.io.fg_clock_us - t0) / 32
        wall_sc = (time.perf_counter() - w0) / 32 * 1e6
        rows.append(row(f"read_path/multi_scan_{engine}", us_sc,
                        wall_us=wall_sc))
    return rows
