"""Table I: insert-only space overhead of Scavenger vs TerarkDB.

Paper claims: RTable's dense index costs <5% extra space (4.78% @1K,
0.51% @4K, 0.04% @16K, 0.29% Mixed-8K, 2.19% Pareto-1K).
"""

from repro.workloads import fixed, mixed_8k, pareto_1k

from .common import build, ds_bytes, row


def run(scale=None):
    rows = []
    wls = [fixed(1024, ds_bytes(8)), fixed(4096, ds_bytes(8)),
           fixed(16384, ds_bytes(16)), mixed_8k(ds_bytes(16)),
           pareto_1k(ds_bytes(8))]
    for spec in wls:
        sizes = {}
        for engine in ("terarkdb", "scavenger"):
            store, r = build(engine, spec)
            r.load()
            sizes[engine] = store.space_bytes()
        over = sizes["scavenger"] / sizes["terarkdb"] - 1
        rows.append(row(f"table1/{spec.name}", 0.0,
                        terarkdb_mb=sizes["terarkdb"] / 1e6,
                        scavenger_mb=sizes["scavenger"] / 1e6,
                        overhead_pct=100 * over))
    return rows
