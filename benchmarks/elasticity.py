"""Elasticity benchmark: hot-shard auto-split under a hotspot workload
(DESIGN.md §14).

A 2-shard range fleet runs the standard load + update procedure with a
*static* contiguous hotspot (``HotspotKeys`` pinned to one phase): 90% of
updates hammer one shard's slice.  Two runs per engine over the identical
op stream:

  * ``static``  — elasticity off: the hot shard soaks up the traffic and
    its space share stays pinned near the hotspot's weight.
  * ``elastic`` — the elasticity manager watches per-shard space/traffic
    shares and splits the hot shard online (checkpoint-copy, re-route,
    delta-replay); the row reports migration count, migrated MB, the
    total write-fence downtime (``fence_ms`` — the only window where
    writes to a moving range block), and the max per-shard space share
    before/after.

The headline contract: splits reduce the hottest shard's share of fleet
space with *bounded* fence downtime (asserted < 1% of update time), and
``fence_ms`` is gated against the trajectory history by
``benchmarks.perf_report --gate`` so migration downtime regressions fail
the build.  Rows append to the repo-root ``BENCH_fleet.json``.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core import EngineConfig, ShardedStore
from repro.workloads import HotspotKeys, Runner, pareto_1k

from .common import (batch_size, ds_bytes, persist_trajectory, row,
                     scale_name, trace_observer, trajectory_path)

TRAJECTORY = "BENCH_fleet.json"
N_FLEET = 2
SPLIT_FRAC = 0.55       # split when a shard holds > 55% of space/traffic
COOLDOWN_OPS = 2048
MAX_SHARDS = 4
HOT_FRAC = 0.9
FENCE_BUDGET = 0.01     # fence downtime must stay < 1% of update time


def _max_share(fleet) -> float:
    space = [s.version.total_bytes() for s in fleet.shards]
    tot = sum(space)
    return max(space) / tot if tot else 0.0


def _hot_seed(n_keys: int) -> int:
    """Smallest HotspotKeys seed whose (hashed) hot-set position lands
    entirely inside one of the two initial shard slices — the benchmark
    needs the hotspot to make exactly one shard hot, not straddle the
    boundary and heat both."""
    half = n_keys // N_FLEET
    for seed in range(64):
        probe = HotspotKeys(n_keys, hot_n=max(1, n_keys // 8),
                            hot_frac=1.0, shift_every=1 << 30, seed=seed)
        ks = probe.sample(np.random.default_rng(0), 512)
        if ks.max() < half or ks.min() >= half:
            return seed
    return 0


def _one(engine: str, elastic: bool) -> dict:
    spec = pareto_1k(ds_bytes(8))
    knobs = dict(elastic_split_frac=SPLIT_FRAC,
                 elastic_cooldown_ops=COOLDOWN_OPS,
                 elastic_max_shards=MAX_SHARDS) if elastic else {}
    cfg = EngineConfig.scaled(engine, spec.dataset_bytes // N_FLEET,
                              est_keys=max(64, spec.n_keys // N_FLEET),
                              observer=trace_observer(), **knobs)
    fleet = ShardedStore(cfg, n_shards=N_FLEET, shard_policy="range",
                         key_space=spec.n_keys)
    # static hotspot (shift_every past the op count): 90% of updates hit
    # one contiguous eighth of the keyspace — one shard's slice
    hot = HotspotKeys(spec.n_keys, hot_n=max(1, spec.n_keys // 8),
                      hot_frac=HOT_FRAC, shift_every=1 << 30,
                      seed=_hot_seed(spec.n_keys))
    r = Runner(fleet, spec, batch=batch_size(), key_gen=hot)
    r.load()
    share_loaded = _max_share(fleet)
    up = r.update()
    fleet.drain()
    st = fleet.stats()
    errors = r.check_reads(
        np.arange(0, spec.n_keys, max(1, spec.n_keys // 512)))
    assert errors == 0, f"{engine} fleet lost reads after elasticity"
    fence_us = sum(m["fence_us"] for m in fleet.migrations)
    return {
        "us_per_update": up["sim_s"] * 1e6 / up["ops"],
        "update_us": up["sim_s"] * 1e6,
        "share_loaded": share_loaded,
        "share_final": _max_share(fleet),
        "n_shards": len(fleet.shards),
        "n_migrations": st["n_migrations"],
        "fence_ms": fence_us / 1e3,
        "migrated_mb": fleet.migrated_bytes() / 2**20,
        "space_amp": st["space_amp"],
    }


def run(scale: str | None = None) -> list[dict]:
    engines = ("scavenger",) if scale_name() == "quick" \
        else ("scavenger", "titan", "scavenger_adaptive")
    rows = []
    for engine in engines:
        static = _one(engine, elastic=False)
        m = _one(engine, elastic=True)
        assert m["n_migrations"] >= 1, \
            f"{engine}: hotspot never triggered a split"
        assert m["share_final"] < static["share_final"], \
            f"{engine}: split did not reduce the hot shard's space share"
        assert m["fence_ms"] * 1e3 <= FENCE_BUDGET * m["update_us"], \
            f"{engine}: fence downtime {m['fence_ms']:.3f}ms exceeds " \
            f"{FENCE_BUDGET:.0%} of update time"
        rows.append(row(
            f"elasticity/{engine}/static", static["us_per_update"],
            share_final=static["share_final"],
            n_shards=static["n_shards"], space_amp=static["space_amp"]))
        er = row(
            f"elasticity/{engine}/elastic", m["us_per_update"],
            share_loaded=m["share_loaded"], share_final=m["share_final"],
            n_shards=m["n_shards"], n_migrations=m["n_migrations"],
            fence_ms=m["fence_ms"], migrated_mb=m["migrated_mb"],
            space_amp=m["space_amp"])
        # top-level copy of the downtime metric: the perf gate only reads
        # typed row keys, not the derived string (perf_report._row_metrics)
        er["fence_ms"] = round(m["fence_ms"], 3)
        rows.append(er)
    persist_trajectory("fleet", rows,
                       path=os.environ.get("REPRO_BENCH_TRAJECTORY",
                                           trajectory_path(TRAJECTORY)))
    return rows
