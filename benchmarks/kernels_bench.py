"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle — correctness
timing on CPU; TPU wall-time comes from real hardware, not this container.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (bloom_build, bloom_probe, bloom_probe_ref,
                           gc_lookup, gc_lookup_ref, hot_cold_partition,
                           merge_dedup, page_gather, page_gather_ref)

from .common import row


def _time(fn, *args, reps=3):
    fn(*args)                      # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(scale=None):
    rng = np.random.default_rng(0)
    rows = []
    n, q = 8192, 1024
    skeys = np.sort(rng.choice(np.arange(10 * n, dtype=np.uint32), n,
                               replace=False))
    svids = skeys + 1
    svf = skeys % 997
    queries = rng.choice(skeys, q)
    us_k = _time(lambda: gc_lookup(queries, skeys, svids, svf))
    us_r = _time(lambda: gc_lookup_ref(jnp.asarray(queries),
                                       jnp.asarray(skeys),
                                       jnp.asarray(svids),
                                       jnp.asarray(svf)))
    rows.append(row("kernels/gc_lookup", us_k, ref_us=us_r, n=n, q=q))

    words, k, nbits = bloom_build(skeys)
    us_k = _time(lambda: bloom_probe(queries, words, k, nbits))
    us_r = _time(lambda: bloom_probe_ref(jnp.asarray(queries), words, k,
                                         nbits))
    rows.append(row("kernels/bloom_probe", us_k, ref_us=us_r, q=q))

    ak = np.sort(rng.choice(np.arange(1 << 20, dtype=np.uint32), 2048,
                            replace=False))
    bk = np.sort(rng.choice(np.arange(1 << 20, dtype=np.uint32), 2048,
                            replace=False))
    us_k = _time(lambda: merge_dedup(ak, ak, ak, bk, bk, bk))
    rows.append(row("kernels/merge_dedup", us_k, n=4096))

    hot = rng.random(4096) < 0.3
    us_k = _time(lambda: hot_cold_partition(
        ak.repeat(2)[:4096], hot, ak.repeat(2)[:4096],
        np.full(4096, 100, np.uint32)))
    rows.append(row("kernels/partition", us_k, n=4096))

    pages = jnp.asarray(rng.standard_normal((256, 16, 128)),
                        jnp.float32)
    table = rng.integers(0, 256, (8, 32)).astype(np.int32)
    us_k = _time(lambda: page_gather(table, pages))
    us_r = _time(lambda: page_gather_ref(jnp.asarray(table), pages))
    rows.append(row("kernels/page_gather", us_k, ref_us=us_r))
    return rows
