"""Kernel microbenchmarks + the hot-path roofline (DESIGN.md §12).

Two sections:

  * legacy per-kernel rows — Pallas (interpret) vs jnp oracle, correctness
    timing on CPU; TPU wall-time comes from real hardware, not this
    container;
  * ``kernels/roofline/*`` — the three fused hot-path ops the engine
    routes through ``core/accel.py`` (lookup_probe / run_coalesce /
    segment_reduce), measured host vs jitted (``resolve_mode`` default:
    the XLA oracle on CPU, compiled Pallas on TPU) at batch 256 / 1024 /
    4096.  The lookup row drives the *real* code both ways — the engine's
    ``BloomFilter.may_contain`` + ``SSTable.find`` host functions against
    the routed ``accel.table_probe`` — on a real flushed table, so the
    row prices everything the dispatch actually pays (padding, device
    residency, output conversion) against everything the host actually
    pays (mask copies, dtype guards, where-passes).  ``us_op`` rows trace
    where the dispatch-overhead/throughput crossover sits — the basis
    for ``EngineConfig.kernel_min_batch``.

Every run is appended to the repo-root ``BENCH_kernels.json`` trajectory
(``benchmarks.common.persist_trajectory``) so the roofline accumulates
across sessions.
"""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, Store, WriteBatch, accel
from repro.core.engine.keys import BloomFilter, hash_family
from repro.core.values.fetch import split_runs
from repro.kernels import (bloom_build, bloom_probe, bloom_probe_ref,
                           gc_lookup, gc_lookup_ref, hot_cold_partition,
                           merge_dedup, page_gather, page_gather_ref,
                           run_coalesce, segment_sum)

from .common import persist_trajectory, row, trajectory_path

TRAJECTORY = "BENCH_kernels.json"

ROOFLINE_BATCHES = (256, 1024, 4096)
_TABLE_N = 65536            # sorted-run length for the lookup roofline
_N_FILES = 8                # vSSTs in the coalesce roofline
_DEPTH, _WIDTH = 2, 4096    # DecaySketch shape for the segment roofline


def _time(fn, *args, reps=3):
    fn(*args)                      # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _wall(fn, reps=20):
    """Best-of-reps wall-clock microseconds for a host-or-dispatch thunk
    (min filters scheduler noise; both sides get the same treatment)."""
    fn(), fn()                     # warm caches / jit
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


# ------------------------------------------------------------- roofline
def _roofline_lookup(rng, rows):
    """Fused bloom + membership/rank probe: the engine's real host
    functions vs the routed ``accel.table_probe``, on a real table.

    Hash-family hoisting (read/lookup.py) is shared by both paths, so
    ``kraw`` is precomputed outside the timed region exactly as the
    engine does."""
    cfg = EngineConfig.scaled("scavenger", 64 << 20, est_keys=_TABLE_N)
    store = Store(cfg)
    keys = np.arange(1, 8 * _TABLE_N, 8, dtype=np.uint64)[:_TABLE_N]
    store.write(WriteBatch().puts(keys, np.full(_TABLE_N, 200, np.int64)))
    store.drain()
    t = max((t for lvl in store.version.levels for t in lvl),
            key=lambda t: t.n)
    k = BloomFilter.k_for(cfg.filter_bits_per_key)

    def host(queries, kraw):
        may = t.bloom.may_contain(queries, raw=kraw)
        return may, t.find(queries[may])

    for q in ROOFLINE_BATCHES:
        queries = rng.choice(t.keys, q)
        kraw = hash_family(queries, k)
        assert accel.table_probe(store, t, queries, kraw) is not None
        host_us = _wall(lambda: host(queries, kraw))
        jit_us = _wall(lambda: accel.table_probe(store, t, queries, kraw))
        rows.append(row(f"kernels/roofline/lookup_probe/b{q}", jit_us,
                        host_us=host_us, us_op=jit_us / q,
                        host_us_op=host_us / q, speedup=host_us / jit_us,
                        batch=q, n=t.n))


def _roofline_coalesce(rng, rows):
    """Global run planning vs the per-file np.unique + split host planner."""
    for m in ROOFLINE_BATCHES:
        rank = np.sort(rng.integers(0, _N_FILES, m))
        pos = rng.integers(0, m // 2, m)

        def host():
            return [split_runs(np.unique(pos[rank == r]), 16)
                    for r in range(_N_FILES)]

        jit_us = _wall(lambda: run_coalesce(rank, pos, window=16))
        host_us = _wall(host)
        rows.append(row(f"kernels/roofline/run_coalesce/b{m}", jit_us,
                        host_us=host_us, us_op=jit_us / m,
                        host_us_op=host_us / m, speedup=host_us / jit_us,
                        batch=m, files=_N_FILES))


def _roofline_segment(rng, rows):
    """Sketch-row increments vs the per-row bincount host update."""
    shift = np.arange(_DEPTH)[:, None] * _WIDTH
    for m in ROOFLINE_BATCHES:
        idx = rng.integers(0, _WIDTH, (_DEPTH, m))
        counts = np.zeros((_DEPTH, _WIDTH))

        def host():
            c = counts.copy()
            for r in range(_DEPTH):
                c[r] += np.bincount(idx[r], minlength=_WIDTH)
            return c

        def jitted():
            seg = segment_sum((idx + shift).ravel(), _DEPTH * _WIDTH)
            return counts + seg.reshape(_DEPTH, _WIDTH)

        jit_us = _wall(jitted)
        host_us = _wall(host)
        rows.append(row(f"kernels/roofline/segment_reduce/b{m}", jit_us,
                        host_us=host_us, us_op=jit_us / m,
                        host_us_op=host_us / m, speedup=host_us / jit_us,
                        batch=m, depth=_DEPTH, width=_WIDTH))


def run(scale=None):
    rng = np.random.default_rng(0)
    rows = []
    n, q = 8192, 1024
    skeys = np.sort(rng.choice(np.arange(10 * n, dtype=np.uint32), n,
                               replace=False))
    svids = skeys + 1
    svf = skeys % 997
    queries = rng.choice(skeys, q)
    us_k = _time(lambda: gc_lookup(queries, skeys, svids, svf))
    us_r = _time(lambda: gc_lookup_ref(jnp.asarray(queries),
                                       jnp.asarray(skeys),
                                       jnp.asarray(svids),
                                       jnp.asarray(svf)))
    rows.append(row("kernels/gc_lookup", us_k, ref_us=us_r, n=n, q=q))

    words, k, nbits = bloom_build(skeys)
    us_k = _time(lambda: bloom_probe(queries, words, k, nbits))
    us_r = _time(lambda: bloom_probe_ref(jnp.asarray(queries), words, k,
                                         nbits))
    rows.append(row("kernels/bloom_probe", us_k, ref_us=us_r, q=q))

    ak = np.sort(rng.choice(np.arange(1 << 20, dtype=np.uint32), 2048,
                            replace=False))
    us_k = _time(lambda: merge_dedup(ak, ak, ak, ak, ak, ak))
    rows.append(row("kernels/merge_dedup", us_k, n=4096))

    hot = rng.random(4096) < 0.3
    us_k = _time(lambda: hot_cold_partition(
        ak.repeat(2)[:4096], hot, ak.repeat(2)[:4096],
        np.full(4096, 100, np.uint32)))
    rows.append(row("kernels/partition", us_k, n=4096))

    pages = jnp.asarray(rng.standard_normal((256, 16, 128)),
                        jnp.float32)
    table = rng.integers(0, 256, (8, 32)).astype(np.int32)
    us_k = _time(lambda: page_gather(table, pages))
    us_r = _time(lambda: page_gather_ref(jnp.asarray(table), pages))
    rows.append(row("kernels/page_gather", us_k, ref_us=us_r))

    _roofline_lookup(rng, rows)
    _roofline_coalesce(rng, rows)
    _roofline_segment(rng, rows)
    # the routing rationale: past the crossover, jitted must win
    for r in rows:
        if r["name"].startswith("kernels/roofline/lookup_probe/b"):
            q = int(r["name"].rsplit("/b", 1)[1])
            if q >= 1024:
                assert "speedup=" in r["derived"], r
                sp = float(r["derived"].split("speedup=")[1].split()[0])
                assert sp > 1.0, f"jitted lookup slower than host: {r}"
    persist_trajectory("kernels", rows,
                       path=os.environ.get("REPRO_BENCH_TRAJECTORY",
                                           trajectory_path(TRAJECTORY)))
    return rows
