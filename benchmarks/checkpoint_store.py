"""Checkpoint-store benchmark: Scavenger GC vs naive exhaustion under a
disk quota (the paper's trade-off on the training substrate).

Writes synthetic 'checkpoints' (param/opt shards) every round, keeps the
last 2, and measures space amp + GC read traffic.
"""

import shutil
import tempfile

import numpy as np

from repro.checkpoint.store import CheckpointStore

from .common import row


def _churn(engine: str, rounds=12, shards=16, shard_kb=64):
    root = tempfile.mkdtemp(prefix=f"ckpt-{engine}-")
    data = np.random.default_rng(0).bytes(shard_kb << 10)
    quota = int(3.0 * shards * (shard_kb << 10))
    st = CheckpointStore(root, engine=engine, quota_bytes=quota,
                         log_target=256 << 10)
    peak = 0
    for step in range(rounds):
        for s in range(shards):
            st.put(f"train/{step}/p{s}", data, hot=True)
        st.put(f"meta/{step}", b"{}", hot=False)
        # retention: keep last 2 steps
        if step >= 2:
            for s in range(shards):
                st.delete(f"train/{step - 2}/p{s}")
            st.delete(f"meta/{step - 2}")
        st.run_gc()
        peak = max(peak, st.total_bytes())
    out = st.stats()
    out["peak_amp"] = peak / max(st.live_bytes(), 1)
    st.close()
    shutil.rmtree(root, ignore_errors=True)
    return out


def run(scale=None):
    rows = []
    for engine in ("scavenger", "naive"):
        st = _churn(engine)
        rows.append(row(f"checkpoint/{engine}", 0.0,
                        space_amp=st["space_amp"],
                        peak_amp=st["peak_amp"],
                        gc_read_mb=st["gc_read_bytes"] / 1e6,
                        gc_runs=st["gc_runs"],
                        throttle_events=st["throttle_events"]))
    return rows
