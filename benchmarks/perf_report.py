"""§Perf report: baseline vs optimized cells, from dry-run artifacts.

  PYTHONPATH=src:. python -m benchmarks.perf_report
  PYTHONPATH=src:. python -m benchmarks.perf_report --gate [--gate-tol X]

Besides printing the markdown table, the report appends its rows to the
repo-root ``BENCH_adaptive.json`` trajectory file (``common.
persist_trajectory``) so perf history survives across runs.

A second table reports tail latency: p50/p95/p99 per op-class histogram
from the observability metrics registry (DESIGN.md §11), measured on a
fresh observed load+update run per engine and persisted to the
``BENCH_obs.json`` trajectory.

``--gate`` is the perf **regression gate** (run by ``make bench-smoke``
and CI): it compares the newest entry of every trajectory section in the
``BENCH_*.json`` files against the median of its trailing window — same
section, same bench scale — and exits nonzero when a tracked metric
regressed past the tolerance.  It only reads trajectory files (no dry-run
artifacts needed), so it can gate any checkout that has history.
"""

from __future__ import annotations

import argparse
import json
import os

from .common import persist_trajectory, trajectory_path

OBS_TRAJECTORY = "BENCH_obs.json"
# trajectory files the regression gate watches
GATE_FILES = ("BENCH_adaptive.json", "BENCH_obs.json", "BENCH_kernels.json",
              "BENCH_recovery.json", "BENCH_fleet.json")
# Default tolerance: trajectory history spans machines (BENCH files are
# committed), so wall-clock metrics need 2x headroom; tighten with
# --gate-tol when gating same-machine runs.
GATE_TOL = 1.0
GATE_WINDOW = 5         # trailing entries (per section+scale) to median

# op-class histograms worth tracking release-over-release (the rest stay
# inspectable via `python -m repro.obs summarize` on a --trace dump)
OBS_HISTS = ("write_us", "multi_get_us", "stall_us", "flush_us",
             "compact_us", "gc_us", "gc_rewrite_bytes",
             "gc_reclaimed_bytes", "kernel_lookup_probe_us",
             "kernel_run_coalesce_us", "kernel_segment_reduce_us")
OBS_ENGINES = ("rocksdb", "scavenger", "scavenger_adaptive")


def pairs():
    from .roofline import load_cells
    base = {(c["arch"], c["shape"], c["mesh"]): c
            for c in load_cells(opt=False)}
    opt = {(c["arch"], c["shape"], c["mesh"]): c
           for c in load_cells(opt=True)}
    for key in sorted(set(base) & set(opt)):
        yield key, base[key], opt[key]


def report_rows() -> list[dict]:
    """-> trajectory rows: one per (cell, mesh, term) with the speedup."""
    from .roofline import BASELINE, OPTIMIZED, analyze
    rows = []
    for (arch, shape, mesh), b, o in pairs():
        ab, ao = analyze(b, BASELINE), analyze(o, OPTIMIZED)
        for name, bv, ov in [
            ("memory_s", ab["t_memory_s"], ao["t_memory_s"]),
            ("collective_s", ab["t_collective_s"], ao["t_collective_s"]),
            ("roofline_frac", ab["roofline_frac"], ao["roofline_frac"]),
            ("temp_gb_hlo", ab["temp_bytes"] / 1e9, ao["temp_bytes"] / 1e9),
            ("coll_gb_hlo", ab["hlo_collective_bytes"] / 1e9,
             ao["hlo_collective_bytes"] / 1e9),
        ]:
            # None (not inf) when the optimized term is 0: float('inf')
            # serializes as the non-RFC-8259 token "Infinity" and would
            # corrupt the JSON trajectory for strict parsers
            rows.append({"cell": f"{arch}/{shape}", "mesh": mesh,
                         "term": name, "baseline": bv, "optimized": ov,
                         "x": (bv / ov) if ov else None})
    return rows


def obs_rows(engines=OBS_ENGINES) -> list[dict]:
    """Tail-latency rows — p50/p95/p99 per op-class histogram, merged
    across shards, from an observed load+update run per engine."""
    from repro.obs import Observer
    from repro.workloads import mixed_8k

    from .common import ds_bytes, load_update

    rows = []
    for engine in engines:
        obs = Observer()
        st = load_update(engine, mixed_8k(dataset_bytes=ds_bytes(4)),
                         observer=obs)
        st["runner"].read(512)          # populate multi_get_us
        obs.finish()
        for name in OBS_HISTS:
            h = obs.metrics.merged(name)
            if not h.count:
                continue
            rows.append({"engine": engine, "metric": name,
                         "count": h.count, "mean": h.mean,
                         "p50": h.quantile(0.50), "p95": h.quantile(0.95),
                         "p99": h.quantile(0.99)})
    return rows


# ------------------------------------------------- regression gate (§11)
def _row_metrics(row: dict):
    """-> (key, {metric: value}) for a trajectory row, or None for row
    shapes the gate does not track (e.g. perf_report's analytic cells,
    whose baseline/optimized terms are model outputs, not measurements)."""
    if "name" in row and "us_per_call" in row:
        out = {"us_per_call": row["us_per_call"]}
        # fleet rows (benchmarks/elasticity.py) expose migration fence
        # downtime as a typed key so regressions fail the gate (§14)
        if isinstance(row.get("fence_ms"), (int, float)):
            out["fence_ms"] = row["fence_ms"]
        return row["name"], out
    if "engine" in row and "metric" in row and "p99" in row:
        return f"{row['engine']}/{row['metric']}", {"p99": row["p99"]}
    if "engine" in row and "us_per_update" in row:
        key = f"{row['engine']}/{row.get('workload', '-')}"
        out = {"us_per_update": row["us_per_update"]}
        if "space_amp" in row:
            out["space_amp"] = row["space_amp"]
        return key, out
    return None


def gate(tol: float = GATE_TOL, window: int = GATE_WINDOW,
         files=GATE_FILES, out=None) -> int:
    """Compare each trajectory section's newest entry against the median
    of its trailing window (same section, same scale).  Returns the number
    of regressed metrics; prints one line per failure."""
    import sys
    out = out or sys.stdout
    failures = checked = 0
    for fname in files:
        path = trajectory_path(fname)
        if not os.path.isfile(path):
            continue
        try:
            with open(path) as f:
                data = json.load(f)
        except json.JSONDecodeError:
            print(f"{fname}: unreadable, skipped", file=out)
            continue
        if not isinstance(data, list):
            continue
        groups: dict[tuple, list] = {}
        for e in data:
            if isinstance(e, dict) and "rows" in e:
                groups.setdefault((e.get("section", "?"), e.get("scale")),
                                  []).append(e)
        for (section, scale), entries in sorted(groups.items()):
            if len(entries) < 2:
                continue        # no history yet: nothing to gate against
            latest, trail = entries[-1], entries[-1 - window:-1]
            hist: dict[tuple, list] = {}
            for e in trail:
                for r in e["rows"]:
                    km = _row_metrics(r)
                    if km is None:
                        continue
                    for m, v in km[1].items():
                        if isinstance(v, (int, float)):
                            hist.setdefault((km[0], m), []).append(v)
            for r in latest["rows"]:
                km = _row_metrics(r)
                if km is None:
                    continue
                for m, v in km[1].items():
                    vals = hist.get((km[0], m))
                    if not vals or not isinstance(v, (int, float)):
                        continue
                    ref = sorted(vals)[len(vals) // 2]
                    checked += 1
                    if ref > 0 and v > ref * (1.0 + tol):
                        failures += 1
                        print(f"GATE FAIL {fname}:{section}[{scale}] "
                              f"{km[0]} {m}: {v:.4g} vs trailing median "
                              f"{ref:.4g} (tol {tol:.0%})", file=out)
    print(f"perf gate: {checked} metrics checked, {failures} regressed "
          f"(tol {tol:.0%}, window {window})", file=out)
    return failures


def report():
    rows = report_rows()
    print("| cell | mesh | term | baseline | optimized | x |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        x = f"{r['x']:.1f}" if r["x"] is not None else "inf"
        print(f"| {r['cell']} | {r['mesh']} | {r['term']} | "
              f"{r['baseline']:.4g} | {r['optimized']:.4g} | {x} |")
    path = persist_trajectory("perf_report", rows)
    print(f"# trajectory appended to {path}")
    orows = obs_rows()
    print("| engine | metric | count | mean | p50 | p95 | p99 |")
    print("|---|---|---|---|---|---|---|")
    for r in orows:
        print(f"| {r['engine']} | {r['metric']} | {r['count']} | "
              f"{r['mean']:.4g} | {r['p50']:.4g} | {r['p95']:.4g} | "
              f"{r['p99']:.4g} |")
    opath = persist_trajectory("obs_tails", orows,
                               path=trajectory_path(OBS_TRAJECTORY))
    print(f"# obs trajectory appended to {opath}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.perf_report",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--gate", action="store_true",
                    help="regression-gate the BENCH_*.json trajectories "
                         "(exit 1 on regression); skips the report")
    ap.add_argument("--gate-tol", type=float, default=GATE_TOL,
                    help="allowed fractional slowdown vs trailing median")
    ap.add_argument("--gate-window", type=int, default=GATE_WINDOW,
                    help="trailing entries per section to compare against")
    args = ap.parse_args(argv)
    if args.gate:
        return 1 if gate(tol=args.gate_tol, window=args.gate_window) else 0
    report()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
