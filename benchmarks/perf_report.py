"""§Perf report: baseline vs optimized cells, from dry-run artifacts.

  PYTHONPATH=src:. python -m benchmarks.perf_report

Besides printing the markdown table, the report appends its rows to the
repo-root ``BENCH_adaptive.json`` trajectory file (``common.
persist_trajectory``) so perf history survives across runs.
"""

from __future__ import annotations

from .common import persist_trajectory
from .roofline import BASELINE, OPTIMIZED, analyze, load_cells


def pairs():
    base = {(c["arch"], c["shape"], c["mesh"]): c
            for c in load_cells(opt=False)}
    opt = {(c["arch"], c["shape"], c["mesh"]): c
           for c in load_cells(opt=True)}
    for key in sorted(set(base) & set(opt)):
        yield key, base[key], opt[key]


def report_rows() -> list[dict]:
    """-> trajectory rows: one per (cell, mesh, term) with the speedup."""
    rows = []
    for (arch, shape, mesh), b, o in pairs():
        ab, ao = analyze(b, BASELINE), analyze(o, OPTIMIZED)
        for name, bv, ov in [
            ("memory_s", ab["t_memory_s"], ao["t_memory_s"]),
            ("collective_s", ab["t_collective_s"], ao["t_collective_s"]),
            ("roofline_frac", ab["roofline_frac"], ao["roofline_frac"]),
            ("temp_gb_hlo", ab["temp_bytes"] / 1e9, ao["temp_bytes"] / 1e9),
            ("coll_gb_hlo", ab["hlo_collective_bytes"] / 1e9,
             ao["hlo_collective_bytes"] / 1e9),
        ]:
            # None (not inf) when the optimized term is 0: float('inf')
            # serializes as the non-RFC-8259 token "Infinity" and would
            # corrupt the JSON trajectory for strict parsers
            rows.append({"cell": f"{arch}/{shape}", "mesh": mesh,
                         "term": name, "baseline": bv, "optimized": ov,
                         "x": (bv / ov) if ov else None})
    return rows


def main():
    rows = report_rows()
    print("| cell | mesh | term | baseline | optimized | x |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        x = f"{r['x']:.1f}" if r["x"] is not None else "inf"
        print(f"| {r['cell']} | {r['mesh']} | {r['term']} | "
              f"{r['baseline']:.4g} | {r['optimized']:.4g} | {x} |")
    path = persist_trajectory("perf_report", rows)
    print(f"# trajectory appended to {path}")


if __name__ == "__main__":
    main()
