"""§Perf report: baseline vs optimized cells, from dry-run artifacts.

  PYTHONPATH=src:. python -m benchmarks.perf_report

Besides printing the markdown table, the report appends its rows to the
repo-root ``BENCH_adaptive.json`` trajectory file (``common.
persist_trajectory``) so perf history survives across runs.

A second table reports tail latency: p50/p95/p99 per op-class histogram
from the observability metrics registry (DESIGN.md §11), measured on a
fresh observed load+update run per engine and persisted to the
``BENCH_obs.json`` trajectory.
"""

from __future__ import annotations

from .common import persist_trajectory, trajectory_path
from .roofline import BASELINE, OPTIMIZED, analyze, load_cells

OBS_TRAJECTORY = "BENCH_obs.json"
# op-class histograms worth tracking release-over-release (the rest stay
# inspectable via `python -m repro.obs summarize` on a --trace dump)
OBS_HISTS = ("write_us", "multi_get_us", "stall_us", "flush_us",
             "compact_us", "gc_us", "gc_rewrite_bytes",
             "gc_reclaimed_bytes", "kernel_lookup_probe_us",
             "kernel_run_coalesce_us", "kernel_segment_reduce_us")
OBS_ENGINES = ("rocksdb", "scavenger", "scavenger_adaptive")


def pairs():
    base = {(c["arch"], c["shape"], c["mesh"]): c
            for c in load_cells(opt=False)}
    opt = {(c["arch"], c["shape"], c["mesh"]): c
           for c in load_cells(opt=True)}
    for key in sorted(set(base) & set(opt)):
        yield key, base[key], opt[key]


def report_rows() -> list[dict]:
    """-> trajectory rows: one per (cell, mesh, term) with the speedup."""
    rows = []
    for (arch, shape, mesh), b, o in pairs():
        ab, ao = analyze(b, BASELINE), analyze(o, OPTIMIZED)
        for name, bv, ov in [
            ("memory_s", ab["t_memory_s"], ao["t_memory_s"]),
            ("collective_s", ab["t_collective_s"], ao["t_collective_s"]),
            ("roofline_frac", ab["roofline_frac"], ao["roofline_frac"]),
            ("temp_gb_hlo", ab["temp_bytes"] / 1e9, ao["temp_bytes"] / 1e9),
            ("coll_gb_hlo", ab["hlo_collective_bytes"] / 1e9,
             ao["hlo_collective_bytes"] / 1e9),
        ]:
            # None (not inf) when the optimized term is 0: float('inf')
            # serializes as the non-RFC-8259 token "Infinity" and would
            # corrupt the JSON trajectory for strict parsers
            rows.append({"cell": f"{arch}/{shape}", "mesh": mesh,
                         "term": name, "baseline": bv, "optimized": ov,
                         "x": (bv / ov) if ov else None})
    return rows


def obs_rows(engines=OBS_ENGINES) -> list[dict]:
    """Tail-latency rows — p50/p95/p99 per op-class histogram, merged
    across shards, from an observed load+update run per engine."""
    from repro.obs import Observer
    from repro.workloads import mixed_8k

    from .common import ds_bytes, load_update

    rows = []
    for engine in engines:
        obs = Observer()
        st = load_update(engine, mixed_8k(dataset_bytes=ds_bytes(4)),
                         observer=obs)
        st["runner"].read(512)          # populate multi_get_us
        obs.finish()
        for name in OBS_HISTS:
            h = obs.metrics.merged(name)
            if not h.count:
                continue
            rows.append({"engine": engine, "metric": name,
                         "count": h.count, "mean": h.mean,
                         "p50": h.quantile(0.50), "p95": h.quantile(0.95),
                         "p99": h.quantile(0.99)})
    return rows


def main():
    rows = report_rows()
    print("| cell | mesh | term | baseline | optimized | x |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        x = f"{r['x']:.1f}" if r["x"] is not None else "inf"
        print(f"| {r['cell']} | {r['mesh']} | {r['term']} | "
              f"{r['baseline']:.4g} | {r['optimized']:.4g} | {x} |")
    path = persist_trajectory("perf_report", rows)
    print(f"# trajectory appended to {path}")
    orows = obs_rows()
    print("| engine | metric | count | mean | p50 | p95 | p99 |")
    print("|---|---|---|---|---|---|---|")
    for r in orows:
        print(f"| {r['engine']} | {r['metric']} | {r['count']} | "
              f"{r['mean']:.4g} | {r['p50']:.4g} | {r['p95']:.4g} | "
              f"{r['p99']:.4g} |")
    opath = persist_trajectory("obs_tails", orows,
                               path=trajectory_path(OBS_TRAJECTORY))
    print(f"# obs trajectory appended to {opath}")


if __name__ == "__main__":
    main()
