"""§Perf report: baseline vs optimized cells, from dry-run artifacts.

  PYTHONPATH=src:. python -m benchmarks.perf_report
"""

from __future__ import annotations

from .roofline import BASELINE, OPTIMIZED, analyze, load_cells


def pairs():
    base = {(c["arch"], c["shape"], c["mesh"]): c
            for c in load_cells(opt=False)}
    opt = {(c["arch"], c["shape"], c["mesh"]): c
           for c in load_cells(opt=True)}
    for key in sorted(set(base) & set(opt)):
        yield key, base[key], opt[key]


def main():
    print("| cell | mesh | term | baseline | optimized | x |")
    print("|---|---|---|---|---|---|")
    for (arch, shape, mesh), b, o in pairs():
        ab, ao = analyze(b, BASELINE), analyze(o, OPTIMIZED)
        rows = [
            ("memory s", ab["t_memory_s"], ao["t_memory_s"]),
            ("collective s", ab["t_collective_s"], ao["t_collective_s"]),
            ("roofline frac", ab["roofline_frac"], ao["roofline_frac"]),
            ("temp GB (HLO)", ab["temp_bytes"] / 1e9,
             ao["temp_bytes"] / 1e9),
            ("coll GB (HLO)", ab["hlo_collective_bytes"] / 1e9,
             ao["hlo_collective_bytes"] / 1e9),
        ]
        for name, bv, ov in rows:
            x = (bv / ov) if ov else float("inf")
            print(f"| {arch}/{shape} | {mesh} | {name} | {bv:.4g} | "
                  f"{ov:.4g} | {x:.1f} |")


if __name__ == "__main__":
    main()
