"""Fig. 16: feature ablation under the 1.5x limit.

TDB = terarkdb; TDB-C = + compensated compaction; +R lazy read; +L DTable
lookup; +W hot/cold writes; Scavenger = TDB-C+R+L+W.
Paper claims: TDB-C alone gives 1.6-2.6x update throughput on fixed-length
workloads; R helps large values, L helps variable-length.
"""

from repro.workloads import fixed, mixed_8k, pareto_1k

from .common import ds_bytes, load_update, row

VARIANTS = {
    "TDB": dict(engine="terarkdb"),
    "TDB-C": dict(engine="terarkdb", compensated_compaction=True),
    "TDB-C+R": dict(engine="scavenger", index_decoupled=False,
                    hotcold_write=False),
    "TDB-C+L": dict(engine="scavenger", lazy_read=False,
                    hotcold_write=False),
    "Scavenger": dict(engine="scavenger"),
}


def run(scale=None):
    wls = [fixed(4096, ds_bytes(8)), fixed(16384, ds_bytes(16)),
           mixed_8k(ds_bytes(16)), pareto_1k(ds_bytes(8))]
    rows = []
    for spec in wls:
        for name, kw in VARIANTS.items():
            kw = dict(kw)
            engine = kw.pop("engine")
            st = load_update(engine, spec, quota_x=1.5, **kw)
            rows.append(row(f"fig16/{name}/{spec.name}",
                            st["us_per_update"],
                            upd_kops=st["upd_kops"],
                            space_amp=st["space_amp"]))
    return rows
