"""Fleet-scheduled vs round-robin per-shard GC on a skewed-shard workload.

A 4-shard range-partitioned ShardedStore takes a Pareto-1K update stream in
which 80% of updates hit shard 0's key range (the hot shard accumulates
garbage much faster than the fleet GC lane can absorb).  Both schedulers
run under the same shared lane budget; the only difference is *where* that
budget goes:

  * round_robin — shards serviced in rotation (per-instance heuristic);
  * fleet       — jobs ranked fleet-wide by garbage ratio / compensated
    score with starvation aging (DESIGN.md §6).

Acceptance row: the fleet scheduler must end the run with aggregate space
amplification no worse than round-robin — ranking globally reclaims more
garbage per unit of GC lane time, which shows up as a lower hot-shard (and
aggregate) space amp.  The run is deterministic (seeded workload, simulated
device), so a regression here is a scheduler regression, not noise.
"""

import numpy as np

from repro.core import EngineConfig, ShardedStore
from repro.workloads import Runner, pareto_1k

from .common import batch_size, ds_bytes, row

N_SHARDS = 4
HOT_FRAC = 0.8


def _skewed_keys(rng, n: int, n_keys: int) -> np.ndarray:
    """80% of updates in shard 0's range slice, the rest uniform."""
    span = n_keys // N_SHARDS
    hot = rng.random(n) < HOT_FRAC
    return np.where(hot, rng.integers(0, span, n),
                    rng.integers(0, n_keys, n)).astype(np.uint64)


def _run_policy(scheduler: str) -> dict:
    spec = pareto_1k(dataset_bytes=ds_bytes(8))
    cfg = EngineConfig.scaled("scavenger", spec.dataset_bytes // N_SHARDS,
                              est_keys=max(64, spec.n_keys // N_SHARDS))
    store = ShardedStore(cfg, n_shards=N_SHARDS, shard_policy="range",
                         key_space=spec.n_keys, scheduler=scheduler)
    r = Runner(store, spec, batch=batch_size())
    r.load()
    rng = np.random.default_rng(spec.seed + 1)
    n = spec.n_updates
    keys = _skewed_keys(rng, n, spec.n_keys)
    sizes = spec.value_dist.sample(rng, n)
    t0 = store.io.fg_clock_us
    r.apply_puts(keys, sizes)
    store.settle()
    st = store.stats()
    st["us_per_update"] = (store.io.fg_clock_us - t0) / n
    assert r.check_reads(keys[:256]) == 0, "sharded reads diverged"
    return st


def run(scale=None):
    rows, res = [], {}
    for scheduler in ("round_robin", "fleet"):
        st = _run_policy(scheduler)
        res[scheduler] = st
        rows.append(row(f"sharding/{scheduler}", st["us_per_update"],
                        space_amp=st["space_amp"],
                        hot_shard_amp=st["shard_space_amp"][0],
                        gc_runs=st["n_gc_runs"],
                        stall_s=st["stall_s"]))
    amp_rr = res["round_robin"]["space_amp"]
    amp_fleet = res["fleet"]["space_amp"]
    rows.append(row("sharding/fleet_vs_round_robin", 0.0,
                    space_amp_saving=amp_rr - amp_fleet,
                    fleet=amp_fleet, round_robin=amp_rr))
    assert amp_fleet <= amp_rr, (
        f"fleet scheduler lost to round-robin: {amp_fleet} > {amp_rr}")
    return rows
