"""Benchmark harness entrypoint — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  REPRO_BENCH_SCALE=quick|full.
Select modules: python -m benchmarks.run [--list] [--shards N]
[--shard-policy {hash,range}] [module ...]
"""

from __future__ import annotations

import argparse
import ast
import os
import time
import traceback

MODULES = [
    "fig02_tradeoff", "fig03_gc_breakdown", "fig05_spaceamp_sources",
    "fig12_micro", "fig13_ycsb", "fig14_nolimit", "fig16_features",
    "fig17_ablation_space", "fig19_workloads", "fig20_space_limits",
    "table1_space_overhead", "batch_api", "read_path", "sharding",
    "adaptive_gc", "recovery", "elasticity", "kernels_bench",
    "serving_cache", "checkpoint_store", "roofline",
]


def describe(name: str) -> str:
    """First docstring line of a benchmark module (AST parse: listing must
    not import heavyweight dependencies like jax)."""
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        f"{name}.py")
    try:
        with open(path) as f:
            doc = ast.get_docstring(ast.parse(f.read())) or ""
    except (OSError, SyntaxError):
        return "(no description)"
    return doc.strip().splitlines()[0] if doc.strip() else "(no description)"


def list_modules() -> None:
    width = max(len(n) for n in MODULES)
    try:
        for name in MODULES:
            print(f"{name:<{width}}  {describe(name)}")
    except BrokenPipeError:            # `--list | head` closed the pipe
        os._exit(0)


def main() -> None:
    import importlib
    ap = argparse.ArgumentParser()
    ap.add_argument("modules", nargs="*", default=None)
    ap.add_argument("--list", action="store_true",
                    help="print registered benchmark modules with one-line "
                         "descriptions and exit")
    ap.add_argument("--shards", type=int, default=None,
                    help="run workloads against a ShardedStore of N shards")
    ap.add_argument("--shard-policy", choices=("hash", "range"),
                    default=None)
    ap.add_argument("--trace", metavar="DIR", default=None,
                    help="attach an observer to every store and dump one "
                         "observability directory per module under DIR "
                         "(events/metrics/health + Chrome trace JSON; see "
                         "python -m repro.obs)")
    args = ap.parse_args()
    if args.list:
        list_modules()
        return
    if args.shards is not None:
        os.environ["REPRO_SHARDS"] = str(args.shards)
    if args.shard_policy is not None:
        os.environ["REPRO_SHARD_POLICY"] = args.shard_policy
    if args.trace is not None:
        os.environ["REPRO_TRACE_DIR"] = args.trace
    names = args.modules or MODULES
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            for r in mod.run():
                print(f"{r['name']},{r['us_per_call']},{r['derived']}",
                      flush=True)
            from benchmarks import common
            out = common.dump_trace(name)
            if out is not None:
                print(f"# {name} trace -> {out}", flush=True)
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:
            failures += 1
            print(f"# {name} FAILED: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
