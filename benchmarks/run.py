"""Benchmark harness entrypoint — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  REPRO_BENCH_SCALE=quick|full.
Select modules: python -m benchmarks.run [module ...]
"""

from __future__ import annotations

import sys
import time
import traceback

MODULES = [
    "fig02_tradeoff", "fig03_gc_breakdown", "fig05_spaceamp_sources",
    "fig12_micro", "fig13_ycsb", "fig14_nolimit", "fig16_features",
    "fig17_ablation_space", "fig19_workloads", "fig20_space_limits",
    "table1_space_overhead", "batch_api", "kernels_bench", "serving_cache",
    "checkpoint_store", "roofline",
]


def main() -> None:
    import importlib
    names = sys.argv[1:] or MODULES
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            for r in mod.run():
                print(f"{r['name']},{r['us_per_call']},{r['derived']}",
                      flush=True)
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:
            failures += 1
            print(f"# {name} FAILED: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
