"""Fig. 19: update performance across value sizes, mix ratios, skew.

Paper claims: KV separation struggles <=2KB (readahead effect; S-RH
recovers); Scavenger still leads other KV-separated stores 1.1-4.0x;
advantage grows with skew (2.1-2.7x at zipf 0.99).
"""

from repro.workloads import Mixed, WorkloadSpec, fixed, mixed_8k

from .common import ds_bytes, load_update, row


def run(scale=None):
    rows = []
    # (a) fixed value sizes
    for vs in (256, 1024, 4096, 16384):
        spec = fixed(vs, ds_bytes(8 if vs <= 1024 else 16))
        for engine in ("rocksdb", "terarkdb", "scavenger"):
            st = load_update(engine, spec, quota_x=1.5)
            rows.append(row(f"fig19a/{engine}/fixed-{vs}",
                            st["us_per_update"],
                            upd_kops=st["upd_kops"],
                            space_amp=st["space_amp"]))
        # S-RH: scavenger with GC readahead enabled
        st = load_update("scavenger", spec, quota_x=1.5, readahead_gc=True)
        rows.append(row(f"fig19a/scavenger-RH/fixed-{vs}",
                        st["us_per_update"], upd_kops=st["upd_kops"]))
    # (b) mixed small:large ratios
    for frac in (0.1, 0.5, 0.9):
        spec = WorkloadSpec(f"Mixed-l{frac}", Mixed(large_frac=frac),
                            ds_bytes(16))
        for engine in ("terarkdb", "scavenger"):
            st = load_update(engine, spec, quota_x=1.5)
            rows.append(row(f"fig19b/{engine}/large{frac}",
                            st["us_per_update"],
                            upd_kops=st["upd_kops"]))
    # (c) skew
    for theta in (0.0, 0.8, 0.99, 1.2):
        spec = mixed_8k(ds_bytes(16), zipf_theta=theta)
        for engine in ("terarkdb", "scavenger"):
            st = load_update(engine, spec, quota_x=1.5)
            rows.append(row(f"fig19c/{engine}/zipf{theta}",
                            st["us_per_update"],
                            upd_kops=st["upd_kops"]))
    return rows
