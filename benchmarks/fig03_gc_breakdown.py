"""Fig. 3: GC latency breakdown (Read / GC-Lookup / Write / Write-Index).

Paper claims: Read dominates (>50%) for most workloads; GC-Lookup grows as
values shrink and dominates Pareto-1K; Titan's Write-Index ~38% of GC.
"""

from repro.core.engine import io as sio
from repro.workloads import fixed, mixed_8k, pareto_1k

from .common import ds_bytes, load_update, row


def run(scale=None):
    wls = [fixed(1024, ds_bytes(8)), fixed(4096, ds_bytes(8)),
           fixed(16384, ds_bytes(16)), mixed_8k(ds_bytes(16)),
           pareto_1k(ds_bytes(8))]
    rows = []
    for engine in ("titan", "terarkdb", "scavenger"):
        for spec in wls:
            st = load_update(engine, spec)
            io = st["store"].io
            gc_us = {c: io.time_us.get(c, 0.0) for c in sio.GC_CATS}
            tot = max(sum(gc_us.values()), 1e-9)
            rows.append(row(
                f"fig03/{engine}/{spec.name}", tot / 1e0,
                read_pct=100 * gc_us[sio.CAT_GC_READ] / tot,
                lookup_pct=100 * gc_us[sio.CAT_GC_LOOKUP] / tot,
                write_pct=100 * gc_us[sio.CAT_GC_WRITE] / tot,
                widx_pct=100 * gc_us[sio.CAT_GC_WRITE_INDEX] / tot))
    return rows
