"""Fig. 5 / Fig. 18: the two sources of space amplification.

Paper claims: S_index exceeds the ideal 1.11 and Exposed/Valid exceeds the
ideal 0.25 for existing KV-separated stores; under Fixed-8K the index tree
accounts for ~half of total amplification.  Scavenger's compensated
compaction drives S_index back to ~1.1.

Next to the analytical decomposition (s_index, exposed/valid, hidden/valid
from store state) each row carries a **live-ledger column** (DESIGN.md
§13): the attribution ledger's write-amp decomposition by cause — bytes
written per user byte on the flush path (pick=memtable_rotation), the
compaction path (compensated/physical size picks), and the GC path
(garbage-ratio / adaptive dead-byte picks, plus blobdb relocation) — so
the paper's static source analysis can be cross-checked against measured
per-cause bytes in the same table.
"""

from repro.obs import Observer, live_breakdown
from repro.workloads import fixed, pareto_1k

from .common import ds_bytes, load_update, row, trace_observer

# pick classes -> amplification source (ledger cause taxonomy, §13);
# age_cutoff is blobdb's compaction-time relocation, GC-equivalent work
_COMPACT_PICKS = ("compensated_size", "physical_size")
_GC_PICKS = ("garbage_ratio", "adaptive_dead_byte", "age_cutoff")


def ledger_wa(obs, store) -> dict:
    """Per-cause write-amp columns from the live attribution ledger."""
    lb = live_breakdown(obs, store)
    shards = getattr(store, "shards", None) or [store]
    uw = max(sum(s.user_write_bytes for s in shards), 1)
    by_pick = lb["write_bytes_by_pick"]
    return {
        "wa_flush": by_pick.get("memtable_rotation", 0) / uw,
        "wa_compact": sum(by_pick.get(p, 0) for p in _COMPACT_PICKS) / uw,
        "wa_gc": sum(by_pick.get(p, 0) for p in _GC_PICKS) / uw,
    }


def run(scale=None):
    rows = []
    for engine in ("blobdb", "titan", "terarkdb", "scavenger"):
        for spec in (fixed(8192, ds_bytes(16)), pareto_1k(ds_bytes(8))):
            # share the module trace observer when --trace is on (so the
            # dump carries the ledger); otherwise a local one per run
            obs = trace_observer() or Observer()
            st = load_update(engine, spec, observer=obs)
            s = st["store"]
            hidden = s.hidden_garbage_bytes() / max(s.valid_bytes, 1)
            rows.append(row(
                f"fig05/{engine}/{spec.name}", st["us_per_update"],
                s_index=st["s_index"],
                exposed_over_valid=st["exposed_over_valid"],
                hidden_over_valid=hidden, space_amp=st["space_amp"],
                **ledger_wa(obs, s)))
    return rows
