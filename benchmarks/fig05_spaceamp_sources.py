"""Fig. 5 / Fig. 18: the two sources of space amplification.

Paper claims: S_index exceeds the ideal 1.11 and Exposed/Valid exceeds the
ideal 0.25 for existing KV-separated stores; under Fixed-8K the index tree
accounts for ~half of total amplification.  Scavenger's compensated
compaction drives S_index back to ~1.1.
"""

from repro.workloads import fixed, pareto_1k

from .common import ds_bytes, load_update, row


def run(scale=None):
    rows = []
    for engine in ("blobdb", "titan", "terarkdb", "scavenger"):
        for spec in (fixed(8192, ds_bytes(16)), pareto_1k(ds_bytes(8))):
            st = load_update(engine, spec)
            s = st["store"]
            hidden = s.hidden_garbage_bytes() / max(s.valid_bytes, 1)
            rows.append(row(
                f"fig05/{engine}/{spec.name}", st["us_per_update"],
                s_index=st["s_index"],
                exposed_over_valid=st["exposed_over_valid"],
                hidden_over_valid=hidden, space_amp=st["space_amp"]))
    return rows
