"""Shared benchmark machinery.

Every module exposes ``run(scale) -> list[row]`` where a row is
``{"name": str, "us_per_call": float, "derived": str}`` (the CSV contract
of benchmarks/run.py).  ``us_per_call`` is simulated microseconds per user
operation (deterministic device model — see DESIGN.md §3); ``derived``
carries the figure-specific metrics being validated against the paper.

Scales: quick (default, CI-sized) | full (EXPERIMENTS.md numbers).
Dataset sizes are scaled-down versions of the paper's 100GB/300GB runs
with structural ratios held (EngineConfig.scaled).
"""

from __future__ import annotations

import os

from repro.core import EngineConfig, ShardedStore, Store
from repro.workloads import (Runner, WorkloadSpec, fixed, mixed_8k,
                             pareto_1k)

ENGINES5 = ("rocksdb", "blobdb", "titan", "terarkdb", "scavenger")


def scale_name() -> str:
    return os.environ.get("REPRO_BENCH_SCALE", "quick")


def batch_size() -> int:
    """Client batch size for the columnar Store API (REPRO_BATCH=1 for the
    scalar op-at-a-time baseline)."""
    return int(os.environ.get("REPRO_BATCH", "256"))


def shard_count() -> int:
    """Number of Store shards (REPRO_SHARDS, or --shards on
    benchmarks.run); 1 = plain single Store."""
    return int(os.environ.get("REPRO_SHARDS", "1"))


def shard_policy() -> str:
    return os.environ.get("REPRO_SHARD_POLICY", "range")


def ds_bytes(quick_mb: int) -> int:
    mult = 4 if scale_name() == "full" else 1
    return quick_mb * mult << 20


# ------------------------------------------------ tracing (DESIGN.md §11)
_TRACE_OBS = None


def trace_dir() -> str | None:
    """Observability dump root (``--trace=DIR`` on benchmarks.run, or
    REPRO_TRACE_DIR); None disables tracing."""
    return os.environ.get("REPRO_TRACE_DIR") or None


def trace_observer():
    """The Observer shared by every store built while tracing is on (one
    per benchmark module — ``dump_trace`` closes it out); None when off."""
    global _TRACE_OBS
    if trace_dir() is None:
        return None
    if _TRACE_OBS is None:
        from repro.obs import Observer
        _TRACE_OBS = Observer()
    return _TRACE_OBS


def dump_trace(module: str) -> str | None:
    """Dump and reset the live trace observer into
    ``<trace_dir>/<module>/`` (events/metrics/health/trace JSON)."""
    global _TRACE_OBS
    if _TRACE_OBS is None:
        return None
    out = os.path.join(trace_dir(), module)
    _TRACE_OBS.dump(out)
    _TRACE_OBS = None
    return out


def build(engine: str, spec: WorkloadSpec, quota_x: float | None = None,
          **overrides) -> tuple[Store, Runner]:
    """Build a (possibly sharded) store + Runner for a workload spec.

    With REPRO_SHARDS > 1 each shard gets a config scaled to its slice of
    the dataset (a shard is a full store over 1/N of the keyspace), and the
    space quota — when requested — is enforced fleet-wide."""
    quota = int(quota_x * spec.dataset_bytes) if quota_x else None
    overrides.setdefault("observer", trace_observer())
    shards = shard_count()
    if shards > 1:
        cfg = EngineConfig.scaled(engine, spec.dataset_bytes // shards,
                                  est_keys=max(64, spec.n_keys // shards),
                                  space_quota_bytes=quota, **overrides)
        store = ShardedStore(cfg, n_shards=shards,
                             shard_policy=shard_policy(),
                             key_space=spec.n_keys)
    else:
        cfg = EngineConfig.scaled(engine, spec.dataset_bytes,
                                  est_keys=spec.n_keys,
                                  space_quota_bytes=quota, **overrides)
        store = Store(cfg)
    return store, Runner(store, spec, batch=batch_size())


def load_update(engine: str, spec: WorkloadSpec,
                quota_x: float | None = None, **overrides) -> dict:
    """The paper's standard procedure: load all keys, update 3x dataset."""
    store, r = build(engine, spec, quota_x, **overrides)
    r.load()
    up = r.update()
    st = store.stats()
    st["upd_kops"] = up["ops"] / up["sim_s"] / 1e3
    st["us_per_update"] = up["sim_s"] * 1e6 / up["ops"]
    st["runner"] = r
    st["store"] = store
    return st


def row(name: str, us: float, **derived) -> dict:
    dstr = " ".join(f"{k}={v:.3f}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in derived.items())
    return {"name": name, "us_per_call": round(us, 3), "derived": dstr}


# --------------------------------------------------- perf-history trajectory
TRAJECTORY_FILE = "BENCH_adaptive.json"


def trajectory_path(filename: str) -> str:
    """Repo-root path for a named trajectory file (``BENCH_*.json``)."""
    return os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), filename)


def persist_trajectory(section: str, rows: list[dict],
                       path: str | None = None) -> str:
    """Append one benchmark run to the repo-root ``BENCH_adaptive.json``
    trajectory file (a JSON list, one entry per run), so perf history
    accumulates across sessions instead of evaporating with stdout.

    Entries carry the section name, the bench scale, a UTC timestamp, and
    the standard CSV-contract rows.  A corrupt/legacy file is restarted
    rather than crashing the benchmark."""
    import datetime
    import json

    if path is None:
        path = os.environ.get("REPRO_BENCH_TRAJECTORY",
                              trajectory_path(TRAJECTORY_FILE))
    try:
        with open(path) as f:
            data = json.load(f)
        if not isinstance(data, list):
            data = []
    except (FileNotFoundError, json.JSONDecodeError):
        data = []
    data.append({
        "ts": datetime.datetime.now(datetime.timezone.utc)
        .strftime("%Y-%m-%dT%H:%M:%SZ"),
        "section": section,
        "scale": scale_name(),
        "rows": rows,
    })
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    return path
