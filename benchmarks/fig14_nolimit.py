"""Fig. 14/15: update performance and space amp WITHOUT a space limit.

Paper claims: Scavenger matches TerarkDB's foreground performance while
cutting space amplification up to 40% (2.21 on Mixed-8K, 1.96 Pareto-1K).
"""

from repro.workloads import mixed_8k, pareto_1k

from .common import ENGINES5, ds_bytes, load_update, row


def run(scale=None):
    rows = []
    for mk, mb in ((mixed_8k, 16), (pareto_1k, 8)):
        spec = mk(dataset_bytes=ds_bytes(mb))
        best_other = 0.0
        for engine in ENGINES5:
            st = load_update(engine, spec)
            rows.append(row(f"fig14/{engine}/{spec.name}",
                            st["us_per_update"],
                            upd_kops=st["upd_kops"],
                            space_amp=st["space_amp"]))
    return rows
