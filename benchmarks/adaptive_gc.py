"""Adaptive GC benchmark: skewed + shifting-hotspot workloads.

Compares the static-threshold GC engines (``titan`` writeback baseline,
``scavenger``) against ``scavenger_adaptive`` (workload tracker + predicted
dead-byte-yield candidate choice + temperature-partitioned vSSTs,
DESIGN.md §8) on the two workloads where workload-awareness should pay:

  * ``skewed``   — stationary Zipf(0.99) updates (the paper's default key
    distribution): hot keys churn, cold values should stop being rewritten.
  * ``shifting`` — ``HotspotKeys``: 90% of updates hit a 2%-of-keyspace
    hotspot that relocates periodically, so hotness must *decay* to stay
    correct.

Reported per engine: simulated us/update, GC rewrite bytes (GC value
writes + Titan Write-Index), space amplification, GC run count.  The
adaptive rows carry ``beats_titan``: 1 when GC rewrite bytes are lower at
equal-or-better space_amp than the titan baseline on that workload (the
ISSUE 4 acceptance gate).  Results are appended to the repo-root
``BENCH_adaptive.json`` trajectory.
"""

from __future__ import annotations

from repro.core.engine import io as sio
from repro.workloads import HotspotKeys, Runner, pareto_1k

from .common import build, ds_bytes, persist_trajectory, row

ENGINES = ("titan", "scavenger", "scavenger_adaptive")


def gc_rewrite_bytes(store) -> int:
    """Value-store rewrite traffic charged to GC: merged-survivor writes
    plus Titan's Write-Index records (both are bytes GC re-wrote to keep
    live data alive)."""
    shards = getattr(store, "shards", None) or [store]
    return sum(s.io.write_bytes.get(sio.CAT_GC_WRITE, 0)
               + s.io.write_bytes.get(sio.CAT_GC_WRITE_INDEX, 0)
               for s in shards)


def _run_workload(engine: str, wl: str):
    spec = pareto_1k(ds_bytes(16))
    store, r = build(engine, spec)
    if wl == "shifting":
        r = Runner(store, spec, batch=r.batch,
                   key_gen=HotspotKeys(spec.n_keys,
                                       hot_n=max(64, spec.n_keys // 50),
                                       hot_frac=0.9,
                                       shift_every=max(2048,
                                                       spec.n_updates // 8),
                                       seed=spec.seed))
    r.load()
    up = r.update()
    st = store.stats()
    return {
        "us": up["sim_s"] * 1e6 / up["ops"],
        "gc_mb": gc_rewrite_bytes(store) / 2**20,
        "space_amp": st["space_amp"],
        "n_gc": st["n_gc_runs"],
    }


def run(scale=None):
    rows, traj = [], []
    for wl in ("skewed", "shifting"):
        res = {e: _run_workload(e, wl) for e in ENGINES}
        for e in ENGINES:
            m = res[e]
            derived = dict(gc_rewrite_mb=m["gc_mb"],
                           space_amp=m["space_amp"], n_gc=m["n_gc"])
            if e == "scavenger_adaptive" and wl == "skewed":
                # the ISSUE 4 acceptance gate is the skewed-hotspot
                # workload; on shifting, titan's low rewrite volume is
                # GC starvation (its space_amp shows it), so the gate
                # would compare incomparable operating points
                t = res["titan"]
                derived["beats_titan"] = int(
                    m["gc_mb"] < t["gc_mb"]
                    and m["space_amp"] <= t["space_amp"])
            rows.append(row(f"adaptive_gc/{wl}/{e}", m["us"], **derived))
            # trajectory entries keep the metrics structured (plottable /
            # gateable without parsing the CSV display string)
            traj.append({"workload": wl, "engine": e,
                         "us_per_update": m["us"], **derived})
    persist_trajectory("adaptive_gc", traj)
    return rows
