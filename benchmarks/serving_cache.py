"""Serving-cache benchmark: Scavenger-style extent GC vs naive paging.

Drives the paged KV manager with a churn trace (mixed short/long
sequences); reports fragmentation amplification, admission blocks and
relocation traffic — the HBM analog of the paper's space-time trade-off.
Admission metadata writes are mirrored into a ``scavenger_adaptive``
metadata store exactly like ``ServeEngine._admit`` does, with an observer
attached (DESIGN.md §11): the derived columns add the *simulated* p50/p99
admission latency on the metadata critical path and the hot/cold vSST
byte mix the temperature-segregated store settles into.
"""

import numpy as np

from repro.core import EngineConfig, Store, WriteBatch
from repro.obs import Observer, sample_store
from repro.serve.paged_cache import PagedKVCacheManager

from .common import row

# Mirrors repro.serve.engine._PAGE_META_BYTES: vsize per reserved page in
# a rid's admission record.
_PAGE_META_BYTES = 16


def _drive(mgr, rng, meta, n_reqs=400, ckpt_every=48):
    live = []
    pages: dict[int, int] = {}
    for rid in range(n_reqs):
        if rid and rid % ckpt_every == 0:
            # periodic metadata checkpoint: the live rid set is tiny, so
            # the memtable would otherwise never fill and never flush —
            # rotation is what materializes the temperature-classified
            # vSSTs this benchmark reports on
            meta.rotate_memtable()
            meta.drain()
        need = int(rng.integers(1, 8))
        hot = rng.random() < 0.75          # 25% long-lived (cold)
        if mgr.admit(rid, need, hot=hot):
            live.append((rid, hot))
            pages[rid] = need
            # admission wave: one metadata record per admitted rid, timed
            # on the simulated foreground clock (ServeEngine._admit shape)
            t0 = meta.io.fg_clock_us
            meta.write(WriteBatch().puts(
                np.array([rid], np.uint64),
                np.array([need * _PAGE_META_BYTES], np.int64)))
            meta.obs.on_op(meta, "admission_us", meta.io.fg_clock_us - t0)
            meta.obs.on_op(meta, "admission_pages", need)
        # decode growth: an extension grows the sequence's page table, so
        # its metadata record is rewritten with the new reservation — this
        # churn is what the adaptive store's temperature tracker sees
        grown = [s for s, h in live if rng.random() < 0.5]
        for s in grown:
            mgr.extend(s, 1)
            pages[s] = pages.get(s, 1) + 1
        if grown:
            meta.write(WriteBatch().puts(
                np.array(grown, np.uint64),
                np.array([pages[s] * _PAGE_META_BYTES for s in grown],
                         np.int64)))
        # finish short sequences quickly, long ones rarely
        keep, finished = [], []
        for s, h in live:
            p_done = 0.05 if not h else 0.35
            if rng.random() < p_done:
                mgr.finish(s)
                finished.append(s)
            else:
                keep.append((s, h))
        if finished:
            meta.write(WriteBatch().deletes(
                np.array(finished, np.uint64)))
        live = keep
    return mgr.stats()


def run(scale=None):
    rows = []
    for name, thr in (("scavenger", 0.2), ("no-reloc", 1.1)):
        rng = np.random.default_rng(0)
        mgr = PagedKVCacheManager(n_pages=2048, page_size=16,
                                  extent_pages=32, gc_threshold=thr)
        obs = Observer(sample_every=32)
        # page-table records are small (16 B/page); drop the separation
        # threshold so they still flow into temperature-segregated vSSTs
        # (the mix is the signal this benchmark reports)
        meta = Store(EngineConfig.scaled("scavenger_adaptive", 4 << 20,
                                         observer=obs, sep_threshold=16))
        st = _drive(mgr, rng, meta)
        meta.drain()
        obs.finish()
        adm = obs.metrics.merged("admission_us")
        mix = _mean_mix(obs.health.series.get("0", ()))
        rows.append(row(f"serving/{name}", adm.mean,
                        frag_amp=st["frag_amp"],
                        admission_blocks=st["admission_blocks"],
                        pages_relocated=st["pages_relocated"],
                        gc_runs=st["gc_runs"],
                        adm_p50_us=adm.quantile(0.50),
                        adm_p99_us=adm.quantile(0.99),
                        hot_mix=mix.get("hot", 0.0),
                        warm_mix=mix.get("warm", 0.0),
                        cold_mix=mix.get("cold", 0.0)))
    return rows


def _mean_mix(series) -> dict:
    """Mean per-temperature byte fraction over the health time series
    (sequences churn to death, so the *final* state is empty — the mix
    lives in the samples taken while the store was loaded)."""
    acc: dict[str, float] = {}
    n = 0
    for sample in series:
        mix = sample.get("temp_bytes", {})
        tot = sum(mix.values())
        if not tot:
            continue
        n += 1
        for temp, b in mix.items():
            acc[temp] = acc.get(temp, 0.0) + b / tot
    return {t: v / n for t, v in acc.items()} if n else {}
