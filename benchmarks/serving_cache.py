"""Serving-cache benchmark: Scavenger-style extent GC vs naive paging.

Drives the paged KV manager with a churn trace (mixed short/long
sequences); reports fragmentation amplification, admission blocks and
relocation traffic — the HBM analog of the paper's space-time trade-off.
"""

import numpy as np

from repro.serve.paged_cache import PagedKVCacheManager

from .common import row


def _drive(mgr, rng, n_reqs=400):
    live = []
    for rid in range(n_reqs):
        need = int(rng.integers(1, 8))
        hot = rng.random() < 0.75          # 25% long-lived (cold)
        if mgr.admit(rid, need, hot=hot):
            live.append((rid, hot))
        # decode growth
        for s, h in live:
            if rng.random() < 0.5:
                mgr.extend(s, 1)
        # finish short sequences quickly, long ones rarely
        keep = []
        for s, h in live:
            p_done = 0.05 if not h else 0.35
            if rng.random() < p_done:
                mgr.finish(s)
            else:
                keep.append((s, h))
        live = keep
    return mgr.stats()


def run(scale=None):
    rows = []
    for name, thr in (("scavenger", 0.2), ("no-reloc", 1.1)):
        rng = np.random.default_rng(0)
        mgr = PagedKVCacheManager(n_pages=2048, page_size=16,
                                  extent_pages=32, gc_threshold=thr)
        st = _drive(mgr, rng)
        rows.append(row(f"serving/{name}", 0.0,
                        frag_amp=st["frag_amp"],
                        admission_blocks=st["admission_blocks"],
                        pages_relocated=st["pages_relocated"],
                        gc_runs=st["gc_runs"]))
    return rows
