"""Fig. 2: space-time trade-offs of existing solutions (Mixed-8K, no limit).

Paper claims: KV-separated stores beat RocksDB's update throughput by
2.57-4.16x at 8KB values while using 2.42-2.97x more space.
"""

from .common import ENGINES5, ds_bytes, load_update, row
from repro.workloads import mixed_8k


def run(scale=None):
    spec = mixed_8k(dataset_bytes=ds_bytes(16))
    rows, base = [], None
    for engine in ENGINES5:
        st = load_update(engine, spec)
        if engine == "rocksdb":
            base = st
        rows.append(row(
            f"fig02/{engine}", st["us_per_update"],
            upd_kops=st["upd_kops"], space_amp=st["space_amp"],
            x_rocksdb_thpt=st["upd_kops"] / base["upd_kops"],
            x_rocksdb_space=st["space_amp"] / base["space_amp"]))
    return rows
