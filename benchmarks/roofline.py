"""§Roofline: three-term analysis per (arch x shape x mesh) cell.

Sources and methodology (see EXPERIMENTS.md §Roofline):
  * The dry-run artifacts (benchmarks/artifacts/dryrun/*.json) prove each
    cell lowers+compiles on the production meshes and provide
    memory_analysis and the post-SPMD collective op inventory.
  * Compute/memory/collective BYTES AND FLOPS are ANALYTIC, derived from
    the config, shape and sharding policy below.  We attempted to use
    compiled.cost_analysis(), but XLA:CPU does not recurse into the
    rematerialized called computations produced by jax.checkpoint-under-
    scan (verified: 1-layer and 4-layer lowerings report identical FLOPs),
    so HLO-derived totals undercount by ~the layer count.  The analytic
    terms are exact for matmuls and first-order for elementwise traffic;
    the HLO inventory cross-checks which collectives exist and where.

Terms per chip (v5e): peak 197 TFLOP/s bf16, HBM 819 GB/s, ICI 50 GB/s:
  compute    = analytic_flops_per_chip / peak
  memory     = analytic_hbm_bytes_per_chip / hbm_bw
  collective = analytic_collective_bytes_per_chip / ici_bw
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

from repro.configs import get_config
from repro.launch.shapes import SHAPES, TRAIN_OVERRIDES, cache_len_for

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ART = Path(__file__).parent / "artifacts" / "dryrun"


@dataclasses.dataclass
class Policy:
    """Sharding/impl policy knobs that §Perf iterations flip."""
    attn_impl: str = "naive"          # naive materializes (B,H,Sq,Sk) f32
    gqa_grouped: bool = False         # naive repeats KV to H heads
    grad_sharded: bool = False        # else grads all-reduce at full size
    serve_tp_only: bool = False       # else FSDP params gathered per step
    accum_divisor: int = 1            # chunked attn -> fewer microbatches


BASELINE = Policy()
OPTIMIZED = Policy(attn_impl="chunked", gqa_grouped=True, grad_sharded=True,
                   serve_tp_only=True, accum_divisor=1)


def _counts(cfg):
    per = {"attn": 0, "mamba": 0, "mlstm": 0, "slstm": 0, "moe": 0,
           "dense": 0}
    for b, f in zip(cfg.block_pattern, cfg.ffn_pattern):
        per[b] += 1
        if f in ("moe", "moe+dense"):
            per["moe"] += 1
        if f in ("dense", "moe+dense"):
            per["dense"] += 1
    return {k: v * cfg.n_periods for k, v in per.items()}


def analytic_terms(cfg, shape_name: str, n_chips: int,
                   policy: Policy = BASELINE) -> dict:
    """FLOPs / HBM bytes / collective bytes per chip for one step."""
    s = SHAPES[shape_name]
    kind = s["kind"]
    tp = 16
    fsdp = n_chips // tp
    seq, batch = s["seq"], s["batch"]
    counts = _counts(cfg)
    d, hd, H, K = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    G = H // K

    # ----- token geometry -----
    if kind == "train":
        q_tokens, kv_len, bsz = seq, seq, batch
        fwd_mult, train = 3.0, True          # fwd + ~2x bwd
    elif kind == "prefill":
        q_tokens, kv_len, bsz = seq, seq, batch
        fwd_mult, train = 1.0, False
    else:
        q_tokens, kv_len, bsz = 1, cache_len_for(cfg, shape_name), batch
        fwd_mult, train = 1.0, False
    tokens = q_tokens * bsz

    # ----- FLOPs (global) -----
    n_embed = cfg.vocab_padded * d * (1 if cfg.tie_embeddings else 2)
    n_mm = cfg.active_param_count() - n_embed + cfg.vocab_padded * d
    flops = 2.0 * n_mm * tokens * fwd_mult
    eff_kv = min(kv_len, cfg.window) if cfg.window else kv_len
    causal = 0.5 if (kind != "decode" and cfg.window is None) else 1.0
    attn = (4.0 * bsz * q_tokens * eff_kv * H * hd * causal
            * counts["attn"] * fwd_mult)
    if cfg.enc_dec and kind != "decode":
        attn += (4.0 * bsz * seq * seq * H * hd
                 * (cfg.n_enc_layers + cfg.n_layers) * fwd_mult)
    flops += attn
    flops += (10.0 * tokens * cfg.d_inner * cfg.d_state * counts["mamba"]
              * fwd_mult)
    flops += 6.0 * tokens * H * hd * hd * counts["mlstm"] * fwd_mult
    flops += 8.0 * tokens * d * hd * counts["slstm"] * fwd_mult

    # ----- HBM bytes (per chip) -----
    # TP-only serving applies only when the TP shard fits the HBM budget
    # (mirrors launch/dryrun.OPT_REPLICATE_SERVE_PARAMS_GB)
    tp_only = (policy.serve_tp_only and kind != "train"
               and cfg.param_count() * 2 / tp <= 8e9)
    p_active_dev = cfg.active_param_count() * 2 / (
        tp if tp_only else n_chips)
    tok_dev = max(tokens / n_chips, 1.0)
    if train:
        p_dev = cfg.param_count() * 2 / n_chips
        mdt = 2 if TRAIN_OVERRIDES.get(cfg.name, {}).get(
            "moment_dtype") == "bfloat16" else 4
        # fwd read + remat re-read + bwd read + write, f32 grad rw,
        # optimizer moment rw
        mem = p_dev * (4 + 4 + 2 * mdt)
        mem += 16.0 * tok_dev * d * 2 * cfg.n_layers      # activations
    elif kind == "prefill":
        mem = p_active_dev
        mem += 8.0 * tok_dev * d * 2 * cfg.n_layers
        mem += 2.0 * bsz * seq * K * hd * 2 * counts["attn"] / n_chips
    else:
        mem = p_active_dev                                 # weights stream
        cache_dev = (2.0 * bsz * eff_kv * K * hd * 2 * counts["attn"]
                     / n_chips)
        gqa_factor = (1 + G) if not policy.gqa_grouped else 1.0
        mem += cache_dev * gqa_factor
    # naive attention materializes f32 score matrices
    if policy.attn_impl == "naive" and counts["attn"]:
        heads = H if not policy.gqa_grouped else H
        scores = (4.0 * bsz * heads * q_tokens * eff_kv * counts["attn"]
                  / n_chips)
        mem += scores * (3 if train else 1)

    # ----- collective bytes (per chip) -----
    coll = 0.0
    p_bytes_dev = cfg.param_count() * 2 / n_chips
    if train:
        accum = TRAIN_OVERRIDES.get(cfg.name, {}).get("accum_steps", 1)
        accum = max(1, accum // policy.accum_divisor)
        # FSDP param all-gather (fwd + remat'd bwd), per microbatch
        coll += 2 * accum * p_bytes_dev * (fsdp - 1)
        if policy.grad_sharded:
            coll += cfg.param_count() * 4 / n_chips * (fsdp - 1)   # RS
        else:
            coll += 2 * cfg.param_count() * 4 / n_chips * fsdp     # AR
    elif not tp_only:
        coll += 2 * p_bytes_dev * (fsdp - 1)     # param gather per step!
    # TP activation all-reduces: ~2 per layer
    coll += 4.0 * tok_dev * d * 2 * cfg.n_layers * fwd_mult
    # EP all-to-all: dispatch+combine of top-k routed tokens
    if counts["moe"]:
        coll += 4.0 * tok_dev * cfg.top_k * d * 2 * counts["moe"] \
            * fwd_mult

    flops_dev = flops / n_chips
    return {
        "flops_per_chip": flops_dev,
        "hbm_bytes_per_chip": mem,
        "coll_bytes_per_chip": coll,
        "t_compute_s": flops_dev / PEAK_FLOPS,
        "t_memory_s": mem / HBM_BW,
        "t_collective_s": coll / LINK_BW,
    }


def analyze(info: dict, policy: Policy | None = None) -> dict:
    cfg = get_config(info["arch"])
    if policy is None:
        policy = OPTIMIZED if info.get("opt") else BASELINE
    shape = SHAPES[info["shape"]]
    chips = info["n_chips"]
    t = analytic_terms(cfg, info["shape"], chips, policy)
    n = cfg.active_param_count()
    if info["kind"] == "train":
        model_flops = 6 * n * shape["seq"] * shape["batch"]
    elif info["kind"] == "prefill":
        model_flops = 2 * n * shape["seq"] * shape["batch"]
    else:
        model_flops = 2 * n * shape["batch"]
    model_per_dev = model_flops / chips
    terms = {"compute": t["t_compute_s"], "memory": t["t_memory_s"],
             "collective": t["t_collective_s"]}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    return dict(
        t, dominant=dom,
        model_flops_per_dev=model_per_dev,
        useful_ratio=model_per_dev / max(t["flops_per_chip"], 1e-9),
        roofline_frac=(model_per_dev / PEAK_FLOPS) / max(bound, 1e-12),
        hlo_collective_count=info["collectives"]["count"],
        hlo_collective_bytes=info["collectives"]["total"],
        temp_bytes=info["memory"].get("temp_size_in_bytes", 0))


def load_cells(include_smoke=False, opt=None):
    cells = []
    if not ART.exists():
        return cells
    for p in sorted(ART.glob("*.json")):
        if p.stem.endswith("_smoke") and not include_smoke:
            continue
        info = json.loads(p.read_text())
        if opt is not None and bool(info.get("opt")) != opt:
            continue
        cells.append(info)
    return cells


def run(scale=None):
    from .common import row
    rows = []
    for info in load_cells(opt=False):
        a = analyze(info)
        rows.append(row(
            f"roofline/{info['arch']}/{info['shape']}/{info['mesh']}",
            a["t_compute_s"] * 1e6,
            mem_us=a["t_memory_s"] * 1e6,
            coll_us=a["t_collective_s"] * 1e6,
            dominant=a["dominant"],
            useful_ratio=a["useful_ratio"],
            roofline_frac=a["roofline_frac"]))
    if not rows:
        rows.append(row("roofline/NO-ARTIFACTS", 0.0,
                        note="run python -m repro.launch.dryrun --all"))
    return rows


def markdown_table(mesh="16x16", opt=False) -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | useful | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for info in load_cells(opt=opt):
        if info["mesh"] != mesh:
            continue
        a = analyze(info)
        lines.append(
            f"| {info['arch']} | {info['shape']} | "
            f"{a['t_compute_s']:.3e} | {a['t_memory_s']:.3e} | "
            f"{a['t_collective_s']:.3e} | {a['dominant']} | "
            f"{a['useful_ratio']:.2f} | {a['roofline_frac']:.3f} |")
    return "\n".join(lines)
