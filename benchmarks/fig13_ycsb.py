"""Fig. 13: YCSB A-F under Mixed-8K with the 1.5x space limit.

Paper claims: Scavenger ~2.2-3.2x others on YCSB-A, 1.9-3.5x on YCSB-F;
comparable to RocksDB on scan-heavy YCSB-E.
"""

from repro.workloads import mixed_8k, run_ycsb

from .common import ENGINES5, build, ds_bytes, row


def run(scale=None):
    spec = mixed_8k(dataset_bytes=ds_bytes(8))
    rows = []
    for engine in ENGINES5:
        store, r = build(engine, spec, quota_x=1.5)
        r.load()
        r.update()
        for wl in "ABCDEF":
            res = run_ycsb(store, spec, wl, n_ops=spec.n_keys // 2,
                           runner=r)
            rows.append(row(f"fig13/{engine}/ycsb-{wl}",
                            res["sim_s"] * 1e6 / res["ops"],
                            kops=res["kops_per_s"]))
    return rows
