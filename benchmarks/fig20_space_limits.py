"""Fig. 20: update performance under varying space limits.

Paper claims: Scavenger dominates under stringent quotas (1.25x/1.5x) and
is the only KV-separated store matching RocksDB at 1.25x.
"""

from repro.workloads import mixed_8k

from .common import ds_bytes, load_update, row


def run(scale=None):
    spec = mixed_8k(dataset_bytes=ds_bytes(16))
    rows = []
    for engine in ("rocksdb", "titan", "terarkdb", "scavenger"):
        for q in (1.25, 1.5, 2.0, None):
            st = load_update(engine, spec, quota_x=q)
            rows.append(row(f"fig20/{engine}/quota-{q or 'none'}",
                            st["us_per_update"],
                            upd_kops=st["upd_kops"],
                            space_amp=st["space_amp"],
                            stall_s=st["stall_s"]))
    return rows
