"""Batched vs scalar Store API microbenchmark.

Acceptance row for the batched columnar API: ``multi_get`` at batch size
256 must be >= 3x lower simulated us/op than the scalar ``get`` loop on the
quick scale (the batch issues at NVMe queue depth ``fg_qd_max`` instead of
queue depth 1, and coalesces vSST record fetches into runs).  ``wall_us``
carries the Python-side per-op cost — the interpreter-overhead win that
motivated the batch API in the first place.

Scalar and batched sides run on independently built but identically seeded
stores, so cache and LSM state are byte-identical at measurement start.
"""

import time

import numpy as np

from repro.core import WriteBatch
from repro.workloads import pareto_1k

from .common import build, ds_bytes, row

BATCH = 256


def _loaded(engine="scavenger"):
    spec = pareto_1k(dataset_bytes=ds_bytes(8))
    store, r = build(engine, spec)
    r.load()
    r.update(spec.n_keys)
    store.drain()
    return store, r, spec


def run(scale=None):
    rows = []

    # ------------------------------------------------------------- reads
    store_s, r_s, spec = _loaded()
    keys = r_s.keys.sample(np.random.default_rng(123), BATCH)
    t0, w0 = store_s.io.fg_clock_us, time.perf_counter()
    for k in keys.tolist():
        store_s.get(int(k))
    us_scalar = (store_s.io.fg_clock_us - t0) / BATCH
    wall_scalar = (time.perf_counter() - w0) / BATCH * 1e6

    store_b, _, _ = _loaded()
    t0, w0 = store_b.io.fg_clock_us, time.perf_counter()
    store_b.multi_get(keys.astype(np.uint64))
    us_batch = (store_b.io.fg_clock_us - t0) / BATCH
    wall_batch = (time.perf_counter() - w0) / BATCH * 1e6

    rows.append(row("batch/scalar_get", us_scalar, wall_us=wall_scalar))
    rows.append(row(f"batch/multi_get_{BATCH}", us_batch,
                    wall_us=wall_batch,
                    speedup=us_scalar / max(us_batch, 1e-9)))

    # ------------------------------------------------------------ writes
    store_s, r_s, spec = _loaded()
    rng = np.random.default_rng(7)
    wkeys = r_s.keys.sample(rng, BATCH)
    wsz = spec.value_dist.sample(rng, BATCH)
    t0, w0 = store_s.io.fg_clock_us, time.perf_counter()
    for k, v in zip(wkeys.tolist(), wsz.tolist()):
        store_s.put(int(k), int(v))
    us_scalar_w = (store_s.io.fg_clock_us - t0) / BATCH
    wall_scalar_w = (time.perf_counter() - w0) / BATCH * 1e6

    store_b, _, _ = _loaded()
    t0, w0 = store_b.io.fg_clock_us, time.perf_counter()
    store_b.write(WriteBatch().puts(wkeys.astype(np.uint64),
                                    wsz.astype(np.int64)))
    us_batch_w = (store_b.io.fg_clock_us - t0) / BATCH
    wall_batch_w = (time.perf_counter() - w0) / BATCH * 1e6

    rows.append(row("batch/scalar_put", us_scalar_w, wall_us=wall_scalar_w))
    rows.append(row(f"batch/writebatch_{BATCH}", us_batch_w,
                    wall_us=wall_batch_w,
                    speedup=us_scalar_w / max(us_batch_w, 1e-9)))

    # ------------------------------------------------------------- scans
    store_s, _, spec = _loaded()
    starts = np.random.default_rng(5).integers(0, spec.n_keys, 64)
    t0 = store_s.io.fg_clock_us
    for s in starts.tolist():
        store_s.scan(int(s), 20)
    us_scalar_sc = (store_s.io.fg_clock_us - t0) / 64

    store_b, _, _ = _loaded()
    t0 = store_b.io.fg_clock_us
    store_b.multi_scan(starts, 20)
    us_batch_sc = (store_b.io.fg_clock_us - t0) / 64
    rows.append(row("batch/scalar_scan", us_scalar_sc))
    rows.append(row("batch/multi_scan_64", us_batch_sc,
                    speedup=us_scalar_sc / max(us_batch_sc, 1e-9)))
    return rows
