"""Fig. 12: microbenchmarks under the 1.5x space limit (+12c: I/O bytes).

Paper claims: Scavenger update 2.1-2.6x other KV-separated stores under
Mixed-8K; read 1.3x RocksDB; I/O reduction 42-99% (read) / 12-41% (write).
"""

from repro.workloads import mixed_8k, pareto_1k

from .common import ENGINES5, build, ds_bytes, row


def run(scale=None):
    rows = []
    for mk, mb in ((mixed_8k, 16), (pareto_1k, 8)):
        spec = mk(dataset_bytes=ds_bytes(mb))
        for engine in ENGINES5:
            store, r = build(engine, spec, quota_x=1.5)
            r.load()
            up = r.update()
            rd = r.read(max(200, spec.n_keys // 8))
            sc = r.scan(64, max_len=100)
            io = store.io
            rows.append(row(
                f"fig12/{engine}/{spec.name}",
                up["sim_s"] * 1e6 / up["ops"],
                upd_kops=up["ops"] / up["sim_s"] / 1e3,
                read_kops=rd["ops"] / rd["sim_s"] / 1e3,
                scan_kops=sc["ops"] / sc["sim_s"] / 1e3,
                read_gb=io.total_read_bytes() / 1e9,
                write_gb=io.total_write_bytes() / 1e9,
                space_amp=store.space_amplification()))
    return rows
