"""Serving substrate: paged cache manager invariants + engine E2E."""

import jax
import numpy as np
import pytest

from _hypothesis_support import given, settings, st

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve import PagedKVCacheManager, Request, ServeEngine


def test_admit_extend_finish_cycle():
    m = PagedKVCacheManager(n_pages=64, page_size=16, extent_pages=8)
    assert m.admit(1, 4)
    assert m.extend(1, 2)
    assert len(m.page_tables[1]) == 6
    stats0 = m.stats()
    assert stats0["live_pages"] == 6
    m.finish(1)
    assert m.stats()["dead_pages"] == 6
    m.run_gc()
    assert m.free_pages() == 64


def test_no_page_double_allocation():
    m = PagedKVCacheManager(n_pages=128, page_size=16, extent_pages=8)
    rng = np.random.default_rng(0)
    for rid in range(40):
        m.admit(rid, int(rng.integers(1, 6)))
        if rid >= 3 and rng.random() < 0.5:
            m.finish(rid - 3)
    pages = [p for pt in m.page_tables.values() for p in pt]
    assert len(pages) == len(set(pages)), "page double-booked!"
    # page_owner agrees with tables
    for s, pt in m.page_tables.items():
        for p in pt:
            assert m.page_owner[p] == s


def test_gc_relocation_updates_tables():
    m = PagedKVCacheManager(n_pages=64, page_size=16, extent_pages=8,
                            gc_threshold=0.2)
    for rid in range(8):
        assert m.admit(rid, 2)
    for rid in range(0, 8, 2):
        m.finish(rid)              # half the extents' pages die
    m.run_gc()
    for s, pt in m.page_tables.items():
        for p in pt:
            assert m.page_owner[p] == s
    assert m.pages_relocated >= 0


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 6), st.booleans()),
                min_size=5, max_size=60))
def test_manager_invariants_property(reqs):
    m = PagedKVCacheManager(n_pages=256, page_size=16, extent_pages=16)
    live = []
    for rid, (need, hot) in enumerate(reqs):
        if m.admit(rid, need, hot=hot):
            live.append(rid)
        if len(live) > 6:
            m.finish(live.pop(0))
        # invariant: live accounting consistent
        owned = int((m.page_owner >= 0).sum())
        assert owned == sum(len(pt) for pt in m.page_tables.values())
        assert m.stats()["live_pages"] == owned


def test_serve_engine_end_to_end():
    cfg = get_config("smollm_360m", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    eng = ServeEngine(model, params, batch_slots=2, cache_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 5).tolist(),
                    max_new=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)
    assert all(0 <= t < cfg.vocab for r in reqs for t in r.out)
    # all pages returned after completion
    eng.pager.run_gc()
    assert eng.pager.stats()["live_pages"] == 0


def test_serve_duplicate_rid_rejected():
    """Admission metadata (batched KV writes) guards against re-admitting a
    live request id, which would corrupt its page table."""
    cfg = get_config("smollm_360m", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    eng = ServeEngine(model, params, batch_slots=2, cache_len=64)
    eng.submit(Request(rid=7, prompt=[1, 2, 3], max_new=64))
    eng.step()
    eng.submit(Request(rid=7, prompt=[4, 5], max_new=4))
    ok = Request(rid=8, prompt=[6], max_new=1)
    eng.submit(ok)
    with pytest.raises(ValueError, match="already admitted"):
        eng.step()
    eng.run(max_steps=10)       # duplicate was dropped; queue still drains
    assert ok.done


def test_serve_greedy_matches_forward():
    """Engine decode must agree with a full forward pass (greedy)."""
    import jax.numpy as jnp
    cfg = get_config("qwen2_05b", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(1))
    prompt = [3, 7, 11, 2]
    eng = ServeEngine(model, params, batch_slots=1, cache_len=32)
    req = Request(rid=0, prompt=prompt, max_new=3)
    eng.submit(req)
    eng.run()
    # reference: greedy decode via forward
    toks = list(prompt)
    for _ in range(3):
        logits = model.forward(params, {"tokens": jnp.asarray([toks])})
        toks.append(int(jnp.argmax(logits[0, -1, :cfg.vocab])))
    assert req.out == toks[len(prompt):]
