"""Observability subsystem tests (DESIGN.md §11).

Four contracts:

  * **No-op parity** — a store built without an observer (the default
    ``NULL_OBSERVER``) reproduces the PR-2 golden accounting byte-for-byte
    on all seven engines, and attaching a real ``Observer`` changes
    *nothing* about the accounting either (the tap never participates).
  * **Tiling** — per-(shard, lane) span durations sum exactly to the final
    ``SimIO.lanes`` clocks, on a single store and on a quota-stressed
    fleet (every simulated microsecond is inside exactly one span).
  * **Histogram math** — property tests: the log-bucket quantile is an
    upper bound within ``1/NSUB`` relative error, and merging is exactly
    associative on bucket counts and quantiles.
  * **Recovery timeline** — ``Store.open(dir, observer=)`` emits the
    ``recovery_begin → checkpoint_restored → replay_segment* →
    recovery_end`` instant sequence across the §9 crash matrix, without
    perturbing recovered state.
"""

from __future__ import annotations

import json

import numpy as np
import pytest

from _hypothesis_support import HealthCheck, given, settings, st
from test_refactor_parity import GOLDENS, run_fixed_workload

from repro.core import (CrashPoint, ENGINES, EngineConfig, ShardedStore,
                        Store, WriteBatch)
from repro.obs import LogHist, NullObserver, Observer, SpanTracer
from repro.obs.cli import main as obs_main
from repro.obs.metrics import NSUB, bucket_index, bucket_upper

N_KEYS = 2048
VSIZES = np.array([64, 200, 600, 2000, 9000], np.int64)


def _drive(store, groups: int = 20, seed: int = 0) -> None:
    """Deterministic mixed workload exercising every instrumented path."""
    rng = np.random.default_rng(seed)
    for _ in range(groups):
        keys = rng.integers(0, N_KEYS, 128).astype(np.uint64)
        sizes = VSIZES[rng.integers(0, len(VSIZES), 128)]
        store.write(WriteBatch().puts(keys, sizes))
        store.write(WriteBatch().deletes(
            rng.integers(0, N_KEYS, 8).astype(np.uint64)))
        store.multi_get(rng.integers(0, N_KEYS, 48).astype(np.uint64))
        store.multi_scan(rng.integers(0, N_KEYS, 4).astype(np.int64), 8)
    store.drain()


def _assert_tiles(obs: Observer, rtol: float = 1e-6) -> None:
    obs.finish()
    assert obs.tracer.dropped == 0
    sums = obs.tracer.track_sums()
    assert obs.tracer.shard_lanes, "finish() recorded no stores"
    for shard, lanes in obs.tracer.shard_lanes.items():
        for lane, want in lanes.items():
            got = sums.get((shard, lane), 0.0)
            assert got == pytest.approx(want, rel=rtol, abs=1e-6), \
                (shard, lane, got, want)


# ========================================================== no-op parity
@pytest.mark.parametrize("engine", sorted(GOLDENS))
def test_observer_off_matches_goldens(engine):
    """Default (no observer) accounting is byte-identical to the golden
    table captured before the observability layer existed."""
    got = run_fixed_workload(engine)
    want = GOLDENS[engine]
    for field, val in want.items():
        assert got[field] == pytest.approx(val, rel=0, abs=0), field


@pytest.mark.parametrize("engine", ENGINES)
def test_observer_on_changes_nothing(engine):
    """An enabled Observer is a pure tap: stats with it attached are
    byte-identical to an un-observed run, on all seven engines
    (``scavenger_adaptive`` has no golden row; it compares run-vs-run)."""
    got = run_fixed_workload(engine, observer=Observer(sample_every=16))
    want = GOLDENS.get(engine) or run_fixed_workload(engine)
    for field, val in want.items():
        assert got[field] == pytest.approx(val, rel=0, abs=0), field


def test_null_observer_is_constant_and_shared():
    null = NullObserver()
    ctx = null.span(None, "write")
    assert ctx is null.span(None, "anything", lane="gc")
    with ctx:
        pass


# ================================================================ tiling
def test_single_store_spans_tile_lane_clocks():
    obs = Observer(sample_every=16)
    cfg = EngineConfig.scaled("scavenger", 8 << 20, est_keys=N_KEYS,
                              observer=obs)
    store = Store(cfg)
    _drive(store)
    _assert_tiles(obs)
    # ops and GC jobs actually got recorded
    names = {ev["name"] for ev in obs.tracer.events}
    assert {"write", "multi_get", "multi_scan", "flush"} <= names


@pytest.mark.parametrize("quota", [None, 2 << 20])
def test_fleet_spans_tile_lane_clocks(quota):
    """Tiling holds across shards, including the fleet quota stall and
    slowdown paths (force-run jobs + lane_sync jumps)."""
    obs = Observer(sample_every=16)
    cfg = EngineConfig.scaled("scavenger_adaptive", 8 << 20,
                              est_keys=N_KEYS, observer=obs,
                              space_quota_bytes=quota)
    fleet = ShardedStore(cfg, n_shards=3, shard_policy="range",
                         key_space=N_KEYS)
    _drive(fleet, groups=12)
    _assert_tiles(obs)
    assert len(obs.tracer.shard_lanes) == 3


def test_tracer_ring_buffer_drops_oldest_and_counts():
    t = SpanTracer(cap=4)
    for i in range(7):
        t.span(f"s{i}", "fg", "0", float(i), 1.0)
    assert len(t.events) == 4 and t.dropped == 3
    assert [ev["name"] for ev in t.events] == ["s3", "s4", "s5", "s6"]


# ======================================================== histogram math
def test_bucket_bounds_are_consistent():
    """Buckets are [lower, upper): a power of two starts its own bucket."""
    for v in (1e-9, 0.3, 1.0, 1.5, 7.0, 1e12):
        idx = bucket_index(v)
        assert bucket_upper(idx - 1) <= v < bucket_upper(idx)


@settings(max_examples=200, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.lists(st.floats(min_value=1e-6, max_value=1e9,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=200),
       st.sampled_from([0.5, 0.9, 0.95, 0.99]))
def test_quantile_is_bounded_overestimate(values, q):
    """t <= estimate <= t * (1 + 1/NSUB) for the true empirical quantile
    t of positive samples (the §11 error bound)."""
    h = LogHist()
    for v in values:
        h.record(v)
    est = h.quantile(q)
    values.sort()
    import math
    t = values[max(0, math.ceil(q * len(values)) - 1)]
    assert t <= est <= t * (1 + 1 / NSUB) + 1e-12


@settings(max_examples=100, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.lists(st.lists(st.floats(min_value=0, max_value=1e9,
                                   allow_nan=False, allow_infinity=False),
                         max_size=50),
                min_size=3, max_size=3))
def test_merge_is_associative_on_counts_and_quantiles(parts):
    """(a+b)+c == a+(b+c) on bucket counts, zeros, count, and every
    quantile (float totals may differ in rounding; counts may not)."""
    def hist(vals):
        h = LogHist()
        for v in vals:
            h.record(v)
        return h

    a, b, c = (hist(p) for p in parts)
    left = hist(parts[0]).merge(hist(parts[1])).merge(hist(parts[2]))
    right = hist(parts[1]).merge(hist(parts[2]))
    right = hist(parts[0]).merge(right)
    assert left.buckets == right.buckets
    assert left.zeros == right.zeros and left.count == right.count
    for q in (0.5, 0.9, 0.99):
        assert left.quantile(q) == right.quantile(q)


def test_merged_registry_equals_single_hist():
    """Per-shard histograms merged through the registry match one
    histogram that saw every sample."""
    obs = Observer()
    rng = np.random.default_rng(3)
    want = LogHist()
    for shard in range(4):
        store = type("S", (), {"cfg": type("C", (), {"engine": "x"})(),
                               "obs_label": str(shard)})()
        for v in rng.uniform(0.1, 1e6, 100):
            obs.on_op(store, "lat_us", v)
            want.record(v)
    merged = obs.metrics.merged("lat_us")
    assert merged.buckets == want.buckets
    assert merged.count == want.count
    for q in (0.5, 0.95, 0.99):
        assert merged.quantile(q) == want.quantile(q)


# ==================================================== export round-trip
def test_dump_roundtrip_and_chrome_trace(tmp_path):
    obs = Observer(sample_every=16)
    cfg = EngineConfig.scaled("scavenger", 8 << 20, est_keys=N_KEYS,
                              observer=obs)
    _drive(Store(cfg), groups=8)
    paths = obs.dump(tmp_path / "dump")

    # events round-trip: reloaded tracer reproduces the track sums
    reloaded = SpanTracer.from_state(json.loads(
        open(paths["events"]).read()))
    assert reloaded.track_sums() == obs.tracer.track_sums()
    assert reloaded.shard_lanes == obs.tracer.shard_lanes

    # chrome trace: valid JSON, metadata + spans, lane threads
    trace = json.loads(open(paths["trace"]).read())
    evs = trace["traceEvents"]
    assert {e["ph"] for e in evs} >= {"M", "X"}
    x = [e for e in evs if e["ph"] == "X"]
    assert all({"pid", "tid", "ts", "dur", "name"} <= set(e) for e in x)
    assert {e["tid"] for e in x} <= {0, 1, 2}
    # fg/bg/gc track durations sum to the recorded lane clocks
    for lane, tid in (("fg", 0), ("bg", 1), ("gc", 2)):
        got = sum(e["dur"] for e in x if e["tid"] == tid)
        want = obs.tracer.shard_lanes["0"][lane]
        assert got == pytest.approx(want, rel=1e-6, abs=1e-6)

    # health dump has the derived series
    health = json.loads(open(paths["health"]).read())
    last = health["series"]["0"][-1]
    for k in ("space_amp", "s_index", "lane_util", "temp_bytes",
              "garbage_ratio", "wal_bytes", "manifest_bytes"):
        assert k in last, k


def test_cli_summarize_check_dashboard(tmp_path, capsys):
    obs = Observer(sample_every=16)
    cfg = EngineConfig.scaled("scavenger", 8 << 20, est_keys=N_KEYS,
                              observer=obs, space_quota_bytes=3 << 20)
    _drive(Store(cfg), groups=10)
    obs.dump(tmp_path / "run")

    assert obs_main(["summarize", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    # acceptance: p50/p99 for at least multi_get latency, stall time,
    # and GC rewrite bytes per job
    assert "p50" in out and "p99" in out
    for metric in ("multi_get_us", "stall_us", "gc_rewrite_bytes"):
        assert metric in out, metric

    assert obs_main(["check", str(tmp_path / "run")]) == 0
    assert "OK" in capsys.readouterr().out

    assert obs_main(["convert", str(tmp_path / "run")]) == 0
    capsys.readouterr()
    assert obs_main(["dashboard", str(tmp_path / "run")]) == 0
    assert "space_amp" in capsys.readouterr().out


def test_cli_check_flags_broken_tiling(tmp_path, capsys):
    d = tmp_path / "run"
    d.mkdir()
    (d / "metrics.json").write_text("{}")
    (d / "events.json").write_text(json.dumps({
        "cap": 100, "dropped": 0,
        "shard_lanes": {"0": {"fg": 10.0, "bg": 0.0, "gc": 0.0}},
        "shard_meta": {},
        "events": [{"name": "write", "ph": "X", "lane": "fg",
                    "shard": "0", "ts": 0.0, "dur": 4.0}]}))
    assert obs_main(["check", str(d)]) == 1
    assert "FAIL" in capsys.readouterr().out


# ===================================================== recovery timeline
_INAPPLICABLE = {"rocksdb": {"gc_pre_chain", "gc_post_chain"},
                 "blobdb": {"gc_pre_chain", "gc_post_chain"}}


@pytest.mark.parametrize("engine,point", [
    ("scavenger", "after_wal"), ("scavenger", "mid_flush"),
    ("scavenger", "gc_pre_chain"), ("titan", "gc_post_chain"),
    ("rocksdb", "mid_compaction"), ("scavenger_adaptive", "gc_post_chain"),
])
def test_recovery_emits_replay_timeline(engine, point, tmp_path):
    """Crash-recovering with an observer attached emits the §11 recovery
    timeline and recovers the exact same state as recovering without."""
    cfg = EngineConfig.scaled(engine, 8 << 20, est_keys=N_KEYS)
    store = Store(cfg, durability_dir=tmp_path)
    rng = np.random.default_rng(11)
    try:
        for i in range(16):
            if i == 6:
                store.checkpoint()
            if i == 9:
                store.arm_crash(point, hits=2)
            keys = rng.integers(0, N_KEYS, 160).astype(np.uint64)
            store.write(WriteBatch().puts(
                keys, VSIZES[rng.integers(0, len(VSIZES), 160)]))
    except CrashPoint:
        pass

    obs = Observer()
    recovered = Store.open(tmp_path, observer=obs)
    names = [ev["name"] for ev in obs.tracer.events if ev["ph"] == "i"]
    assert names[0] == "recovery_begin"
    assert names[-1] == "recovery_end"
    assert "checkpoint_restored" in names
    assert "replay_segment" in names
    assert names.index("checkpoint_restored") < names.index("replay_segment")
    # replayed write batches produced real spans on the recovered store
    assert any(ev["name"] == "write" and ev["ph"] == "X"
               for ev in obs.tracer.events)
    assert obs.metrics.merged("replay_records").count >= 1

    plain = Store.open(tmp_path)
    assert recovered.stats() == plain.stats()


def test_fleet_recovery_attaches_observer(tmp_path):
    cfg = EngineConfig.scaled("scavenger", 8 << 20, est_keys=N_KEYS)
    fleet = ShardedStore(cfg, n_shards=2, shard_policy="range",
                         key_space=N_KEYS, durability_dir=tmp_path)
    rng = np.random.default_rng(5)
    for i in range(8):
        if i == 4:
            fleet.checkpoint()
        keys = rng.integers(0, N_KEYS, 160).astype(np.uint64)
        fleet.write(WriteBatch().puts(
            keys, VSIZES[rng.integers(0, len(VSIZES), 160)]))
    fleet.close()

    obs = Observer()
    recovered = ShardedStore.open(tmp_path, observer=obs)
    assert all(s.obs is obs for s in recovered.shards)
    assert any(ev["name"] == "write" for ev in obs.tracer.events)
    names = [ev["name"] for ev in obs.tracer.events if ev["ph"] == "i"]
    assert names[0] == "recovery_begin"
    assert names[-1] == "recovery_end"
    # one checkpoint_restored per shard, before the journal replay
    assert names.count("checkpoint_restored") == 2
    assert "replay_segment" in names
    assert names.index("checkpoint_restored") < names.index("replay_segment")
    plain = ShardedStore.open(tmp_path)
    assert recovered.stats() == plain.stats()


# ==================================================== config persistence
def test_observer_never_persisted(tmp_path):
    """state_dict strips the observer; a recovered store defaults back to
    the null observer."""
    obs = Observer()
    cfg = EngineConfig.scaled("scavenger", 8 << 20, est_keys=N_KEYS,
                              observer=obs)
    assert "observer" not in cfg.state_dict()
    store = Store(cfg, durability_dir=tmp_path)
    store.put(1, 100)
    store.checkpoint()
    store.close()
    recovered = Store.open(tmp_path)
    assert recovered.cfg.observer is None
    assert recovered.obs.enabled is False


def test_serving_admission_metrics():
    """ServeEngine admission records simulated fg latency + page counts
    through the metadata store's observer."""
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.serve import Request, ServeEngine

    obs = Observer()
    meta = Store(EngineConfig.scaled("scavenger_adaptive", 4 << 20,
                                     observer=obs))
    cfg = get_config("smollm_360m", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    eng = ServeEngine(model, params, batch_slots=2, cache_len=64,
                      meta_store=meta)
    reqs = [Request(rid=i, prompt=[1, 2, 3], max_new=2) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=60)
    adm = obs.metrics.merged("admission_us")
    assert adm.count >= 1
    assert adm.quantile(0.99) >= adm.quantile(0.5) >= 0.0
    assert obs.metrics.merged("admission_pages").count == adm.count
