"""Engine-level kernel routing (core/accel.py, DESIGN.md §12).

The byte-parity contract: ``use_kernels`` flips which code executes the
batched hot paths — never what they compute.  Every engine must produce
an identical stats dict and identical lookup results with kernels on and
off, at the default routing threshold and with routing forced onto every
batch (``kernel_min_batch=1``), and the ``kernel_interpret`` mode switch
must not change results either.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import EngineConfig, Store, WriteBatch
from repro.core.engine.config import ENGINES
from repro.obs import Observer

N_KEYS = 2048
VSIZES = np.array([64, 200, 600, 2000, 9000], np.int64)


def run_workload(engine: str, rounds: int = 4, **overrides):
    """Small deterministic mixed workload -> (stats dict, final vid column).

    Mirrors tests/test_refactor_parity.py at reduced scale; the returned
    vids come from one large final ``multi_get`` so value resolution (the
    run_coalesce path) is part of the compared bytes."""
    cfg = EngineConfig.scaled(engine, 8 << 20, est_keys=N_KEYS, **overrides)
    store = Store(cfg)
    rng = np.random.default_rng(99)
    for _ in range(rounds):
        keys = rng.integers(0, N_KEYS, 256).astype(np.uint64)
        sizes = VSIZES[rng.integers(0, len(VSIZES), 256)]
        store.write(WriteBatch().puts(keys, sizes))
        store.write(WriteBatch().deletes(
            rng.integers(0, N_KEYS, 16).astype(np.uint64)))
        store.multi_get(rng.integers(0, N_KEYS, 192).astype(np.uint64))
        store.multi_scan(rng.integers(0, N_KEYS, 4).astype(np.int64), 8)
    store.drain()
    res = store.multi_get(np.arange(N_KEYS, dtype=np.uint64))
    return store.stats(), np.where(res["found"], res["vid"], 0)


@pytest.mark.parametrize("engine", ENGINES)
def test_kernels_on_off_parity_all_engines(engine):
    on_stats, on_vids = run_workload(engine)
    off_stats, off_vids = run_workload(engine, use_kernels=False)
    assert on_stats == off_stats
    np.testing.assert_array_equal(on_vids, off_vids)


def test_kernels_forced_on_every_batch():
    """min_batch=1 routes even the smallest probes through the kernels."""
    on_stats, on_vids = run_workload("scavenger_adaptive", rounds=3,
                                     kernel_min_batch=1)
    off_stats, off_vids = run_workload("scavenger_adaptive", rounds=3,
                                       use_kernels=False)
    assert on_stats == off_stats
    np.testing.assert_array_equal(on_vids, off_vids)


def test_kernel_interpret_mode_parity():
    """The Pallas interpreter computes the same bytes as the auto mode
    (the jitted XLA oracle on CPU; ``kernel_interpret=False`` would force
    compiled Pallas, which needs a TPU).  Tiny workload: interpret mode
    runs the kernel bodies in Python."""
    a = run_workload("scavenger", rounds=1, kernel_interpret=True)
    b = run_workload("scavenger", rounds=1, kernel_interpret=None)
    assert a[0] == b[0]
    np.testing.assert_array_equal(a[1], b[1])


def test_coalesce_window_parity_and_effect():
    """A window must be honored identically by both planners; a 1-record
    window degenerates runs to single records (more random reads)."""
    on = run_workload("scavenger", rounds=2, coalesce_window=2)
    off = run_workload("scavenger", rounds=2, coalesce_window=2,
                       use_kernels=False)
    assert on[0] == off[0]
    unb = run_workload("scavenger", rounds=2)
    assert on[0] != unb[0]      # the window is a real semantic knob


def test_kernel_config_validation():
    with pytest.raises(ValueError, match="kernel_min_batch"):
        EngineConfig(engine="scavenger", kernel_min_batch=0)
    with pytest.raises(ValueError, match="coalesce_window"):
        EngineConfig(engine="scavenger", coalesce_window=0)


def test_kernel_knobs_survive_state_dict_roundtrip():
    cfg = EngineConfig(engine="scavenger", use_kernels=False,
                       kernel_min_batch=7, coalesce_window=3)
    d = cfg.state_dict()
    back = EngineConfig(**d)
    assert (back.use_kernels, back.kernel_min_batch,
            back.coalesce_window) == (False, 7, 3)


def test_kernel_us_histograms_reach_observer():
    """Routed ops emit wall-clock kernel_<opclass>_us histograms through
    the PR 7 observer; unrouted runs emit none."""
    obs = Observer()
    cfg = EngineConfig.scaled("scavenger_adaptive", 8 << 20,
                              est_keys=N_KEYS, observer=obs,
                              kernel_min_batch=1)
    store = Store(cfg)
    rng = np.random.default_rng(7)
    for _ in range(2):
        keys = rng.integers(0, N_KEYS, 256).astype(np.uint64)
        store.write(WriteBatch().puts(
            keys, VSIZES[rng.integers(0, len(VSIZES), 256)]))
        store.multi_get(rng.integers(0, N_KEYS, 192).astype(np.uint64))
    store.drain()
    store.multi_get(np.arange(N_KEYS, dtype=np.uint64))
    for op in ("lookup_probe", "run_coalesce", "segment_reduce"):
        h = obs.metrics.merged(f"kernel_{op}_us")
        assert h.count > 0, f"no kernel_{op}_us samples"
        assert h.vmax < 60e6        # sanity: wall-clock us, not ns

    off = Observer()
    cfg2 = EngineConfig.scaled("scavenger", 8 << 20, est_keys=N_KEYS,
                               observer=off, use_kernels=False)
    s2 = Store(cfg2)
    s2.write(WriteBatch().puts(np.arange(512, dtype=np.uint64),
                               np.full(512, 200, np.int64)))
    s2.multi_get(np.arange(512, dtype=np.uint64))
    assert off.metrics.merged("kernel_lookup_probe_us").count == 0
