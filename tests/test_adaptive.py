"""Adaptive subsystem tests (DESIGN.md §8).

Property tests (hypothesis, optional via ``_hypothesis_support``):

  * the decayed count-min sketch never under-counts against an exact
    oracle when decay is off (conservative estimates);
  * decay is monotone: advancing the op clock without adding events can
    only lower estimates.

Plus unit coverage of the lifetime estimator and temperature map, golden
parity locking ``scavenger_adaptive`` with the tracker disabled to the
``scavenger`` pre-refactor golden (and the five paper engines stay locked
by ``test_refactor_parity.py`` — they never construct a tracker), and a
smoke check of the ISSUE 4 acceptance gate against the titan baseline.
"""

import math

import numpy as np
import pytest

from _hypothesis_support import HealthCheck, given, settings, st
from test_refactor_parity import (FLOAT_FIELDS, GOLDENS, INT_FIELDS,
                                  run_fixed_workload)

from repro.core import EngineConfig, Store, WriteBatch
from repro.core.adaptive import (TEMP_COLD, TEMP_HOT, AccessTracker,
                                 DecaySketch, LifetimeEstimator,
                                 TemperatureMap)


def tiny_cfg(engine, **kw):
    base = dict(
        memtable_bytes=4 << 10, ksst_bytes=4 << 10, vsst_bytes=16 << 10,
        base_level_bytes=8 << 10, cache_bytes=8 << 10, dropcache_keys=64,
        sep_threshold=256, max_levels=5)
    base.update(kw)
    return EngineConfig(engine=engine, **base)


# ========================================================== sketch properties
keys_strategy = st.lists(st.integers(min_value=0, max_value=1 << 20),
                         min_size=1, max_size=300)


@settings(max_examples=50, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(keys=keys_strategy, width=st.integers(16, 256),
       depth=st.integers(1, 4))
def test_sketch_never_undercounts_vs_exact_oracle(keys, width, depth):
    """Without decay, estimate(k) >= exact count for every key (count-min
    collisions over-count, never under-count)."""
    sk = DecaySketch(width, depth, half_life=None)
    ks = np.array(keys, np.uint64)
    sk.add(ks)
    exact = {}
    for k in keys:
        exact[k] = exact.get(k, 0) + 1
    uniq = np.array(sorted(exact), np.uint64)
    est = sk.estimate(uniq)
    for k, e in zip(uniq.tolist(), est.tolist()):
        assert e >= exact[k] - 1e-9


@settings(max_examples=50, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(keys=keys_strategy, half_life=st.floats(1.0, 1e6),
       steps=st.lists(st.floats(0.0, 1e5), min_size=1, max_size=8))
def test_sketch_decay_is_monotone(keys, half_life, steps):
    """Advancing the clock without adds can only lower every estimate."""
    sk = DecaySketch(64, 2, half_life=half_life)
    ks = np.array(keys, np.uint64)
    sk.add(ks)
    clock = 0.0
    prev = sk.estimate(ks)
    for d in steps:
        clock += d
        sk.decay_to(clock)
        cur = sk.estimate(ks)
        assert np.all(cur <= prev + 1e-9)
        assert np.all(cur >= 0)
        prev = cur


def test_sketch_estimates_are_decayed_counts():
    sk = DecaySketch(128, 2, half_life=100.0)
    k = np.array([7], np.uint64)
    sk.add(np.repeat(k, 8))
    assert sk.estimate(k)[0] == pytest.approx(8.0)
    sk.decay_to(100.0)          # one half-life
    assert sk.estimate(k)[0] == pytest.approx(4.0)
    assert sk.total_mass() == pytest.approx(4.0)


def test_sketch_rejects_bad_shape():
    with pytest.raises(ValueError):
        DecaySketch(0, 1)
    with pytest.raises(ValueError):
        DecaySketch(16, 0)


# ============================================================ lifetime model
def test_lifetime_mean_interval_tracks_update_cadence():
    est = LifetimeEstimator(64, half_life=None)
    fast, slow = np.array([1], np.int64), np.array([2], np.int64)
    now = 0.0
    for i in range(64):
        now += 10
        est.observe(fast, now)              # every 10 ops
        if i % 8 == 7:
            est.observe(slow, now)          # every 80 ops
    mf = est.mean_interval(fast)[0]
    ms = est.mean_interval(slow)[0]
    assert mf < ms
    assert 8 <= mf <= 32                    # log2 buckets: coarse but sane
    assert 48 <= ms <= 192


def test_lifetime_residual_grows_once_overdue():
    """A group that stops updating must stop predicting imminent death
    (the Lindy turn: residual grows with age past the mean interval)."""
    est = LifetimeEstimator(16, half_life=None)
    g = np.array([3], np.int64)
    now = 0.0
    for _ in range(32):
        now += 10
        est.observe(g, now)
    fresh = est.residual(g, now)[0]
    overdue = est.residual(g, now + 1000)[0]
    assert overdue > 10 * fresh
    # unknown group -> infinite residual (treated as cold, never deferred)
    assert est.residual(np.array([9], np.int64), now)[0] == np.inf


# ========================================================== temperature map
def test_temperature_classifies_zipf_head_hot_tail_cold():
    cfg = EngineConfig(engine="scavenger_adaptive",
                       adaptive_half_life_ops=1e9)
    tr = AccessTracker.from_config(cfg)
    rng = np.random.default_rng(7)
    hot = rng.integers(0, 8, 4000).astype(np.uint64)          # 8 hot keys
    cold = np.arange(100, 1100, dtype=np.uint64)              # 1000 singles
    tr.observe_writes(hot)
    tr.observe_writes(cold)
    tm = TemperatureMap(tr, hot_mult=4.0, cold_mult=0.5)
    t_hot = tm.classify(np.arange(8, dtype=np.uint64))
    t_cold = tm.classify(cold[:64])
    assert np.all(t_hot == TEMP_HOT)
    assert np.all(t_cold == TEMP_COLD)


def test_temperature_map_rejects_bad_cutpoints():
    cfg = EngineConfig(engine="scavenger_adaptive")
    tr = AccessTracker.from_config(cfg)
    with pytest.raises(ValueError):
        TemperatureMap(tr, hot_mult=1.0, cold_mult=2.0)


# ====================================================== config validation
def test_adaptive_flag_defaults_resolve_from_registry():
    assert EngineConfig(engine="scavenger_adaptive").adaptive_enabled
    for e in ("rocksdb", "blobdb", "titan", "terarkdb", "scavenger",
              "hybrid"):
        assert not EngineConfig(engine=e).adaptive_enabled
    # explicit override wins over the registry default
    cfg = EngineConfig(engine="scavenger_adaptive", adaptive_enabled=False)
    assert not cfg.adaptive_enabled
    assert Store(cfg).strategy.tracker is None
    # enabling tracking on a strategy without a tracker is rejected, not a
    # silent no-op
    with pytest.raises(ValueError, match="does not support"):
        EngineConfig(engine="titan", adaptive_enabled=True)


@pytest.mark.parametrize("bad", [
    dict(adaptive_groups=0), dict(adaptive_sketch_width=0),
    dict(adaptive_sketch_depth=0), dict(adaptive_half_life_ops=0.0),
    dict(adaptive_gc_horizon_ops=-1.0), dict(adaptive_defer_weight=1.5),
    dict(adaptive_defer_weight=-0.1),
    dict(temp_hot_mult=0.5, temp_cold_mult=0.5),
])
def test_adaptive_config_validation_rejects(bad):
    with pytest.raises(ValueError):
        EngineConfig(engine="scavenger_adaptive", **bad)


def test_scaled_sizes_adaptive_windows_from_keyspace():
    cfg = EngineConfig.scaled("scavenger_adaptive", 32 << 20, est_keys=50_000)
    assert cfg.adaptive_half_life_ops == 100_000
    assert cfg.adaptive_gc_horizon_ops == 50_000


# ============================================================ golden parity
def test_adaptive_engine_tracker_off_matches_scavenger_golden():
    """``scavenger_adaptive`` with the tracker disabled must be
    byte-identical to plain ``scavenger`` (every hook falls back to the
    inherited default), locked against the pre-refactor golden."""
    got = run_fixed_workload("scavenger_adaptive", adaptive_enabled=False)
    want = GOLDENS["scavenger"]
    for f in INT_FIELDS:
        assert got[f] == want[f], f"{f}: {got[f]} != {want[f]}"
    for f in FLOAT_FIELDS:
        assert math.isclose(got[f], want[f], rel_tol=1e-9, abs_tol=1e-12), \
            f"{f}: {got[f]} != {want[f]}"


# ==================================================== end-to-end behaviour
def test_temperature_partitioned_vssts_on_skewed_writes():
    """Hot-key churn lands in hot vSSTs, the cold bulk in cold vSSTs."""
    cfg = tiny_cfg("scavenger_adaptive", adaptive_half_life_ops=1e6)
    s = Store(cfg)
    rng = np.random.default_rng(0)
    for _ in range(40):
        hot = rng.integers(0, 4, 48).astype(np.uint64)       # 4 hot keys
        cold = rng.integers(4, 2000, 16).astype(np.uint64)
        keys = np.concatenate([hot, cold])
        s.write(WriteBatch().puts(keys, np.full(len(keys), 600)))
    s.flush()
    temps = {t.temperature for t in s.version.value_files.values()}
    assert TEMP_HOT in temps and TEMP_COLD in temps
    # hot files hold only head keys
    for t in s.version.value_files.values():
        if t.temperature == TEMP_HOT:
            assert t.keys.max() < 4


def test_adaptive_store_keeps_dict_semantics():
    """Observation and adaptive GC must not corrupt reads."""
    s = Store(tiny_cfg("scavenger_adaptive", gc_garbage_ratio=0.05))
    oracle = {}
    rng = np.random.default_rng(11)
    for _ in range(6):
        for k in range(40):
            if rng.random() < 0.7:
                oracle[k] = s.put(k, int(rng.choice([64, 700, 1500, 4000])))
        s.flush()
    assert s.n_gc_runs > 0
    assert s.strategy.tracker.ops > 0
    for k, v in oracle.items():
        assert s.get(k) == v


def test_adaptive_beats_titan_on_skewed_smoke():
    """Compressed version of the ISSUE 4 acceptance gate
    (``benchmarks/adaptive_gc.py`` runs the full version): on a skewed
    update stream, scavenger_adaptive must reclaim with less GC rewrite
    traffic than the titan writeback baseline at equal-or-better
    space amplification."""
    from repro.core.engine import io as sio
    from repro.workloads import Runner, pareto_1k

    spec = pareto_1k(8 << 20)

    def measure(engine):
        cfg = EngineConfig.scaled(engine, spec.dataset_bytes,
                                  est_keys=spec.n_keys)
        s = Store(cfg)
        r = Runner(s, spec, batch=256)
        r.load()
        r.update()
        gcw = (s.io.write_bytes.get(sio.CAT_GC_WRITE, 0)
               + s.io.write_bytes.get(sio.CAT_GC_WRITE_INDEX, 0))
        return gcw, s.space_amplification()

    titan_gc, titan_sa = measure("titan")
    adapt_gc, adapt_sa = measure("scavenger_adaptive")
    assert adapt_gc < titan_gc
    assert adapt_sa <= titan_sa
