"""Engine strategy registry, config validation, and the layered-core
vectorized primitives (Memtable.get_batch, LatestOracle, hidden-garbage)."""

import numpy as np
import pytest

from repro.core import (ENGINES, EngineConfig, EngineStrategy, Store,
                        WriteBatch, available_engines, register_engine)
from repro.core.engines import registry as engreg
from repro.core.engine.memtable import Memtable
from repro.core.engine.tables import ETYPE_REF
from repro.core.oracle import LatestOracle


def tiny_cfg(engine, **kw):
    base = dict(
        memtable_bytes=4 << 10, ksst_bytes=4 << 10, vsst_bytes=16 << 10,
        base_level_bytes=8 << 10, cache_bytes=8 << 10, dropcache_keys=64,
        sep_threshold=256, max_levels=5)
    base.update(kw)
    return EngineConfig(engine=engine, **base)


# ============================================================== registry
def test_registry_matches_canonical_engine_list():
    assert available_engines() == ENGINES
    assert ENGINES[:5] == ("rocksdb", "blobdb", "titan", "terarkdb",
                           "scavenger")
    assert "hybrid" in ENGINES


def test_unknown_engine_rejected_with_clear_error():
    with pytest.raises(ValueError, match="unknown engine 'leveldb'"):
        EngineConfig(engine="leveldb")
    with pytest.raises(ValueError, match="registered engines"):
        EngineConfig(engine="")


@pytest.mark.parametrize("engine,bad_scheme", [
    ("rocksdb", "inherit"), ("rocksdb", "writeback"),
    ("blobdb", "inherit"), ("titan", "compaction"),
    ("terarkdb", "compaction"), ("scavenger", "none"),
    ("hybrid", "compaction"),
])
def test_incompatible_gc_scheme_rejected(engine, bad_scheme):
    with pytest.raises(ValueError, match="does not support gc_scheme"):
        EngineConfig(engine=engine, gc_scheme=bad_scheme)


def test_gc_scheme_defaults_and_overrides():
    assert EngineConfig(engine="rocksdb").gc_scheme == "none"
    assert EngineConfig(engine="blobdb").gc_scheme == "compaction"
    assert EngineConfig(engine="titan").gc_scheme == "writeback"
    assert EngineConfig(engine="terarkdb").gc_scheme == "inherit"
    assert EngineConfig(engine="scavenger").gc_scheme == "inherit"
    assert EngineConfig(engine="hybrid").gc_scheme == "inherit"
    # terarkdb/scavenger/hybrid accept the writeback ablation
    cfg = EngineConfig(engine="scavenger", gc_scheme="writeback")
    assert cfg.gc_scheme == "writeback"


def test_strategy_flag_defaults():
    scav = EngineConfig(engine="scavenger")
    assert (scav.compensated_compaction and scav.lazy_read
            and scav.index_decoupled and scav.hotcold_write)
    tdb = EngineConfig(engine="terarkdb")
    assert not (tdb.compensated_compaction or tdb.lazy_read
                or tdb.index_decoupled or tdb.hotcold_write)
    rdb = EngineConfig(engine="rocksdb")
    assert not rdb.kv_separated
    hyb = EngineConfig(engine="hybrid")
    assert hyb.kv_separated and hyb.compensated_compaction


def test_custom_engine_registration_roundtrip():
    """A third-party engine plugs in with zero core edits."""

    @register_engine
    class EagerSepEngine(EngineStrategy):
        name = "eager-sep-test"
        kv_separated = True
        gc_schemes = ("inherit",)

        def separation_mask(self, store, keys, ety, vsizes):
            from repro.core.engine.tables import ETYPE_INLINE
            return ety == ETYPE_INLINE        # separate everything

    try:
        s = Store(tiny_cfg("eager-sep-test"))
        oracle = {}
        for k in range(30):
            oracle[k] = s.put(k, 64)          # below any size threshold
        s.flush()
        assert len(s.version.value_files) >= 1   # even tiny values separated
        for k, v in oracle.items():
            assert s.get(k) == v
        # reusing a registered name (built-in or custom) must fail fast
        with pytest.raises(ValueError, match="already registered"):
            @register_engine
            class Clobber(EngineStrategy):
                name = "scavenger"
    finally:
        del engreg._REGISTRY["eager-sep-test"]


# ================================================================ hybrid
def test_hybrid_size_tiered_placement():
    cfg = tiny_cfg("hybrid", hybrid_large_threshold=4096)
    s = Store(cfg)
    s.put(1, 64)        # small  -> inline
    s.put(2, 1000)      # medium, cold -> separated
    s.put(3, 8000)      # large  -> separated
    s.rotate_memtable()
    s._flush_job()      # flush exactly one kSST, no compactions yet
    t = s.version.levels[0][0]
    etype = {int(k): int(e) for k, e in zip(t.keys, t.etype)}
    assert etype[1] != ETYPE_REF
    assert etype[2] == ETYPE_REF
    assert etype[3] == ETYPE_REF


def test_hybrid_hot_medium_values_stay_inline():
    cfg = tiny_cfg("hybrid", hybrid_large_threshold=4096)
    s = Store(cfg)
    s.dropcache.record(np.array([7], np.uint64))    # key 7 is write-hot
    s.put(7, 1000)      # medium + hot -> inline
    s.put(8, 1000)      # medium + cold -> separated
    s.put(9, 8000)      # large, hot or not -> separated
    s.dropcache.record(np.array([9], np.uint64))
    s.rotate_memtable()
    s._flush_job()
    t = s.version.levels[0][0]
    etype = {int(k): int(e) for k, e in zip(t.keys, t.etype)}
    assert etype[7] != ETYPE_REF
    assert etype[8] == ETYPE_REF
    assert etype[9] == ETYPE_REF


def test_hybrid_full_workload_roundtrip():
    s = Store(tiny_cfg("hybrid", gc_garbage_ratio=0.05))
    oracle = {}
    rng = np.random.default_rng(3)
    for _ in range(5):
        for k in range(40):
            if rng.random() < 0.7:
                oracle[k] = s.put(k, int(rng.choice([64, 1000, 9000])))
        s.flush()
    for k, v in oracle.items():
        assert s.get(k) == v


# =================================================== promoted constants
def test_write_pressure_constants_are_config_fields():
    assert EngineConfig().max_immutables == 2
    assert EngineConfig().delayed_write_rate == 16.0
    # a tighter immutable cap must stall the foreground more
    def run(max_imm):
        s = Store(tiny_cfg("scavenger", max_immutables=max_imm))
        for k in range(200):
            s.put(k, 600)
        return s.stall_us
    assert run(0) >= run(8)


# ==================================================== vectorized probes
def test_memtable_get_batch_matches_scalar_get():
    cfg = EngineConfig(engine="scavenger")
    mt = Memtable(cfg)
    rng = np.random.default_rng(11)
    for i in range(200):
        k = int(rng.integers(0, 64))
        if rng.random() < 0.2:
            mt.delete(k, i)
        elif rng.random() < 0.3:
            mt.put_ref(k, i, i + 1, int(rng.integers(1, 999)), 5)
        else:
            mt.put(k, i, i + 1, int(rng.integers(1, 999)))
    probe = np.arange(0, 80, dtype=np.uint64)
    found, seqs, ety, vids, vsz, vf = mt.get_batch(probe)
    for j, k in enumerate(probe.tolist()):
        e = mt.get(k)
        assert bool(found[j]) == (e is not None)
        if e is not None:
            assert (int(seqs[j]), int(ety[j]), int(vids[j]), int(vsz[j]),
                    int(vf[j])) == e


def test_memtable_snapshot_invalidation():
    cfg = EngineConfig(engine="scavenger")
    mt = Memtable(cfg)
    mt.put(5, 1, 1, 100)
    k1, *_ = mt.snapshot()
    assert k1.tolist() == [5]
    mt.put(3, 2, 2, 100)
    k2, *_ = mt.snapshot()
    assert k2.tolist() == [3, 5]


def test_latest_oracle_matches_dict_reference():
    rng = np.random.default_rng(99)
    oracle = LatestOracle()
    ref: dict = {}
    ref_valid = 0
    for _ in range(40):
        n = int(rng.integers(1, 32))
        keys = rng.integers(0, 50, n).astype(np.uint64)
        is_put = rng.random(n) < 0.8
        vids = rng.integers(1, 1 << 20, n).astype(np.uint64)
        vsz = np.where(is_put, rng.integers(1, 5000, n), 0).astype(np.int64)
        oracle.apply_batch(is_put, keys, vids, vsz)
        for j in range(n):
            k = int(keys[j])
            prev = ref.pop(k, None)
            if prev is not None:
                ref_valid -= prev[1]
            if is_put[j]:
                ref[k] = (int(vids[j]), int(vsz[j]))
                ref_valid += int(vsz[j])
        assert oracle.valid_bytes == ref_valid
        assert len(oracle) == len(ref)
    for k in range(55):
        assert oracle.get(k) == ref.get(k)
    found, vids, vsz = oracle.lookup_batch(np.arange(55, dtype=np.uint64))
    for k in range(55):
        assert bool(found[k]) == (k in ref)
        if k in ref:
            assert (int(vids[k]), int(vsz[k])) == ref[k]


def test_hidden_garbage_matches_scalar_reference():
    s = Store(tiny_cfg("terarkdb"))
    rng = np.random.default_rng(5)
    for _ in range(4):
        for k in range(30):
            if rng.random() < 0.8:
                s.put(k, 1500)
        s.flush()

    # scalar reimplementation of the pre-refactor walk
    hidden, seen = 0, set()
    for t in s.version.all_kssts():
        refm = t.etype == ETYPE_REF
        for k, vid, vsz, vf in zip(t.keys[refm].tolist(),
                                   t.vids[refm].tolist(),
                                   t.vsizes[refm].tolist(),
                                   t.vfiles[refm].tolist()):
            cur = s.latest.get(k)
            if cur is not None and cur[0] == vid:
                continue
            if (k, vid) in seen:
                continue
            seen.add((k, vid))
            vt = s.resolve_value_file(int(vf), int(k), int(vid))
            if vt is None:
                continue
            hidden += vsz
    assert s.hidden_garbage_bytes() == hidden
    assert hidden > 0       # overwrites left stale refs behind


@pytest.mark.parametrize("engine", ["terarkdb", "scavenger", "hybrid"])
def test_chain_compression_matches_uncompressed_walk(engine):
    """Differential check of compress_group: resolve every REF locator in
    the store through (a) a reference uncompressed chain walk over a
    snapshot of the group structure and (b) the production vectorized
    resolver (which compresses in place) — results must agree exactly."""
    from repro.core.values.resolve import resolve_value_fids

    s = Store(tiny_cfg(engine, gc_garbage_ratio=0.05))
    rng = np.random.default_rng(17)
    for _ in range(8):          # many GC generations -> deep chains
        for k in range(40):
            if rng.random() < 0.7:
                s.put(k, int(rng.choice([700, 1500, 4000])))
        s.flush()
    assert s.n_gc_runs > 2

    # snapshot the (uncompressed or partially-compressed) group structure
    snap = {fid: list(g.files) for fid, g in s.chains.items()}
    live = set(s.version.value_files)

    def ref_resolve(vf, k, vid):
        cur = int(vf)
        for _ in range(10_000):
            if cur in live:
                return cur
            members = snap.get(cur)
            if members is None:
                return -1
            nxt = -1
            for t in members:
                p = int(t.find(np.array([k], np.uint64))[0])
                if p >= 0 and int(t.vids[p]) == vid:
                    nxt = t.fid
                    break
            if nxt < 0:
                return -1
            cur = nxt
        raise RuntimeError("cycle")

    checked = 0
    for t in s.version.all_kssts():
        m = t.etype == ETYPE_REF
        if not m.any():
            continue
        keys, vids, vfs = t.keys[m], t.vids[m], t.vfiles[m]
        want = [ref_resolve(vf, int(k), int(v))
                for k, v, vf in zip(keys.tolist(), vids.tolist(),
                                    vfs.tolist())]
        got = resolve_value_fids(s, vfs, keys, vids)   # compresses in place
        assert got.tolist() == want
        checked += len(want)
    assert checked > 0


def test_scan_accepts_negative_start_key():
    s = Store(tiny_cfg("scavenger"))
    for k in range(20):
        s.put(k, 600)
    s.flush()                       # keys now live in SSTables
    got = s.scan(-3, 5)
    assert [k for k, _ in got] == [0, 1, 2, 3, 4]


def test_write_batch_oracle_consistency_through_store():
    """latest oracle tracks last-write-wins through the batched write path
    (duplicate keys inside one batch, deletes of missing keys)."""
    s = Store(tiny_cfg("scavenger"))
    b = WriteBatch()
    b.puts(np.array([1, 2, 1], np.uint64), np.array([100, 200, 300],
                                                    np.int64))
    b.deletes(np.array([2, 9], np.uint64))
    vids = s.write(b)
    assert s.latest.get(1) == (int(vids[2]), 300)   # second put of key 1 won
    assert s.latest.get(2) is None                  # deleted in same batch
    assert s.valid_bytes == 300
