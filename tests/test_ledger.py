"""Amplification-ledger & causality tests (DESIGN.md §13).

Five contracts:

  * **Conservation** — per (shard, category) the cause cells sum
    *byte-identically* (exact integer equality, no tolerance) to the
    ``final − base`` SimIO counters, on every engine, on random
    workloads (hypothesis), and on a quota-stressed fleet.
  * **Golden parity** — attaching the ledger-bearing ``Observer``
    changes nothing about the accounting (the PR-2 goldens hold with the
    ledger enabled *and* it actually recorded cells — the tap is live,
    not dormant).
  * **Span well-formedness** — parent/child links form a forest: ids
    are unique and increasing, every non-root parent exists, children
    inherit the parent's trace id, roots start their own trace.
  * **Exemplar round-trip** — a LogHist tail exemplar is a trace id
    that resolves to real span events in the Chrome trace export.
  * **CLI & gate** — ``obs blame`` emits blame.json and a per-cause
    table; ``obs check`` flags a tampered ledger; the perf regression
    gate passes stable trajectories and fails regressed ones.
"""

from __future__ import annotations

import io
import json

import numpy as np
import pytest

from _hypothesis_support import HealthCheck, given, settings, st
from test_refactor_parity import GOLDENS, run_fixed_workload

from repro.core import ENGINES, EngineConfig, ShardedStore, Store, WriteBatch
from repro.obs import (Observer, blame_rows, cause_key, check_conservation,
                       live_breakdown, parse_cause)
from repro.obs.cli import main as obs_main
from repro.obs.trace import chrome_trace

N_KEYS = 2048
VSIZES = np.array([64, 200, 600, 2000, 9000], np.int64)


def _drive(store, groups: int = 12, seed: int = 0) -> None:
    rng = np.random.default_rng(seed)
    for _ in range(groups):
        keys = rng.integers(0, N_KEYS, 128).astype(np.uint64)
        sizes = VSIZES[rng.integers(0, len(VSIZES), 128)]
        store.write(WriteBatch().puts(keys, sizes))
        store.write(WriteBatch().deletes(
            rng.integers(0, N_KEYS, 8).astype(np.uint64)))
        store.multi_get(rng.integers(0, N_KEYS, 48).astype(np.uint64))
        store.multi_scan(rng.integers(0, N_KEYS, 4).astype(np.int64), 8)
    store.drain()


def _observed_state(engine: str, groups: int = 12, seed: int = 0,
                    **cfg_kw) -> tuple[Observer, dict]:
    obs = Observer(sample_every=16)
    cfg = EngineConfig.scaled(engine, 8 << 20, est_keys=N_KEYS,
                              observer=obs, **cfg_kw)
    _drive(Store(cfg), groups=groups, seed=seed)
    obs.finish()
    return obs, obs.ledger.state_dict()


def _cause_keys(state: dict) -> set[str]:
    return {k for sh in state["shards"].values() for k in sh["cells"]}


# =========================================================== conservation
@pytest.mark.parametrize("engine", ENGINES)
def test_conservation_on_all_engines(engine):
    """Every byte the SimIO counted is in exactly one cause cell — exact
    integer equality per (shard, category), on all seven engines."""
    obs, state = _observed_state(engine)
    assert check_conservation(state) == []
    keys = _cause_keys(state)
    assert any("op=write" in k and "trigger=user" in k for k in keys)
    # background work was attributed, not just the user op
    assert any("trigger=lane_budget" in k for k in keys)


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.sampled_from(ENGINES), st.integers(2, 8), st.integers(0, 1000))
def test_conservation_random_workloads(engine, groups, seed):
    """Property: conservation is workload-independent — random group
    counts and seeds never produce an unattributed or double-counted
    byte on any engine."""
    _, state = _observed_state(engine, groups=groups, seed=seed)
    assert check_conservation(state) == []


@pytest.mark.parametrize("quota", [None, 1 << 20])
def test_conservation_on_quota_stressed_fleet(quota):
    """Fleet-scheduled shards conserve per shard; the hard-quota path
    shows up as a distinct ``trigger=quota_stall`` cause."""
    obs = Observer(sample_every=16)
    cfg = EngineConfig.scaled("scavenger", 8 << 20, est_keys=N_KEYS,
                              observer=obs, space_quota_bytes=quota)
    fleet = ShardedStore(cfg, n_shards=3, shard_policy="range",
                         key_space=N_KEYS)
    rng = np.random.default_rng(0)
    for _ in range(10):         # write-heavy: keeps space above the quota
        keys = rng.integers(0, N_KEYS, 128).astype(np.uint64)
        fleet.write(WriteBatch().puts(
            keys, VSIZES[rng.integers(0, len(VSIZES), 128)]))
        fleet.multi_get(rng.integers(0, N_KEYS, 48).astype(np.uint64))
    fleet.drain()
    obs.finish()
    state = obs.ledger.state_dict()
    assert len(state["shards"]) == 3
    assert check_conservation(state) == []
    if quota is not None:
        assert any("trigger=quota_stall" in k for k in _cause_keys(state))


def test_pick_taxonomy_present():
    """Policy decisions materialize as ``pick=`` facets: flushes carry
    memtable_rotation; compaction carries the compensated-size pick on a
    compensating engine; GC carries garbage_ratio."""
    _, state = _observed_state("scavenger", groups=20)
    picks = {parse_cause(k).get("pick") for k in _cause_keys(state)}
    assert {"memtable_rotation", "compensated_size",
            "garbage_ratio"} <= picks


def test_pinned_origin_scope():
    """A cause scope with an explicit origin (the serving tier's
    admission writes) pins it: the user-op span does not override it."""
    obs = Observer()
    cfg = EngineConfig.scaled("scavenger", 8 << 20, est_keys=N_KEYS,
                              observer=obs)
    store = Store(cfg)
    with obs.cause(store, origin="admission"):
        store.write(WriteBatch().puts(
            np.arange(64, dtype=np.uint64),
            np.full(64, 512, np.int64)))
    store.drain()
    obs.finish()
    state = obs.ledger.state_dict()
    assert check_conservation(state) == []
    assert any(parse_cause(k).get("origin") == "admission"
               for k in _cause_keys(state))


def test_cause_key_round_trip():
    cause = {"origin": "write", "op": "gc", "trigger": "lane_budget",
             "pick": "garbage_ratio"}
    assert parse_cause(cause_key(cause)) == cause


def test_live_breakdown_matches_ledger():
    """The fig05 live view (write bytes by op/pick) sums to the same
    totals as the raw cells, without finish()."""
    obs = Observer(sample_every=16)
    cfg = EngineConfig.scaled("scavenger", 8 << 20, est_keys=N_KEYS,
                              observer=obs)
    store = Store(cfg)
    _drive(store, groups=8)
    view = live_breakdown(obs, store)
    assert view["write_bytes_by_op"].get("write", 0) > 0
    assert view["write_bytes_by_pick"].get("memtable_rotation", 0) > 0
    obs.finish()
    state = obs.ledger.state_dict()
    total = sum(sum(c.get("write_bytes", {}).values())
                for sh in state["shards"].values()
                for c in sh["cells"].values())
    assert sum(view["write_bytes_by_op"].values()) == total


# ========================================================== golden parity
@pytest.mark.parametrize("engine", sorted(GOLDENS))
def test_golden_parity_with_live_ledger(engine):
    """The PR-2 goldens hold with the ledger-bearing observer attached,
    and the ledger demonstrably recorded (non-empty cells + exact
    conservation): attribution is free, byte-wise."""
    obs = Observer(sample_every=16)
    got = run_fixed_workload(engine, observer=obs)
    for field, val in GOLDENS[engine].items():
        assert got[field] == pytest.approx(val, rel=0, abs=0), field
    obs.finish()
    state = obs.ledger.state_dict()
    assert _cause_keys(state), "ledger recorded nothing"
    assert check_conservation(state) == []


# ==================================================== span well-formedness
def test_spans_form_a_well_linked_forest():
    """Ids unique & increasing; every non-root parent is a recorded span
    with a smaller id (acyclic by construction); children inherit the
    parent's trace; roots start their own trace (trace == id)."""
    obs, _ = _observed_state("scavenger_adaptive")
    spans = [ev for ev in obs.tracer.events
             if ev["ph"] == "X" and "id" in ev]
    assert spans
    by_id = {ev["id"]: ev for ev in spans}
    assert len(by_id) == len(spans), "duplicate span ids"
    for ev in spans:
        parent = ev.get("parent", 0)
        if parent:
            assert parent in by_id, f"orphan span {ev['id']}"
            assert parent < ev["id"]
            assert ev["trace"] == by_id[parent]["trace"]
        else:
            assert ev["trace"] == ev["id"]


def test_stalled_write_has_background_children():
    """The payoff of request-scoped tracing: a background job force-run
    inside a stalled user op is a *child* of that op's span."""
    obs, _ = _observed_state("scavenger", groups=20)
    spans = [ev for ev in obs.tracer.events
             if ev["ph"] == "X" and "id" in ev]
    by_id = {ev["id"]: ev for ev in spans}
    bg_children = [ev for ev in spans
                   if ev["lane"] in ("bg", "gc") and ev.get("parent")
                   and by_id[ev["parent"]]["name"] in
                   ("write", "multi_get", "multi_scan")]
    assert bg_children, "no background job nested under a user op"


# ===================================================== exemplar round-trip
def test_exemplar_round_trips_through_chrome_trace():
    """A p99 exemplar from the latency histogram is a trace id that
    resolves to at least one span in the Chrome export, and that trace's
    events include the op class the histogram measured."""
    obs, _ = _observed_state("scavenger")
    for metric, opname in (("write_us", "write"),
                           ("multi_get_us", "multi_get")):
        h = obs.metrics.merged(metric)
        ex = h.exemplar_at(0.99)
        assert ex, f"{metric} kept no tail exemplar"
        evs = [e for e in chrome_trace(obs.tracer)["traceEvents"]
               if e.get("args", {}).get("trace_id") == ex]
        assert evs, f"exemplar {ex} not in chrome trace"
        assert any(e["name"] == opname for e in evs)


def test_exemplar_survives_dump_reload(tmp_path):
    from repro.obs import LogHist
    obs, _ = _observed_state("scavenger", groups=6)
    paths = obs.dump(tmp_path / "d")
    state = json.loads(open(paths["metrics"]).read())
    h = LogHist()
    for entry in state["write_us"]:
        h.merge(LogHist.from_state(entry))
    assert h.exemplar_at(0.99) == obs.metrics.merged(
        "write_us").exemplar_at(0.99)


# ================================================================ CLI
def test_cli_blame_emits_table_and_json(tmp_path, capsys):
    obs, _ = _observed_state("scavenger")
    obs.dump(tmp_path / "run")
    assert obs_main(["blame", str(tmp_path / "run")]) == 0
    out = capsys.readouterr().out
    assert "conservation: OK" in out
    assert "write<-write [user]" in out
    blame = json.loads((tmp_path / "run" / "blame.json").read_text())
    assert blame["conservation_failures"] == []
    assert blame["rows"] == blame_rows(json.loads(
        (tmp_path / "run" / "ledger.json").read_text()))
    wa = {r["op"]: r["wa"] for r in blame["rows"]}
    assert all(v >= 0.0 for v in wa.values())


def test_cli_blame_missing_ledger_fails(tmp_path, capsys):
    d = tmp_path / "empty"
    d.mkdir()
    (d / "metrics.json").write_text("{}")
    assert obs_main(["blame", str(d)]) == 1
    assert "no ledger.json" in capsys.readouterr().out


def test_cli_check_flags_tampered_ledger(tmp_path, capsys):
    """Corrupting one cell breaks exact conservation -> check fails."""
    obs, _ = _observed_state("scavenger", groups=6)
    obs.dump(tmp_path / "run")
    lpath = tmp_path / "run" / "ledger.json"
    state = json.loads(lpath.read_text())
    sh = next(iter(state["shards"].values()))
    for cell in sh["cells"].values():
        if cell.get("write_bytes"):
            cat = next(iter(cell["write_bytes"]))
            cell["write_bytes"][cat] += 1          # one stolen byte
            break
    lpath.write_text(json.dumps(state))
    assert obs_main(["check", str(tmp_path / "run")]) == 1
    out = capsys.readouterr().out
    assert "conservation" in out and "FAIL" in out
    capsys.readouterr()
    assert obs_main(["blame", str(tmp_path / "run")]) == 1
    assert "conservation: FAIL" in capsys.readouterr().out


def test_cli_dashboard_shows_cause_bars_and_exemplars(tmp_path, capsys):
    obs, _ = _observed_state("scavenger")
    obs.dump(tmp_path / "run")
    assert obs_main(["dashboard", str(tmp_path / "run")]) == 0
    out = capsys.readouterr().out
    assert "write bytes by cause:" in out
    assert "tail exemplars" in out and "trace" in out


# ======================================================== perf gate unit
def _traj(rows_list, section="bench", scale="quick"):
    return [{"section": section, "scale": scale, "rows": rows}
            for rows in rows_list]


def _run_gate(tmp_path, entries, tol=0.5, window=5):
    from benchmarks.perf_report import gate
    p = tmp_path / "BENCH_t.json"
    p.write_text(json.dumps(entries))
    buf = io.StringIO()
    n = gate(tol=tol, window=window, files=(str(p),), out=buf)
    return n, buf.getvalue()


def test_gate_passes_stable_trajectory(tmp_path):
    rows = [{"name": "op", "us_per_call": 10.0}]
    n, out = _run_gate(tmp_path, _traj([rows, rows, rows]))
    assert n == 0 and "0 regressed" in out


def test_gate_fails_regression_and_respects_tol(tmp_path):
    entries = _traj([[{"name": "op", "us_per_call": 10.0}],
                     [{"name": "op", "us_per_call": 10.0}],
                     [{"name": "op", "us_per_call": 30.0}]])
    n, out = _run_gate(tmp_path, entries, tol=0.5)
    assert n == 1 and "GATE FAIL" in out and "op us_per_call" in out
    n, _ = _run_gate(tmp_path, entries, tol=5.0)
    assert n == 0


def test_gate_needs_history_and_skips_untracked_rows(tmp_path):
    # single entry: nothing to compare against
    n, out = _run_gate(tmp_path, _traj([[{"name": "op",
                                          "us_per_call": 99.0}]]))
    assert n == 0 and "0 metrics checked" in out
    # analytic rows (no tracked shape) are ignored even when they grow
    entries = _traj([[{"cell": "c", "baseline": 1.0}],
                     [{"cell": "c", "baseline": 9.0}]])
    n, out = _run_gate(tmp_path, entries)
    assert n == 0 and "0 metrics checked" in out


def test_gate_tracks_p99_and_space_amp_shapes(tmp_path):
    entries = _traj([
        [{"engine": "e", "metric": "m", "p99": 5.0},
         {"engine": "e", "workload": "w", "us_per_update": 2.0,
          "space_amp": 1.5}],
        [{"engine": "e", "metric": "m", "p99": 5.0},
         {"engine": "e", "workload": "w", "us_per_update": 2.0,
          "space_amp": 1.5}],
        [{"engine": "e", "metric": "m", "p99": 50.0},
         {"engine": "e", "workload": "w", "us_per_update": 2.0,
          "space_amp": 9.0}],
    ])
    n, out = _run_gate(tmp_path, entries, tol=0.5)
    assert n == 2
    assert "e/m p99" in out and "e/w space_amp" in out
