"""scavlint self-tests (DESIGN.md §10).

Good/bad fixture snippets per pass, the suppression-comment escape hatch,
the baseline round-trip, CLI exit codes, and the zero-findings smoke on
``src/`` — a regression in the analyzer is caught the same way as a
regression in the store it guards.
"""

from __future__ import annotations

import json
from pathlib import Path

import pytest

from repro.analysis import SourceFile, all_passes, run_analysis
from repro.analysis.baseline import load_baseline, write_baseline
from repro.analysis.cli import main as cli_main
from repro.analysis.findings import Finding

REPO = Path(__file__).resolve().parent.parent


def check(pass_name: str, text: str, rel: str) -> list[Finding]:
    """Run one file-scoped pass over a source snippet."""
    p = all_passes()[pass_name]
    sf = SourceFile(text, rel)
    assert p.scope(sf.rel), f"{rel} should be in scope of {pass_name}"
    return [f for f in p.check(sf) if f is not None]


def in_scope(pass_name: str, rel: str) -> bool:
    return all_passes()[pass_name].scope(rel)


# ---------------------------------------------------------------- registry
def test_registry_has_all_passes():
    names = set(all_passes())
    assert names == {"durability-coverage", "hook-purity", "io-accounting",
                     "vectorization", "kernel-parity", "config-discipline",
                     "docs-citation", "obs-purity", "attribution-coverage"}


def test_finding_key_is_line_independent():
    a = Finding("p", "error", "f.py", 10, "msg", context="fn")
    b = Finding("p", "error", "f.py", 99, "msg", context="fn")
    assert a.key == b.key
    c = Finding("p", "error", "f.py", 10, "other", context="fn")
    assert a.key != c.key


# ---------------------------------------------------- durability-coverage
BAD_DURABILITY = """
def drop(store, fid):
    store.version.retire_value_file(fid, None)
"""

GOOD_DURABILITY = """
def drop(store, fid):
    store.version.retire_value_file(fid, None)
    store._log_edit("retire_value_file", fid=fid)
"""


def test_durability_flags_unlogged_mutation():
    fs = check("durability-coverage", BAD_DURABILITY,
               "src/repro/core/values/x.py")
    assert len(fs) == 1 and "retire_value_file" in fs[0].message
    assert fs[0].context == "drop"


def test_durability_accepts_paired_log_edit():
    assert not check("durability-coverage", GOOD_DURABILITY,
                     "src/repro/core/values/x.py")


def test_durability_suppression_on_def_line():
    text = BAD_DURABILITY.replace(
        "def drop(store, fid):",
        "def drop(store, fid):  # scavlint: allow-durability replay only")
    assert not check("durability-coverage", text,
                     "src/repro/core/values/x.py")


def test_durability_scope_excludes_version_and_durability():
    assert not in_scope("durability-coverage",
                        "src/repro/core/engine/version.py")
    assert not in_scope("durability-coverage",
                        "src/repro/core/durability/wal.py")
    assert not in_scope("durability-coverage", "benchmarks/run.py")


# ------------------------------------------------------------- hook-purity
BAD_HOOK_ASSIGN = """
class E:
    def gc_candidate_score(self, store, t):
        store.version.marker = 1
        return 0.0
"""

BAD_HOOK_CALL = """
class E:
    def observe_batch(self, store, keys):
        store.io.seq_write(100)
"""

GOOD_HOOK = """
class E:
    def gc_candidate_score(self, store, t):
        self._cache[t.fid] = t.garbage_bytes
        return t.garbage_bytes / max(t.file_bytes, 1)

    def gc_finalize(self, store, batch):
        store.version.retire_value_file(batch[0], None)
        store._log_edit("retire_value_file", fid=batch[0])
"""


def test_purity_flags_param_rooted_assign():
    fs = check("hook-purity", BAD_HOOK_ASSIGN,
               "src/repro/core/engines/custom.py")
    assert len(fs) == 1 and "'store'" in fs[0].message


def test_purity_flags_mutating_call():
    fs = check("hook-purity", BAD_HOOK_CALL,
               "src/repro/core/engines/custom.py")
    assert len(fs) == 1 and "seq_write" in fs[0].message


def test_purity_allows_self_state_and_effectful_hooks():
    assert not check("hook-purity", GOOD_HOOK,
                     "src/repro/core/engines/custom.py")


def test_purity_scope_is_engines_and_adaptive_engine():
    assert in_scope("hook-purity", "src/repro/core/adaptive/engine.py")
    assert not in_scope("hook-purity", "src/repro/core/store.py")


# -------------------------------------------------------------- obs-purity
BAD_OBS_CALL = """
class Hook:
    def on_op(self, store, name, value):
        store.io.stall(10.0)
        self.metrics[name] = value
"""

BAD_OBS_ASSIGN = """
def sample(store):
    store.io.lanes["fg"] = 0.0
    return dict(store.io.lanes)
"""

BAD_OBS_IMPORT = """
from repro.core.store import Store


def f(store):
    return store.stall_us
"""

GOOD_OBS = """
import json


def sample(store):
    out = {}
    out["fg"] = store.io.lanes.get("fg", 0.0)
    out["stall"] = store.stall_us
    return json.dumps(out)
"""


def test_obs_purity_flags_clock_advancing_call():
    fs = check("obs-purity", BAD_OBS_CALL, "src/repro/obs/custom.py")
    assert len(fs) == 1 and "stall()" in fs[0].message


def test_obs_purity_flags_param_rooted_assign():
    fs = check("obs-purity", BAD_OBS_ASSIGN, "src/repro/obs/custom.py")
    assert len(fs) == 1 and "'store'" in fs[0].message


def test_obs_purity_flags_core_import():
    fs = check("obs-purity", BAD_OBS_IMPORT, "src/repro/obs/custom.py")
    assert len(fs) == 1 and "repro.core" in fs[0].message


def test_obs_purity_allows_reads_and_dict_get():
    assert not check("obs-purity", GOOD_OBS, "src/repro/obs/custom.py")


def test_obs_purity_suppression():
    text = BAD_OBS_CALL.replace(
        "store.io.stall(10.0)",
        "store.io.stall(10.0)  # scavlint: allow-obs-impure test hook")
    assert not check("obs-purity", text, "src/repro/obs/custom.py")


def test_obs_purity_scope_is_obs_only():
    assert not in_scope("obs-purity", "src/repro/core/store.py")
    assert in_scope("obs-purity", "src/repro/obs/observer.py")


# ---------------------------------------------- attribution-coverage (§13)
BAD_RUNJOB = """
def pump(store):
    job = store.next_compact_job()
    store.run_job(job, "bg")
"""

GOOD_RUNJOB = """
def pump(store):
    store.run_job(store.next_compact_job(), "bg", trigger="lane_budget")
    store.run_job(store.next_gc_job(), "gc", "drain")
"""

BAD_EDIT = """
def install(store, t):
    store.version.add_value_file(t)
    store._log_edit("add_value_file", fid=t.fid)
"""

GOOD_EDIT_SPACE = """
def install(store, t):
    store.version.add_value_file(t)
    store._log_edit("add_value_file", fid=t.fid)
    store.obs.on_space(store, "vsst_add", t.file_bytes)
"""

GOOD_EDIT_CAUSE = """
def install(store, t):
    with store.obs.cause(store, temp="cold"):
        store.version.add_value_file(t)
        store._log_edit("add_value_file", fid=t.fid)
"""


def test_attribution_flags_triggerless_run_job():
    fs = check("attribution-coverage", BAD_RUNJOB, "src/repro/core/x.py")
    assert len(fs) == 1 and "without an explicit trigger" in fs[0].message
    assert fs[0].context == "pump"


def test_attribution_accepts_trigger_kw_or_positional():
    assert not check("attribution-coverage", GOOD_RUNJOB,
                     "src/repro/core/x.py")


def test_attribution_exempts_run_job_definition_itself():
    text = "def run_job(self, job, lane):\n    self.run_job(job, lane)\n"
    assert not check("attribution-coverage", text, "src/repro/core/x.py")


def test_attribution_flags_unattributed_value_file_edit():
    fs = check("attribution-coverage", BAD_EDIT, "src/repro/core/x.py")
    assert len(fs) == 1 and "add_value_file" in fs[0].message
    assert "attributing the space transition" in fs[0].message


def test_attribution_accepts_on_space_or_cause_scope():
    assert not check("attribution-coverage", GOOD_EDIT_SPACE,
                     "src/repro/core/x.py")
    assert not check("attribution-coverage", GOOD_EDIT_CAUSE,
                     "src/repro/core/x.py")


def test_attribution_suppression():
    text = BAD_RUNJOB.replace(
        "def pump(store):",
        "def pump(store):  # scavlint: allow-attribution test pump")
    assert not check("attribution-coverage", text, "src/repro/core/x.py")


def test_attribution_scope_excludes_durability_replay():
    assert not in_scope("attribution-coverage",
                        "src/repro/core/durability/manifest.py")
    assert not in_scope("attribution-coverage", "src/repro/obs/observer.py")
    assert in_scope("attribution-coverage", "src/repro/core/gc.py")


# ---------------------------------------------------------- io-accounting
BAD_IO = """
import os


def slurp(path):
    with open(path) as f:          # builtin open
        data = f.read()
    os.read(0, 10)
    return data
"""


def test_io_accounting_flags_raw_io():
    fs = check("io-accounting", BAD_IO, "src/repro/core/read/x.py")
    msgs = " ".join(f.message for f in fs)
    assert len(fs) == 2 and "open()" in msgs and "os.read" in msgs


def test_io_accounting_scope_excludes_device_and_durability():
    assert not in_scope("io-accounting", "src/repro/core/engine/io.py")
    assert not in_scope("io-accounting", "src/repro/core/durability/wal.py")


def test_io_accounting_suppression():
    text = BAD_IO.replace("os.read(0, 10)",
                          "os.read(0, 10)  # scavlint: allow-raw-io probe")
    fs = check("io-accounting", text, "src/repro/core/read/x.py")
    assert len(fs) == 1 and "open()" in fs[0].message


# ----------------------------------------------------------- vectorization
BAD_LOOPS = """
def f(keys, vals, arr):
    for k, v in zip(keys, vals):
        pass
    for i in range(len(keys)):
        pass
    for v in arr.tolist():
        pass
"""

GOOD_LOOPS = """
import numpy as np


def f(fids, tables):
    for fid in np.unique(fids):
        pass
    for t in reversed(tables):
        pass
    for fid in np.unique(fids).tolist():
        pass
"""


def test_vectorization_flags_per_element_loops():
    fs = check("vectorization", BAD_LOOPS, "src/repro/core/read/x.py")
    assert len(fs) == 3


def test_vectorization_exempts_structure_bounded_loops():
    assert not check("vectorization", GOOD_LOOPS,
                     "src/repro/core/values/x.py")


def test_vectorization_suppression_on_line_above():
    text = BAD_LOOPS.replace(
        "    for v in arr.tolist():",
        "    # per-file walk  # scavlint: allow-loop\n"
        "    for v in arr.tolist():")
    fs = check("vectorization", text, "src/repro/core/read/x.py")
    assert len(fs) == 2


def test_vectorization_scope_is_hot_paths_only():
    assert not in_scope("vectorization", "src/repro/core/store.py")
    assert in_scope("vectorization", "src/repro/core/adaptive/tracker.py")


# ------------------------------------------------------- config-discipline
BAD_CONST = """
def f(x):
    return x * 37
"""

GOOD_CONST = """
CAP = 37
MASK = 0xFF


def f(x, k=37):
    y = 1 << 20
    z = x[3]
    return x + 1, y, z, k
"""


def test_config_discipline_flags_bare_literal():
    fs = check("config-discipline", BAD_CONST, "src/repro/core/values/x.py")
    assert len(fs) == 1 and "37" in fs[0].message


def test_config_discipline_exemptions():
    assert not check("config-discipline", GOOD_CONST,
                     "src/repro/core/values/x.py")


def test_config_discipline_suppression():
    text = BAD_CONST.replace(
        "return x * 37",
        "return x * 37  # scavlint: allow-const format width")
    assert not check("config-discipline", text, "src/repro/core/values/x.py")


def test_config_discipline_scope_excludes_config_and_io():
    assert not in_scope("config-discipline", "src/repro/core/engine/config.py")
    assert not in_scope("config-discipline", "src/repro/core/engine/io.py")


# ------------------------------------------------- project passes (tmp repo)
def make_repo(tmp_path: Path, design: str, modules: dict[str, str],
              tests: dict[str, str] | None = None) -> Path:
    (tmp_path / "pyproject.toml").write_text("[project]\nname='x'\n")
    (tmp_path / "DESIGN.md").write_text(design)
    for rel, text in modules.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)
    for rel, text in (tests or {}).items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)
    return tmp_path


DESIGN_2 = "## §1 One\n\ntext\n\n## §2 Two\n\ntext\n"


def test_docs_pass_clean_tree(tmp_path):
    root = make_repo(tmp_path, DESIGN_2, {
        "src/repro/core/mod.py": '"""Thing (DESIGN.md §1)."""\n'})
    res = run_analysis(["src"], root=root, select=["docs-citation"])
    assert not res.failed


def test_docs_pass_flags_missing_and_stale_citations(tmp_path):
    root = make_repo(tmp_path, DESIGN_2, {
        "src/repro/core/nocite.py": '"""No citation here."""\n',
        "src/repro/core/stale.py": '"""Thing (DESIGN.md §7)."""\n'})
    res = run_analysis(["src"], root=root, select=["docs-citation"])
    msgs = " ".join(f.message for f in res.findings)
    assert "does not cite" in msgs and "nonexistent DESIGN.md §7" in msgs


def test_docs_pass_flags_non_contiguous_sections(tmp_path):
    root = make_repo(tmp_path, "## §1 One\n\n## §3 Three\n", {
        "src/repro/core/mod.py": '"""Thing (DESIGN.md §1)."""\n'})
    res = run_analysis(["src"], root=root, select=["docs-citation"])
    assert any("not contiguous" in f.message for f in res.findings)


KERNEL_FILES = {
    "src/repro/kernels/foo/__init__.py": "",
    "src/repro/kernels/foo/kernel.py": "def _k():\n    pass\n",
    "src/repro/kernels/foo/ref.py": "def _r():\n    pass\n",
    "src/repro/kernels/foo/ops.py": "def foo_lookup():\n    pass\n",
}


def test_kernel_parity_clean(tmp_path):
    root = make_repo(tmp_path, DESIGN_2, KERNEL_FILES,
                     {"tests/test_kernels.py": "import foo_lookup\n"})
    res = run_analysis(["src"], root=root, select=["kernel-parity"])
    assert not res.failed


def test_kernel_parity_flags_missing_ref_and_missing_test(tmp_path):
    files = {k: v for k, v in KERNEL_FILES.items() if not k.endswith("ref.py")}
    root = make_repo(tmp_path, DESIGN_2, files,
                     {"tests/test_other.py": "unrelated = 1\n"})
    res = run_analysis(["src"], root=root, select=["kernel-parity"])
    msgs = " ".join(f.message for f in res.findings)
    assert "missing ref.py" in msgs
    assert "not referenced by any test" in msgs


# --------------------------------------------------------------- baseline
def test_baseline_round_trip_and_grandfathering(tmp_path):
    root = make_repo(tmp_path, DESIGN_2, {
        "src/repro/core/bad.py":
            '"""Bad module (DESIGN.md §1)."""\n\n' + BAD_DURABILITY})
    res = run_analysis(["src"], root=root)
    assert res.failed and len(res.findings) == 1

    bl = tmp_path / "baseline.json"
    write_baseline(bl, [f.key for f in res.findings])
    assert load_baseline(bl) == {res.findings[0].key}

    res2 = run_analysis(["src"], root=root, baseline_keys=load_baseline(bl))
    assert not res2.failed and not res2.findings
    assert len(res2.baselined) == 1


def test_baseline_rejects_unknown_format(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"format": 99, "suppress": []}))
    with pytest.raises(ValueError):
        load_baseline(p)


# -------------------------------------------------------------------- CLI
def test_cli_exit_codes(tmp_path, capsys):
    root = make_repo(tmp_path, DESIGN_2, {
        "src/repro/core/good.py": '"""Fine (DESIGN.md §1)."""\n'})
    assert cli_main(["src", "--root", str(root)]) == 0

    bad = tmp_path / "src/repro/core/bad.py"
    bad.write_text('"""Bad (DESIGN.md §1)."""\n\n' + BAD_DURABILITY)
    assert cli_main(["src", "--root", str(root)]) == 1

    assert cli_main(["src", "--root", str(root),
                     "--select", "no-such-pass"]) == 2
    capsys.readouterr()


def test_cli_write_baseline_then_clean(tmp_path, capsys):
    root = make_repo(tmp_path, DESIGN_2, {
        "src/repro/core/bad.py":
            '"""Bad (DESIGN.md §1)."""\n\n' + BAD_DURABILITY})
    assert cli_main(["src", "--root", str(root), "--write-baseline"]) == 0
    assert (root / "scavlint_baseline.json").exists()
    # baseline is picked up automatically -> now clean (1 baselined)
    assert cli_main(["src", "--root", str(root)]) == 0
    out = capsys.readouterr().out
    assert "baselined" in out


def test_cli_json_report(tmp_path, capsys):
    root = make_repo(tmp_path, DESIGN_2, {
        "src/repro/core/bad.py":
            '"""Bad (DESIGN.md §1)."""\n\n' + BAD_DURABILITY})
    assert cli_main(["src", "--root", str(root), "--json"]) == 1
    report = json.loads(capsys.readouterr().out)
    assert report["failed"] is True
    assert report["findings"][0]["pass_name"] == "durability-coverage"
    assert "key" in report["findings"][0]


def test_cli_reports_syntax_errors(tmp_path, capsys):
    root = make_repo(tmp_path, DESIGN_2, {
        "src/repro/core/broken.py": "def oops(:\n"})
    assert cli_main(["src", "--root", str(root)]) == 1
    assert "syntax error" in capsys.readouterr().out


# ------------------------------------------------------------------ smoke
def test_src_tree_is_clean_without_baseline():
    """The merged tree carries zero unbaselined *and* zero baselined
    findings — the analyzer gate is real, not grandfathered away."""
    res = run_analysis(["src"], root=REPO)
    msgs = [f.render() for f in res.parse_errors + res.findings]
    assert not res.failed, "\n".join(msgs)
    assert not res.findings and not res.baselined


def test_benchmarks_and_examples_are_clean():
    res = run_analysis(["benchmarks", "examples"], root=REPO)
    msgs = [f.render() for f in res.parse_errors + res.findings]
    assert not res.failed, "\n".join(msgs)
