"""Optional-dependency shim for hypothesis (declared in requirements.txt).

``hypothesis`` drives the property tests but is not baked into every
container this repo runs in.  Importing through this module keeps test
*collection* working without it: plain tests still run, and each
``@given``-decorated test turns into an explicit skip instead of a
module-level ImportError.
"""

import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _Anything:
        """Stands in for strategies/HealthCheck so module-level strategy
        definitions still evaluate; the tests using them are skipped."""

        def __getattr__(self, name):
            return _Anything()

        def __call__(self, *args, **kwargs):
            return _Anything()

    st = _Anything()
    HealthCheck = _Anything()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        return lambda f: f

__all__ = ["HAVE_HYPOTHESIS", "HealthCheck", "given", "settings", "st"]
