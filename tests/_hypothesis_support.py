"""Optional-dependency shim for hypothesis (declared in requirements.txt).

``hypothesis`` drives the property tests but is not baked into every
container this repo runs in.  Importing through this module keeps test
*collection* working without it: plain tests still run, and each
``@given``-decorated test skips at *runtime* with an explicit reason.

The fallback ``given`` deliberately returns a fresh skipper function
(not a ``pytest.mark.skip`` on the original): a mark can silently fall
through to a trivial pass when the decorated function is re-wrapped or
invoked outside pytest's collection (e.g. a ``@given`` helper called
from inside another test), whereas ``pytest.skip(...)`` in the body
always registers a real skip with its reason.  The skipper keeps the
original's name for test-id stability but intentionally drops its
signature (``functools.wraps`` would make pytest demand fixtures named
after the strategy parameters).
"""

import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _Anything:
        """Stands in for strategies/HealthCheck so module-level strategy
        definitions still evaluate; the tests using them are skipped."""

        def __getattr__(self, name):
            return _Anything()

        def __call__(self, *args, **kwargs):
            return _Anything()

    st = _Anything()
    HealthCheck = _Anything()

    def given(*args, **kwargs):
        import inspect
        bound = set(kwargs)

        def deco(fn):
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed")
            skipper.__name__ = getattr(fn, "__name__", "hypothesis_test")
            skipper.__doc__ = getattr(fn, "__doc__", None)
            try:
                # expose the original signature minus the strategy-bound
                # params (keyword strategies bind by name, positional ones
                # bind rightmost — hypothesis semantics) so pytest still
                # maps parametrize arguments onto the skipper
                sig = inspect.signature(fn)
                params = [p for name, p in sig.parameters.items()
                          if name not in bound]
                if args:
                    params = params[:-len(args)]
                skipper.__signature__ = sig.replace(parameters=params)
            except (ValueError, TypeError):    # pragma: no cover
                pass
            return skipper
        return deco

    def settings(*args, **kwargs):
        # robust to both ``@settings`` (bare) and ``@settings(...)``
        if args and callable(args[0]) and not kwargs and len(args) == 1:
            return args[0]
        return lambda f: f

__all__ = ["HAVE_HYPOTHESIS", "HealthCheck", "given", "settings", "st"]
