"""Roofline analytics + shape-catalog sanity (no heavy lowering)."""

import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).parent.parent))   # for benchmarks/

from benchmarks.roofline import (BASELINE, OPTIMIZED, analytic_terms)
from repro.configs import ARCHS, get_config
from repro.launch.shapes import SHAPES, cache_len_for, runnable


def test_runnable_matrix_counts():
    runnable_cells = 0
    for a in ARCHS:
        cfg = get_config(a)
        for s in SHAPES:
            ok, why = runnable(cfg, s)
            if ok:
                runnable_cells += 1
            else:
                assert s == "long_500k" and why
    # 40 assigned cells minus 7 full-attention long_500k skips
    assert runnable_cells == 33


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_analytic_terms_positive_and_policy_monotone(arch, shape):
    cfg = get_config(arch)
    ok, _ = runnable(cfg, shape)
    if not ok:
        pytest.skip("cell skipped by design")
    for chips in (256, 512):
        b = analytic_terms(cfg, shape, chips, BASELINE)
        o = analytic_terms(cfg, shape, chips, OPTIMIZED)
        for k in ("t_compute_s", "t_memory_s", "t_collective_s"):
            assert b[k] >= 0 and o[k] >= 0
        # optimizations never worsen the dominant bound (TP-only serving
        # deliberately trades extra local weight reads for collectives)
        bound = lambda t: max(t["t_compute_s"], t["t_memory_s"],
                              t["t_collective_s"])
        assert bound(o) <= bound(b) * 1.001
        # compute term is impl-independent
        assert o["t_compute_s"] == pytest.approx(b["t_compute_s"])


def test_cache_len_rolls_for_windowed_long_context():
    llava = get_config("llava_next_mistral_7b")
    assert cache_len_for(llava, "long_500k") == llava.window
    assert cache_len_for(llava, "decode_32k") == 32768
    jamba = get_config("jamba_15_large")
    assert cache_len_for(jamba, "long_500k") == 524288


def test_multipod_scales_collectives_up_and_compute_down():
    cfg = get_config("arctic_480b")
    single = analytic_terms(cfg, "train_4k", 256, BASELINE)
    multi = analytic_terms(cfg, "train_4k", 512, BASELINE)
    assert multi["flops_per_chip"] < single["flops_per_chip"]
    # more FSDP ways -> same or more collective per chip
    assert multi["t_collective_s"] >= single["t_collective_s"] * 0.9


def test_decode_collective_dominated_by_fsdp_gather_baseline():
    cfg = get_config("phi35_moe")
    b = analytic_terms(cfg, "decode_32k", 256, BASELINE)
    o = analytic_terms(cfg, "decode_32k", 256, OPTIMIZED)
    assert b["t_collective_s"] > 100 * o["t_collective_s"]
