"""Batched columnar API: batch/scalar parity across all five engines.

The WriteBatch path must be *semantically* identical to the scalar loop:
same vids, same oracle, byte-identical ``user_write_bytes`` always; and in
the drain-converged regime (background work runs between writes, where
group-commit clock skew cannot reorder the scheduler) byte-identical
``space_amp`` and ``stall_us`` too — with GC active on the engines that
have one.
"""

import numpy as np
import pytest

from repro.core import ENGINES, EngineConfig, Store, WriteBatch

PARITY_CFG = dict(
    memtable_bytes=512 << 10, ksst_bytes=32 << 10, vsst_bytes=64 << 10,
    base_level_bytes=64 << 10, cache_bytes=32 << 10, dropcache_keys=64,
    sep_threshold=256, max_levels=5, gc_garbage_ratio=0.1)

TINY_CFG = dict(
    memtable_bytes=4 << 10, ksst_bytes=4 << 10, vsst_bytes=16 << 10,
    base_level_bytes=8 << 10, cache_bytes=8 << 10, dropcache_keys=64,
    sep_threshold=256, max_levels=5)


def _op_stream(rounds=6, n=300, nkeys=120, seed=3):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, nkeys, n).astype(np.uint64),
             rng.choice([64, 600, 2000, 9000], n).astype(np.int64))
            for _ in range(rounds)]


@pytest.mark.parametrize("engine", ENGINES)
def test_batch_scalar_parity_byte_identical(engine):
    """Scalar loop vs WriteBatch+multi_get: identical oracle and
    byte-identical user_write_bytes / space_amp / stall_us."""
    stream = _op_stream()
    s1 = Store(EngineConfig(engine=engine, **PARITY_CFG))
    o1 = {}
    for ks, vs in stream:
        for k, v in zip(ks.tolist(), vs.tolist()):
            o1[k] = s1.put(int(k), int(v))
        s1.flush()

    s2 = Store(EngineConfig(engine=engine, **PARITY_CFG))
    o2 = {}
    for ks, vs in stream:
        for i in range(0, len(ks), 64):
            vids = s2.write(WriteBatch().puts(ks[i:i + 64], vs[i:i + 64]))
            o2.update(zip(ks[i:i + 64].tolist(), vids.tolist()))
        s2.flush()

    assert o1 == o2, "vid assignment diverged"
    st1, st2 = s1.stats(), s2.stats()
    assert st1["user_write_bytes"] == st2["user_write_bytes"]
    assert st1["space_amp"] == st2["space_amp"]
    assert st1["stall_s"] == st2["stall_s"]
    if s1.cfg.gc_scheme in ("inherit", "writeback"):
        assert s1.n_gc_runs == s2.n_gc_runs > 0, "parity regime must GC"

    # reads agree between the two stores and with the oracle
    all_keys = np.arange(120, dtype=np.uint64)
    r1, r2 = s1.multi_get(all_keys), s2.multi_get(all_keys)
    np.testing.assert_array_equal(r1["found"], r2["found"])
    np.testing.assert_array_equal(r1["vid"], r2["vid"])
    for k in range(120):
        expect = o1.get(k, 0)
        assert int(r1["vid"][k]) == expect


@pytest.mark.parametrize("engine", ENGINES)
def test_multi_get_matches_oracle_under_churn(engine):
    """Batched reads stay correct while rotations/compactions/GC interleave
    (tiny config, GC active on the engines that have one)."""
    rng = np.random.default_rng(11)
    s = Store(EngineConfig(engine=engine, **TINY_CFG))
    oracle = {}
    for round_ in range(12):
        ks = rng.integers(0, 50, 40).astype(np.uint64)
        vs = rng.choice([64, 600, 4000], 40).astype(np.int64)
        vids = s.write(WriteBatch().puts(ks, vs))
        oracle.update(zip(ks.tolist(), vids.tolist()))
        dels = rng.integers(0, 50, 4).astype(np.uint64)
        s.write(WriteBatch().deletes(dels))
        for k in dels.tolist():
            oracle.pop(k, None)
        res = s.multi_get(np.arange(50, dtype=np.uint64))
        for k in range(50):
            got = int(res["vid"][k]) if res["found"][k] else None
            assert got == oracle.get(k), (round_, k)
    s.flush()
    for k in range(50):
        assert s.get(k) == oracle.get(k)


def test_writebatch_dup_keys_last_write_wins():
    s = Store(EngineConfig(engine="scavenger", **TINY_CFG))
    wb = WriteBatch()
    wb.put(7, 100).put(7, 2000).delete(9).put(9, 300)
    vids = s.write(wb)
    assert len(vids) == 4 and vids[2] == 0    # deletes get no vid
    assert s.get(7) == int(vids[1])
    assert s.get(9) == int(vids[3])
    wb2 = WriteBatch().put(7, 50).delete(7)
    s.write(wb2)
    assert s.get(7) is None


def test_writebatch_atomic_seq_range_one_wal_append():
    from repro.core.engine import io as sio
    s = Store(EngineConfig(engine="scavenger", **PARITY_CFG))
    seq0 = s.seq
    wal_ops0 = s.io.write_ops[sio.CAT_WAL]
    ks = np.arange(100, dtype=np.uint64)
    s.write(WriteBatch().puts(ks, np.full(100, 600, np.int64)))
    assert s.seq == seq0 + 100, "one contiguous sequence-number range"
    assert s.io.write_ops[sio.CAT_WAL] == wal_ops0 + 1, \
        "whole batch group-committed as one WAL append"


def test_multi_scan_matches_scalar_scan():
    rng = np.random.default_rng(5)
    s = Store(EngineConfig(engine="scavenger", **TINY_CFG))
    oracle = {}
    for _ in range(6):
        ks = rng.integers(0, 200, 60).astype(np.uint64)
        vs = rng.choice([64, 600, 4000], 60).astype(np.int64)
        vids = s.write(WriteBatch().puts(ks, vs))
        oracle.update(zip(ks.tolist(), vids.tolist()))
    starts = np.array([0, 17, 60, 150, 199], np.int64)
    outs = s.multi_scan(starts, 12)
    for st_, out in zip(starts.tolist(), outs):
        assert out == s.scan(st_, 12)
        exp = sorted(k for k in oracle if k >= st_)[:12]
        assert out == [(k, oracle[k]) for k in exp]


def test_multi_get_simulated_speedup_3x():
    """Acceptance: multi_get >= 3x lower simulated us/op than the scalar
    get loop at batch size 256 (quick scale)."""
    from repro.workloads import Runner, pareto_1k

    def loaded():
        spec = pareto_1k(dataset_bytes=4 << 20)
        store = Store(EngineConfig.scaled("scavenger", spec.dataset_bytes))
        r = Runner(store, spec)
        r.load()
        r.update(spec.n_keys)
        store.drain()
        return store, r

    s1, r1 = loaded()
    keys = r1.keys.sample(np.random.default_rng(123), 256)
    t0 = s1.io.fg_clock_us
    for k in keys.tolist():
        s1.get(int(k))
    us_scalar = (s1.io.fg_clock_us - t0) / 256

    s2, _ = loaded()
    t0 = s2.io.fg_clock_us
    s2.multi_get(keys.astype(np.uint64))
    us_batch = (s2.io.fg_clock_us - t0) / 256
    assert us_batch * 3 <= us_scalar, (us_scalar, us_batch)


def test_scaled_dropcache_clamped_to_keyspace():
    tiny = EngineConfig.scaled("scavenger", 64 << 10)
    assert tiny.dropcache_keys < (64 << 10) // 1024, \
        "DropCache must not cover the whole keyspace"
    small = EngineConfig.scaled("scavenger", 64 << 10, est_keys=40)
    assert small.dropcache_keys < 40
    big = EngineConfig.scaled("scavenger", 1 << 30)
    assert big.dropcache_keys >= 512


def test_runner_batch_one_degenerates_to_scalar():
    """batch=1 Runner must equal the batched Runner's oracle results."""
    from repro.workloads import Runner, fixed
    spec = fixed(600, dataset_bytes=64 << 10, update_factor=1.0)
    s1 = Store(EngineConfig.scaled("scavenger", spec.dataset_bytes))
    r1 = Runner(s1, spec, batch=1)
    r1.load()
    r1.update()
    s2 = Store(EngineConfig.scaled("scavenger", spec.dataset_bytes))
    r2 = Runner(s2, spec, batch=64)
    r2.load()
    r2.update()
    assert r1.oracle == r2.oracle
    assert r1.check_reads(np.arange(spec.n_keys)) == 0
    assert r2.check_reads(np.arange(spec.n_keys)) == 0
