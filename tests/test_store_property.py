"""Property-based tests: every engine must behave like a dict under any
interleaving of puts/deletes/gets/scans, with GC never losing data."""

import numpy as np
import pytest

from _hypothesis_support import HealthCheck, given, settings, st

from repro.core import ENGINES, EngineConfig, Store


def tiny_cfg(engine, **kw):
    base = dict(
        memtable_bytes=4 << 10, ksst_bytes=4 << 10, vsst_bytes=16 << 10,
        base_level_bytes=8 << 10, cache_bytes=8 << 10, dropcache_keys=64,
        sep_threshold=256, max_levels=5)
    base.update(kw)
    return EngineConfig(engine=engine, **base)


ops_strategy = st.lists(
    st.tuples(
        st.sampled_from(["put", "put", "put", "del", "get", "scan"]),
        st.integers(min_value=0, max_value=40),     # key
        st.sampled_from([64, 200, 600, 2000, 9000]),  # value size
    ),
    min_size=20, max_size=250)


@pytest.mark.parametrize("engine", ENGINES)
@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=ops_strategy)
def test_store_matches_dict_oracle(engine, ops):
    s = Store(tiny_cfg(engine))
    oracle = {}
    for op, key, vsize in ops:
        if op == "put":
            oracle[key] = s.put(key, vsize)
        elif op == "del":
            oracle.pop(key, None)
            s.delete(key)
        elif op == "get":
            assert s.get(key) == oracle.get(key)
        else:
            got = dict(s.scan(key, 10))
            expect_keys = sorted(k for k in oracle if k >= key)[:10]
            assert got == {k: oracle[k] for k in expect_keys}
    # final full verification after draining all background work
    s.flush()
    for k in range(41):
        assert s.get(k) == oracle.get(k), f"key {k} mismatch after drain"
    # scan everything
    assert dict(s.scan(0, 1000)) == oracle


@pytest.mark.parametrize("engine", ENGINES)
def test_heavy_update_churn_preserves_data(engine):
    rng = np.random.default_rng(42)
    s = Store(tiny_cfg(engine))
    oracle = {}
    for i in range(300):
        k = int(rng.zipf(1.3)) % 50
        oracle[k] = s.put(k, int(rng.choice([100, 700, 4000])))
        if i % 7 == 0:
            kk = int(rng.integers(0, 50))
            assert s.get(kk) == oracle.get(kk)
    s.flush()
    for k, v in oracle.items():
        assert s.get(k) == v


@pytest.mark.parametrize("engine", ["terarkdb", "scavenger"])
def test_gc_inheritance_chains_resolve(engine):
    """Force many GC generations; reads must follow inheritance chains."""
    s = Store(tiny_cfg(engine, gc_garbage_ratio=0.05))
    oracle = {}
    rng = np.random.default_rng(0)
    for round_ in range(6):
        for k in range(30):
            if rng.random() < 0.7:
                oracle[k] = s.put(k, 1500)
        s.flush()       # drain -> compactions expose garbage -> GC runs
    assert s.n_gc_runs > 0, "GC should have run"
    for k, v in oracle.items():
        assert s.get(k) == v


def test_space_quota_is_respected():
    ds = 64 << 10
    cfg = tiny_cfg("scavenger", space_quota_bytes=int(3.0 * ds))
    s = Store(cfg)
    oracle = {}
    rng = np.random.default_rng(1)
    for i in range(400):
        k = int(rng.integers(0, 32))
        oracle[k] = s.put(k, 2000)
        assert s.space_bytes() <= cfg.space_quota_bytes * 1.25, \
            "space should stay near the quota under throttling"
    s.flush()
    for k, v in oracle.items():
        assert s.get(k) == v


@pytest.mark.parametrize("engine", ENGINES[1:])   # kv-separated engines
def test_separation_threshold(engine):
    s = Store(tiny_cfg(engine))
    s.put(1, 100)      # below 256 threshold -> inline
    s.put(2, 5000)     # above -> separated
    s.flush()
    assert len(s.version.value_files) >= 1
    assert s.get(1) is not None and s.get(2) is not None


def test_stats_sanity():
    s = Store(tiny_cfg("scavenger"))
    for k in range(100):
        s.put(k, 1000)
    for k in range(100):
        s.put(k, 1000)
    s.flush()
    st = s.stats()
    assert st["space_amp"] >= 1.0
    assert st["s_index"] >= 1.0
    assert st["write_amp"] > 0
    assert s.valid_bytes == 100 * 1000
    assert st["clock_s"] > 0
