"""Dry-run path (subprocess: 512 fake devices), trainer integration,
crash/restart fault tolerance."""

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).parent.parent
ENV = dict(os.environ, PYTHONPATH=str(REPO / "src"))


def _run(args, timeout=420):
    return subprocess.run([sys.executable, *args], cwd=REPO, env=ENV,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.parametrize("arch,shape", [
    ("smollm-360m", "train_4k"),
    ("jamba-1.5-large-398b", "decode_32k"),
])
def test_dryrun_smoke_multipod(arch, shape, tmp_path):
    """Smoke configs on the REAL 512-device multi-pod mesh: proves the
    sharding config lowers+compiles per (arch, shape, mesh)."""
    r = _run(["-m", "repro.launch.dryrun", "--arch", arch, "--shape",
              shape, "--mesh", "multi", "--smoke", "--out",
              str(tmp_path)])
    assert r.returncode == 0, r.stdout + r.stderr
    arts = list(tmp_path.glob("*.json"))
    assert len(arts) == 1
    info = json.loads(arts[0].read_text())
    assert info["n_chips"] == 512
    assert info["flops_per_device"] > 0
    assert info["collectives"]["count"] > 0


def test_trainer_loss_decreases(tmp_path):
    r = _run(["-m", "repro.launch.train", "--arch", "xlstm-125m",
              "--smoke", "--steps", "30", "--batch", "4", "--seq", "48",
              "--lr", "3e-3", "--log-every", "29"])
    assert r.returncode == 0, r.stdout + r.stderr
    lines = [l for l in r.stdout.splitlines() if "loss" in l]
    first = float(lines[0].split("loss")[1].split()[0])
    last = float(lines[-1].split("loss")[1].split()[0])
    assert last < first, f"loss did not decrease: {first} -> {last}"


def test_crash_restart_resumes_bitexact(tmp_path):
    """Kill training mid-run; resumed run must continue from the last
    durable checkpoint and end with the same loss as an uninterrupted run
    (deterministic pipeline + deterministic init)."""
    common = ["-m", "repro.launch.train", "--arch", "xlstm-125m",
              "--smoke", "--steps", "16", "--batch", "2", "--seq", "32",
              "--ckpt-every", "5", "--log-every", "1"]
    # uninterrupted reference
    r_ref = _run(common + ["--ckpt-dir", str(tmp_path / "ref")])
    assert r_ref.returncode == 0, r_ref.stderr
    ref_losses = {l.split()[2]: l.split()[4] for l in
                  r_ref.stdout.splitlines() if l.startswith("[train] step")}
    # crashed run + resume
    r1 = _run(common + ["--ckpt-dir", str(tmp_path / "cr"),
                        "--fail-at-step", "12"])
    assert r1.returncode == 42          # injected crash
    r2 = _run(common + ["--ckpt-dir", str(tmp_path / "cr")])
    assert r2.returncode == 0, r2.stderr
    assert "resuming from checkpoint step 10" in r2.stdout
    res_losses = {l.split()[2]: l.split()[4] for l in
                  r2.stdout.splitlines() if l.startswith("[train] step")}
    for step, loss in res_losses.items():
        assert abs(float(loss) - float(ref_losses[step])) < 5e-4, \
            f"step {step}: resumed {loss} != reference {ref_losses[step]}"


def test_mesh_and_param_shardings_resolve():
    """In-process sanity of the sharding resolution (1-device mesh)."""
    import jax
    from repro.configs import get_config
    from repro.launch import mesh as meshlib
    from repro.models.model import build_model
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("smollm_360m", "jamba_15_large", "whisper_base"):
        model = build_model(get_config(arch, smoke=True))
        sh = meshlib.param_shardings(model, mesh)
        n_params = len(jax.tree.leaves(model.abstract_params()))
        assert len(jax.tree.leaves(sh)) == n_params
