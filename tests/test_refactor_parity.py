"""Golden I/O-accounting parity for the layered-core refactor.

The five paper engines must be *byte-identical* to their pre-refactor
behaviour: the goldens below were captured by running this exact workload
against the pre-refactor monolithic ``Store`` (PR 2 tree), and the layered
core must reproduce every byte/op counter and derived ratio.  ``hybrid``
(added by the refactor) is locked in as a regression golden from its first
implementation.

Regenerate (only when the change is *meant* to alter accounting)::

    PYTHONPATH=src:tests python -m test_refactor_parity
"""

from __future__ import annotations

import math

import numpy as np
import pytest

from repro.core import EngineConfig, Store

# Stats fields that must match exactly (ints) and to float precision.
INT_FIELDS = ("space_bytes", "valid_bytes", "user_write_bytes",
              "read_bytes", "write_bytes", "n_compactions", "n_gc_runs")
FLOAT_FIELDS = ("space_amp", "s_index", "exposed_over_valid", "write_amp",
                "cache_hit_ratio", "stall_s", "gc_time_s", "clock_s")

N_KEYS = 4096
VSIZES = np.array([64, 200, 600, 2000, 9000], np.int64)


def run_fixed_workload(engine: str, **overrides) -> dict:
    """Deterministic mixed workload: seeded writes, deletes, point reads and
    scans, then a full drain.  Every engine sees the identical op stream.
    ``overrides`` pass through to ``EngineConfig.scaled`` (used by
    ``tests/test_adaptive.py`` to lock the tracker-off parity)."""
    from repro.core import WriteBatch

    cfg = EngineConfig.scaled(engine, 8 << 20, est_keys=N_KEYS, **overrides)
    store = Store(cfg)
    rng = np.random.default_rng(1234)
    for _ in range(6):
        keys = rng.integers(0, N_KEYS, 256).astype(np.uint64)
        sizes = VSIZES[rng.integers(0, len(VSIZES), 256)]
        store.write(WriteBatch().puts(keys, sizes))
        dels = rng.integers(0, N_KEYS, 16).astype(np.uint64)
        store.write(WriteBatch().deletes(dels))
        gets = rng.integers(0, N_KEYS, 128).astype(np.uint64)
        res = store.multi_get(gets)
        # semantic check against the oracle while we are at it
        for k, found, vid in zip(gets.tolist(), res["found"].tolist(),
                                 res["vid"].tolist()):
            cur = store.latest.get(int(k))
            assert (cur is not None) == bool(found)
            if cur is not None:
                assert cur[0] == vid
        starts = rng.integers(0, N_KEYS, 8).astype(np.int64)
        store.multi_scan(starts, 10)
    store.drain()
    st = store.stats()
    out = {f: int(st[f]) for f in INT_FIELDS}
    out.update({f: float(st[f]) for f in FLOAT_FIELDS})
    return out


# Captured from the pre-refactor monolithic Store (see module docstring).
GOLDENS: dict[str, dict] = {
    "rocksdb": {
        "cache_hit_ratio": 0.01598173515981735,
        "clock_s": 0.05287324759999993,
        "exposed_over_valid": 0.0,
        "gc_time_s": 0.0,
        "n_compactions": 66,
        "n_gc_runs": 0,
        "read_bytes": 41699616,
        "s_index": 1.0856594995599145,
        "space_amp": 1.0603116125566046,
        "space_bytes": 3315552,
        "stall_s": 0.042306713066666515,
        "user_write_bytes": 3832768,
        "valid_bytes": 3126960,
        "write_amp": 11.105315009935378,
        "write_bytes": 46396864,
    },
    "blobdb": {
        "cache_hit_ratio": 0.23008849557522124,
        "clock_s": 0.04748628879999961,
        "exposed_over_valid": 0.03258897854506787,
        "gc_time_s": 0.02046105200000003,
        "n_compactions": 32,
        "n_gc_runs": 0,
        "read_bytes": 9656520,
        "s_index": 1.1011250740180274,
        "space_amp": 1.1155371351088597,
        "space_bytes": 3488240,
        "stall_s": 0.03812402106666666,
        "user_write_bytes": 3832768,
        "valid_bytes": 3126960,
        "write_amp": 2.7838846494230802,
        "write_bytes": 14502752,
    },
    "titan": {
        "cache_hit_ratio": 0.30306122448979594,
        "clock_s": 0.021129054133333353,
        "exposed_over_valid": 0.03369757492880352,
        "gc_time_s": 0.021129054133333384,
        "n_compactions": 38,
        "n_gc_runs": 5,
        "read_bytes": 5998040,
        "s_index": 1.1011250740180274,
        "space_amp": 1.1142681710031468,
        "space_bytes": 3484272,
        "stall_s": 0.0,
        "user_write_bytes": 3832768,
        "valid_bytes": 3126960,
        "write_amp": 1.5763187336149749,
        "write_bytes": 9874432,
    },
    "terarkdb": {
        "cache_hit_ratio": 0.22786759045419552,
        "clock_s": 0.018840202933333362,
        "exposed_over_valid": 0.033905110862936516,
        "gc_time_s": 0.01884020293333338,
        "n_compactions": 38,
        "n_gc_runs": 5,
        "read_bytes": 5984776,
        "s_index": 1.1011250740180274,
        "space_amp": 1.1142246782817817,
        "space_bytes": 3484136,
        "stall_s": 0.0,
        "user_write_bytes": 3832768,
        "valid_bytes": 3126960,
        "write_amp": 1.5691030607644396,
        "write_bytes": 9846776,
    },
    "scavenger": {
        "cache_hit_ratio": 0.314638783269962,
        "clock_s": 0.010316862933333336,
        "exposed_over_valid": 0.03524015179586778,
        "gc_time_s": 0.01031686293333333,
        "n_compactions": 63,
        "n_gc_runs": 6,
        "read_bytes": 4667568,
        "s_index": 1.0272625420141914,
        "space_amp": 1.096822472944969,
        "space_bytes": 3429720,
        "stall_s": 4.440746666666678e-05,
        "user_write_bytes": 3832768,
        "valid_bytes": 3126960,
        "write_amp": 1.6953345467296743,
        "write_bytes": 10330592,
    },
    # hybrid post-dates the refactor: its golden is a regression lock from
    # the first implementation, not a pre-refactor capture.
    "hybrid": {
        "cache_hit_ratio": 0.3016917293233083,
        "clock_s": 0.010728240266666668,
        "exposed_over_valid": 0.03544394895454487,
        "gc_time_s": 0.010728240266666664,
        "n_compactions": 63,
        "n_gc_runs": 6,
        "read_bytes": 4880928,
        "s_index": 1.0239894840617811,
        "space_amp": 1.0961828741013637,
        "space_bytes": 3427720,
        "stall_s": 4.440746666666678e-05,
        "user_write_bytes": 3832768,
        "valid_bytes": 3126960,
        "write_amp": 1.7242619433265984,
        "write_bytes": 10441464,
    },
}


@pytest.mark.parametrize("engine", sorted(GOLDENS))
def test_refactor_parity(engine):
    got = run_fixed_workload(engine)
    want = GOLDENS[engine]
    for f in INT_FIELDS:
        assert got[f] == want[f], f"{engine}.{f}: {got[f]} != {want[f]}"
    for f in FLOAT_FIELDS:
        assert math.isclose(got[f], want[f], rel_tol=1e-9, abs_tol=1e-12), \
            f"{engine}.{f}: {got[f]} != {want[f]}"


if __name__ == "__main__":
    import json
    engines = ("rocksdb", "blobdb", "titan", "terarkdb", "scavenger")
    try:
        from repro.core import ENGINES as _all
        engines = tuple(_all)
    except Exception:
        pass
    all_out = {e: run_fixed_workload(e) for e in engines}
    print(json.dumps(all_out, indent=2, sort_keys=True))
