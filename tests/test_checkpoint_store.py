"""Checkpoint store: durability, GC, quota, pytree round-trips."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointStore, drop_steps, load_pytree,
                              save_pytree, steps_available)


def test_put_get_roundtrip(tmp_path):
    st = CheckpointStore(tmp_path, log_target=4 << 10)
    st.put("a", b"hello")
    st.put("b", b"x" * 5000)
    assert st.get("a") == b"hello"
    assert st.get("b") == b"x" * 5000
    st.close()


def test_overwrite_exposes_garbage_and_gc_reclaims(tmp_path):
    st = CheckpointStore(tmp_path, log_target=2 << 10, gc_threshold=0.2)
    for i in range(20):
        st.put("k", bytes([i]) * 1000)      # same key overwritten
    before = st.total_bytes()
    st.run_gc()
    assert st.total_bytes() < before
    assert st.get("k") == bytes([19]) * 1000
    assert st.gc_runs > 0
    st.close()


def test_lazy_read_gc_reads_only_live(tmp_path):
    st = CheckpointStore(tmp_path, log_target=1 << 10)
    for i in range(10):
        st.put(f"dead{i}", b"d" * 500)
    for i in range(10):
        st.delete(f"dead{i}")
    st.put("live", b"L" * 500)
    read0 = st.gc_read_bytes
    st.run_gc(threshold=0.01)
    gc_read = st.gc_read_bytes - read0
    # far less than the ~5KB of dead data (footers + the one live record)
    assert gc_read < 3000
    assert st.get("live") == b"L" * 500
    st.close()


def test_recovery_after_unclean_shutdown(tmp_path):
    st = CheckpointStore(tmp_path, log_target=1 << 20)
    st.put("x", b"abc" * 100)
    st.put("y", b"def" * 100)
    st.flush()
    # simulate crash: no close/seal
    del st
    st2 = CheckpointStore(tmp_path)
    assert st2.get("x") == b"abc" * 100
    assert st2.get("y") == b"def" * 100
    st2.close()


def test_recovery_truncates_torn_record(tmp_path):
    st = CheckpointStore(tmp_path, log_target=1 << 20)
    st.put("good", b"G" * 100)
    st.flush()
    log = st.open_logs[True]
    # simulate a torn write: garbage appended without manifest entry
    log._fh.write(b"\x01\x02\x03half-a-record")
    log._fh.flush()
    del st
    st2 = CheckpointStore(tmp_path)
    assert st2.get("good") == b"G" * 100
    st2.close()


def test_quota_throttling(tmp_path):
    st = CheckpointStore(tmp_path, quota_bytes=64 << 10,
                         log_target=4 << 10)
    for i in range(50):
        st.put("k", os.urandom(4000))
    assert st.total_bytes() <= (64 << 10) * 1.3
    assert st.throttle_events > 0
    st.close()


def test_hot_cold_separation(tmp_path):
    st = CheckpointStore(tmp_path, log_target=1 << 10)
    st.put("hotk", b"h" * 500, hot=True)
    st.put("coldk", b"c" * 500, hot=False)
    hot_logs = {l.hot for l in st.logs.values()}
    assert hot_logs == {True, False}
    st.close()


def test_pytree_roundtrip_and_retention(tmp_path):
    st = CheckpointStore(tmp_path, log_target=64 << 10)
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "nested": {"b": np.ones(5, np.int32)}}
    for step in (1, 2, 3):
        save_pytree(st, "m", step, tree)
    assert steps_available(st, "m") == [1, 2, 3]
    got = load_pytree(st, "m", 3, jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree))
    np.testing.assert_array_equal(got["w"], tree["w"])
    np.testing.assert_array_equal(got["nested"]["b"], tree["nested"]["b"])
    drop_steps(st, "m", keep_last=1)
    assert steps_available(st, "m") == [3]
    st.close()


def test_naive_engine_keeps_space_longer(tmp_path):
    def churn(engine):
        root = tmp_path / engine
        st = CheckpointStore(root, engine=engine, log_target=2 << 10)
        for step in range(8):
            st.put("k1", os.urandom(1500))
            st.put("k2", os.urandom(1500))
            st.run_gc()
        amp = st.space_amp()
        st.close()
        return amp
    assert churn("scavenger") <= churn("naive") + 1e-9
