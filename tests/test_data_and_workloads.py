"""Data pipeline determinism/elasticity + workload generator stats."""

import numpy as np
import pytest

from repro.data import PipelineConfig, TokenPipeline
from repro.workloads import Mixed, Pareto, ZipfKeys, mixed_8k, pareto_1k


def test_pipeline_deterministic_and_skippable():
    cfg = PipelineConfig(vocab=1000, seq_len=32, global_batch=8, seed=7)
    p1 = TokenPipeline(cfg)
    batches = [next(p1)["tokens"] for _ in range(5)]
    # O(1) random access reproduces the stream
    p2 = TokenPipeline(cfg)
    np.testing.assert_array_equal(p2.batch_at(3)["tokens"], batches[3])
    # resume from checkpointed state
    p3 = TokenPipeline(cfg)
    p3.restore({"step": 4})
    np.testing.assert_array_equal(next(p3)["tokens"], batches[4])


def test_pipeline_host_sharding_disjoint():
    full = TokenPipeline(PipelineConfig(1000, 16, 8, seed=1))
    h0 = TokenPipeline(PipelineConfig(1000, 16, 8, seed=1, host_id=0,
                                      n_hosts=2))
    h1 = TokenPipeline(PipelineConfig(1000, 16, 8, seed=1, host_id=1,
                                      n_hosts=2))
    b0, b1 = next(h0)["tokens"], next(h1)["tokens"]
    assert b0.shape == (4, 16) and b1.shape == (4, 16)
    assert not np.array_equal(b0, b1)


def test_pipeline_tokens_in_vocab():
    p = TokenPipeline(PipelineConfig(vocab=97, seq_len=64, global_batch=4))
    for _ in range(3):
        t = next(p)["tokens"]
        assert t.min() >= 0 and t.max() < 97


def test_mixed_distribution_mean():
    rng = np.random.default_rng(0)
    d = Mixed()
    s = d.sample(rng, 20000)
    assert abs(s.mean() - d.mean) / d.mean < 0.05
    assert set(np.unique(s[s > 1000])) == {16384}


def test_pareto_distribution_mean():
    rng = np.random.default_rng(0)
    d = Pareto(mean_size=1024)
    s = d.sample(rng, 50000)
    assert 800 < s.mean() < 1300
    assert s.min() >= 64


def test_zipf_keys_skewed_and_in_range():
    z = ZipfKeys(10000, theta=0.99, seed=0)
    rng = np.random.default_rng(0)
    ks = z.sample(rng, 20000)
    assert ks.min() >= 0 and ks.max() < 10000
    # top-1% of keys should receive a large share of accesses
    _, counts = np.unique(ks, return_counts=True)
    counts.sort()
    top_share = counts[-100:].sum() / counts.sum()
    assert top_share > 0.15


def test_workload_specs():
    spec = mixed_8k(dataset_bytes=16 << 20)
    assert spec.n_keys > 0 and spec.n_updates == 3 * spec.n_keys
    spec2 = pareto_1k(dataset_bytes=8 << 20)
    assert spec2.n_keys > spec.n_keys     # smaller values -> more keys
